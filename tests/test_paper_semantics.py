"""Paper's headline semantic claim, pinned as iteration-count bands.

Table 5.2 / Fig. 5.1: HBMC converges like BMC (equivalent reordering —
identical preconditioner, identical counts) and beats nodal MC on most
problems (13 of 15 cases in the paper; our synthetic ``ieej`` analogue is
the counter-example here, as the eddy-current family is in the paper).

The bands below are measured on the committed generators (seed 7,
``block_size=8, w=4``, ``PAPER_SHIFTS`` applied) — a convergence
regression in ANY ordering (a broken coloring, factorization, packing or
solve) moves a count out of its band and trips tier-1.
"""
import numpy as np
import pytest

from repro.core import solve_iccg
from repro.core.matrices import PAPER_PROBLEMS, PAPER_SHIFTS, paper_problem

BS, W = 8, 4

# measured hbmc iteration counts at the settings above; band = ±2 absorbs
# reduction-order-level drift without letting a real regression through
EXPECTED_HBMC = {
    "thermal2": 38,
    "parabolic_fem": 6,
    "g3_circuit": 21,
    "audikw_1": 21,
    "ieej": 31,
}
BAND = 2
# the one problem family where nodal MC wins (the paper's 2 of 15 cases)
MC_WINS = {"ieej"}


def _iterations(name):
    a, _ = paper_problem(name, scale="tiny")
    b = np.random.default_rng(7).normal(size=a.shape[0])
    shift = PAPER_SHIFTS.get(name, 0.0)
    reps = {m: solve_iccg(a, b, method=m, block_size=BS, w=W, shift=shift)
            for m in ("mc", "bmc", "hbmc")}
    for m, rep in reps.items():
        assert rep.result.converged, (name, m)
    return {m: rep.result.iterations for m, rep in reps.items()}


@pytest.mark.parametrize("name", PAPER_PROBLEMS)
def test_hbmc_tracks_bmc_and_beats_nodal_mc(name):
    its = _iterations(name)
    # HBMC is an equivalent reordering of BMC: identical counts (§4.2)
    assert its["hbmc"] == its["bmc"], its
    # absolute band: any ordering regressing its convergence trips this
    assert abs(its["hbmc"] - EXPECTED_HBMC[name]) <= BAND, its
    if name in MC_WINS:
        # the paper's own counter-example family: nodal MC may win, but
        # block coloring must stay within a few iterations
        assert its["hbmc"] <= its["mc"] + 2 * BAND, its
    else:
        # the headline claim: block coloring converges no worse than MC
        assert its["hbmc"] <= its["mc"], its
