"""SELL-w SpMV kernel family: oracle parity, plan integration, jaxpr.

Pins the tentpole claims of the Pallas-native SpMV hot path:

  1. the kernels match their jnp oracles (and the production XLA
     ``spmv_sell`` path) BIT FOR BIT in interpret mode, across
     f32/f64 × single/batched × padded tail slices × grid tilings;
  2. a ``spmv_backend="pallas"`` plan reproduces the
     ``spmv_backend="xla"`` PCG iteration counts exactly for all four
     orderings × single/batched, and under a 1-device mesh;
  3. the pallas plan's iteration contains no gather-based SpMV — the only
     gathers live inside ``pallas_call`` kernels (asserted on the jaxpr);
  4. the knob validates its inputs (pallas requires the SELL format).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (FULL_PALLAS_ITERATION, PALLAS_SPMV, lint,
                            primitives)
from repro.core import build_plan, make_sharded_spmv, pcg_iteration, solve_iccg
from repro.core.iccg import spmv_sell, spmv_sell_batched
from repro.core.matrices import graph_laplacian, laplace_2d
from repro.core.plan import _make_spmv
from repro.core.sell import pack_sell
from repro.kernels import (sell_spmv, sell_spmv_batched, sell_spmv_block,
                           sell_spmv_batched_ref, sell_spmv_ref)

ORDERINGS = ("mc", "bmc", "hbmc", "natural")

# n deliberately not a multiple of w -> the last slice is a padded tail
MATRICES = [
    ("lap2d_tail", laplace_2d(13, 11)),          # n = 143
    ("graph_tail", graph_laplacian(157, avg_degree=5, seed=3)),
]


# ---------------------------------------------------------------------------
# 1. Bitwise kernel == oracle == XLA path.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,a", MATRICES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("w", [4, 8])
@pytest.mark.parametrize("batched", [False, True], ids=["single", "batched"])
def test_kernel_matches_oracle_bitwise(name, a, dtype, w, batched):
    sm = pack_sell(a, w)
    vals = jnp.asarray(sm.vals, dtype=dtype)
    cols = jnp.asarray(sm.cols)
    n = a.shape[0]
    assert sm.cols.shape[0] * w > n, "tail slice must be padded"
    rng = np.random.default_rng(0)
    shape = (n, 3) if batched else (n,)
    x = jnp.asarray(rng.normal(size=shape), dtype=dtype)
    if batched:
        y = sell_spmv_batched(vals, cols, x, interpret=True)
        y_ref = sell_spmv_batched_ref(vals, cols, x)
        y_xla = spmv_sell_batched(vals, cols, x, n)
    else:
        y = sell_spmv(vals, cols, x, interpret=True)
        y_ref = sell_spmv_ref(vals, cols, x)
        y_xla = spmv_sell(vals, cols, x, n)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))
    np.testing.assert_array_equal(np.asarray(y)[:n], np.asarray(y_xla))
    # padded tail rows beyond n are exact zeros (all-zero vals lanes)
    assert not np.asarray(y)[n:].any()
    # correctness against the dense product
    tol = 1e-4 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(y, dtype=np.float64)[:n],
                               a @ np.asarray(x, dtype=np.float64),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("slice_tile", [1, 3, 256])
def test_grid_tiling_is_invisible(slice_tile):
    """Tiling the slice axis over the grid never changes a bit (the tile
    is padded with all-zero slices, cut after the call)."""
    a = laplace_2d(9, 7)
    sm = pack_sell(a, 4)
    vals, cols = jnp.asarray(sm.vals), jnp.asarray(sm.cols)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=a.shape[0]))
    xb = jnp.asarray(rng.normal(size=(a.shape[0], 2)))
    y_ref = sell_spmv_ref(vals, cols, x)
    yb_ref = sell_spmv_batched_ref(vals, cols, xb)
    np.testing.assert_array_equal(
        np.asarray(sell_spmv(vals, cols, x, slice_tile=slice_tile,
                             interpret=True)), np.asarray(y_ref))
    np.testing.assert_array_equal(
        np.asarray(sell_spmv_batched(vals, cols, xb, slice_tile=slice_tile,
                                     interpret=True)), np.asarray(yb_ref))


def test_block_variant_dispatches_on_rank():
    a = laplace_2d(10, 6)
    sm = pack_sell(a, 4)
    vals, cols = jnp.asarray(sm.vals), jnp.asarray(sm.cols)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=a.shape[0]))
    xb = jnp.asarray(rng.normal(size=(a.shape[0], 3)))
    np.testing.assert_array_equal(
        np.asarray(sell_spmv_block(vals, cols, x, interpret=True)),
        np.asarray(sell_spmv(vals, cols, x, interpret=True)))
    np.testing.assert_array_equal(
        np.asarray(sell_spmv_block(vals, cols, xb, interpret=True)),
        np.asarray(sell_spmv_batched(vals, cols, xb, interpret=True)))


# ---------------------------------------------------------------------------
# 2. Plan integration: pallas SpMV == xla SpMV, iteration for iteration.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ORDERINGS)
def test_plan_backend_parity_all_orderings(method):
    """Acceptance: spmv_backend='pallas' reproduces the xla iteration
    counts exactly (bitwise solutions, in fact — interpret-mode kernel
    arithmetic is identical)."""
    a = laplace_2d(14, 12)
    b = np.random.default_rng(2).normal(size=a.shape[0])
    rx = solve_iccg(a, b, method=method, block_size=8, w=4,
                    spmv_format="sell")
    rp = solve_iccg(a, b, method=method, block_size=8, w=4,
                    spmv_format="sell", spmv_backend="pallas")
    assert rp.spmv_backend == "pallas"
    assert rx.result.iterations == rp.result.iterations
    assert rp.result.converged
    np.testing.assert_array_equal(rx.x, rp.x)


@pytest.mark.parametrize("method", ORDERINGS)
def test_plan_backend_parity_batched(method):
    a = laplace_2d(12, 10)
    bb = np.random.default_rng(3).normal(size=(a.shape[0], 4))
    px = build_plan(a, method=method, block_size=8, w=4, spmv_format="sell")
    pp = build_plan(a, method=method, block_size=8, w=4, spmv_format="sell",
                    spmv_backend="pallas")
    rx, rp = px.solve_batched(bb), pp.solve_batched(bb)
    np.testing.assert_array_equal(rx.result.iterations, rp.result.iterations)
    np.testing.assert_array_equal(rx.x, rp.x)
    # warm solves reuse the cached jitted PCG: zero further host setup
    assert pp.setup_count == 1


def test_plan_backend_parity_under_mesh():
    """The sharded SpMV path (sell_spmv_block inside shard_map) matches
    the xla sharded path on a 1-device mesh — same collective structure,
    same floats."""
    a = laplace_2d(12, 10)
    b = np.random.default_rng(4).normal(size=a.shape[0])
    bb = np.random.default_rng(5).normal(size=(a.shape[0], 3))
    mesh = jax.make_mesh((1,), ("data",))
    px = build_plan(a, method="hbmc", block_size=8, w=4, spmv_format="sell",
                    mesh=mesh)
    pp = build_plan(a, method="hbmc", block_size=8, w=4, spmv_format="sell",
                    mesh=mesh, spmv_backend="pallas")
    rx, rp = px.solve(b), pp.solve(b)
    assert rx.result.iterations == rp.result.iterations
    np.testing.assert_array_equal(rx.x, rp.x)
    rbx, rbp = px.solve_batched(bb), pp.solve_batched(bb)
    np.testing.assert_array_equal(rbx.result.iterations,
                                  rbp.result.iterations)
    np.testing.assert_array_equal(rbx.x, rbp.x)


def test_sharded_spmv_kernel_matches_xla_bitwise():
    a = laplace_2d(11, 9)
    n = a.shape[0]
    sm = pack_sell(a, 4)
    vals, cols = jnp.asarray(sm.vals), jnp.asarray(sm.cols)
    mesh = jax.make_mesh((1,), ("data",))
    rng = np.random.default_rng(6)
    for batched, shape in ((False, (n,)), (True, (n, 3))):
        x = jnp.asarray(rng.normal(size=shape))
        f_x = make_sharded_spmv("sell", n, mesh, "data", vals, cols, batched)
        f_p = make_sharded_spmv("sell", n, mesh, "data", vals, cols, batched,
                                spmv_backend="pallas", interpret=True)
        np.testing.assert_array_equal(np.asarray(f_x(x)), np.asarray(f_p(x)))


# ---------------------------------------------------------------------------
# 3. Jaxpr: the pallas plan's iteration has no gather-based SpMV.
# ---------------------------------------------------------------------------

def test_pallas_spmv_closure_has_no_gather():
    a = laplace_2d(10, 8)
    sm = pack_sell(a, 4)
    vals, cols = jnp.asarray(sm.vals), jnp.asarray(sm.cols)
    n = a.shape[0]
    spmv_p = _make_spmv("sell", n, vals, cols, batched=False,
                        spmv_backend="pallas", interpret=True)
    spmv_x = _make_spmv("sell", n, vals, cols, batched=False)
    assert lint(spmv_p, jnp.zeros((n,)), budget=PALLAS_SPMV) == []
    prims_x = primitives(spmv_x, jnp.zeros((n,)), descend_pallas=False)
    assert any("gather" in p for p in prims_x)


def test_full_pallas_iteration_has_no_gather():
    """With backend='pallas' AND spmv_backend='pallas', one PCG iteration
    lowers to exactly two pallas_call kernels (fused trisolve + SpMV) and
    vector work — zero gather/scatter primitives outside the kernels."""
    a = laplace_2d(10, 8)
    plan = build_plan(a, method="hbmc", block_size=8, w=4,
                      spmv_format="sell", backend="pallas",
                      spmv_backend="pallas", interpret=True)
    spmv = _make_spmv("sell", plan._spmv_n, plan._spmv_vals,
                      plan._spmv_cols, batched=False,
                      spmv_backend="pallas", interpret=True)
    step = pcg_iteration(spmv, plan._precond)
    m = plan._precond.m
    z = jnp.zeros((m,))
    assert lint(step, z, z, z, jnp.asarray(1.0),
                budget=FULL_PALLAS_ITERATION) == []


# ---------------------------------------------------------------------------
# 4. Validation.
# ---------------------------------------------------------------------------

def test_pallas_spmv_requires_sell_format():
    a = laplace_2d(8, 8)
    with pytest.raises(ValueError, match="sell"):
        build_plan(a, method="hbmc", block_size=4, w=2,
                   spmv_backend="pallas")          # default format is ell
    with pytest.raises(ValueError, match="spmv backend"):
        build_plan(a, method="hbmc", block_size=4, w=2,
                   spmv_format="sell", spmv_backend="banana")
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="sell"):
        make_sharded_spmv("ell", a.shape[0], mesh, "data", None, None,
                          False, spmv_backend="pallas")
