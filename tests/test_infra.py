"""Infrastructure layers: sharding rules, checkpointing, data pipeline,
HLO analyzer, optimizer."""
import os
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import (latest_checkpoint, load_checkpoint,
                                   save_checkpoint)
from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import DataConfig, host_slice, sample_batch
from repro.dist.sharding import param_partition_spec
from repro.launch.hlo_analysis import analyze_hlo
from repro.models import param_specs
from repro.train.optimizer import (AdamWConfig, adamw_update, init_opt_state,
                                   schedule)


def _fake_mesh(shape, names):
    return types.SimpleNamespace(axis_names=names,
                                 devices=np.empty(shape))


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh_shape,names", [
    ((16, 16), ("data", "model")),
    ((2, 16, 16), ("pod", "data", "model")),
])
def test_param_specs_always_divisible(arch, mesh_shape, names):
    """The greedy sharding rule must never produce an indivisible spec —
    this is what guarantees the dry-run lowers for every arch."""
    mesh = _fake_mesh(mesh_shape, names)
    sizes = dict(zip(names, mesh_shape))
    specs = param_specs(get_config(arch), dtype=jnp.bfloat16)
    leaves = jax.tree_util.tree_flatten_with_path(specs)[0]
    n_sharded = 0
    for path, leaf in leaves:
        spec = param_partition_spec(path, leaf, mesh)
        for d, ent in enumerate(spec):
            if ent is None:
                continue
            axes = (ent,) if isinstance(ent, str) else ent
            prod = int(np.prod([sizes[a] for a in axes]))
            assert leaf.shape[d] % prod == 0, (arch, path, leaf.shape, spec)
            n_sharded += 1
    assert n_sharded > 0, "rule must shard something"


def test_big_matrices_are_fsdp_and_tp_sharded():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    specs = param_specs(get_config("llama3-405b"), dtype=jnp.bfloat16)
    leaves = jax.tree_util.tree_flatten_with_path(specs)[0]
    for path, leaf in leaves:
        if leaf.ndim >= 3 and leaf.size > 2**24:   # stacked big weights
            spec = param_partition_spec(path, leaf, mesh)
            used = {a for e in spec if e
                    for a in ((e,) if isinstance(e, str) else e)}
            assert used == {"data", "model"}, (path, spec)


def test_checkpoint_roundtrip_and_corruption(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": (jnp.ones((2, 2), jnp.bfloat16),
                  {"c": jnp.asarray(3)})}
    path = str(tmp_path / "ck")
    f = save_checkpoint(path, tree, step=7)
    assert latest_checkpoint(path) == f
    restored, step = load_checkpoint(f, tree)
    assert step == 7
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # corrupt one byte -> must fail loudly
    blob = bytearray(open(f, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    bad = str(tmp_path / "ck" / "bad.ckpt")
    open(bad, "wb").write(bytes(blob))
    with pytest.raises(Exception):
        load_checkpoint(bad, tree)


def test_checkpoint_atomicity_leaves_no_tmp(tmp_path):
    path = str(tmp_path / "ck")
    save_checkpoint(path, {"x": jnp.ones(4)}, step=1)
    save_checkpoint(path, {"x": jnp.ones(4) * 2}, step=2)
    assert not [f for f in os.listdir(path) if f.startswith("tmp")]
    restored, step = load_checkpoint(latest_checkpoint(path),
                                     {"x": jnp.ones(4)})
    assert step == 2


def test_data_pipeline_deterministic_and_host_disjoint():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=8)
    b1 = sample_batch(cfg, step=5)
    b2 = sample_batch(cfg, step=5)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    b3 = sample_batch(cfg, step=6)
    assert not np.array_equal(b1["inputs"], b3["inputs"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["inputs"][:, 1:], b1["labels"][:, :-1])
    # two hosts see different slices
    h0 = DataConfig(vocab=1000, seq_len=32, global_batch=8, n_hosts=2,
                    host_id=0)
    h1 = DataConfig(vocab=1000, seq_len=32, global_batch=8, n_hosts=2,
                    host_id=1)
    assert host_slice(h0) == (0, 4) and host_slice(h1) == (4, 4)
    assert not np.array_equal(sample_batch(h0, 0)["inputs"],
                              sample_batch(h1, 0)["inputs"])


SYNTH_HLO = """
HloModule synth

%body (p: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %p = (s32[], f32[64,64]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[64,64]{1,0} get-tuple-element(%p), index=1
  %c1 = s32[] constant(1)
  %ni = s32[] add(%i, %c1)
  %ar = f32[64,64]{1,0} all-reduce(%x), replica_groups={}, to_apply=%sum
  %d = f32[64,64]{1,0} dot(%ar, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[64,64]{1,0}) tuple(%ni, %d)
}

%cond (p: (s32[], f32[64,64])) -> pred[] {
  %p = (s32[], f32[64,64]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %k = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}

ENTRY %main (x: f32[64,64]) -> f32[64,64] {
  %x = f32[64,64]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %tt = (s32[], f32[64,64]{1,0}) tuple(%c0, %x)
  %w = (s32[], f32[64,64]{1,0}) while(%tt), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %o = f32[64,64]{1,0} get-tuple-element(%w), index=1
}
"""


def test_hlo_analyzer_multiplies_loop_bodies():
    r = analyze_hlo(SYNTH_HLO)
    # 10 iterations x (dot 2*64^3 + add 1)
    assert abs(r["flops"] - 10 * (2 * 64 ** 3 + 1)) < 100
    assert r["collective_counts"]["all-reduce"] == 10
    assert r["collective_bytes_by_kind"]["all-reduce"] == 10 * 64 * 64 * 4
    # all-reduce wire multiplier = 2x
    assert r["collective_wire_bytes"] == 2 * 10 * 64 * 64 * 4


def test_adamw_moves_toward_minimum():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=0.3, weight_decay=0.0, warmup_steps=0,
                      total_steps=100, min_lr_frac=1.0)
    for _ in range(60):
        grads = {"w": params["w"]}          # grad of 0.5*||w||^2
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    s = [float(schedule(cfg, jnp.asarray(t))) for t in (0, 5, 10, 55, 100)]
    assert s[0] == 0.0 and abs(s[1] - 0.5) < 1e-6 and abs(s[2] - 1.0) < 1e-6
    assert 0.1 < s[3] < 1.0 and abs(s[4] - 0.1) < 1e-6
