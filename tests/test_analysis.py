"""Static analysis tier: race detector witnesses, contracts, kernel checks.

Three layers of evidence, mirroring ``src/repro/analysis``:

  1. **soundness** — every mutation of a legal schedule (rows swapped
     across rounds, colors merged, IC(0) steps reordered, tables tampered)
     is rejected with a witness naming the exact offending DAG edge;
  2. **completeness** — all four orderings over all five paper generators
     (and the Laplacians) pass ``validate="full"``, and the same proof
     gates ``build_plan`` and ``PlanCache`` admission;
  3. **packing hardening** — corrupted CSR indices raise
     ``PackingIndexError`` on the host instead of packing garbage tables.

The PR-9 analyzers (dtype flow, collective structure, traffic model,
bench gate — including ``validate="deep"``) have their own mutation
tier in ``tests/test_numerics_analysis.py``.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp

from repro.analysis import (FULL_PALLAS_ITERATION, PALLAS_SPMV,
                            ContractError, PrimitiveBudget, ScheduleError,
                            assert_budget, assert_plan_valid,
                            check_fused_tables, check_ic0_structure,
                            check_plan_kernels, check_reversed_rounds,
                            check_rounds,
                            check_sell_spmv, check_step_tables,
                            check_trisolve_fused, lint, retraces,
                            validate_plan)
from repro.analysis.__main__ import main as analysis_main
from repro.core import (PackingIndexError, build_plan, fuse_round_major,
                        ic0, pack_ell, pack_factor, pack_sell, pack_steps)
from repro.core.ic0 import ic0_structure
from repro.core.matrices import (PAPER_PROBLEMS, PAPER_SHIFTS, laplace_2d,
                                 paper_problem)
from repro.core.solvers import _order_system
from repro.serve.solver import PlanCache

ORDERINGS = ("mc", "bmc", "hbmc", "natural")


def _system(method, nx=13, ny=11, bs=8, w=4):
    a = laplace_2d(nx, ny)
    sysd = _order_system(sp.csr_matrix(a), None, method, bs, w)
    return a, sysd, ic0(sysd.a_bar)


def _dependent_pair(sysd):
    """A DAG edge (j -> i) whose endpoints sit in different rounds."""
    low = sp.tril(sp.csr_matrix(sysd.a_bar), k=-1).tocoo()
    round_of = {}
    for s, r in enumerate(sysd.fwd_rounds):
        for row in r:
            round_of[int(row)] = s
    for j, i, v in zip(low.col, low.row, low.data):
        j, i = int(j), int(i)
        if v != 0 and j in round_of and i in round_of \
                and round_of[j] != round_of[i]:
            return j, i
    raise AssertionError("no cross-round dependency edge found")


def _swap_rows_in_place(rounds, i, j):
    for r in rounds:
        mi, mj = r == i, r == j
        r[mi] = j
        r[mj] = i


# ---------------------------------------------------------------------------
# 1. Soundness: mutations are rejected with the exact witness.
# ---------------------------------------------------------------------------

def test_row_swap_across_rounds_pins_exact_edge():
    """Swapping a dependent pair across rounds must produce a
    cross-round-order witness naming exactly that DAG edge."""
    _, sysd, _ = _system("mc")
    j, i = _dependent_pair(sysd)
    _swap_rows_in_place(sysd.fwd_rounds, i, j)
    vio = check_rounds(sysd.a_bar, sysd.fwd_rounds, drop_mask=sysd.drop)
    assert any(v.kind == "cross-round-order" and v.edge == (j, i)
               for v in vio), [str(v) for v in vio]


def test_merged_colors_break_the_antichain():
    _, sysd, _ = _system("mc")
    merged = [np.concatenate(sysd.fwd_rounds[:2])] + sysd.fwd_rounds[2:]
    vio = check_rounds(sysd.a_bar, merged, drop_mask=sysd.drop)
    kinds = {v.kind for v in vio}
    assert "intra-round-edge" in kinds, [str(v) for v in vio]
    # the witness pins a real edge of the merged round
    v = next(v for v in vio if v.kind == "intra-round-edge")
    assert v.round == 0 and v.edge is not None
    src, dst = v.edge
    assert sysd.a_bar[dst, src] != 0


def test_duplicate_and_unscheduled_rows_are_witnessed():
    _, sysd, _ = _system("mc")
    rounds = [r.copy() for r in sysd.fwd_rounds]
    dropped = int(rounds[0][0])
    rounds[0] = rounds[0][1:]                  # row now in no round
    rounds[1] = np.concatenate([rounds[1], [int(rounds[1][0])]])
    vio = check_rounds(sysd.a_bar, rounds, drop_mask=sysd.drop)
    kinds = {v.kind for v in vio}
    assert "duplicate-row" in kinds
    assert any(v.kind == "unscheduled-row" and v.rows == (dropped, dropped)
               for v in vio)


def test_backward_must_reverse_forward():
    _, sysd, _ = _system("hbmc")
    assert check_reversed_rounds(sysd.fwd_rounds, sysd.bwd_rounds) == []
    vio = check_reversed_rounds(sysd.fwd_rounds, sysd.bwd_rounds[::-1])
    assert vio and vio[0].kind == "backward-not-reversed"


def test_step_table_premature_read_is_witnessed():
    _, sysd, l_bar = _system("hbmc")
    fwd, _ = pack_factor(l_bar, sysd.fwd_rounds, sysd.bwd_rounds, sysd.drop)
    late_row = int(np.asarray(sysd.fwd_rounds[-1])[0])
    fwd.cols[0, 0, 0] = late_row            # step 0 reads a last-round row
    fwd.vals[0, 0, 0] = 1.0
    vio = check_step_tables(fwd)
    assert any(v.kind == "premature-read" and v.edge[0] == late_row
               and v.round == 0 for v in vio), [str(v) for v in vio]


def test_step_table_dropped_dependency_is_witnessed():
    _, sysd, l_bar = _system("mc")
    tri = sp.tril(sp.csr_matrix(l_bar), k=-1, format="csr")
    fwd, _ = pack_factor(l_bar, sysd.fwd_rounds, sysd.bwd_rounds, sysd.drop)
    assert check_step_tables(fwd, tri=tri) == []
    live = np.argwhere(fwd.vals != 0)
    s, t, k = (int(x) for x in live[0])
    fwd.vals[s, t, k] = 0.0                 # silently drop one dependency
    vio = check_step_tables(fwd, tri=tri)
    assert any(v.kind == "dropped-dependency" for v in vio)


def test_fused_table_self_read_is_witnessed():
    _, sysd, l_bar = _system("hbmc")
    fused = fuse_round_major(*pack_factor(l_bar, sysd.fwd_rounds,
                                          sysd.bwd_rounds, sysd.drop))
    assert check_fused_tables(fused) == []
    lay = fused.layout
    g, t = 1, 0
    assert lay.rows[g, t] != lay.n_slots - 1
    pos = g * lay.lanes + t
    fused.cols[g, t, 0] = pos               # forward half reads its own slot
    fused.vals[g, t, 0] = 1.0
    vio = check_fused_tables(fused)
    assert any(v.kind == "premature-read" and v.edge == (pos, pos)
               for v in vio), [str(v) for v in vio]


def test_ic0_step_reorder_is_witnessed():
    _, sysd, _ = _system("mc")
    st = ic0_structure(sysd.a_bar, sysd.fwd_rounds)
    assert check_ic0_structure(st) == []
    bad = dataclasses.replace(st, steps=list(reversed(st.steps)))
    vio = check_ic0_structure(bad)
    assert any(v.kind == "premature-read" for v in vio)


# ---------------------------------------------------------------------------
# 2. Completeness: the paper's orderings prove clean, and the proof gates
#    build_plan and PlanCache admission.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ORDERINGS)
@pytest.mark.parametrize("problem", PAPER_PROBLEMS)
def test_paper_generators_prove_race_free(problem, method):
    a, _ = paper_problem(problem, "tiny")
    plan = build_plan(a, method=method,
                      shift=PAPER_SHIFTS.get(problem, 0.0),
                      validate="full")     # raises ScheduleError on a race
    assert plan.validate == "full"
    assert validate_plan(plan, "cheap") == []


@pytest.mark.parametrize("method", ORDERINGS)
def test_validate_full_passes_all_layouts(method):
    a = laplace_2d(13, 11)
    for layout in ("index", "round_major"):
        plan = build_plan(a, method=method, block_size=8, w=4,
                          layout=layout, validate="full")
        assert validate_plan(plan, "full") == []


def test_build_plan_rejects_unknown_validate_mode():
    with pytest.raises(ValueError, match="validate"):
        build_plan(laplace_2d(6, 5), method="mc", validate="banana")


def test_tampered_plan_fails_validation():
    plan = build_plan(laplace_2d(13, 11), method="mc", validate="full")
    j, i = _dependent_pair(plan._sysd)
    _swap_rows_in_place(plan._sysd.fwd_rounds, i, j)
    _swap_rows_in_place(plan._sysd.bwd_rounds, i, j)
    with pytest.raises(ScheduleError) as exc:
        assert_plan_valid(plan, "cheap", context="tampered")
    assert any(v.kind == "cross-round-order" and v.edge == (j, i)
               for v in exc.value.violations)
    assert "tampered" in str(exc.value)


def test_plan_cache_admission_rejects_racy_plans():
    a = laplace_2d(9, 8)

    def sabotaged_build(a_, **knobs):
        plan = build_plan(a_, **knobs)
        j, i = _dependent_pair(plan._sysd)
        _swap_rows_in_place(plan._sysd.fwd_rounds, i, j)
        _swap_rows_in_place(plan._sysd.bwd_rounds, i, j)
        return plan

    cache = PlanCache(capacity=2, build=sabotaged_build, validate="full")
    with pytest.raises(ScheduleError):
        cache.get(a, method="mc")
    # the racy plan never entered the cache: no later hit can dispatch it
    assert len(cache) == 0

    clean = PlanCache(capacity=2, validate="full")
    plan, status = clean.get(a, method="mc")
    assert status == "miss" and len(clean) == 1
    _, status = clean.get(a, method="mc")
    assert status == "hit"                   # admission runs on misses only

    with pytest.raises(ValueError, match="validate"):
        PlanCache(validate="banana")


def test_analysis_cli_clean_run_exits_zero(capsys):
    rc = analysis_main(["--problems", "laplace2d,thermal2",
                        "--methods", "hbmc,mc", "--scale", "tiny",
                        "--contracts"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "all 4 audits clean" in out


# ---------------------------------------------------------------------------
# 3. Contract linter and kernel checks.
# ---------------------------------------------------------------------------

def test_lint_flags_forbidden_required_and_exact():
    gatherful = lambda x: x[jnp.array([0, 2, 1])]           # noqa: E731
    v = jnp.arange(4.0)
    findings = lint(gatherful, v, budget=PALLAS_SPMV)
    assert any("gather" in f for f in findings)
    assert any("pallas_call" in f for f in findings)        # required, absent
    with pytest.raises(ContractError, match="gather"):
        assert_budget(gatherful, v, budget=PALLAS_SPMV, context="spmv")
    exact = PrimitiveBudget(name="exact", exact=(("sin", 2),))
    assert lint(jnp.sin, v, budget=exact) != []
    assert lint(lambda x: jnp.sin(jnp.sin(x)), v, budget=exact) == []
    loops = PrimitiveBudget(name="loops", min_loops=2)
    assert any("loop" in f for f in lint(jnp.sin, v, budget=loops))


def test_full_pallas_budget_enforced_on_plan():
    plan = build_plan(laplace_2d(10, 8), method="hbmc", block_size=8, w=4,
                      spmv_format="sell", backend="pallas",
                      spmv_backend="pallas", interpret=True,
                      validate="full")
    pre = plan._precond
    assert lint(pre, jnp.zeros((plan.slab_m,)),
                budget=FULL_PALLAS_ITERATION) == []
    assert retraces(plan, lambda: None) == 0
    # the backend selection implies static kernel contracts — all clean
    assert check_plan_kernels(plan) == []


def test_kernel_checks_catch_corruption_and_vmem():
    plan = build_plan(laplace_2d(10, 8), method="hbmc", block_size=8, w=4,
                      spmv_format="sell", backend="pallas",
                      spmv_backend="pallas", interpret=True)
    t = plan._precond.tables
    cols = np.asarray(t.cols).copy()
    vals = np.asarray(t.vals).copy()
    dinv = np.asarray(t.dinv)
    m = (cols.shape[0] // 2) * cols.shape[1]
    assert check_trisolve_fused(cols, vals, dinv) == []
    vio = check_trisolve_fused(cols, vals, dinv, vmem_budget=1024)
    assert any(v.kind == "vmem-budget" for v in vio)
    cols_bad = cols.copy()
    cols_bad[0, 0, 0] = m + 5
    vio = check_trisolve_fused(cols_bad, vals, dinv)
    assert any(v.kind == "index-bounds" for v in vio)
    vals_bad = vals.copy()
    vals_bad[np.asarray(cols) == m] = 1.0    # live value on the pad slot
    vio = check_trisolve_fused(cols, vals_bad, dinv)
    assert any(v.kind == "index-bounds" for v in vio)
    # odd step axis cannot split into fwd/bwd sweeps
    vio = check_trisolve_fused(cols[:-1], vals[:-1], dinv[:-1])
    assert any(v.kind == "grid-divisibility" for v in vio)


def test_sell_kernel_checks():
    a = laplace_2d(10, 8)
    sm = pack_sell(a, 4)
    n_pad = sm.cols.shape[0] * sm.w
    assert check_sell_spmv(sm.vals, sm.cols, n_pad=n_pad) == []
    cols_bad = sm.cols.copy()
    live = np.argwhere(sm.vals != 0)
    s, k, w = (int(x) for x in live[0])
    cols_bad[s, k, w] = 10**6
    vio = check_sell_spmv(sm.vals, cols_bad, n_pad=n_pad)
    assert any(v.kind == "index-bounds" for v in vio)
    vio = check_sell_spmv(sm.vals, sm.cols, n_pad=n_pad, vmem_budget=256)
    assert any(v.kind == "vmem-budget" for v in vio)


# ---------------------------------------------------------------------------
# 4. Packing hardening: corrupted CSR never reaches a packed table.
# ---------------------------------------------------------------------------

def test_pack_ell_and_sell_reject_corrupt_indices():
    a = sp.csr_matrix(laplace_2d(6, 5))
    a.indices[3] = 10_000
    with pytest.raises(PackingIndexError, match="pack_ell"):
        pack_ell(a)
    with pytest.raises(PackingIndexError, match="pack_sell"):
        pack_sell(a, 4)
    a.indices[3] = -2
    with pytest.raises(PackingIndexError, match="pack_ell"):
        pack_ell(a)


def test_pack_steps_rejects_corrupt_inputs():
    _, sysd, l_bar = _system("mc", nx=6, ny=5)
    l_bar = sp.csr_matrix(l_bar)
    diag = l_bar.diagonal()
    tri = sp.tril(l_bar, k=-1, format="csr")
    n = tri.shape[0]
    bad_rounds = [r.copy() for r in sysd.fwd_rounds]
    bad_rounds[0] = np.concatenate([bad_rounds[0], [n + 7]])
    with pytest.raises(PackingIndexError, match="round"):
        pack_steps(tri, diag, bad_rounds)
    tri.indices[0] = n + 3
    with pytest.raises(PackingIndexError, match="pack_steps"):
        pack_steps(tri, diag, sysd.fwd_rounds)
