"""Ordering-layer invariants: MC / BMC / HBMC (paper §3-4)."""
import numpy as np
import pytest
import scipy.sparse as sp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # fallback engine: property sweeps still RUN without it
    from _hypothesis_stub import given, settings, st

from repro.core import (block_multicolor_ordering, check_er_condition,
                        hbmc_from_bmc, multicolor_ordering,
                        ordering_digraph_edges, pad_system, pad_system_hbmc,
                        verify_level2_structure)
from repro.core.matrices import graph_laplacian, laplace_2d, laplace_3d


def random_spd(n, density, seed):
    rng = np.random.default_rng(seed)
    m = sp.random(n, n, density=density, random_state=rng, format="coo")
    a = (m + m.T).tocsr()
    a.setdiag(np.abs(a).sum(axis=1).A1 + 1.0
              if hasattr(np.abs(a).sum(axis=1), "A1")
              else np.asarray(np.abs(a).sum(axis=1)).ravel() + 1.0)
    return a.tocsr()


MATRICES = [
    ("lap2d", laplace_2d(12, 9)),
    ("lap3d", laplace_3d(5, 4, 3)),
    ("graph", graph_laplacian(150, avg_degree=5, seed=2)),
]


@pytest.mark.parametrize("name,a", MATRICES)
def test_mc_colors_are_independent_sets(name, a):
    mc = multicolor_ordering(a)
    coo = sp.coo_matrix(a)
    mask = (coo.row != coo.col) & (coo.data != 0)
    same = mc.colors[coo.row[mask]] == mc.colors[coo.col[mask]]
    assert not same.any(), "adjacent unknowns share a color"


@pytest.mark.parametrize("name,a", MATRICES)
@pytest.mark.parametrize("bs", [3, 8])
def test_bmc_blocks_partition_and_color(name, a, bs):
    bmc = block_multicolor_ordering(a, bs)
    n = a.shape[0]
    # perm is a bijection onto a subset of padded slots
    assert len(set(bmc.perm.tolist())) == n
    assert bmc.n_padded % bs == 0
    # blocks of the same color are mutually independent (no cross edges)
    a_bar, _ = pad_system(a, None, bmc)
    coo = sp.coo_matrix(a_bar)
    blk = bmc.block_of_new
    col = bmc.block_color
    mask = (blk[coo.row] != blk[coo.col]) & (coo.data != 0)
    same_color = col[blk[coo.row[mask]]] == col[blk[coo.col[mask]]]
    assert not same_color.any(), "cross-block edge inside one color"


@pytest.mark.parametrize("name,a", MATRICES)
@pytest.mark.parametrize("bs,w", [(2, 2), (4, 3), (8, 4)])
def test_hbmc_er_condition_and_level2(name, a, bs, w):
    bmc = block_multicolor_ordering(a, bs)
    hb = hbmc_from_bmc(bmc, w)
    # ER condition (eq. 3.5) of the secondary reordering wrt the BMC system
    a_bmc, _ = pad_system(a, None, bmc)
    assert check_er_condition(a_bmc, hb.secondary_perm)
    # identical ordering graphs <=> equivalent orderings (paper §4.2.1)
    assert ordering_digraph_edges(a_bmc) == \
        ordering_digraph_edges(a_bmc, hb.secondary_perm)
    # level-2 diagonal blocks are diagonal matrices (eq. 4.7)
    a_hb, _ = pad_system_hbmc(a, None, hb)
    assert verify_level2_structure(a_hb, hb)
    # padded size bookkeeping
    assert hb.n_final % (bs * w) == 0
    assert (~hb.is_dummy).sum() == a.shape[0]


@settings(max_examples=20, deadline=None)
@given(n=st.integers(12, 60), bs=st.integers(2, 6), w=st.integers(2, 5),
       seed=st.integers(0, 10_000))
def test_hbmc_property_random_spd(n, bs, w, seed):
    a = random_spd(n, density=0.08, seed=seed)
    bmc = block_multicolor_ordering(a, bs)
    hb = hbmc_from_bmc(bmc, w)
    a_bmc, _ = pad_system(a, None, bmc)
    assert check_er_condition(a_bmc, hb.secondary_perm)
    a_hb, _ = pad_system_hbmc(a, None, hb)
    assert verify_level2_structure(a_hb, hb)
    # the full permutation embeds every original unknown exactly once
    assert len(set(hb.perm.tolist())) == n


# ---------------------------------------------------------------------------
# Entry-point validation regressions (block_size / w / RHS dtype).
# ---------------------------------------------------------------------------

def test_block_size_validation_names_the_argument():
    """block_size=0 used to silently return an empty padded system
    (n_padded=0); every entry point must reject it with a ValueError
    naming the argument."""
    a = laplace_2d(6, 6)
    from repro.core import build_blocks, build_plan, color_blocks
    for bad in (0, -1, -32):
        for fn in (lambda: block_multicolor_ordering(a, bad),
                   lambda: build_blocks(a, bad),
                   lambda: build_plan(a, block_size=bad)):
            with pytest.raises(ValueError, match="block_size.*>= 1"):
                fn()
    for bad in (1.5, "8", True, None):
        with pytest.raises(ValueError, match="block_size must be an int"):
            block_multicolor_ordering(a, bad)
        with pytest.raises(ValueError, match="block_size must be an int"):
            build_plan(a, block_size=bad)
    # np integers are fine (callers index with numpy scalars)
    assert block_multicolor_ordering(a, np.int64(4)).block_size == 4


def test_w_validation_names_the_argument():
    """w=0 used to emit divide-by-zero RuntimeWarnings and die with an
    opaque IndexError inside the secondary-permutation scatter."""
    import warnings

    from repro.core import build_plan, hbmc_ordering
    a = laplace_2d(6, 6)
    bmc = block_multicolor_ordering(a, 4)
    for bad in (0, -1, -8):
        for fn in (lambda: hbmc_from_bmc(bmc, bad),
                   lambda: hbmc_ordering(a, 4, bad),
                   lambda: build_plan(a, w=bad)):
            with warnings.catch_warnings():
                warnings.simplefilter("error")   # no RuntimeWarnings allowed
                with pytest.raises(ValueError, match="w must be >= 1"):
                    fn()
    for bad in (2.5, "4", True, None):
        with pytest.raises(ValueError, match="w must be an int"):
            hbmc_from_bmc(bmc, bad)
        with pytest.raises(ValueError, match="w must be an int"):
            build_plan(a, w=bad)
    assert hbmc_from_bmc(bmc, np.int64(2)).w == 2


def test_pad_system_promotes_int_rhs_like_matrix_data():
    """pad_system / pad_system_hbmc promote int matrix data to f64; an
    int RHS must follow the same rule instead of flowing into the float
    solve un-promoted."""
    a = laplace_2d(6, 6)
    a_int = sp.csr_matrix((a.data.astype(np.int64) * 0 + 4,
                           a.indices, a.indptr), shape=a.shape)
    b_int = np.arange(a.shape[0], dtype=np.int32)
    bmc = block_multicolor_ordering(a_int, 4)
    a_bar, b_bar = pad_system(a_int, b_int, bmc)
    assert a_bar.dtype == np.float64
    assert b_bar.dtype == np.float64
    np.testing.assert_array_equal(np.sort(b_bar[bmc.perm]), np.sort(b_int))
    hb = hbmc_from_bmc(bmc, 2)
    a_bar2, b_bar2 = pad_system_hbmc(a_int, b_int, hb)
    assert a_bar2.dtype == np.float64
    assert b_bar2.dtype == np.float64
    # float32 callers keep float32 (the promotion is int -> f64 only)
    b_f32 = b_int.astype(np.float32)
    assert pad_system(a_int, b_f32, bmc)[1].dtype == np.float32
    assert pad_system_hbmc(a_int, b_f32, hb)[1].dtype == np.float32
