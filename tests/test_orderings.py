"""Ordering-layer invariants: MC / BMC / HBMC (paper §3-4)."""
import numpy as np
import pytest
import scipy.sparse as sp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # fallback engine: property sweeps still RUN without it
    from _hypothesis_stub import given, settings, st

from repro.core import (block_multicolor_ordering, check_er_condition,
                        hbmc_from_bmc, multicolor_ordering,
                        ordering_digraph_edges, pad_system, pad_system_hbmc,
                        verify_level2_structure)
from repro.core.matrices import graph_laplacian, laplace_2d, laplace_3d


def random_spd(n, density, seed):
    rng = np.random.default_rng(seed)
    m = sp.random(n, n, density=density, random_state=rng, format="coo")
    a = (m + m.T).tocsr()
    a.setdiag(np.abs(a).sum(axis=1).A1 + 1.0
              if hasattr(np.abs(a).sum(axis=1), "A1")
              else np.asarray(np.abs(a).sum(axis=1)).ravel() + 1.0)
    return a.tocsr()


MATRICES = [
    ("lap2d", laplace_2d(12, 9)),
    ("lap3d", laplace_3d(5, 4, 3)),
    ("graph", graph_laplacian(150, avg_degree=5, seed=2)),
]


@pytest.mark.parametrize("name,a", MATRICES)
def test_mc_colors_are_independent_sets(name, a):
    mc = multicolor_ordering(a)
    coo = sp.coo_matrix(a)
    mask = (coo.row != coo.col) & (coo.data != 0)
    same = mc.colors[coo.row[mask]] == mc.colors[coo.col[mask]]
    assert not same.any(), "adjacent unknowns share a color"


@pytest.mark.parametrize("name,a", MATRICES)
@pytest.mark.parametrize("bs", [3, 8])
def test_bmc_blocks_partition_and_color(name, a, bs):
    bmc = block_multicolor_ordering(a, bs)
    n = a.shape[0]
    # perm is a bijection onto a subset of padded slots
    assert len(set(bmc.perm.tolist())) == n
    assert bmc.n_padded % bs == 0
    # blocks of the same color are mutually independent (no cross edges)
    a_bar, _ = pad_system(a, None, bmc)
    coo = sp.coo_matrix(a_bar)
    blk = bmc.block_of_new
    col = bmc.block_color
    mask = (blk[coo.row] != blk[coo.col]) & (coo.data != 0)
    same_color = col[blk[coo.row[mask]]] == col[blk[coo.col[mask]]]
    assert not same_color.any(), "cross-block edge inside one color"


@pytest.mark.parametrize("name,a", MATRICES)
@pytest.mark.parametrize("bs,w", [(2, 2), (4, 3), (8, 4)])
def test_hbmc_er_condition_and_level2(name, a, bs, w):
    bmc = block_multicolor_ordering(a, bs)
    hb = hbmc_from_bmc(bmc, w)
    # ER condition (eq. 3.5) of the secondary reordering wrt the BMC system
    a_bmc, _ = pad_system(a, None, bmc)
    assert check_er_condition(a_bmc, hb.secondary_perm)
    # identical ordering graphs <=> equivalent orderings (paper §4.2.1)
    assert ordering_digraph_edges(a_bmc) == \
        ordering_digraph_edges(a_bmc, hb.secondary_perm)
    # level-2 diagonal blocks are diagonal matrices (eq. 4.7)
    a_hb, _ = pad_system_hbmc(a, None, hb)
    assert verify_level2_structure(a_hb, hb)
    # padded size bookkeeping
    assert hb.n_final % (bs * w) == 0
    assert (~hb.is_dummy).sum() == a.shape[0]


@settings(max_examples=20, deadline=None)
@given(n=st.integers(12, 60), bs=st.integers(2, 6), w=st.integers(2, 5),
       seed=st.integers(0, 10_000))
def test_hbmc_property_random_spd(n, bs, w, seed):
    a = random_spd(n, density=0.08, seed=seed)
    bmc = block_multicolor_ordering(a, bs)
    hb = hbmc_from_bmc(bmc, w)
    a_bmc, _ = pad_system(a, None, bmc)
    assert check_er_condition(a_bmc, hb.secondary_perm)
    a_hb, _ = pad_system_hbmc(a, None, hb)
    assert verify_level2_structure(a_hb, hb)
    # the full permutation embeds every original unknown exactly once
    assert len(set(hb.perm.tolist())) == n
