"""End-to-end ICCG equivalence and correctness (paper Table 5.2 / Fig 5.1)."""
import numpy as np
import pytest
import scipy.sparse.linalg as spla

from repro.core import solve_iccg
from repro.core.matrices import (PAPER_PROBLEMS, PAPER_SHIFTS, graph_laplacian,
                                 laplace_2d, paper_problem)


def _solve_all(a, b, bs=8, w=4, **kw):
    return {m: solve_iccg(a, b, method=m, block_size=bs, w=w, **kw)
            for m in ("mc", "bmc", "hbmc")}


@pytest.mark.slow
def test_bmc_hbmc_identical_iterations_paper_table52():
    """The paper's central claim: HBMC is equivalent to BMC — identical
    iteration counts on every dataset (Table 5.2)."""
    for name in PAPER_PROBLEMS:
        a, _ = paper_problem(name, scale="tiny")
        b = np.random.default_rng(1).normal(size=a.shape[0])
        shift = PAPER_SHIFTS.get(name, 0.0)
        reps = _solve_all(a, b, shift=shift)
        assert reps["bmc"].result.iterations == \
            reps["hbmc"].result.iterations, name
        assert reps["hbmc"].result.converged, name


@pytest.mark.parametrize("bs,w", [(4, 2), (8, 4), (16, 8)])
def test_equivalence_across_block_sizes(bs, w):
    a = laplace_2d(24, 18)
    b = np.random.default_rng(2).normal(size=a.shape[0])
    r1 = solve_iccg(a, b, method="bmc", block_size=bs, w=w,
                    record_history=True)
    r2 = solve_iccg(a, b, method="hbmc", block_size=bs, w=w,
                    record_history=True)
    assert r1.result.iterations == r2.result.iterations
    h1, h2 = r1.result.history, r2.result.history
    m = ~np.isnan(h1)
    np.testing.assert_allclose(h1[m], h2[m], rtol=1e-10)


def test_solution_correct_vs_direct():
    a = laplace_2d(20, 20)
    b = np.random.default_rng(3).normal(size=a.shape[0])
    x_ref = spla.spsolve(a.tocsc(), b)
    for m in ("mc", "bmc", "hbmc"):
        rep = solve_iccg(a, b, method=m, block_size=4, w=4, rtol=1e-10)
        err = np.linalg.norm(rep.x - x_ref) / np.linalg.norm(x_ref)
        assert err < 1e-8, (m, err)


def test_sell_and_ell_spmv_same_convergence():
    a = graph_laplacian(400, avg_degree=5, seed=4)
    b = np.random.default_rng(5).normal(size=a.shape[0])
    r_ell = solve_iccg(a, b, method="hbmc", block_size=8, w=4,
                       spmv_format="ell")
    r_sell = solve_iccg(a, b, method="hbmc", block_size=8, w=4,
                        spmv_format="sell")
    assert r_ell.result.iterations == r_sell.result.iterations
    np.testing.assert_allclose(r_ell.x, r_sell.x, rtol=1e-9, atol=1e-9)


def test_mc_typically_needs_more_iterations():
    """Convergence advantage of block coloring (paper Table 5.2 trend)."""
    wins = 0
    for name in ("thermal2", "g3_circuit", "parabolic_fem"):
        a, _ = paper_problem(name, scale="tiny")
        b = np.random.default_rng(6).normal(size=a.shape[0])
        reps = _solve_all(a, b)
        if reps["mc"].result.iterations >= reps["bmc"].result.iterations:
            wins += 1
    assert wins >= 2, "block coloring should win on most problems"
