"""Flash attention (fwd + custom VJP) vs naive oracle, plus hypothesis
property sweeps over shapes/windows/chunkings."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # fallback engine: property sweeps still RUN without it
    from _hypothesis_stub import given, settings, st

from repro.models.layers import decode_attention, flash_attention


def naive(q, k, v, window=None, q_start=0):
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qr = q.reshape(b, s, kvh, g, hd)
    sc = jnp.einsum("btkgh,bukh->bkgtu", qr, k) / math.sqrt(hd)
    iq = jnp.arange(s) + q_start
    ik = jnp.arange(k.shape[1])
    m = iq[:, None] >= ik[None, :]
    if window is not None:
        m &= ik[None, :] > (iq[:, None] - window)
    sc = jnp.where(m[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgtu,bukh->bkgth", p, v)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, s, h, hd)


def _qkv(key, b, s, h, kvh, hd):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (b, s, h, hd)),
            jax.random.normal(ks[1], (b, s, kvh, hd)),
            jax.random.normal(ks[2], (b, s, kvh, hd)))


@pytest.mark.parametrize("window", [None, 24])
@pytest.mark.parametrize("qc,kc", [(32, 16), (16, 64), (128, 128)])
def test_flash_forward_and_grads_match_naive(window, qc, kc):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 96, 8, 4, 16)
    pos = jnp.arange(96)
    o1 = flash_attention(q, k, v, pos, pos, window=window, q_chunk=qc,
                         kv_chunk=kc)
    o2 = naive(q, k, v, window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)
    f = lambda *a: flash_attention(*a, pos, pos, window=window, q_chunk=qc,
                                   kv_chunk=kc).sum() * 0.01
    n = lambda *a: naive(*a, window).sum() * 0.01
    g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(n, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(s=st.integers(3, 70), h=st.sampled_from([2, 4, 6]),
       kv_div=st.sampled_from([1, 2]), window=st.sampled_from([None, 7, 33]),
       qc=st.sampled_from([8, 16, 32]), kc=st.sampled_from([8, 16, 32]))
def test_flash_property_sweep(s, h, kv_div, window, qc, kc):
    kvh = h // kv_div
    q, k, v = _qkv(jax.random.PRNGKey(s * 7 + h), 1, s, h, kvh, 8)
    pos = jnp.arange(s)
    o1 = flash_attention(q, k, v, pos, pos, window=window, q_chunk=qc,
                         kv_chunk=kc)
    o2 = naive(q, k, v, window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5)


def test_decode_attention_matches_naive_last_row():
    b, s, h, kvh, hd = 2, 33, 8, 4, 16
    q, k, v = _qkv(jax.random.PRNGKey(3), b, s, h, kvh, hd)
    full = naive(q, k, v)
    kv_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    out = decode_attention(q[:, -1:], k, v,
                           jnp.full((b,), s - 1), kv_pos)
    np.testing.assert_allclose(np.asarray(out[:, 0]),
                               np.asarray(full[:, -1]), atol=2e-5)
