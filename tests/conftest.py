import os

import jax
import numpy as np
import pytest

# float64 is required for the solver-equivalence guarantees (the paper's
# Table 5.2 iteration counts are only bitwise-stable in double precision).
# Model tests pass explicit f32 dtypes, unaffected by this flag.
jax.config.update("jax_enable_x64", True)

try:
    # Bounded CI profile: capped examples, no deadline flakes, derandomized
    # so every CI run covers the same example set.  Local runs keep
    # hypothesis defaults (or the deterministic fallback engine in
    # tests/_hypothesis_stub.py when hypothesis is absent).
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("ci", max_examples=25, deadline=None,
                                   derandomize=True)
    if os.environ.get("CI"):
        _hyp_settings.load_profile("ci")
except ImportError:
    pass


@pytest.fixture(scope="module", autouse=True)
def _clear_jax_caches():
    """XLA:CPU's JIT linker accumulates dylibs per compiled executable; a
    full-suite run (~1000 compilations) can exhaust it ("Failed to
    materialize symbols").  Dropping the compilation cache between test
    modules keeps the process well under the limit."""
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
