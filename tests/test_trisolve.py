"""Triangular-solver layers: IC(0), step packing, jnp + Pallas paths."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (block_multicolor_ordering, build_preconditioner,
                        hbmc_from_bmc, ic0, ic0_error, pack_factor_hbmc,
                        pad_system_hbmc, sequential_ic_solve)
from repro.core.matrices import graph_laplacian, laplace_2d, laplace_3d
from repro.kernels.ops import build_kernel_preconditioner
from repro.kernels.sell_spmv import sell_spmv
from repro.kernels.ref import sell_spmv_ref
from repro.core.sell import pack_sell


MATRICES = [
    ("lap2d", laplace_2d(16, 16)),
    ("lap3d", laplace_3d(6, 6, 4)),
    ("graph", graph_laplacian(300, avg_degree=4, seed=1)),
]


@pytest.mark.parametrize("name,a", MATRICES)
def test_ic0_exact_on_pattern(name, a):
    l = ic0(a)
    assert ic0_error(a, l) < 1e-12


def test_ic0_shift_changes_diagonal():
    a = laplace_2d(10, 10)
    l0 = ic0(a, shift=0.0)
    l3 = ic0(a, shift=0.3)
    assert (l3.diagonal() > l0.diagonal()).all()


@pytest.mark.parametrize("name,a", MATRICES)
@pytest.mark.parametrize("bs,w", [(4, 4), (8, 2)])
def test_jnp_trisolve_matches_scipy(name, a, bs, w):
    bmc = block_multicolor_ordering(a, bs)
    hb = hbmc_from_bmc(bmc, w)
    a_hb, _ = pad_system_hbmc(a, None, hb)
    l = ic0(a_hb)
    pre = build_preconditioner(l, hb)
    r = np.random.default_rng(3).normal(size=hb.n_final)
    z = np.asarray(pre(jnp.asarray(r)))
    z_ref = sequential_ic_solve(l, r)
    real = ~hb.is_dummy   # dummy lanes are dropped from the packed rounds
    np.testing.assert_allclose(z[real], z_ref[real], rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("bs,w", [(2, 2), (4, 4), (8, 8), (16, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_pallas_kernel_sweep(bs, w, dtype):
    a = laplace_2d(14, 11)
    bmc = block_multicolor_ordering(a, bs)
    hb = hbmc_from_bmc(bmc, w)
    a_hb, _ = pad_system_hbmc(a, None, hb)
    l = ic0(a_hb)
    fwd, bwd = pack_factor_hbmc(l, hb)
    r = np.random.default_rng(4).normal(size=hb.n_final)
    z_ref = sequential_ic_solve(l, r)

    pre_k = build_kernel_preconditioner(fwd, bwd, dtype=dtype,
                                        use_kernel=True, interpret=True)
    pre_j = build_kernel_preconditioner(fwd, bwd, dtype=dtype,
                                        use_kernel=False)
    zk = np.asarray(pre_k(jnp.asarray(r, dtype=dtype)))
    zj = np.asarray(pre_j(jnp.asarray(r, dtype=dtype)))
    tol = 1e-4 if dtype == jnp.float32 else 1e-11
    real = ~hb.is_dummy
    np.testing.assert_allclose(zk[real], z_ref[real], rtol=tol, atol=tol)
    # kernel and jnp oracle agree bit-for-bit (same op order)
    np.testing.assert_array_equal(zk, zj)


@pytest.mark.parametrize("w", [2, 4, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_sell_spmv_kernel_sweep(w, dtype):
    a = graph_laplacian(257, avg_degree=6, seed=5)   # deliberately odd n
    sm = pack_sell(a, w)
    n_pad = sm.cols.shape[0] * w
    x = np.zeros(n_pad)
    x[:a.shape[0]] = np.random.default_rng(6).normal(size=a.shape[0])
    vals = jnp.asarray(sm.vals, dtype=dtype)
    cols = jnp.asarray(sm.cols)
    xd = jnp.asarray(x, dtype=dtype)
    yk = np.asarray(sell_spmv(vals, cols, xd, slice_tile=16))
    yr = np.asarray(sell_spmv_ref(vals, cols, xd))
    y_true = a @ x[:a.shape[0]]
    tol = 1e-4 if dtype == jnp.float32 else 1e-11
    np.testing.assert_allclose(yk[:a.shape[0]], y_true, rtol=tol, atol=tol)
    np.testing.assert_array_equal(yk, yr[:len(yk)])
