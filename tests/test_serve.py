"""Serving-path invariants: decode == full forward; prefill == decode replay;
ring caches for windowed layers; O(1) state for recurrent archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import forward, init_cache, init_params
from repro.serve.step import greedy_generate, prefill, serve_step

# one representative per cache kind: full attn, MoE+SWA ring, hybrid
# (RG-LRU + local ring), pure SSM
ARCHS = ("qwen3-14b", "mixtral-8x22b", "recurrentgemma-2b", "mamba2-130m")
B = 2


def _toks(cfg, key, b, s):
    if cfg.takes_embeddings:
        return jax.random.normal(key, (b, s, cfg.d_model), jnp.float32) * 0.3
    return jax.random.randint(key, (b, s), 0, cfg.vocab)


def _pos(cfg, b, s):
    if cfg.m_rope:
        return jnp.broadcast_to(jnp.arange(s)[None, None], (3, b, s))
    return jnp.broadcast_to(jnp.arange(s)[None], (b, s))


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.slow
def test_decode_matches_full_forward(arch):
    cfg = get_smoke_config(arch)
    s = 20
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    toks = _toks(cfg, jax.random.PRNGKey(1), B, s)
    ref, _, _ = forward(params, cfg, toks, _pos(cfg, B, s))
    cache = init_cache(cfg, B, max_len=s, dtype=jnp.float32)
    outs = []
    for t in range(s):
        tok = toks[:, t:t + 1]
        p = (jnp.full((3, B, 1), t) if cfg.m_rope else jnp.full((B, 1), t))
        lg, cache, _ = forward(params, cfg, tok, p, cache=cache,
                               cur_pos=jnp.asarray(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.slow
def test_prefill_matches_decode_replay(arch):
    cfg = get_smoke_config(arch)
    s, extra = 18, 5
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    toks = _toks(cfg, jax.random.PRNGKey(1), B, s + extra)
    cache, _ = prefill(params, cfg, toks[:, :s], max_len=s + extra,
                       cache_dtype=jnp.float32)
    cache_r = init_cache(cfg, B, max_len=s + extra, dtype=jnp.float32)
    for t in range(s):
        _, cache_r, _ = forward(
            params, cfg, toks[:, t:t + 1],
            (jnp.full((3, B, 1), t) if cfg.m_rope else jnp.full((B, 1), t)),
            cache=cache_r, cur_pos=jnp.asarray(t))
    for t in range(s, s + extra):
        lgA, cache = serve_step(params, cache, toks[:, t:t + 1],
                                jnp.asarray(t), cfg=cfg)
        lgB, cache_r = serve_step(params, cache_r, toks[:, t:t + 1],
                                  jnp.asarray(t), cfg=cfg)
        np.testing.assert_allclose(np.asarray(lgA), np.asarray(lgB),
                                   rtol=2e-4, atol=2e-4)


def test_ring_cache_is_window_sized():
    cfg = get_smoke_config("mixtral-8x22b")      # window 16
    cache = init_cache(cfg, B, max_len=1000, dtype=jnp.float32)
    k = cache[0]["k"]
    assert k.shape[2] == cfg.attn_window, \
        "windowed cache must be ring-buffer sized, not context sized"
    # recurrent arch: state size independent of context
    cfg2 = get_smoke_config("mamba2-130m")
    c2 = init_cache(cfg2, B, max_len=10**6, dtype=jnp.float32)
    total = sum(x.size for x in jax.tree.leaves(c2))
    assert total < 10**6, "SSM cache must be O(1) in context length"


@pytest.mark.slow
def test_windowed_decode_beyond_window_consistent():
    """Decoding past the window: ring overwrite must equal full recompute
    restricted to the window."""
    cfg = get_smoke_config("mixtral-8x22b")
    w = cfg.attn_window
    s = w + 9
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    toks = _toks(cfg, jax.random.PRNGKey(1), B, s)
    ref, _, _ = forward(params, cfg, toks, _pos(cfg, B, s))
    cache = init_cache(cfg, B, max_len=s, dtype=jnp.float32)
    for t in range(s):
        lg, cache, _ = forward(params, cfg, toks[:, t:t + 1],
                               jnp.full((B, 1), t), cache=cache,
                               cur_pos=jnp.asarray(t))
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(ref[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_greedy_generate_runs():
    cfg = get_smoke_config("qwen2.5-3b")
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, cfg.vocab)
    out = greedy_generate(params, cfg, prompt, n_new=5, max_len=16,
                          cache_dtype=jnp.float32)
    assert out.shape == (B, 5)
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < cfg.vocab).all()
