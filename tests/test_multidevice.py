"""Multi-device semantics, via subprocesses with forced host device counts
(jax pins the device count at first init, so these must be fresh processes).

Covers: distributed ICCG (solver sharded over a mesh) iterating identically
to single-device; pjit train_step on a 2x2 mesh matching the unsharded
step; shard_map MoE gradients matching the plain path.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


PARITY_CODE = """
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    from repro.core.plan import build_plan
    from repro.core.matrices import laplace_2d

    n_dev = {n_dev}
    assert len(jax.devices()) == n_dev
    a = laplace_2d(13, 17)               # n=221: padding in every ordering
    n = a.shape[0]
    rng = np.random.default_rng(0)
    b = rng.normal(size=n)
    bb = rng.normal(size=(n, 3))
    mesh = jax.make_mesh((n_dev,), ("data",))
    for method in ("hbmc", "bmc"):
        # single-device oracle with MATCHED lane padding: the distributed
        # sweep's per-lane arithmetic is identical, so everything —
        # iteration counts AND solutions — must agree bitwise
        ref = build_plan(a, method=method, block_size=8, w=4,
                         lane_multiple=n_dev)
        dist = build_plan(a, method=method, block_size=8, w=4, mesh=mesh)
        r_ref, r = ref.solve(b, rtol=1e-9), dist.solve(b, rtol=1e-9)
        assert r.x.shape == (n,)
        assert r.result.iterations == r_ref.result.iterations
        assert np.array_equal(r.x, r_ref.x)
        rb_ref = ref.solve_batched(bb, rtol=1e-9)
        rb = dist.solve_batched(bb, rtol=1e-9)
        assert np.array_equal(rb.result.iterations, rb_ref.result.iterations)
        assert np.array_equal(rb.x, rb_ref.x)
        # and against the DEFAULT (unpadded) plan the solve still converges
        # to the same solution (lane padding may perturb reduction
        # rounding, so this check is tolerance-based)
        base = build_plan(a, method=method, block_size=8, w=4)
        rp = base.solve(b, rtol=1e-9)
        err = np.linalg.norm(r.x - rp.x) / np.linalg.norm(rp.x)
        assert err < 1e-8, err
        print("PARITY", method, n_dev, r.result.iterations,
              list(rb.result.iterations))
"""


@pytest.mark.parametrize("n_dev", [1, 2, 4, 8])
def test_distributed_plan_matches_single_device(n_dev):
    """Distributed plan == single-device plan, bitwise: iteration counts and
    solutions for hbmc/bmc x single/batched at every device count."""
    out = run_py(textwrap.dedent(PARITY_CODE.format(n_dev=n_dev)),
                 n_devices=n_dev)
    assert out.count("PARITY") == 2


SPMV_PALLAS_CODE = """
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    from repro.core.plan import build_plan
    from repro.core.matrices import laplace_2d

    n_dev = {n_dev}
    assert len(jax.devices()) == n_dev
    a = laplace_2d(13, 17)               # n=221: padded tail slices
    n = a.shape[0]
    rng = np.random.default_rng(1)
    b = rng.normal(size=n)
    bb = rng.normal(size=(n, 3))
    mesh = jax.make_mesh((n_dev,), ("data",))
    kw = dict(method="hbmc", block_size=8, w=4, spmv_format="sell",
              mesh=mesh)
    px = build_plan(a, **kw)
    pp = build_plan(a, spmv_backend="pallas", **kw)
    rx, rp = px.solve(b), pp.solve(b)
    assert rx.result.iterations == rp.result.iterations
    assert np.array_equal(rx.x, rp.x)
    rbx, rbp = px.solve_batched(bb), pp.solve_batched(bb)
    assert np.array_equal(rbx.result.iterations, rbp.result.iterations)
    assert np.array_equal(rbx.x, rbp.x)
    print("SPMV_PALLAS", n_dev, rx.result.iterations)
"""


@pytest.mark.parametrize("n_dev", [2, 4])
def test_sharded_pallas_spmv_matches_xla(n_dev):
    """spmv_backend='pallas' under a REAL multi-shard mesh (sell_spmv_block
    per device inside shard_map) reproduces the sharded xla SpMV bitwise —
    the >1-device counterpart of the 1-device mesh test in
    tests/test_spmv.py."""
    out = run_py(textwrap.dedent(SPMV_PALLAS_CODE.format(n_dev=n_dev)),
                 n_devices=n_dev)
    assert "SPMV_PALLAS" in out


def test_distributed_iccg_returns_caller_ordering():
    """Regression (padded-state leak): the seed-era distributed path fed the
    padded HBMC system into pcg and returned the internal padded/permuted
    vector.  The shim must return the solution in the caller's ordering,
    shape (n,), on a system whose padded size differs from n."""
    code = textwrap.dedent("""
        import jax
        jax.config.update("jax_enable_x64", True)
        import numpy as np
        from repro.core import solve_iccg
        from repro.core.partition import distributed_iccg
        from repro.core.matrices import laplace_2d

        a = laplace_2d(13, 17)            # n=221 -> padded size > n
        n = a.shape[0]
        b = np.random.default_rng(0).normal(size=n)
        ref = solve_iccg(a, b, method="hbmc", block_size=8, w=4, rtol=1e-9)
        mesh = jax.make_mesh((4,), ("data",))
        rep = distributed_iccg(a, b, mesh, block_size=8, w=4, rtol=1e-9)
        assert rep.n_padded > n           # padding actually exercised
        assert rep.x.shape == (n,)
        assert rep.result.x.shape == (n,)
        err = np.linalg.norm(rep.x - ref.x) / np.linalg.norm(ref.x)
        print("LEAK-REGRESSION ERR", err)
        assert err < 1e-8
        # A x = b in the ORIGINAL ordering is the leak-proof check
        res = np.linalg.norm(a @ rep.x - b) / np.linalg.norm(b)
        assert res < 1e-8
    """)
    out = run_py(code, n_devices=4)
    assert "LEAK-REGRESSION" in out


def test_distributed_refactor_zero_retrace():
    """plan.refactor under a mesh swaps sharded device arrays without
    retracing the jitted PCG, and warm solves do zero host-side setup."""
    code = textwrap.dedent("""
        import jax
        jax.config.update("jax_enable_x64", True)
        import numpy as np
        import repro.core.plan as plan_mod
        from repro.core.plan import build_plan
        from repro.core.matrices import laplace_2d

        a = laplace_2d(13, 17)
        n = a.shape[0]
        b = np.random.default_rng(0).normal(size=n)
        mesh = jax.make_mesh((2,), ("data",))
        plan = build_plan(a, method="hbmc", block_size=8, w=4, mesh=mesh)
        r1 = plan.solve(b, rtol=1e-9)
        count = plan.setup_count

        names = ("_order_system", "ic0_structure", "_build_spmv_ops",
                 "_pack_spmv", "_build_preconditioner")
        saved = {name: getattr(plan_mod, name) for name in names}

        def boom(*a_, **k_):
            raise AssertionError("setup ran during a warm mesh solve")
        for name in names:
            setattr(plan_mod, name, boom)
        warm = plan.solve(b, rtol=1e-9)          # zero host-side setup
        assert plan.setup_count == count
        np.testing.assert_array_equal(warm.x, r1.x)
        for name, fn in saved.items():
            setattr(plan_mod, name, fn)

        # refactor: new values, same pattern -> sharded arrays swapped,
        # jitted PCG reused without a retrace (ordering + symbolic analysis
        # must not rerun either)
        plan_mod._order_system = boom
        plan_mod.ic0_structure = boom
        a2 = a.copy(); a2.data = a2.data * 1.1
        plan.refactor(a2)
        r2 = plan.solve(b, rtol=1e-9)
        assert plan._trace_count == 1, plan._trace_count
        plan_mod._order_system = saved["_order_system"]
        plan_mod.ic0_structure = saved["ic0_structure"]
        ref = plan_mod.build_plan(a2, method="hbmc", block_size=8, w=4,
                                  lane_multiple=2).solve(b, rtol=1e-9)
        np.testing.assert_array_equal(r2.x, ref.x)
        print("RETRACE OK", plan._trace_count)
    """)
    out = run_py(code, n_devices=2)
    assert "RETRACE OK 1" in out


@pytest.mark.slow
def test_pjit_train_step_matches_unsharded():
    code = textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp
        from functools import partial
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke_config
        from repro.models import init_params
        from repro.dist.sharding import params_shardings, batch_partition_spec
        from repro.train.optimizer import AdamWConfig, init_opt_state
        from repro.train.step import train_step

        cfg = get_smoke_config("qwen3-14b")
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        opt = init_opt_state(params)
        ocfg = AdamWConfig(lr=1e-3, total_steps=10, warmup_steps=1)
        batch = {"inputs": jax.random.randint(jax.random.PRNGKey(1),
                                              (4, 16), 0, cfg.vocab)}
        batch["labels"] = batch["inputs"]
        step = partial(train_step, cfg=cfg, opt_cfg=ocfg)

        p1, o1, m1 = jax.jit(step)(params, opt, batch)   # default devices

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        psh = params_shardings(params, mesh)
        osh = init_opt_state(params)
        osh = jax.tree.map(lambda x: None, osh)  # placeholder
        with mesh:
            params_s = jax.device_put(params, psh)
            opt_s = jax.device_put(opt, jax.tree.map(
                lambda _: NamedSharding(mesh, P()), opt,
                is_leaf=lambda x: hasattr(x, "shape")))
            bsh = NamedSharding(mesh, batch_partition_spec(mesh, 4, ndim=2))
            batch_s = jax.tree.map(lambda x: jax.device_put(x, bsh), batch)
            p2, o2, m2 = jax.jit(step)(params_s, opt_s, batch_s)
        print("LOSS", float(m1["loss"]), float(m2["loss"]))
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
        mx = max(jax.tree.leaves(d))
        print("MAXDIFF", mx)
        assert mx < 1e-4
    """)
    run_py(code)


@pytest.mark.slow
def test_shardmap_moe_grads_match_plain():
    code = textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp
        from functools import partial
        from repro.configs import get_smoke_config
        from repro.models import init_params
        from repro.train.step import loss_fn

        cfg = get_smoke_config("mixtral-8x22b")
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        inputs = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                    cfg.vocab)
        labels = inputs
        f = lambda p: loss_fn(p, cfg, inputs, labels)[0]
        g_plain = jax.grad(f)(params)                      # no mesh

        mesh = jax.make_mesh((2, 4), ("data", "model"))    # ff=128 % 4 == 0
        with mesh:
            g_sm = jax.jit(jax.grad(f))(params)
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         g_plain, g_sm)
        mx = max(jax.tree.leaves(d))
        print("GRAD MAXDIFF", mx)
        assert mx < 1e-4
    """)
    run_py(code)


def test_elastic_checkpoint_reshard(tmp_path):
    code = textwrap.dedent(f"""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt.checkpoint import save_checkpoint, load_checkpoint
        tree = {{"w": jnp.arange(64.0).reshape(8, 8)}}
        mesh1 = jax.make_mesh((8,), ("data",))
        t1 = jax.device_put(tree, jax.tree.map(
            lambda _: NamedSharding(mesh1, P("data")), tree))
        f = save_checkpoint("{tmp_path}", t1, step=3)
        # restore onto a DIFFERENT mesh layout (elastic rescale)
        mesh2 = jax.make_mesh((2, 4), ("data", "model"))
        sh2 = jax.tree.map(lambda _: NamedSharding(mesh2, P(None, "model")),
                           tree)
        t2, step = load_checkpoint(f, tree, shardings=sh2)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(t2["w"]),
                                      np.asarray(tree["w"]))
        print("ELASTIC OK")
    """)
    out = run_py(code)
    assert "ELASTIC OK" in out


def test_solver_step_lowers_on_mesh():
    """Bonus dry-run: one ICCG iteration (the paper's kernel) lowers and
    compiles with the tables sharded over the mesh data axis — and the
    lowered module contains BOTH triangular sweeps (regression: the
    seed-era iteration used the unpreconditioned (r, r) pairings, which
    lowered a plain-CG kernel with zero trisolve loops)."""
    code = textwrap.dedent("""
        import jax
        jax.config.update("jax_enable_x64", True)
        import numpy as np, jax.numpy as jnp
        from repro.core import (block_multicolor_ordering, hbmc_from_bmc,
                                pad_system_hbmc, ic0, pack_factor_hbmc)
        from repro.core.trisolve import DeviceTables
        from repro.core.partition import lower_solver_step
        from repro.core.sell import pack_ell
        from repro.core.matrices import laplace_2d

        a = laplace_2d(32, 32)
        bmc = block_multicolor_ordering(a, 8)
        hb = hbmc_from_bmc(bmc, 4)
        a_hb, _ = pad_system_hbmc(a, None, hb)
        l = ic0(a_hb)
        fwd_h, bwd_h = pack_factor_hbmc(l, hb)
        fwd = DeviceTables.from_host(fwd_h)
        bwd = DeviceTables.from_host(bwd_h)
        cols, vals = pack_ell(a_hb)
        mesh = jax.make_mesh((8,), ("data",))
        lowered = lower_solver_step(fwd, bwd, jnp.asarray(cols),
                                    jnp.asarray(vals), mesh)
        # the fwd and bwd substitution fori_loops — a plain-CG lowering
        # (the seed bug) has none
        n_while = lowered.as_text().count("while")
        assert n_while >= 2, n_while
        compiled = lowered.compile()
        txt = compiled.as_text()
        assert "all-gather" in txt or "all-reduce" in txt
        ca = compiled.cost_analysis()   # list of dicts on newer jax
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        print("SOLVER LOWERED", ca.get("flops"), "whiles", n_while)
    """)
    out = run_py(code)
    assert "SOLVER LOWERED" in out
