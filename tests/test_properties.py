"""Property-based ordering/layout invariants over random instances.

Runs under real ``hypothesis`` when installed (CI) and under the
deterministic fallback engine in ``_hypothesis_stub.py`` otherwise — the
sweeps RUN in both environments (never skip).

Each property pins a paper-level invariant on random
``graph_laplacian`` / ``laplace_2d`` instances:

  * every ordering's ``perm`` is a valid permutation (a bijection of the
    original unknowns into the padded system);
  * no intra-round edges survive — the rows of one execution round are
    mutually independent in the permuted matrix for mc/bmc/hbmc (the
    §3/§4 independence property that makes the trisolve rounds parallel);
  * HBMC's secondary reordering respects level-1 block membership: the
    unknowns of BMC block p (within its color) land in level-1 block
    ``p // w`` of the same color (paper eq. 4.1);
  * ``RoundMajorLayout`` b-in/x-out permutations round-trip bitwise, for
    (n,) and (n, B) vectors.
"""
import numpy as np
import scipy.sparse as sp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # fallback engine: property sweeps still RUN without it
    from _hypothesis_stub import given, settings, st

from repro.analysis import check_reversed_rounds, check_rounds
from repro.core import fuse_round_major, pack_factor
from repro.core.ic0 import ic0
from repro.core.matrices import graph_laplacian, laplace_2d
from repro.core.solvers import _order_system

METHODS = ("mc", "bmc", "hbmc")


def _random_instance(kind: str, size: int, seed: int) -> sp.csr_matrix:
    if kind == "graph":
        return graph_laplacian(30 + 10 * size, avg_degree=3 + size % 3,
                               seed=seed)
    nx, ny = 4 + size, 4 + (size * 7 + seed) % 9
    return laplace_2d(nx, ny)


@settings(max_examples=15, deadline=None)
@given(kind=st.sampled_from(["graph", "lap2d"]), size=st.integers(0, 8),
       seed=st.integers(0, 10_000), bs=st.sampled_from([2, 4, 8]),
       w=st.sampled_from([2, 3, 4]))
def test_orderings_are_valid_permutations(kind, size, seed, bs, w):
    a = _random_instance(kind, size, seed)
    n = a.shape[0]
    for method in METHODS:
        sysd = _order_system(sp.csr_matrix(a), None, method, bs, w)
        perm = sysd.perm
        # injective over the original unknowns, into the padded range
        assert perm.shape == (n,)
        assert len(np.unique(perm)) == n, method
        assert perm.min() >= 0 and perm.max() < sysd.n_padded, method
        # non-perm slots (if any) are exactly the dummy padding
        if sysd.drop is not None:
            assert sysd.n_padded - n == int(sysd.drop.sum()), method
            assert not sysd.drop[perm].any(), method


@settings(max_examples=15, deadline=None)
@given(kind=st.sampled_from(["graph", "lap2d"]), size=st.integers(0, 8),
       seed=st.integers(0, 10_000), bs=st.sampled_from([2, 4, 8]),
       w=st.sampled_from([2, 3, 4]))
def test_no_intra_round_edges_survive(kind, size, seed, bs, w):
    """Rows of one execution round are mutually independent in A_bar."""
    a = _random_instance(kind, size, seed)
    for method in METHODS:
        sysd = _order_system(sp.csr_matrix(a), None, method, bs, w)
        coo = sp.coo_matrix(sysd.a_bar)
        off = (coo.row != coo.col) & (coo.data != 0)
        round_of = np.full(sysd.n_padded, -1, dtype=np.int64)
        for s, rows in enumerate(sysd.fwd_rounds):
            live = rows if sysd.drop is None else rows[~sysd.drop[rows]]
            round_of[live] = s
        same = round_of[coo.row[off]] == round_of[coo.col[off]]
        # dummy rows (round -1) have no entries at all, so -1 == -1 never
        # fires; any surviving same-round edge breaks the parallel sweep
        assert not same.any(), method


@settings(max_examples=15, deadline=None)
@given(kind=st.sampled_from(["graph", "lap2d"]), size=st.integers(0, 8),
       seed=st.integers(0, 10_000), bs=st.sampled_from([2, 4, 8]),
       w=st.sampled_from([2, 3, 4]))
def test_hbmc_respects_level1_block_membership(kind, size, seed, bs, w):
    """Paper eq. 4.1: the secondary reordering moves unknowns only within
    their level-1 block — BMC block p of color c maps into level-1 block
    p // w of color c."""
    from repro.core import block_multicolor_ordering, hbmc_from_bmc
    a = _random_instance(kind, size, seed)
    bmc = block_multicolor_ordering(sp.csr_matrix(a), bs)
    hb = hbmc_from_bmc(bmc, w)
    color_first_block = np.concatenate([[0],
                                        np.cumsum(bmc.blocks_per_color)])
    i = np.arange(bmc.n_padded)
    g = i // bs                                   # BMC block, color-major
    c = bmc.block_color[g]
    p = g - color_first_block[c]                  # block index within color
    f = hb.secondary_perm[i]                      # final HBMC index
    lev1 = (f - hb.color_start[c]) // (bs * w)    # level-1 block of f
    np.testing.assert_array_equal(lev1, p // w)
    # and the color never changes
    assert (f >= hb.color_start[c]).all()
    assert (f < hb.color_start[c + 1]).all()


@settings(max_examples=15, deadline=None)
@given(kind=st.sampled_from(["graph", "lap2d"]), size=st.integers(0, 8),
       seed=st.integers(0, 10_000), bs=st.sampled_from([2, 4, 8]),
       w=st.sampled_from([2, 3, 4]), nb=st.sampled_from([1, 3]))
def test_round_major_layout_roundtrips_bitwise(kind, size, seed, bs, w, nb):
    """embed (b in) and extract (x out) invert each other bit for bit."""
    a = _random_instance(kind, size, seed)
    sysd = _order_system(sp.csr_matrix(a), None, "hbmc", bs, w)
    l_bar = ic0(sysd.a_bar)
    fused = fuse_round_major(*pack_factor(l_bar, sysd.fwd_rounds,
                                          sysd.bwd_rounds, sysd.drop))
    lay = fused.layout
    rng = np.random.default_rng(seed)
    shape = (sysd.n_padded,) if nb == 1 else (sysd.n_padded, nb)
    v = rng.normal(size=shape)
    if sysd.drop is not None:
        v[sysd.drop] = 0.0                        # dummies have no position
    rm = lay.embed(v)
    assert rm.shape[0] == lay.m
    np.testing.assert_array_equal(lay.extract(rm), v)
    # holes (pad lanes) hold exact zeros after embed
    flat = lay.rows.reshape(-1)
    holes = flat == lay.n_slots - 1
    assert not np.asarray(rm[holes]).any()


@settings(max_examples=15, deadline=None)
@given(kind=st.sampled_from(["graph", "lap2d"]), size=st.integers(0, 8),
       seed=st.integers(0, 10_000), bs=st.sampled_from([2, 4, 8]),
       w=st.sampled_from([2, 3, 4]))
def test_round_schedules_prove_race_free(kind, size, seed, bs, w):
    """The static race detector (repro.analysis) proves every ordering's
    round schedule: all dependency edges cross strictly forward, and the
    backward schedule is the reversed forward one."""
    a = _random_instance(kind, size, seed)
    for method in METHODS:
        sysd = _order_system(sp.csr_matrix(a), None, method, bs, w)
        assert check_rounds(sysd.a_bar, sysd.fwd_rounds,
                            drop_mask=sysd.drop) == [], method
        assert check_reversed_rounds(sysd.fwd_rounds,
                                     sysd.bwd_rounds) == [], method


@settings(max_examples=15, deadline=None)
@given(kind=st.sampled_from(["graph", "lap2d"]), size=st.integers(0, 8),
       seed=st.integers(0, 10_000), bs=st.sampled_from([1, 2, 4, 8, 16]))
def test_vectorized_block_builder_matches_legacy_walk(kind, size, seed, bs):
    """The windowed array-program block builder is bitwise-equal to the
    legacy Python walk: same blocks (members and order), and — through
    the shared coloring stage — the same BMC permutation."""
    from repro.core.coloring import (BlockPartition, _build_blocks_walk,
                                     build_blocks, color_blocks)
    a = _random_instance(kind, size, seed)
    walk = _build_blocks_walk(a, bs)
    part = build_blocks(a, bs)
    assert part.tolists() == walk
    walk_part = BlockPartition(
        members=np.concatenate(
            [np.asarray(b, dtype=np.int64) for b in walk]),
        lens=np.array([len(b) for b in walk], dtype=np.int64))
    fast = color_blocks(a, part, bs)
    oracle = color_blocks(a, walk_part, bs)
    np.testing.assert_array_equal(fast.perm, oracle.perm)
    np.testing.assert_array_equal(fast.is_dummy, oracle.is_dummy)


@settings(max_examples=10, deadline=None)
@given(kind=st.sampled_from(["graph", "lap2d"]), size=st.integers(0, 8),
       seed=st.integers(0, 10_000), bs=st.sampled_from([2, 4, 8]),
       w=st.sampled_from([2, 3, 4]))
def test_levelset_rounds_prove_race_free(kind, size, seed, bs, w):
    """scheduler="levelset" rounds satisfy the same static race contract
    as the coloring rounds, for every ordering method."""
    a = _random_instance(kind, size, seed)
    for method in METHODS:
        sysd = _order_system(sp.csr_matrix(a), None, method, bs, w,
                             scheduler="levelset")
        assert check_rounds(sysd.a_bar, sysd.fwd_rounds,
                            drop_mask=sysd.drop) == [], method
        assert check_reversed_rounds(sysd.fwd_rounds,
                                     sysd.bwd_rounds) == [], method
        # every (non-dummy) row appears in exactly one forward round
        seen = np.concatenate(sysd.fwd_rounds)
        assert len(seen) == sysd.n_padded
        assert len(np.unique(seen)) == sysd.n_padded


def test_levelset_plans_match_coloring_on_paper_generators():
    """scheduler="levelset" passes the full schedule audit and reproduces
    the coloring scheduler's solutions on every paper generator."""
    import pytest  # noqa: F401  (kept local: file runs under the stub too)

    from repro.core import build_plan
    from repro.core.matrices import PAPER_PROBLEMS, PAPER_SHIFTS, paper_problem
    for name in PAPER_PROBLEMS:
        a, _ = paper_problem(name, "tiny")
        shift = PAPER_SHIFTS.get(name, 0.0)
        b = np.random.default_rng(7).normal(size=a.shape[0])
        xs = {}
        for scheduler in ("coloring", "levelset"):
            plan = build_plan(a, method="hbmc", block_size=8, w=4,
                              shift=shift, scheduler=scheduler,
                              validate="full")
            rep = plan.solve(b, rtol=1e-9, maxiter=6000)
            assert rep.result.converged, (name, scheduler)
            assert rep.scheduler == scheduler
            xs[scheduler] = rep.x
        scale = np.linalg.norm(xs["coloring"])
        err = np.linalg.norm(xs["levelset"] - xs["coloring"]) / scale
        assert err < 1e-6, (name, err)
