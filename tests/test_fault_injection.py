"""Fault-injection tier: the serving layer under adversarial load.

Drives seeded :class:`repro.serve.FaultInjector` traces into a
``SolverService`` on a virtual clock and pins the harness contract:

  * every submitted request terminates with a *definite* status from its
    fault kind's expected set — no silent NaN solutions, no hung slots,
    and the service drains to empty (stays live);
  * healthy requests interleaved with faults still match their bitwise
    slab oracle (``plan.solve_slab`` at the served width/slot);
  * unhealthy columns are quarantined the moment their dispatch ends,
    freeing their slots;
  * deadlines (reaped while queued, retired in-flight), cancellation,
    bounded-queue backpressure (``QueueFullError``), and poisoned-matrix
    fast-fail all behave as documented.

Everything is seeded and runs on ``VirtualClock`` — the tier is exactly
reproducible, which is what makes it CI-able.
"""
import numpy as np
import pytest

from repro.core import UNHEALTHY_STATUSES, build_plan
from repro.serve import (FaultInjector, QueueFullError, SolverService,
                         VirtualClock)
from repro.serve.faults import EXPECTED_STATUSES

KNOBS = dict(method="hbmc", block_size=8, w=4)


def make_service(**kw):
    defaults = dict(slab_width=4, quantum=8, maxiter=3000,
                    clock=VirtualClock(), max_queue=64, **KNOBS)
    defaults.update(kw)
    return SolverService(**defaults)


def _drain(svc, max_steps=200_000):
    svc.drain(max_steps=max_steps)
    assert svc.n_queued == 0 and svc.n_in_flight == 0, \
        "service failed to drain — hung slots or stuck queue"


# ---------------------------------------------------------------------------
# The headline contract: a seeded mixed trace, every status definite.
# ---------------------------------------------------------------------------

def test_mixed_trace_every_request_definite():
    inj = FaultInjector(seed=3, n_side=6)
    svc = make_service()
    rids, shed = inj.inject(svc, 30, spacing=0.01)
    assert len(rids) + len(shed) == 30
    _drain(svc)

    seen_kinds = set()
    for rid, fp in rids.items():
        c = svc.completed[rid]
        assert c.status in fp.expected, \
            f"{fp.kind}: got {c.status!r}, allowed " \
            f"{sorted(fp.expected)}"
        seen_kinds.add(fp.kind)
        if c.status == "CONVERGED":
            assert c.x is not None and np.isfinite(c.x).all()
        if c.status in UNHEALTHY_STATUSES and fp.kind != "nan_matrix":
            # quarantined solves report their solve metadata, never a
            # poisoned iterate
            assert c.x is None
    # the seeded trace actually exercised a spread of kinds
    assert len(seen_kinds) >= 6
    assert svc.n_quarantined > 0


def test_service_stays_live_healthy_oracle_bitwise():
    """Healthy requests interleaved with faults match the standalone slab
    oracle bitwise — fault churn in neighbouring slots (quarantine,
    repack, deadline retirement) never perturbs a healthy column."""
    inj = FaultInjector(seed=5, n_side=6)
    svc = make_service()
    rids, _ = inj.inject(svc, 24, spacing=0.01)
    _drain(svc)

    plan = build_plan(inj.base, **KNOBS)
    checked = 0
    for rid, fp in rids.items():
        if fp.kind not in ("healthy", "deadline"):
            continue
        c = svc.completed[rid]
        if c.status != "CONVERGED":
            continue
        oracle = plan.solve_slab(fp.b, slab_width=c.slab_width,
                                 slot=c.slot, rtol=svc.rtol,
                                 maxiter=svc.maxiter)
        np.testing.assert_array_equal(c.x, oracle.x)
        assert c.iterations == oracle.result.iterations
        checked += 1
    assert checked > 0


@pytest.mark.parametrize("kind", sorted(EXPECTED_STATUSES))
def test_single_kind_definite_status(kind):
    """Each fault kind in isolation resolves to its expected set."""
    inj = FaultInjector(seed=11, n_side=6, kinds=(kind,))
    svc = make_service(slab_width=2)
    rids, _ = inj.inject(svc, 2, spacing=0.01)
    _drain(svc)
    for rid in rids:
        assert svc.completed[rid].status in EXPECTED_STATUSES[kind]


def test_zero_rhs_served_as_zero_solution():
    inj = FaultInjector(seed=0, n_side=6)
    svc = make_service()
    fp = inj.make("zero_rhs")
    rid = svc.submit(fp.a, fp.b)
    _drain(svc)
    c = svc.completed[rid]
    assert c.status == "CONVERGED"
    np.testing.assert_array_equal(c.x, np.zeros(inj.n))


# ---------------------------------------------------------------------------
# Quarantine.
# ---------------------------------------------------------------------------

def test_quarantine_frees_slot_for_later_requests():
    """A terminal-unhealthy column retires at the end of its dispatch —
    its slot is reused, not held for the full maxiter budget."""
    inj = FaultInjector(seed=2, n_side=6)
    svc = make_service(slab_width=2)
    bad = inj.make("nan_rhs")
    rid_bad = svc.submit(bad.a, bad.b)
    healthy = [inj.make("healthy") for _ in range(3)]
    rid_ok = [svc.submit(fp.a, fp.b) for fp in healthy]
    _drain(svc)
    assert svc.completed[rid_bad].status == "BREAKDOWN"
    assert svc.n_quarantined >= 1
    for rid in rid_ok:
        assert svc.completed[rid].status == "CONVERGED"


# ---------------------------------------------------------------------------
# Deadlines.
# ---------------------------------------------------------------------------

def test_deadline_storm_all_definite():
    """A burst of tight-deadline requests: each either converges in time
    or retires DEADLINE; nothing hangs, nothing silently drops."""
    inj = FaultInjector(seed=7, n_side=6, kinds=("deadline",),
                        deadline_timeout=1e-4)
    svc = make_service(slab_width=2)
    rids, _ = inj.inject(svc, 12, spacing=1e-5)
    _drain(svc)
    statuses = {rid: svc.completed[rid].status for rid in rids}
    assert set(statuses.values()) <= {"DEADLINE", "CONVERGED"}
    assert "DEADLINE" in statuses.values()


def test_deadline_reaped_while_queued():
    svc = make_service(slab_width=1)
    inj = FaultInjector(seed=1, n_side=6)
    t0 = svc.clock.now()
    # slot hog arrives first; the second request's deadline passes while
    # it waits for the single slot
    rid_hog = svc.submit(inj.base, inj._rhs(), arrival_time=t0)
    rid_late = svc.submit(inj.base, inj._rhs(), arrival_time=t0,
                          timeout=1e-9)
    _drain(svc)
    assert svc.completed[rid_hog].status == "CONVERGED"
    c = svc.completed[rid_late]
    assert c.status == "DEADLINE"
    assert c.started < 0 and c.slot == -1   # never packed


def test_submit_rejects_nonpositive_timeout():
    svc = make_service()
    inj = FaultInjector(seed=0, n_side=6)
    with pytest.raises(ValueError, match="timeout"):
        svc.submit(inj.base, inj._rhs(), timeout=0.0)


# ---------------------------------------------------------------------------
# Cancellation.
# ---------------------------------------------------------------------------

def test_cancel_queued_and_in_flight():
    svc = make_service(slab_width=2)
    inj = FaultInjector(seed=4, n_side=6)
    rid_a = svc.submit(inj.base, inj._rhs())
    rid_b = svc.submit(inj.base, inj._rhs())

    # queued cancel: revoked before any packing
    assert svc.cancel(rid_b)
    assert svc.completed[rid_b].status == "CANCELLED"
    assert svc.completed[rid_b].x is None

    # unknown / already-terminal rids are not cancellable
    assert not svc.cancel(10_000)
    assert not svc.cancel(rid_b)

    _drain(svc)
    assert svc.completed[rid_a].status == "CONVERGED"


def test_cancel_in_flight_frees_slot():
    svc = make_service(slab_width=1, quantum=1, maxiter=3000)
    inj = FaultInjector(seed=4, n_side=6)
    rid = svc.submit(inj.base, inj._rhs())
    svc.step()   # packed and dispatched one quantum; far from converged
    assert svc.n_in_flight == 1
    assert svc.cancel(rid)
    assert svc.n_in_flight == 0
    assert svc.completed[rid].status == "CANCELLED"
    # the freed slot serves the next request normally
    rid2 = svc.submit(inj.base, inj._rhs())
    _drain(svc)
    assert svc.completed[rid2].status == "CONVERGED"


# ---------------------------------------------------------------------------
# Backpressure.
# ---------------------------------------------------------------------------

def test_queue_full_sheds_load():
    inj = FaultInjector(seed=9, n_side=6, kinds=("healthy",))
    svc = make_service(slab_width=1, max_queue=4)
    rids, shed = inj.inject(svc, 10)
    assert len(rids) == 4 and len(shed) == 6
    _drain(svc)
    for rid in rids:
        assert svc.completed[rid].status == "CONVERGED"


def test_queue_full_raises_before_enqueue():
    inj = FaultInjector(seed=9, n_side=6)
    svc = make_service(max_queue=1)
    svc.submit(inj.base, inj._rhs())
    with pytest.raises(QueueFullError):
        svc.submit(inj.base, inj._rhs())
    assert svc.n_queued == 1   # the refused request was never enqueued


# ---------------------------------------------------------------------------
# Poisoned matrices fail fast.
# ---------------------------------------------------------------------------

def test_nan_matrix_poisons_and_fails_fast():
    inj = FaultInjector(seed=6, n_side=6)
    svc = make_service()
    fp = inj.make("nan_matrix")
    rid1 = svc.submit(fp.a, fp.b)
    _drain(svc)
    assert svc.completed[rid1].status == "BREAKDOWN"
    assert len(svc._poisoned) == 1

    # a second request against the same poisoned values fails immediately
    # without re-attempting the factorization
    builds_before = svc.cache.stats.misses + svc.cache.stats.refactors
    rid2 = svc.submit(fp.a, inj._rhs())
    _drain(svc)
    assert svc.completed[rid2].status == "BREAKDOWN"
    assert (svc.cache.stats.misses + svc.cache.stats.refactors
            == builds_before)

    # healthy requests on the same PATTERN keep working — poisoning is
    # per (key, values), not per pattern
    ok = inj.make("healthy")
    rid3 = svc.submit(ok.a, ok.b)
    _drain(svc)
    assert svc.completed[rid3].status == "CONVERGED"


def test_refactor_under_load_with_faults():
    """Value-change requests (refactor path) interleaved with faults:
    both matrix generations converge and the refactor fast path is hit."""
    inj = FaultInjector(seed=8, n_side=6,
                        kinds=("healthy", "value_change", "nan_rhs"))
    svc = make_service(slab_width=2)
    rids, _ = inj.inject(svc, 12, spacing=0.01)
    _drain(svc)
    statuses = {}
    for rid, fp in rids.items():
        c = svc.completed[rid]
        assert c.status in fp.expected
        statuses.setdefault(fp.kind, set()).add(c.plan_status)
    assert "refactor" in statuses.get("value_change", set()) \
        or "hit" in statuses.get("value_change", set())
