"""Skip-stubs standing in for ``hypothesis`` when it is not installed.

``given`` replaces the test with a zero-arg function that skips (so pytest
never looks for fixtures matching the strategy kwargs), ``settings`` is the
identity, and ``st`` accepts any strategy construction at decoration time.
"""
import pytest


def given(*args, **kwargs):
    def deco(fn):
        def skipped():
            pytest.skip("hypothesis not installed")
        skipped.__name__ = fn.__name__
        return skipped
    return deco


def settings(*args, **kwargs):
    return lambda fn: fn


class _StrategyStub:
    def __getattr__(self, name):
        return lambda *a, **k: None


st = _StrategyStub()
