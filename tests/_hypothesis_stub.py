"""Deterministic fallback engine standing in for ``hypothesis``.

When the real ``hypothesis`` package is unavailable (minimal environments
— CI installs it, see ``.github/workflows/ci.yml``), these shims RUN the
property tests instead of skipping them: ``given`` draws ``max_examples``
pseudo-random examples from the declared strategies with a seed derived
from the test name, so every run covers the same example set and a failure
reproduces by rerunning the same test.  The failing example's arguments
are attached to the raised error.  Shrinking, the example database, and
the full strategy algebra are out of scope — only the strategy
constructors the suite uses are provided (``integers``, ``floats``,
``booleans``, ``sampled_from``).
"""
from __future__ import annotations

import zlib

import numpy as np

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    """A draw function over a seeded ``numpy`` Generator."""

    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: np.random.Generator):
        return self._draw(rng)

    def map(self, f):
        return _Strategy(lambda rng: f(self._draw(rng)))


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0,
               **_kwargs) -> _Strategy:
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.integers(2)))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        elements = list(elements)
        return _Strategy(
            lambda rng: elements[int(rng.integers(len(elements)))])


st = _Strategies()


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_kwargs):
    """Records ``max_examples`` on the wrapped runner; other hypothesis
    settings (deadline, profiles, ...) have no fallback equivalent."""
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(**strategies):
    """Run the test over a deterministic sweep of strategy draws.

    The returned runner takes no arguments (pytest must not look for
    fixtures matching the strategy names) and deliberately exposes no
    ``__wrapped__`` (pytest's signature introspection would follow it
    back to the parametrised function).
    """
    def deco(fn):
        def runner():
            n = getattr(runner, "_fallback_max_examples",
                        _DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for i in range(n):
                kwargs = {k: s.example(rng) for k, s in strategies.items()}
                try:
                    fn(**kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (draw {i + 1}/{n}): "
                        f"{fn.__name__}({', '.join(f'{k}={v!r}' for k, v in kwargs.items())})"
                    ) from e
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        return runner
    return deco
