"""In-process coverage of the distribution layer (single-device host).

The full multi-device parity matrix lives in tests/test_multidevice.py
(subprocesses with forced host device counts).  Everything here runs the
SAME distributed machinery — shard_map fused sweep, sharded SpMV, mesh
plan — on a 1-device mesh, where it must be bitwise identical to the
plain single-device path, plus the satellite regressions (PCG-iteration
pairings, dtype preservation through padding/packing).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp

from repro.analysis import PRECONDITIONED_ITERATION, lint
from repro.core import (build_plan, ic0, pcg, pcg_iteration, solve_iccg,
                        spmv_ell, spmv_sell)
from repro.core import sell
from repro.core.coloring import block_multicolor_ordering, pad_system
from repro.core.hbmc import hbmc_from_bmc, pad_system_hbmc
from repro.core.iccg import make_sharded_spmv
from repro.core.matrices import laplace_2d
from repro.core.plan import _order_system
from repro.core.trisolve import (DistributedRoundMajorPreconditioner,
                                 fused_solve, shard_fused_tables)


def _mesh1():
    return jax.make_mesh((1,), ("data",))


# ---------------------------------------------------------------------------
# 1. Distributed machinery on a 1-device mesh == plain single-device path.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["hbmc", "bmc"])
def test_mesh_plan_bitwise_on_one_device(method):
    a = laplace_2d(13, 17)
    n = a.shape[0]
    rng = np.random.default_rng(0)
    b = rng.normal(size=n)
    bb = rng.normal(size=(n, 3))
    ref = build_plan(a, method=method, block_size=8, w=4)
    dist = build_plan(a, method=method, block_size=8, w=4, mesh=_mesh1())
    r_ref, r = ref.solve(b), dist.solve(b)
    assert r.result.iterations == r_ref.result.iterations
    np.testing.assert_array_equal(r.x, r_ref.x)
    rb_ref, rb = ref.solve_batched(bb), dist.solve_batched(bb)
    np.testing.assert_array_equal(rb.result.iterations,
                                  rb_ref.result.iterations)
    np.testing.assert_array_equal(rb.x, rb_ref.x)


@pytest.mark.parametrize("fmt", ["ell", "sell"])
def test_sharded_spmv_matches_plain(fmt):
    a = sp.csr_matrix(laplace_2d(12, 11))
    n = a.shape[0]
    mesh = _mesh1()
    x = jnp.asarray(np.random.default_rng(1).normal(size=n))
    xb = jnp.asarray(np.random.default_rng(2).normal(size=(n, 3)))
    if fmt == "ell":
        cols, vals = sell.pack_ell(a)
        vals_d, cols_d = jnp.asarray(vals), jnp.asarray(cols)
        ref = spmv_ell(vals_d, cols_d, x)
    else:
        sm = sell.pack_sell(a, 4)
        vals_d, cols_d = jnp.asarray(sm.vals), jnp.asarray(sm.cols)
        ref = spmv_sell(vals_d, cols_d, x, n)
    f = make_sharded_spmv(fmt, n, mesh, "data", vals_d, cols_d,
                          batched=False)
    np.testing.assert_array_equal(np.asarray(f(x)), np.asarray(ref))
    fb = make_sharded_spmv(fmt, n, mesh, "data", vals_d, cols_d,
                           batched=True)
    got_b = np.asarray(fb(xb))
    singles = np.stack([np.asarray(f(xb[:, j])) for j in range(3)], axis=1)
    np.testing.assert_allclose(got_b, singles, rtol=0, atol=1e-14)


def test_distributed_preconditioner_matches_fused_solve():
    a = laplace_2d(11, 9)
    sysd = _order_system(sp.csr_matrix(a), None, "hbmc", 8, 4)
    from repro.core.trisolve import \
        build_round_major_preconditioner_from_rounds
    pre, rm = build_round_major_preconditioner_from_rounds(
        ic0(sysd.a_bar), sysd.fwd_rounds, sysd.bwd_rounds,
        drop_mask=sysd.drop)
    mesh = _mesh1()
    dpre = DistributedRoundMajorPreconditioner(
        tables=shard_fused_tables(pre.tables, mesh, "data"),
        mesh=mesh, axis="data")
    r = jnp.asarray(np.random.default_rng(3).normal(size=rm.m))
    want = fused_solve(pre.tables, r.reshape(pre.tables.n_steps, -1))
    np.testing.assert_array_equal(np.asarray(dpre(r)), np.asarray(want))
    rb = jnp.asarray(np.random.default_rng(4).normal(size=(rm.m, 2)))
    want_b = np.stack([np.asarray(dpre(rb[:, j])) for j in range(2)],
                      axis=1)
    np.testing.assert_allclose(np.asarray(dpre.apply_batched(rb)), want_b,
                               rtol=0, atol=1e-14)


# ---------------------------------------------------------------------------
# 2. Lane padding (the mesh divisibility contract).
# ---------------------------------------------------------------------------

def test_lane_multiple_pads_and_converges_identically():
    a = laplace_2d(13, 11)
    b = np.random.default_rng(5).normal(size=a.shape[0])
    base = build_plan(a, method="hbmc", block_size=8, w=4)
    for mult in (3, 8):
        plan = build_plan(a, method="hbmc", block_size=8, w=4,
                          lane_multiple=mult)
        assert plan._precond.tables.lanes % mult == 0
        r, rb = plan.solve(b), base.solve(b)
        # lane padding only adds inert lanes: same Krylov process up to
        # reduction-order rounding of the dots over the padded vector
        assert abs(r.result.iterations - rb.result.iterations) <= 1
        np.testing.assert_allclose(r.x, rb.x, rtol=0, atol=1e-9)


def test_mesh_plan_validation_errors():
    a = laplace_2d(8, 8)
    mesh = _mesh1()
    with pytest.raises(ValueError, match="round_major"):
        build_plan(a, mesh=mesh, layout="index")
    with pytest.raises(ValueError, match="xla"):
        build_plan(a, mesh=mesh, backend="pallas")
    with pytest.raises(ValueError, match="axis"):
        build_plan(a, mesh=mesh, mesh_axis="model")


# ---------------------------------------------------------------------------
# 3. PCG-iteration pairings (the roofline dry-run bugfix).
# ---------------------------------------------------------------------------

def _index_operators(a, method="hbmc"):
    sysd = _order_system(sp.csr_matrix(a), None, method, 8, 4)
    from repro.core.trisolve import build_preconditioner_from_rounds
    pre = build_preconditioner_from_rounds(
        ic0(sysd.a_bar), sysd.fwd_rounds, sysd.bwd_rounds,
        drop_mask=sysd.drop)
    cols, vals = sell.pack_ell(sysd.a_bar)
    vals_d, cols_d = jnp.asarray(vals), jnp.asarray(cols)
    spmv = lambda v: spmv_ell(vals_d, cols_d, v)
    return sysd, spmv, pre


def test_pcg_iteration_reproduces_pcg_iterates():
    """The carried (x, r, p, rz) step must replay ``pcg`` exactly — the
    seed-era ``(r, r)`` pairings diverge from it on the very first step."""
    a = laplace_2d(10, 9)
    sysd, spmv, pre = _index_operators(a)
    b = jnp.asarray(np.random.default_rng(6).normal(size=sysd.n_padded))
    k = 4
    ref = pcg(spmv, pre, b, rtol=0.0, maxiter=k)   # exactly k iterations
    step = pcg_iteration(spmv, pre)
    x = jnp.zeros_like(b)
    r = b
    z = pre(r)
    p = z
    rz = jnp.vdot(r, z)
    for _ in range(k):
        x, r, p, rz = step(x, r, p, rz)
    np.testing.assert_allclose(np.asarray(x), ref.x, rtol=0, atol=1e-12)

    # and the wrong pairings really are wrong (guards against the fix
    # regressing to plain-CG dots)
    def wrong_step(x, r, p):
        ap = spmv(p)
        alpha = jnp.vdot(r, r) / jnp.vdot(p, ap)
        x = x + alpha * p
        r2 = r - alpha * ap
        z = pre(r2)
        beta = jnp.vdot(r2, z) / jnp.vdot(r, r)
        return x, r2, z + beta * p
    xw, rw, pw = jnp.zeros_like(b), b, pre(b)
    for _ in range(k):
        xw, rw, pw = wrong_step(xw, rw, pw)
    assert not np.allclose(np.asarray(xw), ref.x, atol=1e-10)


def test_pcg_iteration_jaxpr_contains_both_sweeps():
    """The lowered iteration must contain the fwd AND bwd substitution
    loops — the seed-era (r, r) pairings never called the preconditioner,
    so the dry-run roofline accounted a plain-CG kernel."""
    a = laplace_2d(9, 8)
    sysd, spmv, pre = _index_operators(a)
    step = pcg_iteration(spmv, pre)
    v = jnp.zeros((sysd.n_padded,))
    assert lint(step, v, v, v, jnp.asarray(1.0),
                budget=PRECONDITIONED_ITERATION) == []


# ---------------------------------------------------------------------------
# 4. Dtype preservation through padding and host pack buffers.
# ---------------------------------------------------------------------------

def test_pad_system_preserves_matrix_dtype():
    a = sp.csr_matrix(laplace_2d(9, 9)).astype(np.float32)
    bmc = block_multicolor_ordering(a, 8)
    a_bar, _ = pad_system(a, None, bmc)
    assert a_bar.dtype == np.float32
    hb = hbmc_from_bmc(bmc, 4)
    a_hb, b_hb = pad_system_hbmc(a, np.ones(a.shape[0], np.float32), hb)
    assert a_hb.dtype == np.float32
    assert b_hb.dtype == np.float32
    # non-floating inputs still promote (1/diag must be exact)
    ai = sp.csr_matrix((np.ones(a.nnz, dtype=np.int64),
                        a.indices.copy(), a.indptr.copy()), shape=a.shape)
    a_bar_i, _ = pad_system(ai, None, bmc)
    assert a_bar_i.dtype == np.float64


def test_pack_buffers_preserve_dtype():
    a = sp.csr_matrix(laplace_2d(9, 9)).astype(np.float32)
    cols, vals = sell.pack_ell(a)
    assert vals.dtype == np.float32
    sm = sell.pack_sell(a, 4)
    assert sm.vals.dtype == np.float32
    sysd = _order_system(sp.csr_matrix(laplace_2d(9, 9)), None, "hbmc", 8, 4)
    l32 = sp.csr_matrix(ic0(sysd.a_bar)).astype(np.float32)
    diag = l32.diagonal()
    tri = sp.tril(l32, k=-1, format="csr")
    t = sell.pack_steps(tri, diag, sysd.fwd_rounds, sysd.drop)
    assert t.vals.dtype == np.float32
    assert t.dinv.dtype == np.float32
    fwd, bwd = sell.pack_factor(l32, sysd.fwd_rounds, sysd.bwd_rounds,
                                sysd.drop)
    fused = sell.fuse_round_major(fwd, bwd)
    assert fused.vals.dtype == np.float32
    assert fused.dinv.dtype == np.float32


def test_f32_matrix_end_to_end_solve():
    """An f32 system stays f32 through padding + packing and still solves
    (previously the padding silently promoted the matrix to f64)."""
    a = sp.csr_matrix(laplace_2d(12, 10)).astype(np.float32)
    b = np.random.default_rng(7).normal(size=a.shape[0]).astype(np.float32)
    rep = solve_iccg(a, b, method="hbmc", block_size=8, w=4,
                     dtype=jnp.float32, rtol=1e-4)
    assert rep.result.converged
    assert rep.x.dtype == np.float32
    res = np.linalg.norm(a @ rep.x - b) / np.linalg.norm(b)
    assert res < 1e-3
