"""Adversarial-matrix tier: breakdown detection + recovery on inputs the
ICCG method is not entitled to.

What is pinned here:

  1. The status taxonomy itself (codes, names, helpers).
  2. The zero-RHS and NaN-column regressions: ``pcg`` with b = 0 returns
     x = 0 / CONVERGED immediately; a NaN column in ``pcg_batched``
     deactivates with an explicit BREAKDOWN instead of silently falling
     out of the active mask.
  3. Adversarial matrices (indefinite / semi-definite / near-singular /
     NaN-contaminated) through single, batched and slab paths, across
     hbmc/bmc orderings and the xla/pallas trisolve backends: every solve
     terminates with a definite status from the kind's expected set and a
     fully finite iterate (broken steps roll back, never leak NaN).
  4. Healthy columns of a mixed slab are bitwise-equal to an all-healthy
     run at the same width — one column's fault never perturbs neighbours.
  5. IC(0) clamped-pivot accounting: sequential and round-parallel sweeps
     report identical counts; the plan's ``on_breakdown`` policies (clamp
     / raise / escalate) and the recorded shift schedule.
  6. The DIVERGED and STAGNATED monitor guards are reachable and select
     the documented terminal codes.

Everything here must hold with the default knobs too — the monitoring is
select-based, so the healthy-path float sequences of the rest of the test
suite (which runs unmodified) are the other half of this tier's contract.
"""
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import (BREAKDOWN, CONVERGED, DIVERGED, MAXITER, RUNNING,
                        STAGNATED, STATUS_NAMES, UNHEALTHY_STATUSES,
                        FactorBreakdownError, build_plan, ic0, ic0_rounds,
                        pcg, pcg_batched, status_name)
from repro.core.matrices import laplace_2d
from repro.core.solvers import _order_system
from repro.serve.faults import (EXPECTED_STATUSES, indefinite_matrix,
                                near_singular_matrix, semidefinite_matrix)

KNOBS = dict(method="hbmc", block_size=8, w=4)

ADVERSARIAL = [
    ("indefinite", indefinite_matrix),
    ("semidefinite", semidefinite_matrix),
    ("near_singular", near_singular_matrix),
]


def _rhs(n, seed=0):
    return np.random.default_rng(seed).standard_normal(n)


# ---------------------------------------------------------------------------
# 1. Taxonomy.
# ---------------------------------------------------------------------------

def test_status_taxonomy():
    assert STATUS_NAMES == ("RUNNING", "CONVERGED", "MAXITER", "BREAKDOWN",
                            "DIVERGED", "STAGNATED")
    assert [STATUS_NAMES[c] for c in
            (RUNNING, CONVERGED, MAXITER, BREAKDOWN, DIVERGED,
             STAGNATED)] == list(STATUS_NAMES)
    assert status_name(BREAKDOWN) == "BREAKDOWN"
    assert set(UNHEALTHY_STATUSES) == {"BREAKDOWN", "DIVERGED", "STAGNATED"}
    # RUNNING is an internal code only — never a terminal status
    assert "RUNNING" not in UNHEALTHY_STATUSES


# ---------------------------------------------------------------------------
# 2. Regressions: zero RHS and explicit NaN-column statuses.
# ---------------------------------------------------------------------------

def test_pcg_zero_rhs_converges_immediately():
    b = jnp.zeros(16)
    res = pcg(lambda v: 2.0 * v, lambda v: v, b)
    assert res.status == "CONVERGED"
    assert res.converged
    assert res.iterations == 0
    np.testing.assert_array_equal(res.x, np.zeros(16))


def test_plan_zero_rhs_converges_immediately():
    a = laplace_2d(6, 6)
    plan = build_plan(a, **KNOBS)
    rep = plan.solve(np.zeros(a.shape[0]))
    assert rep.result.status == "CONVERGED"
    assert rep.result.iterations == 0
    np.testing.assert_array_equal(rep.x, np.zeros(a.shape[0]))


def test_pcg_nan_rhs_is_breakdown_not_silence():
    b = jnp.asarray(_rhs(16)).at[3].set(jnp.nan)
    res = pcg(lambda v: 2.0 * v, lambda v: v, b)
    assert res.status == "BREAKDOWN"
    assert not res.converged
    assert res.iterations == 0
    # the reported iterate is the last finite one (x0 = 0), never NaN
    assert np.isfinite(res.x).all()


def test_pcg_batched_nan_column_explicit_breakdown():
    """A NaN column deactivates with an explicit BREAKDOWN status while its
    neighbours' float sequences are bitwise-untouched (the old behavior
    silently dropped the column out of ``active`` via a NaN comparison)."""
    a = laplace_2d(6, 6)
    n = a.shape[0]
    plan = build_plan(a, **KNOBS)
    b = np.stack([_rhs(n, 0), _rhs(n, 1), _rhs(n, 2)], axis=1)
    b_bad = b.copy()
    b_bad[5, 1] = np.nan

    mixed = plan.solve_batched(b_bad)
    assert mixed.result.status_names == ["CONVERGED", "BREAKDOWN",
                                         "CONVERGED"]
    assert list(mixed.result.converged) == [True, False, True]
    assert mixed.result.iterations[1] == 0
    assert np.isfinite(mixed.x).all()

    # healthy lanes bitwise vs the all-healthy batch at the same width:
    # lane ops never mix columns, so the fault is invisible to neighbours
    clean = plan.solve_batched(b)
    np.testing.assert_array_equal(mixed.x[:, 0], clean.x[:, 0])
    np.testing.assert_array_equal(mixed.x[:, 2], clean.x[:, 2])
    np.testing.assert_array_equal(mixed.result.iterations[[0, 2]],
                                  clean.result.iterations[[0, 2]])


# ---------------------------------------------------------------------------
# 3. Adversarial matrices through every solve path.
# ---------------------------------------------------------------------------

def _assert_definite(status, kind, x):
    assert status in EXPECTED_STATUSES[kind], \
        f"{kind}: status {status!r} not in {sorted(EXPECTED_STATUSES[kind])}"
    assert status != "RUNNING"
    assert np.isfinite(np.asarray(x)).all(), \
        f"{kind}: non-finite iterate leaked through a {status} termination"


@pytest.mark.parametrize("method", ["hbmc", "bmc"])
@pytest.mark.parametrize("kind,make", ADVERSARIAL,
                         ids=[k for k, _ in ADVERSARIAL])
def test_adversarial_matrix_definite_status(kind, make, method):
    a = make(6)
    n = a.shape[0]
    plan = build_plan(a, method=method, block_size=8, w=4)
    maxiter = 300

    single = plan.solve(_rhs(n), maxiter=maxiter)
    _assert_definite(single.result.status, kind, single.x)

    b2 = np.stack([_rhs(n, 1), _rhs(n, 2)], axis=1)
    batched = plan.solve_batched(b2, maxiter=maxiter)
    for s in batched.result.status_names:
        _assert_definite(s, kind, batched.x)

    slab = plan.solve_slab(_rhs(n, 3), slab_width=4, slot=2,
                           maxiter=maxiter)
    _assert_definite(slab.result.status, kind, slab.x)


@pytest.mark.parametrize("kind,make", ADVERSARIAL,
                         ids=[k for k, _ in ADVERSARIAL])
def test_adversarial_matrix_pallas_backend(kind, make):
    """Same contract through the Pallas trisolve kernel (interpret mode on
    CPU) — the monitor lives above the kernel, so the taxonomy must be
    backend-invariant."""
    a = make(6)
    n = a.shape[0]
    plan = build_plan(a, backend="pallas", interpret=True, **KNOBS)
    rep = plan.solve(_rhs(n), maxiter=150)
    _assert_definite(rep.result.status, kind, rep.x)


def test_nan_matrix_build_raises():
    a = laplace_2d(6, 6)
    a.data = a.data.copy()
    a.data[0] = np.nan
    with pytest.raises(FactorBreakdownError, match="not finite"):
        build_plan(a, **KNOBS)


def test_nan_matrix_refactor_raises_and_preserves_plan():
    """A refactor hitting FactorBreakdownError leaves the old (working)
    operators in place — the plan keeps serving the previous matrix."""
    a = laplace_2d(6, 6)
    n = a.shape[0]
    plan = build_plan(a, **KNOBS)
    b = _rhs(n)
    before = plan.solve(b)

    a_nan = a.copy()
    a_nan.data = a_nan.data.copy()
    a_nan.data[0] = np.nan
    with pytest.raises(FactorBreakdownError):
        plan.refactor(a_nan)

    after = plan.solve(b)
    assert after.result.status == "CONVERGED"
    np.testing.assert_array_equal(after.x, before.x)


# ---------------------------------------------------------------------------
# 4. Mixed slab: the fault column is invisible to healthy neighbours.
# ---------------------------------------------------------------------------

def test_mixed_slab_healthy_columns_bitwise():
    a = laplace_2d(6, 6)
    n = a.shape[0]
    plan = build_plan(a, **KNOBS)
    width, bad_slot = 4, 1
    cols = [_rhs(n, s) for s in range(width)]

    def run(slab_cols):
        state = plan.new_slab_state(width)
        r = state.r
        for s, col in enumerate(slab_cols):
            r = r.at[:, s].set(plan.embed_rhs(np.asarray(col)))
        state = state._replace(r=r)
        state, _ = plan.run_slab(state, maxiter=400, quantum=400)
        return state

    bad = np.asarray(cols[bad_slot]).copy()
    bad[7] = np.nan
    mixed = run(cols[:bad_slot] + [bad] + cols[bad_slot + 1:])
    clean = run(cols)

    assert status_name(mixed.status[bad_slot]) == "BREAKDOWN"
    assert not bool(mixed.active[bad_slot])
    for s in range(width):
        if s == bad_slot:
            continue
        assert status_name(mixed.status[s]) == "CONVERGED"
        np.testing.assert_array_equal(np.asarray(mixed.x[:, s]),
                                      np.asarray(clean.x[:, s]))
        assert int(mixed.iters[s]) == int(clean.iters[s])
        np.testing.assert_array_equal(np.asarray(mixed.relres[s]),
                                      np.asarray(clean.relres[s]))


# ---------------------------------------------------------------------------
# 5. Clamped-pivot accounting and the on_breakdown policies.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["hbmc", "bmc", "natural"])
def test_clamp_counts_agree_sequential_vs_round_parallel(method):
    a = indefinite_matrix(6)
    sysd = _order_system(sp.csr_matrix(a), None, method, 8, 4)
    l_seq = ic0(sysd.a_bar)
    l_rnd = ic0_rounds(sysd.a_bar, sysd.fwd_rounds)
    assert l_seq.clamped_pivots > 0
    assert l_rnd.clamped_pivots == l_seq.clamped_pivots
    np.testing.assert_array_equal(l_rnd.data, l_seq.data)


def test_healthy_factor_reports_zero_clamps():
    a = laplace_2d(6, 6)
    assert ic0(a).clamped_pivots == 0
    plan = build_plan(a, **KNOBS)
    assert plan.clamped_pivots == 0
    assert plan.shift_schedule == [(0.0, 0)]
    assert plan.effective_shift == 0.0


def test_on_breakdown_clamp_records_but_proceeds():
    plan = build_plan(indefinite_matrix(6), **KNOBS)   # default "clamp"
    assert plan.on_breakdown == "clamp"
    assert plan.clamped_pivots > 0
    assert plan.shift_schedule == [(0.0, plan.clamped_pivots)]
    assert plan.effective_shift == 0.0


def test_on_breakdown_raise():
    with pytest.raises(FactorBreakdownError) as exc:
        build_plan(indefinite_matrix(6), on_breakdown="raise", **KNOBS)
    assert exc.value.clamped_pivots > 0
    assert len(exc.value.shift_schedule) == 1
    assert exc.value.shift_schedule[0][1] == exc.value.clamped_pivots


def test_on_breakdown_escalate_finds_clean_shift():
    plan = build_plan(indefinite_matrix(6), on_breakdown="escalate", **KNOBS)
    assert plan.clamped_pivots == 0
    assert plan.effective_shift > 0.0
    # schedule: the failed base attempt plus monotone escalations ending
    # in the clean factor actually in use
    shifts = [s for s, _ in plan.shift_schedule]
    clamps = [c for _, c in plan.shift_schedule]
    assert len(plan.shift_schedule) >= 2
    assert shifts == sorted(shifts)
    assert clamps[0] > 0 and clamps[-1] == 0
    assert shifts[-1] == plan.effective_shift
    # the escalated factor is a usable preconditioner: solves terminate
    # with a definite status
    rep = plan.solve(_rhs(plan.n), maxiter=300)
    _assert_definite(rep.result.status, "indefinite", rep.x)


def test_on_breakdown_escalate_noop_on_healthy_matrix():
    plan = build_plan(laplace_2d(6, 6), on_breakdown="escalate", **KNOBS)
    assert plan.effective_shift == 0.0
    assert plan.shift_schedule == [(0.0, 0)]


def test_escalate_refactor_records_schedule():
    a = laplace_2d(6, 6)
    plan = build_plan(a, on_breakdown="escalate", **KNOBS)
    bad = indefinite_matrix(6)   # same pattern, indefinite values
    plan.refactor(bad)
    assert plan.clamped_pivots == 0
    assert plan.effective_shift > 0.0
    assert len(plan.shift_schedule) >= 2


def test_unknown_on_breakdown_rejected():
    with pytest.raises(ValueError, match="on_breakdown"):
        build_plan(laplace_2d(6, 6), on_breakdown="explode", **KNOBS)


# ---------------------------------------------------------------------------
# 6. The DIVERGED / STAGNATED guards are reachable.
# ---------------------------------------------------------------------------

def _diag_op(d):
    d = jnp.asarray(d)
    return lambda v: d * v if v.ndim == 1 else d[:, None] * v


def test_pcg_diverged_guard():
    """With a divergence factor below 1, any residual-norm step that fails
    to beat the running best trips the guard — a deterministic probe of
    the DIVERGED pathway (real divergence takes many more iterations but
    exercises the identical select)."""
    d = np.linspace(1.0, 10.0, 16)
    b = jnp.asarray(_rhs(16, 4))
    res = pcg(_diag_op(d), lambda v: v, b, divergence_factor=1e-6)
    assert res.status == "DIVERGED"
    assert not res.converged
    assert np.isfinite(res.x).all()


def test_pcg_batched_diverged_guard():
    d = np.linspace(1.0, 10.0, 16)
    b = jnp.asarray(np.stack([_rhs(16, 5), _rhs(16, 6)], axis=1))
    res = pcg_batched(_diag_op(d), lambda v: v, b, divergence_factor=1e-6)
    assert res.status_names == ["DIVERGED", "DIVERGED"]


def _near_singular_op():
    a = near_singular_matrix(6).toarray()
    return lambda v: jnp.asarray(a) @ v


def test_pcg_stagnated_guard():
    """Unpreconditioned CG on the near-singular Laplacian stalls well
    before its tight rtol; the stagnation window terminates it with
    STAGNATED instead of burning the full maxiter budget."""
    b = jnp.asarray(_rhs(36, 7))
    res = pcg(_near_singular_op(), lambda v: v, b, rtol=1e-14,
              maxiter=5000, stagnation_window=10)
    assert res.status == "STAGNATED"
    assert res.iterations < 5000
    assert np.isfinite(res.x).all()


def test_monitor_knobs_off_restore_maxiter():
    """divergence_factor=None / stagnation_window=None disable the guards:
    the same stalled solve then runs to MAXITER exactly as before."""
    b = jnp.asarray(_rhs(36, 7))
    res = pcg(_near_singular_op(), lambda v: v, b, rtol=1e-14, maxiter=30,
              divergence_factor=None, stagnation_window=None)
    assert res.status == "MAXITER"
    assert res.iterations == 30
