"""Backend parity (xla vs pallas) and batched multi-RHS PCG.

The Pallas round-major kernel is validated against the XLA substitution as
oracle (same semantics, different layout), and the batched PCG front-end is
validated against B independent single-RHS solves — iteration for
iteration, which is the acceptance bar for per-RHS convergence masking.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (block_multicolor_ordering, build_preconditioner,
                        hbmc_from_bmc, ic0, pack_factor_hbmc, pad_system_hbmc,
                        pcg_batched, solve_iccg, solve_iccg_batched,
                        spmv_ell_batched, to_round_major)
from repro.core.matrices import laplace_2d, laplace_3d
from repro.core.sell import pack_ell
from repro.core.trisolve import (backward_solve, backward_solve_batched,
                                 forward_solve, forward_solve_batched)
from repro.kernels.ops import DeviceRoundMajorTables


MATRICES = [
    ("lap2d", laplace_2d(14, 12)),
    ("lap3d", laplace_3d(5, 5, 4)),
]


def _hbmc_tables(a, bs=8, w=4):
    bmc = block_multicolor_ordering(a, bs)
    hb = hbmc_from_bmc(bmc, w)
    a_hb, _ = pad_system_hbmc(a, None, hb)
    l = ic0(a_hb)
    return hb, l, pack_factor_hbmc(l, hb)


# ---------------------------------------------------------------------------
# Pallas kernel vs XLA forward/backward substitution (f64 oracle).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,a", MATRICES)
def test_pallas_trisolve_matches_xla_solves(name, a):
    from repro.core.trisolve import DeviceTables
    hb, l, (fwd_h, bwd_h) = _hbmc_tables(a)
    fwd = DeviceTables.from_host(fwd_h)
    bwd = DeviceTables.from_host(bwd_h)
    fwd_rm = DeviceRoundMajorTables.from_steps(fwd_h)
    bwd_rm = DeviceRoundMajorTables.from_steps(bwd_h)

    q = jnp.asarray(np.random.default_rng(0).normal(size=hb.n_final))
    y_x = np.asarray(forward_solve(fwd, q))
    y_p = np.asarray(fwd_rm.apply(q, use_kernel=True, interpret=True))
    real = ~hb.is_dummy
    np.testing.assert_allclose(y_p[real], y_x[real], rtol=1e-12, atol=1e-12)

    z_x = np.asarray(backward_solve(bwd, jnp.asarray(y_x)))
    z_p = np.asarray(bwd_rm.apply(jnp.asarray(y_x), use_kernel=True,
                                  interpret=True))
    np.testing.assert_allclose(z_p[real], z_x[real], rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("name,a", MATRICES)
def test_preconditioner_backend_parity(name, a):
    hb, l, _ = _hbmc_tables(a)
    pre_x = build_preconditioner(l, hb, backend="xla")
    pre_p = build_preconditioner(l, hb, backend="pallas")
    r = jnp.asarray(np.random.default_rng(1).normal(size=hb.n_final))
    z_x = np.asarray(pre_x(r))
    z_p = np.asarray(pre_p(r))
    real = ~hb.is_dummy
    np.testing.assert_allclose(z_p[real], z_x[real], rtol=1e-12, atol=1e-12)


def test_unknown_backend_rejected():
    a = laplace_2d(8, 8)
    hb, l, _ = _hbmc_tables(a, bs=4, w=2)
    with pytest.raises(ValueError, match="backend"):
        build_preconditioner(l, hb, backend="cuda")


# ---------------------------------------------------------------------------
# Round-major repacking invariants.
# ---------------------------------------------------------------------------

def test_round_major_layout_contract():
    a = laplace_2d(12, 10)
    hb, l, (fwd_h, _) = _hbmc_tables(a)
    rm = to_round_major(fwd_h)
    s_, r_ = fwd_h.rows.shape
    # the kept permutation (rows) covers every live unknown exactly once
    live = rm.rows[rm.rows != rm.n_slots - 1]
    assert len(np.unique(live)) == len(live)
    # every non-pad column entry points strictly at an EARLIER round-major
    # position (lower-triangular in execution order)
    pos = np.arange(s_ * r_).reshape(s_, r_)
    valid = rm.vals != 0.0
    assert (rm.cols[valid] < pos[..., None].repeat(rm.cols.shape[-1],
                                                   axis=-1)[valid]).all()
    # values/dinv are carried through unchanged
    np.testing.assert_array_equal(rm.vals, fwd_h.vals)
    np.testing.assert_array_equal(rm.dinv, fwd_h.dinv)


# ---------------------------------------------------------------------------
# End-to-end: same PCG iteration counts across backends (acceptance).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,a", MATRICES)
def test_solve_iccg_backend_same_iterations(name, a):
    b = np.random.default_rng(2).normal(size=a.shape[0])
    r_x = solve_iccg(a, b, method="hbmc", block_size=8, w=4, backend="xla")
    r_p = solve_iccg(a, b, method="hbmc", block_size=8, w=4,
                     backend="pallas")
    assert r_x.result.iterations == r_p.result.iterations, name
    assert r_p.result.converged
    np.testing.assert_allclose(r_p.x, r_x.x, rtol=1e-9, atol=1e-9)


# ---------------------------------------------------------------------------
# Batched multi-RHS solves.
# ---------------------------------------------------------------------------

def test_batched_trisolve_matches_columnwise():
    from repro.core.trisolve import DeviceTables
    a = laplace_2d(13, 9)
    hb, l, (fwd_h, bwd_h) = _hbmc_tables(a)
    fwd = DeviceTables.from_host(fwd_h)
    bwd = DeviceTables.from_host(bwd_h)
    q = jnp.asarray(np.random.default_rng(3).normal(size=(hb.n_final, 4)))
    yb = np.asarray(forward_solve_batched(fwd, q))
    zb = np.asarray(backward_solve_batched(bwd, jnp.asarray(yb)))
    for j in range(q.shape[1]):
        yj = np.asarray(forward_solve(fwd, q[:, j]))
        np.testing.assert_allclose(yb[:, j], yj, rtol=1e-13, atol=1e-13)
        zj = np.asarray(backward_solve(bwd, jnp.asarray(yj)))
        np.testing.assert_allclose(zb[:, j], zj, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_batched_pallas_kernel_matches_single(dtype):
    a = laplace_2d(11, 8)
    hb, l, (fwd_h, _) = _hbmc_tables(a, bs=4, w=4)
    rm = DeviceRoundMajorTables.from_steps(fwd_h, dtype=dtype)
    q = jnp.asarray(np.random.default_rng(4).normal(size=(hb.n_final, 3)),
                    dtype=dtype)
    yb = np.asarray(rm.apply_batched(q, use_kernel=True, interpret=True))
    yb_ref = np.asarray(rm.apply_batched(q, use_kernel=False))
    np.testing.assert_array_equal(yb, yb_ref)
    for j in range(q.shape[1]):
        yj = np.asarray(rm.apply(q[:, j], use_kernel=True, interpret=True))
        tol = 1e-5 if dtype == jnp.float32 else 1e-12
        np.testing.assert_allclose(yb[:, j], yj, rtol=tol, atol=tol)


@pytest.mark.parametrize("backend", [
    "xla", pytest.param("pallas", marks=pytest.mark.slow)])
def test_batched_pcg_matches_singles_iteration_for_iteration(backend):
    """Acceptance: every RHS of a batched solve converges, with the same
    per-RHS iteration count as B independent single-RHS solves."""
    a = laplace_2d(16, 14)
    rng = np.random.default_rng(5)
    B = 6
    bb = rng.normal(size=(a.shape[0], B))
    bb[:, 2] *= 1e3          # scale spread exercises per-RHS masking
    bb[:, 4] *= 1e-3
    rb = solve_iccg_batched(a, bb, method="hbmc", block_size=8, w=4,
                            backend=backend)
    assert rb.result.converged.all()
    singles = [solve_iccg(a, bb[:, j], method="hbmc", block_size=8, w=4,
                          backend=backend).result.iterations
               for j in range(B)]
    np.testing.assert_array_equal(rb.result.iterations, singles)
    # masking means the loop ran exactly max(iterations) steps
    assert rb.result.n_steps == max(singles)
    for j in range(B):
        err = (np.linalg.norm(a @ rb.x[:, j] - bb[:, j])
               / np.linalg.norm(bb[:, j]))
        assert err < 1e-6, (j, err)


def test_batched_pcg_zero_rhs_column():
    """An all-zero RHS column must converge instantly (0 iterations) and
    not poison the other columns."""
    a = laplace_2d(10, 10)
    bb = np.random.default_rng(6).normal(size=(a.shape[0], 3))
    bb[:, 1] = 0.0
    rb = solve_iccg_batched(a, bb, method="hbmc", block_size=4, w=4)
    assert rb.result.converged.all()
    assert rb.result.iterations[1] == 0
    np.testing.assert_array_equal(rb.x[:, 1], 0.0)
    assert rb.result.iterations[0] > 0 and rb.result.iterations[2] > 0


def test_pcg_batched_direct_api():
    """pcg_batched with hand-built operators (no solver front-end)."""
    a = laplace_2d(9, 9)
    hb, l, (fwd_h, bwd_h) = _hbmc_tables(a, bs=4, w=2)
    a_hb, _ = pad_system_hbmc(a, None, hb)
    pre = build_preconditioner(l, hb)
    cols_h, vals_h = pack_ell(a_hb)
    vals, cols = jnp.asarray(vals_h), jnp.asarray(cols_h)
    bb = np.zeros((hb.n_final, 2))
    src = np.random.default_rng(7).normal(size=(a.shape[0], 2))
    bb[hb.perm] = src
    res = pcg_batched(lambda x: spmv_ell_batched(vals, cols, x),
                      pre.apply_batched, jnp.asarray(bb))
    assert res.converged.all()
    x = res.x[hb.perm]
    for j in range(2):
        err = (np.linalg.norm(a @ x[:, j] - src[:, j])
               / np.linalg.norm(src[:, j]))
        assert err < 1e-6
