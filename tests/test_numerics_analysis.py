"""Numerics & performance contract tier: dtype flow, collectives, traffic.

Mirrors ``src/repro/analysis``'s PR-9 analyzers with mutation evidence:

  1. **dtype flow** — every lowering path of a plan proves its precision
     contract clean, and an injected silent demotion / wrong-accumulator /
     stray dtype is pinned to the exact jaxpr eqn;
  2. **collectives** — the structural proof accepts the one-tiled-gather-
     per-round sweep shape and pins every doctored HLO mutation (extra
     gather, forbidden all-reduce, wrong trip count, untiled gather) to
     the exact op; single-device plans lower collective-free;
  3. **traffic** — the static bytes-per-iteration model matches the
     HLO-measured slice bytes within tolerance, and an inflated table
     term is witnessed by name;
  4. **bench gate** — every committed ``BENCH_*.json`` self-gates clean,
     and a doctored snapshot fails naming the exact metric path.
"""
import copy
import dataclasses
import json
import os
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import pytest

from repro.analysis import (VALIDATE_MODES, PrecisionContract, ScheduleError,
                            bench_gate, check_collective_structure,
                            check_plan_collectives, check_plan_dtype_flow,
                            check_plan_traffic, collective_bodies,
                            compare_traffic, contract_for_plan,
                            lint_dtype_flow, traffic_report, validate_plan)
from repro.analysis.__main__ import main as analysis_main
from repro.core import build_plan
from repro.core.matrices import laplace_2d
from repro.serve.solver import PlanCache

REPO = Path(__file__).resolve().parents[1]
BENCH_DIR = REPO / "benchmarks"


# ---------------------------------------------------------------------------
# 1. Dtype flow: clean paths prove clean, injected defects are pinned.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ("hbmc", "natural"))
def test_plan_dtype_flow_proves_clean(method):
    plan = build_plan(laplace_2d(13, 11), method=method, validate="off")
    assert check_plan_dtype_flow(plan) == []


def test_f32_plan_dtype_flow_clean():
    """Weak-typed literal normalization (f64 python floats entering an f32
    plan) is the legitimate jax idiom, not a silent demotion."""
    plan = build_plan(laplace_2d(13, 11), method="hbmc",
                      dtype=jnp.float32, validate="off")
    assert contract_for_plan(plan).vector == "float32"
    assert check_plan_dtype_flow(plan) == []


def test_pallas_plan_dtype_flow_clean():
    plan = build_plan(laplace_2d(10, 8), method="hbmc", block_size=8, w=4,
                      spmv_format="sell", backend="pallas",
                      spmv_backend="pallas", interpret=True, validate="off")
    assert check_plan_dtype_flow(plan) == []


def test_injected_demotion_pinned_to_exact_eqn():
    plan = build_plan(laplace_2d(13, 11), method="hbmc", validate="off")
    contract = contract_for_plan(plan)
    pre = plan._precond
    leaky = lambda q: pre(q.astype(jnp.float32).astype(jnp.float64))  # noqa: E731
    q = jnp.zeros((plan.slab_m,), dtype=plan.dtype)
    vio = lint_dtype_flow(leaky, q, contract=contract, where="mutated")
    demo = [v for v in vio if v.kind == "silent-demotion"]
    assert demo, [str(v) for v in vio]
    # the witness names the offending eqn and the exact dtype pair
    assert "convert_element_type#" in demo[0].detail
    assert "float64 -> float32" in demo[0].detail
    # the round trip back up is a (distinct) silent promotion
    assert any(v.kind == "silent-promotion" for v in vio)


def test_allowlisted_convert_passes():
    """A future mixed-precision plan lands behind this allowlist: the same
    convert pair stops being a witness once the contract names it."""
    plan = build_plan(laplace_2d(13, 11), method="hbmc", validate="off")
    contract = dataclasses.replace(
        contract_for_plan(plan),
        allowed_converts=(("float64", "float32"), ("float32", "float64")))
    pre = plan._precond
    leaky = lambda q: pre(q.astype(jnp.float32).astype(jnp.float64))  # noqa: E731
    q = jnp.zeros((plan.slab_m,), dtype=plan.dtype)
    assert lint_dtype_flow(leaky, q, contract=contract, where="allow") == []


def test_wrong_accumulator_dtype_is_witnessed():
    contract = PrecisionContract(name="f64-accum", vector="float64",
                                 accum="float64", tables="float64")
    x = jnp.zeros((8,), jnp.float32)
    vio = lint_dtype_flow(lambda v: jnp.dot(v, v), x, contract=contract,
                          where="dot")
    assert any(v.kind == "accum-dtype" and "dot" in v.detail
               for v in vio), [str(v) for v in vio]


def test_stray_dtype_is_witnessed():
    contract = PrecisionContract(name="f64-only", vector="float64",
                                 accum="float64", tables="float64")
    x = jnp.zeros((8,), jnp.float16)
    vio = lint_dtype_flow(jnp.sin, x, contract=contract, where="stray")
    assert any(v.kind == "stray-dtype" and "float16" in v.detail
               for v in vio), [str(v) for v in vio]


def test_validate_deep_gates_build_and_cache():
    assert "deep" in VALIDATE_MODES
    a = laplace_2d(9, 8)
    plan = build_plan(a, method="hbmc", validate="deep")
    assert plan.validate == "deep"
    assert validate_plan(plan, "deep") == []
    cache = PlanCache(capacity=1, validate="deep")
    _, status = cache.get(a, method="hbmc")
    assert status == "miss" and len(cache) == 1


# ---------------------------------------------------------------------------
# 2. Collective structure: synthetic-HLO mutations pinned, plans proven.
# ---------------------------------------------------------------------------

# the sweep shape the linter must accept: one while body, trip 2S, one
# tiled all-gather (4 participants: f64[2] operand -> f64[8] result)
GOOD_HLO = """\
HloModule sweep_test

%cond (carg: (f64[8])) -> pred[] {
  %ca = (f64[8]{0}) parameter(0)
  ROOT %lt = pred[] constant(false)
}

%loop_body (barg: (f64[8])) -> (f64[8]) {
  %ba = (f64[8]{0}) parameter(0)
  %x = f64[8]{0} get-tuple-element(%ba), index=0
  %src = f64[2]{0} dynamic-slice(%x, %x), dynamic_slice_sizes={2}
  %ag = f64[8]{0} all-gather(%src), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %r = (f64[8]{0}) tuple(%ag)
}

ENTRY %main (p: f64[8]) -> f64[8] {
  %p1 = f64[8]{0} parameter(0)
  %t = (f64[8]{0}) tuple(%p1)
  %w = (f64[8]{0}) while(%t), condition=%cond, body=%loop_body, backend_config={"known_trip_count":{"n":"6"}}
  ROOT %out = f64[8]{0} get-tuple-element(%w), index=0
}
"""

EXTRA_GATHER_LINE = ("  %ag2 = f64[8]{0} all-gather(%src), "
                     "replica_groups={{0,1,2,3}}, dimensions={0}\n")


def test_good_sweep_structure_is_accepted():
    assert check_collective_structure(GOOD_HLO, n_rounds=3) == []
    bodies, counts = collective_bodies(GOOD_HLO)
    assert counts == {"all-gather": 1}
    assert len(bodies) == 1
    assert bodies[0].comp == "loop_body" and bodies[0].trip == 6


def test_extra_gather_per_round_is_pinned():
    text = GOOD_HLO.replace("  ROOT %r =", EXTRA_GATHER_LINE + "  ROOT %r =")
    vio = check_collective_structure(text, n_rounds=3)
    extra = [v for v in vio if v.kind == "extra-collective"]
    assert extra, [str(v) for v in vio]
    assert "loop_body" in extra[0].detail and "ag2" in extra[0].detail


def test_forbidden_all_reduce_is_pinned():
    text = GOOD_HLO.replace("all-gather", "all-reduce")
    vio = check_collective_structure(text, n_rounds=3)
    kinds = {v.kind for v in vio}
    assert "forbidden-collective" in kinds, [str(v) for v in vio]
    # with its gather gone, the sweep also lost its per-round exchange
    assert "missing-collective" in kinds


def test_wrong_trip_count_is_pinned():
    text = GOOD_HLO.replace('"n":"6"', '"n":"4"')
    vio = check_collective_structure(text, n_rounds=3)
    assert any(v.kind == "trip-count-mismatch" and v.round == 4
               and "2S = 6" in v.detail for v in vio), [str(v) for v in vio]


def test_untiled_gather_is_pinned():
    # result grows to f64[16] = 128 B, but 4 participants x 16 B = 64 B
    text = GOOD_HLO.replace("%ag = f64[8]{0} all-gather",
                            "%ag = f64[16]{0} all-gather")
    vio = check_collective_structure(text)
    assert any(v.kind == "untiled-all-gather" and "ag" in v.detail
               for v in vio), [str(v) for v in vio]


def test_single_device_plan_lowers_collective_free():
    plan = build_plan(laplace_2d(13, 11), method="hbmc", validate="off")
    assert check_plan_collectives(plan) == []


def test_mesh_plan_collective_proof_subprocess():
    """The full mesh proof (one tiled all-gather per round, 2S trips, no
    reductions) needs >1 device, so it runs in a forced-host-device
    subprocess — the same configuration the CI analysis job uses."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=str(REPO / "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--problems", "laplace2d",
         "--methods", "hbmc", "--collectives"],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "all 1 audits clean" in out.stdout


# ---------------------------------------------------------------------------
# 3. Traffic model: static == measured, inflation witnessed by term.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spmv_format", ("ell", "sell"))
def test_traffic_static_matches_measured(spmv_format):
    plan = build_plan(laplace_2d(13, 11), method="hbmc",
                      spmv_format=spmv_format, validate="off")
    rep = traffic_report(plan)
    by_name = {t.name: t for t in rep.terms}
    for name in ("apply", "spmv/gather"):
        term = by_name[name]
        assert term.measured_bytes is not None
        assert term.relative_error < 0.01, (name, term)
    assert check_plan_traffic(plan) == []
    assert rep.iteration_bytes > 0 and rep.arithmetic_intensity > 0


def test_traffic_inflation_is_pinned_to_term():
    plan = build_plan(laplace_2d(13, 11), method="hbmc", validate="off")
    rep = traffic_report(plan)
    doctored = tuple(
        dataclasses.replace(t, static_bytes=t.static_bytes * 1.3)
        if t.name == "apply" else t for t in rep.terms)
    vio = compare_traffic(doctored)
    assert [v.kind for v in vio] == ["traffic-model-mismatch"]
    assert "term apply" in vio[0].detail, vio[0].detail


def test_traffic_requires_round_major():
    plan = build_plan(laplace_2d(9, 8), method="mc", layout="index",
                      validate="off")
    with pytest.raises(ValueError, match="round_major"):
        traffic_report(plan)


# ---------------------------------------------------------------------------
# 4. Bench gate: committed snapshots self-gate, doctored ones fail.
# ---------------------------------------------------------------------------

def _snapshot(name="BENCH_trisolve.json"):
    return json.loads((BENCH_DIR / name).read_text())


def test_bench_gate_self_passes_on_every_snapshot():
    snaps = sorted(BENCH_DIR.glob("BENCH_*.json"))
    assert snaps, "no committed bench snapshots found"
    for path in snaps:
        doc = json.loads(path.read_text())
        assert bench_gate(doc, doc) == [], path.name


def test_bench_gate_catches_doctored_regression():
    base = _snapshot()
    cand = copy.deepcopy(base)
    rec = cand["results"][0]
    rec["apply_us"] *= 3.0
    vio = bench_gate(base, cand)
    assert len(vio) == 1 and vio[0].kind == "perf-regression"
    # the witness names the exact metric path, id keys included
    assert "apply_us" in vio[0].detail
    assert str(rec["problem"]) in vio[0].detail


def test_bench_gate_catches_iteration_growth():
    base = _snapshot()
    cand = copy.deepcopy(base)
    cand["results"][0]["iterations"] += 10
    vio = bench_gate(base, cand)
    assert any(v.kind == "iteration-regression" and "iterations" in v.detail
               for v in vio), [str(v) for v in vio]


def test_bench_gate_schema_drift_is_a_failure():
    base = _snapshot()
    cand = copy.deepcopy(base)
    del cand["results"][0]["solve_us"]
    vio = bench_gate(base, cand)
    assert any(v.kind == "missing-metric" and "solve_us" in v.detail
               for v in vio)


def test_bench_gate_throughput_direction():
    base = {"schema": "t/v1", "rhs_per_s": 100.0}
    assert bench_gate(base, {"schema": "t/v1", "rhs_per_s": 90.0}) == []
    vio = bench_gate(base, {"schema": "t/v1", "rhs_per_s": 50.0})
    assert vio and vio[0].kind == "perf-regression"


def test_bench_gate_refuses_vacuous_pass():
    vio = bench_gate({"foo": 1}, {"foo": 1})
    assert vio and vio[0].kind == "no-metrics"


def test_bench_gate_cli_smoke_and_doctored(tmp_path, capsys):
    rc = analysis_main(["bench-gate", "--smoke",
                        "--baseline-dir", str(BENCH_DIR)])
    out = capsys.readouterr().out
    assert rc == 0 and "gate(s) passed" in out

    cand = _snapshot()
    cand["results"][0]["apply_us"] *= 3.0
    cpath = tmp_path / "cand.json"
    cpath.write_text(json.dumps(cand))
    wpath = tmp_path / "witness.json"
    rc = analysis_main(["bench-gate", "--baseline-dir", str(BENCH_DIR),
                        "--candidate", str(cpath),
                        "--witness-json", str(wpath)])
    capsys.readouterr()
    assert rc == 1
    witnesses = json.loads(wpath.read_text())
    assert any("apply_us" in w["detail"] for w in witnesses)


def test_audit_cli_runs_new_linters(capsys):
    rc = analysis_main(["--problems", "laplace2d", "--methods", "hbmc",
                        "--validate", "deep", "--dtype-flow", "--traffic"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "all 1 audits clean" in out


def test_deep_admission_rejects_contract_breaker():
    """A plan whose precision contract cannot hold (its own dtype absent
    from the allowed set) is refused at deep validation with dtype-flow
    witnesses — the same path PlanCache admission takes."""
    plan = build_plan(laplace_2d(9, 8), method="hbmc", validate="off")
    bad = PrecisionContract(name="impossible", vector="float32",
                            accum="float32", tables="float32")
    vio = check_plan_dtype_flow(plan, contract=bad)
    assert vio and all(v.kind in ("stray-dtype", "accum-dtype",
                                  "silent-demotion", "silent-promotion")
                       for v in vio)
    with pytest.raises(ScheduleError):
        from repro.analysis import assert_plan_dtype_flow
        assert_plan_dtype_flow(plan, contract=bad, context="impossible")
