"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step on CPU, asserting shapes and finiteness (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import forward, init_params
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.step import train_step

B, S = 2, 16


def _inputs(cfg, key, b=B, s=S):
    if cfg.takes_embeddings:
        return jax.random.normal(key, (b, s, cfg.d_model), jnp.float32) * 0.3
    return jax.random.randint(key, (b, s), 0, cfg.vocab)


def _positions(cfg, b=B, s=S):
    if cfg.m_rope:
        return jnp.broadcast_to(jnp.arange(s)[None, None], (3, b, s))
    return jnp.broadcast_to(jnp.arange(s)[None], (b, s))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    x = _inputs(cfg, jax.random.PRNGKey(1))
    logits, _, aux = forward(params, cfg, x, _positions(cfg))
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.slow
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    opt = init_opt_state(params)
    batch = {"inputs": _inputs(cfg, jax.random.PRNGKey(1)),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                          cfg.vocab)}
    params2, opt2, metrics = train_step(
        params, opt, batch, cfg=cfg,
        opt_cfg=AdamWConfig(lr=1e-3, total_steps=10, warmup_steps=1))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, params2))
    assert max(moved) > 0


def test_microbatched_grad_accum_matches_full():
    cfg = get_smoke_config("qwen3-14b")
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = {"inputs": _inputs(cfg, jax.random.PRNGKey(1), b=4),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (4, S), 0,
                                          cfg.vocab)}
    ocfg = AdamWConfig(lr=1e-3, total_steps=10, warmup_steps=1)
    p1, _, m1 = train_step(params, init_opt_state(params), batch,
                           cfg=cfg, opt_cfg=ocfg, microbatches=1)
    p2, _, m2 = train_step(params, init_opt_state(params), batch,
                           cfg=cfg, opt_cfg=ocfg, microbatches=4)
    # loss identical; updates match to accumulation tolerance
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-5)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)
    assert max(jax.tree.leaves(d)) < 2e-5


@pytest.mark.slow
def test_overfit_tiny_batch():
    """The stack can actually learn: loss drops by >30% in 30 steps."""
    cfg = get_smoke_config("qwen2.5-3b")
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    opt = init_opt_state(params)
    ocfg = AdamWConfig(lr=5e-3, total_steps=30, warmup_steps=2)
    batch = {"inputs": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                          0, cfg.vocab)}
    batch["labels"] = batch["inputs"]
    losses = []
    for _ in range(30):
        params, opt, m = train_step(params, opt, batch, cfg=cfg,
                                    opt_cfg=ocfg)
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.7 * losses[0], losses[::6]


def test_full_configs_match_published_param_counts():
    expected = {
        "olmoe-1b-7b": 6.9e9, "mixtral-8x22b": 141e9,
        "recurrentgemma-2b": 2.5e9, "stablelm-12b": 12.1e9,
        "qwen3-14b": 14.8e9, "llama3-405b": 405e9, "qwen2.5-3b": 3.4e9,
        "qwen2-vl-72b": 72.7e9, "musicgen-medium": 1.4e9,
        "mamba2-130m": 0.13e9,
    }
    for arch, target in expected.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < 0.15, (arch, n, target)
