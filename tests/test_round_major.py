"""Round-major-native hot loop: layout contract, oracles, zero permutations.

The tentpole claims, each pinned by a test here:
  1. the fused fwd/bwd solve matches the sequential scipy oracle for every
     ordering x dtype x single/batched combination;
  2. the round-major-native PCG loop reproduces the index-space path's
     iteration counts one for one (round-major is an equivalent reordering);
  3. the per-iteration apply performs ZERO full-vector permutations — no
     scatter primitive appears in the jaxpr of the native preconditioner or
     SpMV, while the index-space path's jaxpr does scatter.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp

from repro.analysis import ROUND_MAJOR_APPLY, lint, primitives
from repro.core import (build_preconditioner_from_rounds,
                        build_round_major_preconditioner_from_rounds,
                        fuse_round_major, ic0, pack_ell, pack_factor,
                        permute_round_major, solve_iccg,
                        solve_iccg_batched, spmv_ell)
from repro.core.ic0 import sequential_ic_solve
from repro.core.matrices import laplace_2d
from repro.core.solvers import _order_system
from repro.kernels.config import default_interpret

ORDERINGS = ("mc", "bmc", "hbmc", "natural")


def _native_system(method, nx=13, ny=11, bs=8, w=4):
    """Ordered+padded system, factor, fused preconditioner inputs."""
    a = laplace_2d(nx, ny)
    sysd = _order_system(sp.csr_matrix(a), None, method, bs, w)
    l_bar = ic0(sysd.a_bar)
    return a, sysd, l_bar


# ---------------------------------------------------------------------------
# 1. Fused solve vs the sequential scipy oracle:
#    orderings x {f32, f64} x {single, batched}.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ORDERINGS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("batched", [False, True], ids=["single", "batched"])
def test_fused_matches_sequential_oracle(method, dtype, batched):
    a, sysd, l_bar = _native_system(method)
    pre, lay = build_round_major_preconditioner_from_rounds(
        l_bar, sysd.fwd_rounds, sysd.bwd_rounds, drop_mask=sysd.drop,
        dtype=dtype, backend="xla")
    rng = np.random.default_rng(0)
    shape = (sysd.n_padded, 3) if batched else (sysd.n_padded,)
    r = rng.normal(size=shape)
    if sysd.drop is not None:
        r[sysd.drop] = 0.0
    apply_fn = pre.apply_batched if batched else pre
    q = jnp.asarray(lay.embed(r.astype(np.dtype(jnp.dtype(dtype)))))
    z = lay.extract(np.asarray(apply_fn(q))).astype(np.float64)
    live = ~sysd.drop if sysd.drop is not None else np.ones(sysd.n_padded,
                                                           bool)
    tol = 2e-4 if dtype == jnp.float32 else 1e-11
    cols = range(r.shape[1]) if batched else [None]
    for j in cols:
        rj = r[:, j] if j is not None else r
        zj = z[:, j] if j is not None else z
        z_ref = sequential_ic_solve(l_bar, rj)
        np.testing.assert_allclose(zj[live], z_ref[live], rtol=tol, atol=tol)


@pytest.mark.parametrize("method", ORDERINGS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_fused_pallas_kernel_matches_oracle_bitwise(method, dtype):
    """The Pallas fused kernel agrees with its jnp oracle bit for bit, and
    with the sequential oracle to dtype tolerance."""
    from repro.core.trisolve import DeviceFusedTables
    from repro.kernels.hbmc_trisolve import hbmc_trisolve_fused
    from repro.kernels.ref import hbmc_trisolve_fused_ref
    a, sysd, l_bar = _native_system(method)
    fwd_h, bwd_h = pack_factor(l_bar, sysd.fwd_rounds, sysd.bwd_rounds,
                               sysd.drop)
    fused = fuse_round_major(fwd_h, bwd_h)
    t = DeviceFusedTables.from_host(fused, dtype=dtype)
    r = np.random.default_rng(1).normal(size=sysd.n_padded)
    if sysd.drop is not None:
        r[sysd.drop] = 0.0
    lay = fused.layout
    q = jnp.asarray(lay.embed(r), dtype=dtype).reshape(lay.n_steps, lay.lanes)
    z_k = np.asarray(hbmc_trisolve_fused(t.cols, t.vals, t.dinv, q,
                                         interpret=True))
    z_r = np.asarray(hbmc_trisolve_fused_ref(t.cols, t.vals, t.dinv, q))
    np.testing.assert_array_equal(z_k, z_r)
    z = lay.extract(z_k).astype(np.float64)
    z_ref = sequential_ic_solve(l_bar, r)
    live = ~sysd.drop if sysd.drop is not None else np.ones(sysd.n_padded,
                                                           bool)
    tol = 2e-4 if dtype == jnp.float32 else 1e-11
    np.testing.assert_allclose(z[live], z_ref[live], rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# 2. Native loop == index-space loop, iteration for iteration.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ORDERINGS)
@pytest.mark.parametrize("backend", [
    "xla", pytest.param("pallas", marks=pytest.mark.slow)])
def test_native_iteration_counts_match_index_layout(method, backend):
    """Acceptance: the fused round-major-native solve reproduces the
    pre-refactor (two-call, per-apply-permutation) path's PCG iteration
    counts exactly."""
    a = laplace_2d(14, 12)
    b = np.random.default_rng(2).normal(size=a.shape[0])
    r_new = solve_iccg(a, b, method=method, block_size=8, w=4,
                       backend=backend, layout="round_major")
    r_old = solve_iccg(a, b, method=method, block_size=8, w=4,
                       backend=backend, layout="index")
    assert r_new.result.iterations == r_old.result.iterations
    assert r_new.result.converged
    np.testing.assert_allclose(r_new.x, r_old.x, rtol=1e-9, atol=1e-9)


def test_native_batched_matches_singles():
    a = laplace_2d(12, 12)
    bb = np.random.default_rng(3).normal(size=(a.shape[0], 4))
    rb = solve_iccg_batched(a, bb, method="hbmc", block_size=8, w=4)
    assert rb.layout == "round_major"
    assert rb.result.converged.all()
    singles = [solve_iccg(a, bb[:, j], method="hbmc", block_size=8,
                          w=4).result.iterations for j in range(4)]
    np.testing.assert_array_equal(rb.result.iterations, singles)


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 1e-5),
                                        (jnp.float64, 1e-7)])
def test_dtype_end_to_end(dtype, rtol):
    """f32 stays f32 from the host conversion onward (no f64 intermediate)."""
    a = laplace_2d(12, 10)
    b = np.random.default_rng(4).normal(size=a.shape[0])
    rep = solve_iccg(a, b, method="hbmc", block_size=8, w=4, dtype=dtype,
                     rtol=rtol)
    assert rep.result.converged
    assert rep.x.dtype == np.dtype(jnp.dtype(dtype))
    err = np.linalg.norm(a @ rep.x - b) / np.linalg.norm(b)
    assert err < 10 * rtol
    bb = np.stack([b, 2.0 * b], axis=1)
    rep_b = solve_iccg_batched(a, bb, method="hbmc", block_size=8, w=4,
                               dtype=dtype, rtol=rtol)
    assert rep_b.result.converged.all()
    assert rep_b.x.dtype == np.dtype(jnp.dtype(dtype))


def test_unknown_layout_rejected():
    a = laplace_2d(8, 8)
    b = np.ones(a.shape[0])
    with pytest.raises(ValueError, match="layout"):
        solve_iccg(a, b, method="hbmc", block_size=4, w=2, layout="banana")


# ---------------------------------------------------------------------------
# 3. Zero full-vector permutations in the hot loop.
# ---------------------------------------------------------------------------

def test_native_apply_has_no_scatter():
    """Layout contract, enforced on the jaxpr: the index-space apply
    scatters (y.at[rows].set per round, plus the solution scatter-back);
    the native apply's stores are dynamic_update_slice only."""
    a, sysd, l_bar = _native_system("hbmc")
    pre_rm, lay = build_round_major_preconditioner_from_rounds(
        l_bar, sysd.fwd_rounds, sysd.bwd_rounds, drop_mask=sysd.drop)
    pre_ix = build_preconditioner_from_rounds(
        l_bar, sysd.fwd_rounds, sysd.bwd_rounds, drop_mask=sysd.drop)
    r_rm = jnp.zeros((lay.m,))
    r_ix = jnp.zeros((sysd.n_padded,))
    assert lint(pre_rm, r_rm, budget=ROUND_MAJOR_APPLY) == []
    prims_ix = primitives(pre_ix, r_ix)
    assert any("scatter" in p for p in prims_ix)
    assert "dynamic_update_slice" in primitives(pre_rm, r_rm)
    # batched applies obey the same contract
    assert lint(pre_rm.apply_batched, jnp.zeros((lay.m, 3)),
                budget=ROUND_MAJOR_APPLY) == []


def test_native_spmv_has_no_scatter():
    a, sysd, l_bar = _native_system("hbmc")
    lay = fuse_round_major(*pack_factor(l_bar, sysd.fwd_rounds,
                                        sysd.bwd_rounds, sysd.drop)).layout
    a_rm = permute_round_major(sysd.a_bar, lay)
    cols_h, vals_h = pack_ell(a_rm)
    vals, cols = jnp.asarray(vals_h), jnp.asarray(cols_h)
    assert lint(lambda x: spmv_ell(vals, cols, x), jnp.zeros((lay.m,)),
                budget=ROUND_MAJOR_APPLY) == []


# ---------------------------------------------------------------------------
# Layout / packing invariants.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ORDERINGS)
def test_fused_layout_contract(method):
    a, sysd, l_bar = _native_system(method)
    fwd_h, bwd_h = pack_factor(l_bar, sysd.fwd_rounds, sysd.bwd_rounds,
                               sysd.drop)
    fused = fuse_round_major(fwd_h, bwd_h)
    lay = fused.layout
    s_, r_ = lay.n_steps, lay.lanes
    assert fused.cols.shape[0] == 2 * s_
    # every live unknown has exactly one round-major position, and
    # embed/extract invert each other on live unknowns
    flat = lay.rows.reshape(-1)
    live = flat != lay.n_slots - 1
    assert len(np.unique(flat[live])) == live.sum()
    v = np.random.default_rng(5).normal(size=lay.n_slots - 1)
    if sysd.drop is not None:
        v[sysd.drop] = 0.0
    np.testing.assert_array_equal(lay.extract(lay.embed(v)), v)
    # forward half gathers strictly below the destination slice, backward
    # half strictly above (triangular in execution order)
    pos = np.arange(s_ * r_).reshape(s_, r_)
    k = fused.cols.shape[-1]
    dest = np.concatenate([pos, pos[::-1]])[:, :, None].repeat(k, axis=-1)
    nz = fused.vals != 0.0
    fwd_nz = nz[:s_]
    bwd_nz = nz[s_:]
    assert (fused.cols[:s_][fwd_nz] < dest[:s_][fwd_nz]).all()
    assert (fused.cols[s_:][bwd_nz] > dest[s_:][bwd_nz]).all()


def test_fuse_rejects_mismatched_rounds():
    a, sysd, l_bar = _native_system("hbmc")
    fwd_h, bwd_h = pack_factor(l_bar, sysd.fwd_rounds, sysd.bwd_rounds,
                               sysd.drop)
    with pytest.raises(ValueError, match="reversed"):
        fuse_round_major(fwd_h, fwd_h)


def test_default_interpret_tracks_backend():
    assert default_interpret() == (jax.default_backend() != "tpu")
