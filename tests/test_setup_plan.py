"""Round-parallel setup pipeline + SolverPlan (factor once, solve many).

Pins the tentpole claims:
  1. ``ic0_rounds`` matches the sequential ``ic0`` (tight tolerance) across
     mc/bmc/hbmc/natural x two generators, with unchanged PCG iterations;
  2. vectorized ``pack_steps``/``pack_ell``/``pack_sell`` reproduce the
     per-row reference packing exactly;
  3. plan reuse is bitwise-identical to ``solve_iccg``, and a warm
     ``plan.solve`` performs ZERO host-side setup (asserted by making every
     setup entry point explode);
  4. ``refactor`` on perturbed values matches a cold solve;
and the satellite bugfixes: ``result.x`` lives in the caller's space
(padded-state leak regression), shifted-IC semantics on the Ieej generator,
and batched ``record_history`` parity.
"""
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import (build_plan, ic0, ic0_refactor, ic0_rounds,
                        ic0_structure, solve_iccg, solve_iccg_batched)
from repro.core import plan as plan_mod
from repro.core import sell
from repro.core.matrices import (PAPER_SHIFTS, graph_laplacian, laplace_2d,
                                 paper_problem)
from repro.core.solvers import _order_system

ORDERINGS = ("mc", "bmc", "hbmc", "natural")
GENERATORS = [
    ("lap2d", lambda: laplace_2d(13, 11)),
    ("graph", lambda: graph_laplacian(300, avg_degree=5, seed=2)),
]


# ---------------------------------------------------------------------------
# 1. Round-parallel IC(0) == sequential IC(0).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ORDERINGS)
@pytest.mark.parametrize("gen_name,gen", GENERATORS, ids=[g[0] for g in
                                                          GENERATORS])
def test_ic0_rounds_matches_sequential(gen_name, gen, method):
    a = gen()
    sysd = _order_system(sp.csr_matrix(a), None, method, 8, 4)
    l_seq = ic0(sysd.a_bar)
    l_rnd = ic0_rounds(sysd.a_bar, sysd.fwd_rounds)
    assert np.array_equal(l_seq.indptr, l_rnd.indptr)
    assert np.array_equal(l_seq.indices, l_rnd.indices)
    # bitwise: the pair accumulation order reproduces the sequential merge
    np.testing.assert_array_equal(l_rnd.data, l_seq.data)


@pytest.mark.parametrize("method", ORDERINGS)
def test_ic0_rounds_unchanged_pcg_iterations(method):
    """The plan path (ic0_rounds) reproduces the paper iteration counts —
    here cross-checked against a solve over the sequential factor."""
    from repro.core.iccg import pcg
    from repro.core.trisolve import \
        build_round_major_preconditioner_from_rounds
    a = laplace_2d(14, 12)
    b = np.random.default_rng(0).normal(size=a.shape[0])
    rep = solve_iccg(a, b, method=method, block_size=8, w=4)
    sysd = _order_system(sp.csr_matrix(a), b, method, 8, 4)
    pre, rm = build_round_major_preconditioner_from_rounds(
        ic0(sysd.a_bar), sysd.fwd_rounds, sysd.bwd_rounds,
        drop_mask=sysd.drop)
    a_rm = sell.permute_round_major(sysd.a_bar, rm)
    cols, vals = sell.pack_ell(a_rm)
    vals_d, cols_d = jnp.asarray(vals), jnp.asarray(cols)
    res = pcg(lambda x: jnp.einsum("rk,rk->r", vals_d, x[cols_d]), pre,
              jnp.asarray(rm.embed(sysd.b_bar)))
    assert rep.result.iterations == res.iterations
    assert rep.result.converged


def test_ic0_structure_rejects_bad_rounds():
    a = laplace_2d(8, 8)
    sysd = _order_system(sp.csr_matrix(a), None, "hbmc", 4, 2)
    with pytest.raises(ValueError, match="dependency-ordered"):
        # natural rounds reversed put every dependency in a LATER round
        n = sysd.n_padded
        ic0_structure(sysd.a_bar, [np.array([i]) for i in
                                   range(n - 1, -1, -1)])
    with pytest.raises(ValueError, match="partition"):
        ic0_structure(sysd.a_bar, sysd.fwd_rounds[:-1])


def test_ic0_refactor_rejects_pattern_change():
    a = laplace_2d(9, 7)
    sysd = _order_system(sp.csr_matrix(a), None, "mc", 4, 2)
    st = ic0_structure(sysd.a_bar, sysd.fwd_rounds)
    other = _order_system(sp.csr_matrix(laplace_2d(7, 9)), None, "mc", 4, 2)
    with pytest.raises(ValueError, match="pattern"):
        ic0_refactor(st, other.a_bar)


# ---------------------------------------------------------------------------
# 2. Vectorized packing == per-row reference packing.
# ---------------------------------------------------------------------------

def _pack_steps_reference(tri, diag, rounds, drop_mask=None):
    """The pre-vectorization per-row loop, kept as the packing oracle."""
    tri = sp.csr_matrix(tri)
    tri.sort_indices()
    n = tri.shape[0]
    n_slots = n + 1
    if drop_mask is not None:
        rounds = [r[~drop_mask[r]] for r in rounds]
        rounds = [r for r in rounds if len(r)]
    S = len(rounds)
    R = max(len(r) for r in rounds)
    K = max(int(np.diff(tri.indptr).max(initial=0)), 1)
    rows = np.full((S, R), n_slots - 1, dtype=np.int32)
    cols = np.full((S, R, K), n_slots - 1, dtype=np.int32)
    vals = np.zeros((S, R, K))
    dinv = np.zeros((S, R))
    live = np.zeros(S, dtype=np.int32)
    for s, rset in enumerate(rounds):
        live[s] = len(rset)
        rows[s, :len(rset)] = rset
        dinv[s, :len(rset)] = 1.0 / diag[rset]
        for t, r in enumerate(rset):
            lo, hi = tri.indptr[r], tri.indptr[r + 1]
            cols[s, t, :hi - lo] = tri.indices[lo:hi]
            vals[s, t, :hi - lo] = tri.data[lo:hi]
    return rows, cols, vals, dinv, live


@pytest.mark.parametrize("method", ORDERINGS)
def test_pack_steps_matches_reference(method):
    a = laplace_2d(11, 9)
    sysd = _order_system(sp.csr_matrix(a), None, method, 8, 4)
    l = ic0(sysd.a_bar)
    diag = l.diagonal()
    tri = sp.tril(l, k=-1, format="csr")
    got = sell.pack_steps(tri, diag, sysd.fwd_rounds, sysd.drop)
    rows, cols, vals, dinv, live = _pack_steps_reference(
        tri, diag, sysd.fwd_rounds, sysd.drop)
    np.testing.assert_array_equal(got.rows, rows)
    np.testing.assert_array_equal(got.cols, cols)
    np.testing.assert_array_equal(got.vals, vals)
    np.testing.assert_array_equal(got.dinv, dinv)
    np.testing.assert_array_equal(got.live, live)


def test_pack_ell_and_sell_match_reference():
    a = sp.csr_matrix(graph_laplacian(200, avg_degree=5, seed=3))
    a.sort_indices()
    cols, vals = sell.pack_ell(a)
    n, k = a.shape[0], cols.shape[1]
    cols_ref = np.zeros((n, k), dtype=np.int32)
    vals_ref = np.zeros((n, k))
    for r in range(n):
        lo, hi = a.indptr[r], a.indptr[r + 1]
        cols_ref[r, :hi - lo] = a.indices[lo:hi]
        vals_ref[r, :hi - lo] = a.data[lo:hi]
    np.testing.assert_array_equal(cols, cols_ref)
    np.testing.assert_array_equal(vals, vals_ref)

    w = 4
    sm = sell.pack_sell(a, w)
    for r in range(n):
        lo, hi = a.indptr[r], a.indptr[r + 1]
        s, lane = divmod(r, w)
        np.testing.assert_array_equal(sm.cols[s, :hi - lo, lane],
                                      a.indices[lo:hi])
        np.testing.assert_array_equal(sm.vals[s, :hi - lo, lane],
                                      a.data[lo:hi])
        assert not sm.vals[s, hi - lo:, lane].any()


# ---------------------------------------------------------------------------
# 3. Plan reuse: identical to solve_iccg, zero warm setup.
# ---------------------------------------------------------------------------

def test_plan_reuse_bitwise_identical_to_solve_iccg():
    a = laplace_2d(16, 14)
    b = np.random.default_rng(1).normal(size=a.shape[0])
    plan = build_plan(a, method="hbmc", block_size=8, w=4)
    cold = solve_iccg(a, b, method="hbmc", block_size=8, w=4)
    r1 = plan.solve(b)
    r2 = plan.solve(b)
    assert r1.result.iterations == cold.result.iterations
    assert r2.result.iterations == cold.result.iterations
    np.testing.assert_array_equal(r1.x, cold.x)
    np.testing.assert_array_equal(r1.x, r2.x)


def test_warm_plan_solve_performs_zero_host_setup(monkeypatch):
    """Acceptance: after the first solve, plan.solve touches NO setup entry
    point — ordering, factorization, packing and operator builds are all
    poisoned and the warm solve must still succeed, bitwise identically."""
    a = laplace_2d(12, 10)
    b = np.random.default_rng(2).normal(size=a.shape[0])
    plan = build_plan(a, method="hbmc", block_size=8, w=4)
    warm_ref = plan.solve(b)
    count = plan.setup_count

    def boom(*a_, **k_):
        raise AssertionError("host-side setup ran during a warm plan.solve")

    for name in ("_order_system", "ic0_structure", "ic0_refactor",
                 "_build_spmv_ops", "_pack_spmv", "_build_preconditioner"):
        monkeypatch.setattr(plan_mod, name, boom)
    monkeypatch.setattr(plan_mod.sell, "pack_steps", boom)
    monkeypatch.setattr(plan_mod.sell, "pack_factor", boom)
    monkeypatch.setattr(plan_mod.sell, "pack_ell", boom)
    monkeypatch.setattr(plan_mod.sell, "pack_sell", boom)
    monkeypatch.setattr(plan_mod.sell, "fuse_round_major", boom)

    warm = plan.solve(b)
    bb = np.stack([b, 0.5 * b], axis=1)
    warm_b = plan.solve_batched(bb)
    assert plan.setup_count == count
    np.testing.assert_array_equal(warm.x, warm_ref.x)
    assert warm_b.result.converged.all()


def test_plan_solve_batched_matches_front_end():
    a = laplace_2d(12, 12)
    bb = np.random.default_rng(3).normal(size=(a.shape[0], 3))
    plan = build_plan(a, method="hbmc", block_size=8, w=4)
    rp = plan.solve_batched(bb)
    rf = solve_iccg_batched(a, bb, method="hbmc", block_size=8, w=4)
    np.testing.assert_array_equal(rp.result.iterations,
                                  rf.result.iterations)
    np.testing.assert_array_equal(rp.x, rf.x)


# ---------------------------------------------------------------------------
# 4. Refactor: numeric-only renewal matches a cold solve.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ("hbmc", "mc"))
def test_refactor_matches_cold_solve(method):
    a = laplace_2d(14, 12)
    b = np.random.default_rng(4).normal(size=a.shape[0])
    plan = build_plan(a, method=method, block_size=8, w=4)
    plan.solve(b)
    # perturb values, keep the pattern (implicit-time-step-style change)
    a2 = (a + 0.37 * sp.diags(a.diagonal())).tocsr()
    a2.sort_indices()
    timings = plan.refactor(a2)
    assert timings.ordering == 0.0        # ordering is never redone
    warm = plan.solve(b)
    cold = solve_iccg(a2, b, method=method, block_size=8, w=4)
    assert warm.result.iterations == cold.result.iterations
    np.testing.assert_allclose(warm.x, cold.x, rtol=1e-12, atol=1e-12)
    assert plan.refactor_count == 1


def test_refactor_does_not_retrace_pcg():
    """The jitted PCG takes the factor/SpMV operands as traced arguments
    (round_major and index+xla paths), so a refactor swaps arrays of
    identical shape without recompiling anything."""
    a = laplace_2d(12, 10)
    b = np.random.default_rng(9).normal(size=a.shape[0])
    plan = build_plan(a, method="hbmc", block_size=8, w=4)
    plan.solve(b)
    assert plan._trace_count == 1
    plan.solve(b)
    assert plan._trace_count == 1          # warm solve: no retrace
    a2 = (a + 0.2 * sp.diags(a.diagonal())).tocsr()
    plan.refactor(a2)
    rep = plan.solve(b)
    assert plan._trace_count == 1          # refactor: still no retrace
    cold = solve_iccg(a2, b, method="hbmc", block_size=8, w=4)
    assert rep.result.iterations == cold.result.iterations
    np.testing.assert_allclose(rep.x, cold.x, rtol=1e-12, atol=1e-12)


def test_refactor_rejects_different_pattern():
    a = laplace_2d(10, 10)
    plan = build_plan(a, method="hbmc", block_size=8, w=4)
    with pytest.raises(ValueError, match="structure-identical"):
        plan.refactor(laplace_2d(11, 10))
    a_denser = (a + sp.diags(np.ones(a.shape[0] - 2), 2)).tocsr()
    with pytest.raises(ValueError, match="structure-identical"):
        plan.refactor(a_denser)


# ---------------------------------------------------------------------------
# Satellite: result.x padded-state-leak regression.
# ---------------------------------------------------------------------------

def test_result_x_in_caller_space_padded_round_major():
    """Regression: result.x used to leak the internal padded round-major
    vector (shape (3264,) on this n=2021 system)."""
    a = laplace_2d(47, 43)
    n = a.shape[0]
    b = np.random.default_rng(5).normal(size=n)
    rep = solve_iccg(a, b, method="hbmc", block_size=16, w=8)
    assert rep.n_padded > n                   # genuinely padded
    assert rep.result.x.shape == (n,)
    np.testing.assert_array_equal(rep.result.x, rep.x)
    err = np.linalg.norm(a @ rep.result.x - b) / np.linalg.norm(b)
    assert err < 1e-6

    bb = np.random.default_rng(6).normal(size=(n, 3))
    rb = solve_iccg_batched(a, bb, method="hbmc", block_size=16, w=8)
    assert rb.result.x.shape == (n, 3)
    np.testing.assert_array_equal(rb.result.x, rb.x)


# ---------------------------------------------------------------------------
# Satellite: shifted-IC semantics on the Ieej generator (paper §5.1).
# ---------------------------------------------------------------------------

def test_shifted_ic_semantics_ieej():
    """shift=alpha factorizes A + alpha*diag(A); equivalently the diagonally
    scaled formulation: L(D^{-1/2}(A + alpha D)D^{-1/2}) == D^{-1/2} L."""
    a, _ = paper_problem("ieej", "tiny")
    alpha = PAPER_SHIFTS["ieej"]
    sysd = _order_system(sp.csr_matrix(a), None, "hbmc", 8, 4)
    a_bar = sysd.a_bar

    l_shift = ic0(a_bar, shift=alpha)
    # 1. explicit shifted matrix, unshifted factorization -> same factor
    a_explicit = (a_bar + alpha * sp.diags(a_bar.diagonal())).tocsr()
    l_explicit = ic0(a_explicit)
    np.testing.assert_allclose(l_shift.toarray(), l_explicit.toarray(),
                               rtol=1e-14, atol=0.0)
    # 2. round-parallel path agrees
    l_rounds = ic0_rounds(a_bar, sysd.fwd_rounds, shift=alpha)
    np.testing.assert_allclose(l_rounds.toarray(), l_shift.toarray(),
                               rtol=1e-14, atol=0.0)
    # 3. diag-scaled equivalence from the docstring
    dinv_sqrt = sp.diags(1.0 / np.sqrt(a_bar.diagonal()))
    b_scaled = (dinv_sqrt @ a_explicit @ dinv_sqrt).tocsr()
    l_scaled = ic0(b_scaled)
    np.testing.assert_allclose(l_scaled.toarray(),
                               (dinv_sqrt @ l_shift).toarray(),
                               rtol=1e-10, atol=1e-12)
    # 4. the shifted solve converges on the semi-definite-ish system
    b = np.random.default_rng(7).normal(size=a.shape[0])
    rep = solve_iccg(a, b, method="hbmc", block_size=8, w=4, shift=alpha)
    assert rep.result.converged


# ---------------------------------------------------------------------------
# Satellite: batched record_history parity.
# ---------------------------------------------------------------------------

def test_batched_history_matches_singles():
    a = laplace_2d(13, 12)
    n = a.shape[0]
    bb = np.random.default_rng(8).normal(size=(n, 4))
    bb[:, 2] *= 1e3                       # spread the iteration counts
    kw = dict(method="hbmc", block_size=8, w=4)
    rb = solve_iccg_batched(a, bb, record_history=True, **kw)
    hist = rb.result.history
    assert hist.shape[1] == 4
    for j in range(4):
        single = solve_iccg(a, bb[:, j], record_history=True, **kw)
        hs = single.result.history
        hj = hist[:len(hs), j]
        # same NaN pattern: column j's history freezes at convergence
        np.testing.assert_array_equal(np.isnan(hj), np.isnan(hs))
        m = ~np.isnan(hs)
        np.testing.assert_allclose(hj[m], hs[m], rtol=1e-10)
        assert rb.result.iterations[j] == single.result.iterations


def test_batched_history_empty_by_default():
    a = laplace_2d(8, 8)
    bb = np.ones((a.shape[0], 2))
    rb = solve_iccg_batched(a, bb, method="hbmc", block_size=4, w=2)
    assert rb.result.history.size == 0
