"""Parallel GS/SOR smoother: correctness + ordering equivalence (the
paper's eq. 3.4 notion, for the GS case)."""
import numpy as np
import pytest

from repro.core import (block_multicolor_ordering, hbmc_from_bmc, pad_system,
                        pad_system_hbmc)
from repro.core.matrices import laplace_2d
from repro.core.sell import rounds_bmc, rounds_hbmc, rounds_natural
from repro.core.smoothers import build_gs_smoother, gs_solve


def test_natural_gs_matches_scipy_reference():
    a = laplace_2d(10, 10)
    n = a.shape[0]
    b = np.random.default_rng(0).normal(size=n)
    sm = build_gs_smoother(a, rounds_natural(n), rounds_natural(n, True))
    x = np.zeros(n)
    import jax.numpy as jnp
    x1 = np.asarray(sm.sweep(jnp.asarray(b), jnp.asarray(x)))
    # hand-rolled sequential GS sweep
    ad = a.toarray()
    xr = x.copy()
    for i in range(n):
        xr[i] = (b[i] - ad[i] @ xr + ad[i, i] * xr[i]) / ad[i, i]
    np.testing.assert_allclose(x1, xr, rtol=1e-12, atol=1e-12)


@pytest.mark.slow
def test_gs_converges_and_bmc_hbmc_equivalent():
    a = laplace_2d(16, 12)
    b = np.random.default_rng(1).normal(size=a.shape[0])
    bmc = block_multicolor_ordering(a, 6)
    hb = hbmc_from_bmc(bmc, 3)
    a_bmc, b_bmc = pad_system(a, b, bmc)
    a_hb, b_hb = pad_system_hbmc(a, b, hb)

    sm_b = build_gs_smoother(a_bmc, rounds_bmc(bmc), rounds_bmc(bmc, True),
                             drop_mask=bmc.is_dummy)
    sm_h = build_gs_smoother(a_hb, rounds_hbmc(hb), rounds_hbmc(hb, True),
                             drop_mask=hb.is_dummy)
    xb, hist_b = gs_solve(sm_b, b_bmc, sweeps=100, a_bar=a_bmc)
    xh, hist_h = gs_solve(sm_h, b_hb, sweeps=100, a_bar=a_hb)
    # GS contracts monotonically (full convergence takes O(1/h^2) sweeps)
    assert hist_b[-1] < 0.2 * hist_b[0]
    # equivalence (paper eq. 3.4 for GS): identical residual history,
    # sweep for sweep
    np.testing.assert_allclose(hist_b, hist_h, rtol=1e-9)
    # same iterate in original coordinates
    np.testing.assert_allclose(xb[bmc.perm], xh[hb.perm], rtol=1e-8,
                               atol=1e-10)


@pytest.mark.slow
def test_sor_relaxation_accelerates():
    a = laplace_2d(14, 14)
    b = np.random.default_rng(2).normal(size=a.shape[0])
    bmc = block_multicolor_ordering(a, 4)
    hb = hbmc_from_bmc(bmc, 4)
    a_hb, b_hb = pad_system_hbmc(a, b, hb)
    rounds_f = rounds_hbmc(hb)
    rounds_r = rounds_hbmc(hb, True)
    gs = build_gs_smoother(a_hb, rounds_f, rounds_r, drop_mask=hb.is_dummy)
    sor = build_gs_smoother(a_hb, rounds_f, rounds_r, drop_mask=hb.is_dummy,
                            omega=1.5)
    _, h_gs = gs_solve(gs, b_hb, sweeps=60, a_bar=a_hb)
    _, h_sor = gs_solve(sor, b_hb, sweeps=60, a_bar=a_hb)
    assert h_sor[-1] < h_gs[-1], "SOR(1.5) should beat plain GS on Poisson"
