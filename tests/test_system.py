"""End-to-end behaviour tests: the paper's solver pipeline and the LM
training/serving pipeline, exercised through their public entry points."""
import numpy as np

from repro.core import solve_iccg
from repro.core.matrices import paper_problem
from repro.launch.train import main as train_main


def test_paper_pipeline_end_to_end():
    """ordering -> IC(0) -> packed trisolve -> PCG -> correct solution,
    with the HBMC == BMC equivalence holding."""
    a, _ = paper_problem("thermal2", scale="tiny")
    b = np.random.default_rng(0).normal(size=a.shape[0])
    bmc = solve_iccg(a, b, method="bmc", block_size=8, w=4)
    hbmc = solve_iccg(a, b, method="hbmc", block_size=8, w=4)
    assert bmc.result.iterations == hbmc.result.iterations
    assert hbmc.result.converged
    r = a @ hbmc.x - b
    assert np.linalg.norm(r) / np.linalg.norm(b) < 1e-6


def test_training_driver_end_to_end(tmp_path):
    """launch.train: trains, checkpoints, resumes, and the loss moves."""
    ck = str(tmp_path / "ck")
    losses = train_main([
        "--arch", "qwen2.5-3b", "--smoke", "--steps", "14", "--batch", "2",
        "--seq", "16", "--ckpt-dir", ck, "--ckpt-every", "7",
        "--log-every", "100"])
    assert len(losses) == 14 and np.isfinite(losses).all()
    # resume continues from step 14
    losses2 = train_main([
        "--arch", "qwen2.5-3b", "--smoke", "--steps", "16", "--batch", "2",
        "--seq", "16", "--ckpt-dir", ck, "--resume", "--log-every", "100"])
    assert len(losses2) == 2   # steps 14, 15 only
