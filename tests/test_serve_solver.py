"""Solver serving: deterministic simulation tier + cache/slab properties.

Pins the tentpole claims of the serving layer (repro/serve/solver.py):

  1. ACCEPTANCE TRACE — a seeded 200-request mixed-pattern trace through
     ``SolverService`` completes every admitted request; each solution is
     bitwise equal to the standalone same-width oracle
     ``plan.solve_slab(b, slab_width=B, slot=s)`` on a FRESH plan, and
     every per-request iteration count equals its single-RHS
     ``plan.solve`` count one for one.  (Slab width and slot are part of
     the numerical contract: XLA lowers batched dots/reductions
     differently from the single-RHS ``vdot`` path, differently per
     width, and — at B = 2 on CPU — differently per lane position, so
     the bitwise oracle is a standalone SAME-WIDTH, SAME-SLOT solve; at
     B = 1 that oracle coincides with ``plan.solve_batched(b[:, None])``,
     pinned below.  ``plan.solve`` agrees to reduction-order rounding
     and in iteration counts exactly.)
  2. DETERMINISM — the scheduler is single-threaded with a virtual clock:
     no wall-clock sleeps, no threads (asserted structurally), and a
     double run of the same trace reproduces solutions, iteration counts
     AND virtual latencies exactly.
  3. NO MIXING — every dispatch recorded in the log holds columns of one
     (plan key, values fingerprint) pair only.
  4. PROPERTIES (hypothesis, or the deterministic fallback engine) —
     iteration-count parity survives random slab-width/quantum/arrival
     interleavings, and ``PlanCache`` never evicts a pinned (in-flight)
     plan under random get/pin/unpin/evict sequences.
  5. VALIDATION — ``plan.solve_batched`` / ``pcg_batched`` reject 1-D b
     with an error naming the (n, B) expectation, accept B = 1 column
     slabs, and reject float dtype mismatches instead of silently
     casting (regression tests for the satellite bugfix).
"""
import numpy as np
import pytest
import scipy.sparse as sp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_stub import given, settings, st

from repro.core import build_plan, pcg_batched
from repro.core.matrices import graph_laplacian, laplace_2d
from repro.serve import (PlanBusyError, PlanCache, PlanKey, SolverService,
                         VirtualClock, WallClock, pattern_fingerprint,
                         values_fingerprint)

KNOBS = dict(method="hbmc", block_size=8, w=4)


def _patterns():
    """Three distinct sparsity patterns + one value-variant of the first
    (same pattern, scaled values — the refactor fast path)."""
    a1 = laplace_2d(10, 10)
    a2 = laplace_2d(8, 12)
    a3 = graph_laplacian(90, avg_degree=5, seed=3)
    a1v = a1.copy()
    a1v.data = a1v.data * 2.0
    return [a1, a2, a3, a1v]


def _seeded_trace(n_requests: int, seed: int, mats=None,
                  mean_gap: float = 0.03):
    """Seeded arrival trace: (matrix, b, arrival_time) triples."""
    rng = np.random.default_rng(seed)
    mats = _patterns() if mats is None else mats
    t, trace = 0.0, []
    for _ in range(n_requests):
        m = mats[int(rng.integers(len(mats)))]
        b = rng.standard_normal(m.shape[0])
        t += float(rng.exponential(mean_gap))
        trace.append((m, b, t))
    return trace


def _fresh_plans(trace):
    """One standalone fresh plan per distinct matrix in the trace (keyed
    by values fingerprint — a fresh build is a valid oracle even where
    the service took the refactor path: refactored == fresh bitwise)."""
    plans = {}
    for m, _, _ in trace:
        fp = (pattern_fingerprint(m), values_fingerprint(m))
        if fp not in plans:
            plans[fp] = build_plan(m, **KNOBS)
    return plans


def _run_trace(trace, **service_kwargs):
    kwargs = dict(slab_width=4, quantum=8, clock=VirtualClock(),
                  record_dispatches=True, **KNOBS)
    kwargs.update(service_kwargs)
    svc = SolverService(**kwargs)
    rids = {}
    for m, b, t in trace:
        rids[svc.submit(m, b, arrival_time=t)] = (m, b)
    svc.drain()
    return svc, rids


# ---------------------------------------------------------------------------
# 1. The acceptance trace (ISSUE 6 acceptance criterion).
# ---------------------------------------------------------------------------

def test_trace_200_requests_bitwise_and_iteration_parity():
    trace = _seeded_trace(200, seed=1234)
    svc, rids = _run_trace(trace)

    # every admitted request completed, exactly once
    assert sorted(svc.completed) == sorted(rids)
    assert svc.n_queued == 0 and svc.n_in_flight == 0

    plans = _fresh_plans(trace)
    for rid, (m, b) in rids.items():
        c = svc.completed[rid]
        plan = plans[(pattern_fingerprint(m), values_fingerprint(m))]
        oracle = plan.solve_slab(b, slab_width=4, slot=c.slot)
        single = plan.solve(b)
        assert c.converged
        # bitwise: served solution == standalone same-width slab solve
        np.testing.assert_array_equal(c.x, oracle.x)
        # iteration counts == the single-RHS plan.solve counts, one for one
        assert c.iterations == single.result.iterations
        assert c.iterations == oracle.result.iterations
    # the trace exercises all three cache outcomes
    stats = svc.cache.stats
    assert stats.misses >= 3          # three distinct patterns
    assert stats.refactors >= 1       # the value-variant of pattern 1
    assert stats.hits >= 1


def test_width_1_service_is_bitwise_one_column_batched_solve():
    """At B = 1 the serving path degenerates to the one-column batched
    solve exactly (and matches plan.solve's iteration counts)."""
    trace = _seeded_trace(12, seed=7, mats=[laplace_2d(9, 9)])
    svc, rids = _run_trace(trace, slab_width=1, quantum=5)
    plans = _fresh_plans(trace)
    for rid, (m, b) in rids.items():
        plan = plans[(pattern_fingerprint(m), values_fingerprint(m))]
        bat = plan.solve_batched(np.ascontiguousarray(b[:, None]))
        np.testing.assert_array_equal(svc.completed[rid].x, bat.x[:, 0])
        np.testing.assert_array_equal(svc.completed[rid].x,
                                      plan.solve_slab(b, slab_width=1).x)
        assert svc.completed[rid].iterations \
            == plan.solve(b).result.iterations


# ---------------------------------------------------------------------------
# 2. Determinism: virtual clock, no sleeps/threads, double-run equality.
# ---------------------------------------------------------------------------

def test_double_run_reproduces_everything_including_latencies():
    trace = _seeded_trace(40, seed=99)
    svc1, _ = _run_trace(trace)
    svc2, _ = _run_trace(trace)
    assert sorted(svc1.completed) == sorted(svc2.completed)
    for rid, c1 in svc1.completed.items():
        c2 = svc2.completed[rid]
        np.testing.assert_array_equal(c1.x, c2.x)
        assert c1.iterations == c2.iterations
        assert c1.latency == c2.latency          # virtual time, bit-equal
        assert c1.queue_wait == c2.queue_wait
        assert c1.plan_status == c2.plan_status
    assert svc1.clock.now() == svc2.clock.now()  # same virtual makespan


def test_scheduler_source_has_no_sleeps_or_threads():
    """Tier-1 determinism is structural: the scheduler never sleeps and
    never spawns threads — simulated time comes only from the clock."""
    import inspect

    import repro.serve.solver as mod
    src = inspect.getsource(mod)
    assert "time.sleep" not in src and "sleep(" not in src
    assert "import threading" not in src and "Thread(" not in src
    assert "concurrent.futures" not in src and "multiprocessing" not in src


def test_idle_service_jumps_to_next_arrival():
    clock = VirtualClock()
    svc = SolverService(slab_width=2, quantum=4, clock=clock, **KNOBS)
    a = laplace_2d(6, 6)
    svc.submit(a, np.ones(a.shape[0]), arrival_time=5.0)
    assert clock.now() == 0.0
    svc.step()   # idle -> advance_to(5.0) -> admit -> pack -> dispatch
    assert clock.now() >= 5.0
    svc.drain()
    assert len(svc.completed) == 1


def test_wall_clock_rejects_future_arrivals():
    svc = SolverService(slab_width=2, clock=WallClock(), **KNOBS)
    a = laplace_2d(5, 5)
    with pytest.raises(ValueError, match="simulated clock"):
        svc.submit(a, np.ones(a.shape[0]), arrival_time=1.0)


def test_wall_clock_service_solves():
    """The service also runs against real time (no arrival pacing)."""
    svc = SolverService(slab_width=2, quantum=16, **KNOBS)
    a = laplace_2d(7, 7)
    rng = np.random.default_rng(0)
    bs = [rng.standard_normal(a.shape[0]) for _ in range(3)]
    rids = [svc.submit(a, b) for b in bs]
    svc.drain()
    plan = build_plan(a, **KNOBS)
    for rid, b in zip(rids, bs):
        c = svc.completed[rid]
        np.testing.assert_array_equal(
            c.x, plan.solve_slab(b, slab_width=2, slot=c.slot).x)


# ---------------------------------------------------------------------------
# 3. Slab packing: no mixing, slot retirement/reuse, continuous batching.
# ---------------------------------------------------------------------------

def test_dispatches_never_mix_incompatible_plans():
    trace = _seeded_trace(60, seed=5)
    svc, rids = _run_trace(trace)
    rid_ident = {}
    for rid, (m, _) in rids.items():
        key, _ = PlanKey.from_matrix(m, **KNOBS)
        rid_ident[rid] = (key, values_fingerprint(m))
    assert svc.dispatch_log
    for entry in svc.dispatch_log:
        idents = {rid_ident[r] for r in entry["rids"] if r is not None}
        assert len(idents) == 1
        key, vfp = idents.pop()
        assert key == entry["key"] and vfp == entry["values_fp"]


def test_slots_retire_and_refill_midflight():
    """Continuous batching, not run-to-stragglers: more distinct requests
    flow through one width-W slab than it has slots, some dispatches show
    mixed generations, and early requests finish while later ones are
    still queued."""
    a = laplace_2d(10, 10)
    trace = _seeded_trace(17, seed=11, mats=[a], mean_gap=0.0)
    svc, rids = _run_trace(trace, slab_width=4, quantum=4)
    entries = [e for e in svc.dispatch_log if e["key"].n == a.shape[0]]
    seen = set()
    slab_rids = [set(r for r in e["rids"] if r is not None)
                 for e in entries]
    for s in slab_rids:
        seen |= s
    assert seen == set(rids)          # all flowed through the one slab
    assert all(len(s) <= 4 for s in slab_rids)
    # some slab composition changed between consecutive dispatches while
    # keeping a survivor: a retire + refill, not a full drain
    assert any(s1 != s2 and (s1 & s2)
               for s1, s2 in zip(slab_rids, slab_rids[1:]))
    # at least one request finished before the last one was even packed
    first_done = min(c.finished for c in svc.completed.values())
    last_started = max(c.started for c in svc.completed.values())
    assert first_done < last_started


def test_slab_columns_are_content_independent():
    """A column's result depends on its (width, slot) position, never on
    what its neighbours hold — the invariant that makes the standalone
    same-width same-slot solve a valid oracle for any packing history."""
    plan = build_plan(laplace_2d(7, 7), **KNOBS)
    rng = np.random.default_rng(2)
    b = rng.standard_normal(plan.n)
    neighbor = rng.standard_normal(plan.n)
    for width, slot in [(2, 0), (2, 1), (4, 2)]:
        alone = plan.solve_slab(b, slab_width=width, slot=slot)
        state = plan.new_slab_state(width)
        state = state._replace(
            r=state.r.at[:, slot].set(plan.embed_rhs(b)))
        other = (slot + 1) % width
        state = state._replace(
            r=state.r.at[:, other].set(plan.embed_rhs(neighbor)))
        state, _ = plan.run_slab(state, quantum=10_000)
        np.testing.assert_array_equal(
            plan.extract_solution(np.asarray(state.x)[:, slot]), alone.x)
        assert int(state.iters[slot]) == alone.result.iterations


def test_value_change_defers_refactor_until_group_drains():
    """Same pattern, different values, interleaved: FIFO per key holds,
    the plan refactors only between groups, and everything stays
    bitwise-correct (fresh == refactored plans)."""
    a = laplace_2d(9, 9)
    av = a.copy()
    av.data = av.data * 3.0
    rng = np.random.default_rng(21)
    clock = VirtualClock()
    svc = SolverService(slab_width=2, quantum=6, clock=clock,
                        record_dispatches=True, **KNOBS)
    subs = []
    for i in range(10):
        m = a if i % 2 == 0 else av
        b = rng.standard_normal(a.shape[0])
        subs.append((svc.submit(m, b, arrival_time=0.001 * i), m, b))
    svc.drain()
    assert len(svc.completed) == 10
    assert svc.cache.stats.refactors >= 1
    plans = {False: build_plan(a, **KNOBS), True: build_plan(av, **KNOBS)}
    for rid, m, b in subs:
        oracle = plans[m is av].solve_slab(
            b, slab_width=2, slot=svc.completed[rid].slot)
        np.testing.assert_array_equal(svc.completed[rid].x, oracle.x)
    # FIFO within the key: completion order == arrival order per value set
    for variant in (a, av):
        fin = [svc.completed[rid].finished for rid, m, _ in subs
               if m is variant]
        assert fin == sorted(fin)


# ---------------------------------------------------------------------------
# 4. PlanCache: LRU, refactor fast path, pinning.
# ---------------------------------------------------------------------------

def test_plan_cache_hit_refactor_miss_and_lru():
    cache = PlanCache(capacity=2)
    a1, a2, a3 = laplace_2d(6, 6), laplace_2d(5, 7), graph_laplacian(30)
    a1v = a1.copy()
    a1v.data = a1v.data * 2.0

    p1, s = cache.get(a1, **KNOBS)
    assert s == "miss"
    _, s = cache.get(a1, **KNOBS)
    assert s == "hit"
    p1b, s = cache.get(a1v, **KNOBS)
    assert s == "refactor" and p1b is p1      # same plan object, new values
    assert p1.refactor_count == 1
    _, s = cache.get(a2, **KNOBS)
    assert s == "miss"
    _, s = cache.get(a3, **KNOBS)             # evicts LRU (a1's entry)
    assert s == "miss"
    assert len(cache) == 2 and cache.stats.evictions == 1
    _, s = cache.get(a1v, **KNOBS)            # must rebuild
    assert s == "miss"


def test_plan_cache_never_evicts_pinned_and_busy_refactor_raises():
    cache = PlanCache(capacity=1)
    a1, a2 = laplace_2d(6, 6), laplace_2d(5, 7)
    a1v = a1.copy()
    a1v.data = a1v.data * 2.0
    _, _ = cache.get(a1, pin=True, **KNOBS)
    key1, _ = PlanKey.from_matrix(a1, **KNOBS)
    key2, _ = PlanKey.from_matrix(a2, **KNOBS)
    with pytest.raises(PlanBusyError):
        cache.get(a1v, **KNOBS)               # in-flight: refactor refused
    # unpinned newcomer while full of pinned entries: served, not retained
    _, s = cache.get(a2, **KNOBS)
    assert s == "miss"
    assert key1 in cache and key2 not in cache
    assert cache.stats.evictions == 1
    # pinned newcomer: both in flight, cache overflows rather than evict
    _, s = cache.get(a2, pin=True, **KNOBS)
    assert s == "miss"
    assert key1 in cache and key2 in cache and len(cache) == 2
    assert cache.stats.pinned_overflow >= 1
    cache.unpin(key2)                         # deferred eviction fires
    assert len(cache) == 1 and key2 not in cache and key1 in cache
    cache.unpin(key1)                         # within capacity: retained
    assert key1 in cache


def test_service_pins_inflight_plans_under_tiny_cache():
    """Capacity-1 cache, two patterns resident at once: the service
    overflows the cache rather than evicting either in-flight plan, and
    every request still completes bitwise-correct."""
    trace = _seeded_trace(24, seed=3,
                          mats=[laplace_2d(8, 8), laplace_2d(6, 10)],
                          mean_gap=0.0)
    svc, rids = _run_trace(trace, cache=PlanCache(capacity=1))
    assert len(svc.completed) == len(rids)
    assert svc.cache.stats.pinned_overflow >= 1
    plans = _fresh_plans(trace)
    for rid, (m, b) in rids.items():
        plan = plans[(pattern_fingerprint(m), values_fingerprint(m))]
        np.testing.assert_array_equal(
            svc.completed[rid].x,
            plan.solve_slab(b, slab_width=4,
                            slot=svc.completed[rid].slot).x)
    assert len(svc.cache) == 1                # drained back under capacity


def test_mesh_plans_are_not_cacheable():
    with pytest.raises(ValueError, match="mesh"):
        PlanKey.from_matrix(laplace_2d(5, 5), mesh=object(), **KNOBS)


# ---------------------------------------------------------------------------
# 5. Property tests (hypothesis or the deterministic fallback engine).
# ---------------------------------------------------------------------------

# shared across examples: plans/compilations are per (pattern, width,
# quantum) signature, so a module-level cache keeps the sweep warm
_PROP_CACHE = PlanCache(capacity=4)
_PROP_ORACLES: dict = {}


def _oracle_plan(m):
    fp = (pattern_fingerprint(m), values_fingerprint(m))
    if fp not in _PROP_ORACLES:
        _PROP_ORACLES[fp] = build_plan(m, **KNOBS)
    return _PROP_ORACLES[fp]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**32 - 1),
       width=st.sampled_from([1, 2, 4]),
       quantum=st.sampled_from([1, 4, 9]),
       n_requests=st.integers(3, 8))
def test_property_iteration_parity_under_interleavings(seed, width,
                                                       quantum, n_requests):
    """Whatever the retire/refill interleaving (random widths, quanta and
    arrival gaps), each served column's iteration count equals its
    single-RHS count one for one — convergence masking freezes columns
    exactly, so slab scheduling can never change WHEN a column converges."""
    mats = [laplace_2d(7, 7), graph_laplacian(40, avg_degree=4, seed=1)]
    trace = _seeded_trace(n_requests, seed=seed, mats=mats, mean_gap=0.02)
    svc, rids = _run_trace(trace, slab_width=width, quantum=quantum,
                           cache=_PROP_CACHE)
    assert sorted(svc.completed) == sorted(rids)
    for rid, (m, b) in rids.items():
        single = _oracle_plan(m).solve(b)
        assert svc.completed[rid].iterations == single.result.iterations
        np.testing.assert_array_equal(
            svc.completed[rid].x,
            _oracle_plan(m).solve_slab(b, slab_width=width,
                                       slot=svc.completed[rid].slot).x)


class _DummyPlan:
    def __init__(self, a, **knobs):
        self.refactor_count = 0

    def refactor(self, a):
        self.refactor_count += 1


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), capacity=st.integers(1, 3))
def test_property_cache_eviction_respects_pins(seed, capacity):
    """Under random get/pin/unpin sequences: pinned keys are never
    evicted, the cache only overflows capacity when every entry is
    pinned, and unpinning restores the bound."""
    rng = np.random.default_rng(seed)
    mats = [sp.eye(4 + i, format="csr") * (1.0 + i) for i in range(5)]
    keys = [PlanKey.from_matrix(m, **KNOBS)[0] for m in mats]
    cache = PlanCache(capacity=capacity, build=_DummyPlan)
    pins: dict = {}
    for _ in range(40):
        op = rng.integers(3)
        i = int(rng.integers(len(mats)))
        if op == 0:
            do_pin = bool(rng.integers(2))
            cache.get(mats[i], pin=do_pin, **KNOBS)
            if do_pin:
                pins[keys[i]] = pins.get(keys[i], 0) + 1
        elif op == 1 and keys[i] in cache:
            cache.pin(keys[i])
            pins[keys[i]] = pins.get(keys[i], 0) + 1
        elif op == 2 and pins.get(keys[i], 0) > 0:
            cache.unpin(keys[i])
            pins[keys[i]] -= 1
        # invariant: every pinned key is still resident
        for k, n in pins.items():
            if n > 0:
                assert k in cache
        # invariant: overflow only when all residents are pinned
        if len(cache) > capacity:
            assert all(cache.pins(k) > 0 for k in cache.keys())
    for k, n in pins.items():
        for _ in range(n):
            cache.unpin(k)
    assert len(cache) <= capacity


# ---------------------------------------------------------------------------
# 6. Validation regressions (satellite bugfix).
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_plan():
    return build_plan(laplace_2d(6, 6), **KNOBS)


def test_solve_batched_rejects_1d_with_crisp_error(small_plan):
    n = small_plan.n
    with pytest.raises(ValueError, match=rf"\({n}, B\).*b\[:, None\]"):
        small_plan.solve_batched(np.ones(n))


def test_solve_batched_accepts_single_column_slab(small_plan):
    b = np.linspace(0.0, 1.0, small_plan.n)
    rep = small_plan.solve_batched(b[:, None])
    assert rep.x.shape == (small_plan.n, 1)
    # B=1 slab == the width-1 serving oracle, bitwise, with iteration
    # counts matching the single solve exactly
    np.testing.assert_array_equal(rep.x[:, 0],
                                  small_plan.solve_slab(b, slab_width=1).x)
    assert rep.result.iterations[0] == small_plan.solve(b).result.iterations


def test_solve_batched_rejects_float_dtype_mismatch(small_plan):
    b = np.ones((small_plan.n, 2), dtype=np.float32)   # plan is float64
    with pytest.raises(TypeError, match="float32.*float64"):
        small_plan.solve_batched(b)


def test_solve_batched_accepts_integer_b(small_plan):
    # non-float b is an intentional convenience, not a precision hazard
    rep = small_plan.solve_batched(np.ones((small_plan.n, 1), dtype=int))
    assert rep.result.converged.all()


def test_pcg_batched_rejects_1d_with_crisp_error():
    with pytest.raises(ValueError, match=r"\(n, B\).*b\[:, None\]"):
        pcg_batched(lambda x: x, lambda x: x, np.ones(8))


def test_submit_rejects_2d_b_and_dtype_mismatch():
    svc = SolverService(clock=VirtualClock(), **KNOBS)
    a = laplace_2d(5, 5)
    with pytest.raises(ValueError, match="shape \\(n,\\)"):
        svc.submit(a, np.ones((a.shape[0], 2)))
    with pytest.raises(TypeError, match="float32"):
        svc.submit(a, np.ones(a.shape[0], dtype=np.float32))
    with pytest.raises(ValueError, match="b has shape"):
        svc.submit(a, np.ones(7))


def test_solve_slab_validates_shape(small_plan):
    with pytest.raises(ValueError, match="solve_slab expects b of shape"):
        small_plan.solve_slab(np.ones((small_plan.n, 1)))
    with pytest.raises(ValueError, match="slab_width"):
        small_plan.new_slab_state(0)


# ---------------------------------------------------------------------------
# 7. Backend coverage: the serving contract holds on the Pallas paths too.
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("knobs", [
    dict(method="hbmc", block_size=8, w=4, backend="pallas",
         spmv_format="sell", spmv_backend="pallas"),
    dict(method="hbmc", block_size=8, w=4, layout="index"),
], ids=["pallas-fused", "index-xla"])
def test_service_bitwise_on_other_backends(knobs):
    a = laplace_2d(8, 8)
    rng = np.random.default_rng(17)
    clock = VirtualClock()
    svc = SolverService(slab_width=3, quantum=6, clock=clock, **knobs)
    subs = [(svc.submit(a, rng.standard_normal(a.shape[0]),
                        arrival_time=0.01 * i), i) for i in range(5)]
    bs = {}   # re-derive: submit copies b, so regenerate deterministically
    rng = np.random.default_rng(17)
    for rid, _ in subs:
        bs[rid] = rng.standard_normal(a.shape[0])
    svc.drain()
    plan = build_plan(a, **knobs)
    singles = build_plan(a, **knobs)
    for rid, _ in subs:
        oracle = plan.solve_slab(bs[rid], slab_width=3,
                                 slot=svc.completed[rid].slot)
        np.testing.assert_array_equal(svc.completed[rid].x, oracle.x)
        assert (svc.completed[rid].iterations
                == singles.solve(bs[rid]).result.iterations)
