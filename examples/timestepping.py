"""Implicit time stepping on ONE SolverPlan: factor once, solve many.

The parabolic_fem workload (paper §5): each implicit Euler step of
u_t = div(grad u) solves  (I + dt * L) u_{k+1} = u_k  against the SAME
matrix.  A cold ``solve_iccg`` would redo ordering + IC(0) + packing every
step; a ``SolverPlan`` pays setup once and each subsequent step is pure
device PCG.  When dt changes mid-run the pattern of I + dt*L is unchanged,
so ``plan.refactor`` renews only the numeric factorization.

    PYTHONPATH=src python examples/timestepping.py
"""
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402
import scipy.sparse as sp  # noqa: E402

from repro.core import build_plan, solve_iccg  # noqa: E402
from repro.core.matrices import laplace_2d  # noqa: E402


def stepping_matrix(lap: sp.csr_matrix, dt: float) -> sp.csr_matrix:
    n = lap.shape[0]
    a = (sp.identity(n, format="csr") + dt * lap).tocsr()
    a.sort_indices()
    return a


def main():
    nx = ny = 64
    lap = laplace_2d(nx, ny)
    n = lap.shape[0]
    dt = 0.25
    n_steps = 20

    # initial condition: a hot square in the middle
    u = np.zeros((ny, nx))
    u[ny // 4: 3 * ny // 4, nx // 4: 3 * nx // 4] = 1.0
    u = u.ravel()

    a = stepping_matrix(lap, dt)
    t0 = time.perf_counter()
    plan = build_plan(a, method="hbmc", block_size=16, w=8)
    setup_s = time.perf_counter() - t0
    print(f"n = {n}: plan setup {setup_s*1e3:.1f} ms "
          f"(ordering {plan.timings.ordering*1e3:.1f} / "
          f"factor {plan.timings.factor*1e3:.1f} / "
          f"pack {plan.timings.pack*1e3:.1f})")

    total_solve = 0.0
    iters = []
    for k in range(n_steps):
        if k == n_steps // 2:
            # halfway: shrink the time step -> same pattern, new values.
            # refactor renews ONLY the numeric factorization + repack.
            dt /= 2
            t0 = time.perf_counter()
            plan.refactor(stepping_matrix(lap, dt))
            print(f"step {k:2d}: dt -> {dt}  (refactor "
                  f"{(time.perf_counter() - t0)*1e3:.1f} ms vs "
                  f"{setup_s*1e3:.1f} ms full setup)")
        rep = plan.solve(u, rtol=1e-8)
        u = rep.x
        iters.append(rep.result.iterations)
        total_solve += rep.solve_seconds

    print(f"{n_steps} implicit steps: {total_solve*1e3:.1f} ms total solve, "
          f"iterations/step {min(iters)}..{max(iters)}")
    print(f"energy drained to {np.linalg.norm(u):.4f} "
          f"(from {np.linalg.norm(np.ones(n//4)):.4f}-ish)")

    # the cold-path comparison: what every step WOULD have paid
    t0 = time.perf_counter()
    solve_iccg(stepping_matrix(lap, dt), u, method="hbmc",
               block_size=16, w=8, rtol=1e-8)
    cold_s = time.perf_counter() - t0
    warm_s = total_solve / n_steps
    print(f"cold solve_iccg per step: {cold_s*1e3:.1f} ms; "
          f"warm plan.solve per step: {warm_s*1e3:.1f} ms "
          f"({cold_s/warm_s:.1f}x)")


if __name__ == "__main__":
    main()
