"""End-to-end driver: train the ~130M-parameter mamba2-130m (a real assigned
architecture, full config) for a few hundred steps on synthetic data, with
checkpointing.  ~3-5 s/step on the CPU container.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/mamba2_ckpt")
    args = ap.parse_args()
    train_main([
        "--arch", "mamba2-130m",            # full config, not smoke
        "--steps", str(args.steps),
        "--batch", str(args.batch),
        "--seq", str(args.seq),
        "--lr", "1e-3",
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "50",
        "--resume",
    ])


if __name__ == "__main__":
    main()
