"""Quickstart: the paper's solver in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import solve_iccg
from repro.core.matrices import laplace_2d


def main():
    # 2-D Poisson problem, 64x64 grid
    a = laplace_2d(64, 64)
    b = np.random.default_rng(0).normal(size=a.shape[0])

    print(f"n = {a.shape[0]}, nnz = {a.nnz}")
    for method in ("mc", "bmc", "hbmc"):
        rep = solve_iccg(a, b, method=method, block_size=16, w=8, rtol=1e-7)
        print(f"{method:5s}: {rep.result.iterations:4d} iterations, "
              f"relres {rep.result.relres:.2e}, "
              f"{rep.n_colors} colors, {rep.n_rounds} sequential rounds, "
              f"lane occupancy {rep.lane_occupancy*100:.1f}%")
    print("\nBMC and HBMC iterate identically (the paper's equivalence "
          "theorem); HBMC additionally exposes w-wide vector lanes per "
          "round for the TPU VPU.")


if __name__ == "__main__":
    main()
