"""Quickstart: the paper's solver in a screenful.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import solve_iccg, solve_iccg_batched
from repro.core.matrices import laplace_2d


def main():
    # 2-D Poisson problem, 64x64 grid
    a = laplace_2d(64, 64)
    rng = np.random.default_rng(0)
    b = rng.normal(size=a.shape[0])

    print(f"n = {a.shape[0]}, nnz = {a.nnz}")
    for method in ("mc", "bmc", "hbmc"):
        rep = solve_iccg(a, b, method=method, block_size=16, w=8, rtol=1e-7)
        print(f"{method:5s}: {rep.result.iterations:4d} iterations, "
              f"relres {rep.result.relres:.2e}, "
              f"{rep.n_colors} colors, {rep.n_rounds} sequential rounds, "
              f"lane occupancy {rep.lane_occupancy*100:.1f}%")
    print("\nBMC and HBMC iterate identically (the paper's equivalence "
          "theorem); HBMC additionally exposes w-wide vector lanes per "
          "round for the TPU VPU.")

    # --- backend switch: the same solve through the Pallas kernel ---------
    # (interpret auto-resolves: compiled on TPU, interpreted elsewhere)
    rep_p = solve_iccg(a, b, method="hbmc", block_size=16, w=8,
                       backend="pallas")
    print(f"\npallas backend: {rep_p.result.iterations} iterations "
          f"(identical to xla), relres {rep_p.result.relres:.2e}")

    # --- batched multi-RHS: 4 systems through ONE PCG while_loop ----------
    bb = rng.normal(size=(a.shape[0], 4))
    rep_b = solve_iccg_batched(a, bb, method="hbmc", block_size=16, w=8)
    print(f"batched B=4:    per-RHS iterations {rep_b.result.iterations} "
          f"in {rep_b.result.n_steps} loop steps "
          f"(converged: {rep_b.result.converged.all()})")


if __name__ == "__main__":
    main()
