"""Batched serving demo: prefill a batch of prompts token-parallel, then
greedy-decode continuations with ring-buffer/recurrent caches.

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x22b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import init_params
from repro.serve.step import greedy_generate, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x22b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    if cfg.takes_embeddings:
        prompt = jax.random.normal(
            jax.random.PRNGKey(1),
            (args.batch, args.prompt_len, cfg.d_model)) * 0.3
        print("frontend-stub arch: prompt = precomputed embeddings")
        cache, logits = prefill(params, cfg, prompt,
                                max_len=args.prompt_len + args.new_tokens,
                                cache_dtype=jnp.float32)
        print(f"prefill logits: {logits.shape}; decode loop skipped for "
              f"stub frontends (needs a tokenizer round-trip)")
        return

    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.perf_counter()
    out = greedy_generate(params, cfg, prompt, n_new=args.new_tokens,
                          max_len=args.prompt_len + args.new_tokens,
                          cache_dtype=jnp.float32)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name}  batch={args.batch}  "
          f"prompt={args.prompt_len}  new={args.new_tokens}")
    print(f"generated token ids:\n{out}")
    print(f"{args.batch * args.new_tokens / dt:.1f} tok/s "
          f"(CPU, smoke config, includes compile)")


if __name__ == "__main__":
    main()
