"""The paper's end-to-end scenario: shifted ICCG on an eddy-current-style
FEM system, comparing MC / BMC / HBMC orderings and the SELL vs CRS-gather
SpMV variants (paper Tables 5.2 + 5.3).

    PYTHONPATH=src python examples/iccg_fem.py [--scale small|bench]
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import solve_iccg
from repro.core.matrices import PAPER_SHIFTS, paper_problem


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small",
                    choices=("tiny", "small", "bench"))
    ap.add_argument("--dataset", default="ieej")
    args = ap.parse_args()

    a, desc = paper_problem(args.dataset, scale=args.scale)
    shift = PAPER_SHIFTS.get(args.dataset, 0.0)
    b = np.random.default_rng(0).normal(size=a.shape[0])
    print(f"dataset={args.dataset} ({desc}), n={a.shape[0]}, nnz={a.nnz}, "
          f"IC shift={shift}")

    print(f"\n{'solver':22s} {'iters':>6s} {'setup(s)':>9s} "
          f"{'solve(s)':>9s} {'relres':>9s}")
    rows = [("mc", "ell"), ("bmc", "ell"), ("hbmc", "ell"), ("hbmc", "sell")]
    for method, fmt in rows:
        rep = solve_iccg(a, b, method=method, block_size=16, w=8,
                         shift=shift, rtol=1e-7, spmv_format=fmt)
        print(f"{method+'('+fmt+'_spmv)':22s} {rep.result.iterations:6d} "
              f"{rep.setup_seconds:9.2f} {rep.solve_seconds:9.2f} "
              f"{rep.result.relres:9.2e}")


if __name__ == "__main__":
    main()
