"""Solver-as-a-service: many time-stepping clients, one cached plan.

Six implicit-Euler heat-equation clients march (I + dt*L) x_{k+1} = x_k
on the same grid.  Every client shares one sparsity pattern, so the
service factors the matrix **once** (one cache miss); each subsequent
solve is a cache hit packed into a shared slab of width 4.  Halfway
through, every client shrinks its time step — same pattern, new values —
and the cache renews the factorization in place (``refactor``: no
reordering, no retrace) instead of building a new plan.

    PYTHONPATH=src python examples/serve_solver.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import scipy.sparse as sp

from repro.core.matrices import laplace_2d
from repro.serve import PlanCache, SolverService


def heat_matrix(grid, dt):
    lap = laplace_2d(grid, grid)
    return (sp.eye(lap.shape[0], format="csr") + dt * lap).tocsr()


def main():
    grid, n_clients, n_steps = 24, 6, 8
    a = heat_matrix(grid, dt=0.5)
    rng = np.random.default_rng(0)

    svc = SolverService(PlanCache(capacity=4), slab_width=4, quantum=16,
                        method="hbmc", block_size=16, w=8)
    # each client starts from its own random temperature field
    fields = [rng.random(a.shape[0]) for _ in range(n_clients)]

    print(f"{n_clients} clients x {n_steps} steps on a {grid}x{grid} grid "
          f"(n = {a.shape[0]}), slab width 4\n")
    for step in range(n_steps):
        if step == n_steps // 2:
            a = heat_matrix(grid, dt=0.1)   # new values, same pattern
            print("  -- all clients shrink dt: cache refactors in place --")
        rids = {svc.submit(a, fields[c], tag=c): c
                for c in range(n_clients)}
        done = svc.drain()
        for c in done:
            fields[rids[c.rid]] = c.x
        iters = sorted({c.iterations for c in done})
        status = {c.plan_status for c in done}
        print(f"  step {step}: {len(done)} solves, iterations {iters}, "
              f"plan {sorted(status)}")

    s = svc.cache.stats
    print(f"\ncache: {s.hits} hits, {s.misses} miss, "
          f"{s.refactors} refactor, hit rate {s.hit_rate:.2f} "
          f"-- {n_clients * n_steps} solves, 1 factorization built")
    print(f"mean field energy: "
          f"{np.mean([np.linalg.norm(f) for f in fields]):.4f}")


if __name__ == "__main__":
    main()
