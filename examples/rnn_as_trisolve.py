"""The paper's idea beyond its domain: vectorizing an RNN recurrence.

The RG-LRU recurrence  h_t = a_t * h_{t-1} + b_t  (RecurrentGemma) is the
forward substitution of a bidiagonal lower-triangular system

    L h = b,   L = I - shift(diag(a)).

A *single* chain admits no equivalent reordering (every edge fixes the
order: the ER condition pins the natural order), so HBMC cannot break the
sequential dependence — the paper's technique is about *exploiting existing
independence*, not creating it.  But a batch of B independent chains is
exactly a B-block, one-color HBMC instance: the secondary reordering
interleaves the chains lane-major (b_s = T, w = B), turning T*B scalar
steps into T rounds of B-wide vector work — with bit-exact results
(equivalent reordering).  Within a chain, the complementary trick is the
*associative scan* (O(log T) depth), which RecurrentGemma uses and which
this repo's RG-LRU layer implements.

    PYTHONPATH=src python examples/rnn_as_trisolve.py
"""
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from repro.core.sell import pack_steps
from repro.core.trisolve import DeviceTables, forward_solve


def main():
    rng = np.random.default_rng(0)
    B, T = 8, 512
    a = rng.uniform(0.5, 0.99, size=(B, T))   # gates
    b = rng.normal(size=(B, T))

    # --- reference: sequential recurrence, chain by chain ----------------
    t0 = time.perf_counter()
    h_seq = np.zeros((B, T))
    for i in range(B):
        h = 0.0
        for t in range(T):
            h = a[i, t] * h + b[i, t]
            h_seq[i, t] = h
    t_seq = time.perf_counter() - t0

    # --- HBMC view: B chains = B blocks of one color, w = B lanes --------
    # lane-major (round-major) order: index(t, i) = t*B + i
    n = B * T
    rows_sub = np.arange(1, T)[:, None] * B + np.arange(B)[None, :]
    cols_sub = rows_sub - B
    tri = sp.coo_matrix(
        (-a[:, 1:].T.ravel(), (rows_sub.ravel(), cols_sub.ravel())),
        shape=(n, n)).tocsr()
    diag = np.ones(n)
    rounds = [np.arange(t * B, (t + 1) * B) for t in range(T)]  # T rounds
    tables = pack_steps(tri, diag, rounds)
    dev = DeviceTables.from_host(tables)
    q = jnp.asarray(b.T.ravel())               # lane-major RHS
    h_hbmc = np.asarray(forward_solve(dev, q)).reshape(T, B).T
    forward_solve(dev, q)                      # warm
    t0 = time.perf_counter()
    forward_solve(dev, q).block_until_ready()
    t_hbmc = time.perf_counter() - t0

    # --- associative scan (intra-chain parallelism) ----------------------
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aj, bj = jnp.asarray(a), jnp.asarray(b)
    scan = jax.jit(lambda aa, bb: jax.lax.associative_scan(
        combine, (aa, bb), axis=1)[1])
    h_scan = np.asarray(scan(aj, bj))
    t0 = time.perf_counter()
    scan(aj, bj).block_until_ready()
    t_scan = time.perf_counter() - t0

    print(f"B={B} chains, T={T} steps")
    print(f"sequential python       : {t_seq*1e3:8.2f} ms "
          f"({B*T} scalar steps)")
    print(f"HBMC lane-major solve   : {t_hbmc*1e3:8.2f} ms "
          f"({T} rounds x {B} lanes)  max|err| = "
          f"{np.abs(h_hbmc-h_seq).max():.2e}")
    print(f"associative scan        : {t_scan*1e3:8.2f} ms "
          f"(log2(T)={int(np.log2(T))} levels)   max|err| = "
          f"{np.abs(h_scan-h_seq).max():.2e}")
    print("\nHBMC exposes *existing* independence (batch lanes) with exact "
          "equivalence; the associative scan creates intra-chain "
          "parallelism algebraically.  RecurrentGemma production code uses "
          "both (see repro/models/rglru.py).")


if __name__ == "__main__":
    main()
