"""Setup-pipeline benchmark: array-program ordering + SolverPlan reuse.

Five questions, one JSON answer (schema ``bench_setup/v2``):

  1. **Setup breakdown + legacy speedup** — cold ``build_plan`` wall-clock
     split into ordering (further split block_build / color / aggregate) /
     factor / pack, against the seed's "legacy" pipeline (per-node Python
     block building, sequential up-looking ``ic0``, per-row step/ELL
     packing — preserved verbatim below), per ordering method.
     ``block_build_speedup`` tracks the vectorized block builder against
     the seed walk on the same matrix (acceptance: >= 3x at n=4096).
  2. **Scheduler backends** — cold setup + warm solve for
     ``scheduler="coloring"`` vs ``scheduler="levelset"`` on the same
     system (round counts, schedule_s, iteration parity).
  3. **Large-n cold setup** (``--large-n``) — one n >= 250k system
     through the full vectorized pipeline, with a single rep of the seed
     block walk for scale (the legacy path's only reachable stage at
     this size).
  4. **Plan-reuse amortization** — cold ``solve_iccg`` vs warm
     ``plan.solve`` for the same system: the warm path must spend ~zero
     host-side setup (``warm_setup_s``) and amortize the cold setup away
     after ``breakeven_solves`` solves.
  5. **Refactor vs full setup** — ``plan.refactor(a')`` (numeric-only:
     values change, pattern fixed — the implicit time-stepping workload)
     vs building a fresh plan.

    PYTHONPATH=src python -m benchmarks.bench_setup [--smoke] [--large-n]
        [--out BENCH_setup.json]

CI runs ``--smoke --large-n`` and uploads the artifact; the committed
snapshot is the tracked trajectory sample.
"""
from __future__ import annotations

import argparse
import json
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402
import scipy.sparse as sp  # noqa: E402

from repro.core import build_plan, coloring, ic0, sell, solve_iccg  # noqa: E402
from repro.core import plan as plan_mod  # noqa: E402
from repro.core.matrices import laplace_2d, laplace_3d  # noqa: E402
from repro.core.solvers import _order_system  # noqa: E402

BS, W = 32, 8


# ---------------------------------------------------------------------------
# The seed setup pipeline, preserved verbatim as the trajectory baseline:
# per-node block building with Python sets, per-row step/ELL packing, and
# the sequential up-looking IC(0) (which still lives in core.ic0 as the
# semantics oracle).  This is what every solve_iccg call paid before the
# round-parallel pipeline.
# ---------------------------------------------------------------------------

def _seed_build_blocks(a, block_size):
    import heapq
    n = a.shape[0]
    from repro.core.graph import adjacency_lists
    indptr, indices = adjacency_lists(a)
    assigned = np.zeros(n, dtype=bool)
    blocks = []
    next_seed = 0
    while True:
        while next_seed < n and assigned[next_seed]:
            next_seed += 1
        if next_seed >= n:
            break
        blk = [next_seed]
        assigned[next_seed] = True
        heap, in_heap = [], set()
        for u in indices[indptr[next_seed]:indptr[next_seed + 1]]:
            if not assigned[u] and u not in in_heap:
                heapq.heappush(heap, int(u)); in_heap.add(int(u))
        while len(blk) < block_size and heap:
            v = heapq.heappop(heap)
            if assigned[v]:
                continue
            blk.append(v)
            assigned[v] = True
            for u in indices[indptr[v]:indptr[v + 1]]:
                u = int(u)
                if not assigned[u] and u not in in_heap:
                    heapq.heappush(heap, u); in_heap.add(u)
        blk.sort()
        blocks.append(blk)
    return blocks


def _seed_build_blocks_partition(a, block_size, adjacency=None):
    """Seed walk behind the new ``build_blocks`` contract (the end-to-end
    legacy baseline swaps this in for the vectorized builder)."""
    blocks = _seed_build_blocks(a, block_size)
    return coloring.BlockPartition(
        members=np.concatenate([np.asarray(b, dtype=np.int64)
                                for b in blocks]),
        lens=np.array([len(b) for b in blocks], dtype=np.int64))


def _seed_pack_steps(tri, diag, rounds, drop_mask=None):
    tri = sp.csr_matrix(tri)
    tri.sort_indices()
    n = tri.shape[0]
    n_slots = n + 1
    if drop_mask is not None:
        rounds = [r[~drop_mask[r]] for r in rounds]
        rounds = [r for r in rounds if len(r)]
    S = len(rounds)
    R = max(len(r) for r in rounds)
    K = max(int(np.diff(tri.indptr).max(initial=0)), 1)
    rows = np.full((S, R), n_slots - 1, dtype=np.int32)
    cols = np.full((S, R, K), n_slots - 1, dtype=np.int32)
    vals = np.zeros((S, R, K))
    dinv = np.zeros((S, R))
    live = np.zeros(S, dtype=np.int32)
    for s, rset in enumerate(rounds):
        live[s] = len(rset)
        rows[s, :len(rset)] = rset
        dinv[s, :len(rset)] = 1.0 / diag[rset]
        for t, r in enumerate(rset):
            lo, hi = tri.indptr[r], tri.indptr[r + 1]
            cols[s, t, :hi - lo] = tri.indices[lo:hi]
            vals[s, t, :hi - lo] = tri.data[lo:hi]
    return sell.StepTables(rows=rows, cols=cols, vals=vals, dinv=dinv,
                           n_slots=n_slots, live=live)


def _seed_pack_ell(a):
    a = sp.csr_matrix(a)
    a.sort_indices()
    n = a.shape[0]
    k = max(int(np.diff(a.indptr).max(initial=0)), 1)
    cols = np.zeros((n, k), dtype=np.int32)
    vals = np.zeros((n, k))
    for r in range(n):
        lo, hi = a.indptr[r], a.indptr[r + 1]
        cols[r, :hi - lo] = a.indices[lo:hi]
        vals[r, :hi - lo] = a.data[lo:hi]
    return cols, vals


def _legacy_setup(a, method):
    """Seed pipeline end to end: ordering -> sequential IC(0) -> per-row
    packing -> fused tables + ELL SpMV operand, moved to device (the same
    endpoint ``build_plan`` is charged for).  Returns the per-stage split
    (ordering_s, factor_s, pack_s)."""
    import jax.numpy as jnp

    from repro.core.trisolve import DeviceFusedTables
    t0 = time.perf_counter()
    orig = plan_mod.build_blocks
    plan_mod.build_blocks = _seed_build_blocks_partition
    try:
        sysd = _order_system(a, None, method, BS, W)
    finally:
        plan_mod.build_blocks = orig
    t1 = time.perf_counter()
    l_bar = ic0(sysd.a_bar)
    t2 = time.perf_counter()
    diag = l_bar.diagonal()
    strict_lower = sp.tril(l_bar, k=-1, format="csr")
    fwd = _seed_pack_steps(strict_lower, diag, sysd.fwd_rounds, sysd.drop)
    bwd = _seed_pack_steps(sp.csr_matrix(strict_lower.T), diag,
                           sysd.bwd_rounds, sysd.drop)
    fused = sell.fuse_round_major(fwd, bwd)
    DeviceFusedTables.from_host(fused)
    cols, vals = _seed_pack_ell(
        sell.permute_round_major(sysd.a_bar, fused.layout))
    jnp.asarray(vals), jnp.asarray(cols)
    t3 = time.perf_counter()
    return t1 - t0, t2 - t1, t3 - t2


def _problems(smoke: bool):
    if smoke:
        return [("lap2d_tiny", laplace_2d(16, 14)),
                ("lap3d_tiny_27", laplace_3d(6, 6, 5, stencil=27))]
    return [("lap2d_64", laplace_2d(64, 64)),
            ("lap3d_16_27", laplace_3d(16, 16, 16, stencil=27))]


def _best(fn, reps):
    """Best-of-reps wall-clock (min is robust to scheduler noise)."""
    best, out = float("inf"), None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def bench_setup_breakdown(name, a, method, reps):
    """Cold plan setup (with stage breakdown) vs the legacy sequential path.

    Plan and legacy reps are interleaved so scheduler noise hits both sides
    alike; best-of-reps on each."""
    a = sp.csr_matrix(a)
    breakdown = {"ordering": float("inf"), "factor": float("inf"),
                 "pack": float("inf"), "block_build": float("inf"),
                 "color": float("inf"), "aggregate": float("inf")}
    lg = {"ordering": float("inf"), "factor": float("inf"),
          "pack": float("inf")}
    plan_s = legacy_s = seed_build_s = float("inf")
    for _ in range(reps):
        plan = build_plan(a, method=method, block_size=BS, w=W)
        t = plan.timings
        plan_s = min(plan_s, t.total)
        for k in breakdown:
            breakdown[k] = min(breakdown[k], getattr(t, k))
        t0 = time.perf_counter()
        lo, lf, lp = _legacy_setup(a, method)
        legacy_s = min(legacy_s, time.perf_counter() - t0)
        lg["ordering"] = min(lg["ordering"], lo)
        lg["factor"] = min(lg["factor"], lf)
        lg["pack"] = min(lg["pack"], lp)
        if method != "mc":
            t0 = time.perf_counter()
            _seed_build_blocks(a, BS)
            seed_build_s = min(seed_build_s, time.perf_counter() - t0)
    # the stages the round-parallel pipeline vectorizes (the ordering
    # front-end is itself an array program since bench_setup/v2)
    fp_plan = breakdown["factor"] + breakdown["pack"]
    fp_legacy = lg["factor"] + lg["pack"]
    out = {
        "problem": name, "n": int(a.shape[0]), "method": method,
        "plan_setup_s": round(plan_s, 5),
        "ordering_s": round(breakdown["ordering"], 5),
        "block_build_s": round(breakdown["block_build"], 5),
        "color_s": round(breakdown["color"], 5),
        "aggregate_s": round(breakdown["aggregate"], 5),
        "factor_s": round(breakdown["factor"], 5),
        "pack_s": round(breakdown["pack"], 5),
        "legacy_setup_s": round(legacy_s, 5),
        "legacy_ordering_s": round(lg["ordering"], 5),
        "legacy_factor_s": round(lg["factor"], 5),
        "legacy_pack_s": round(lg["pack"], 5),
        "legacy_over_plan": round(legacy_s / plan_s, 2),
        "factor_pack_speedup": round(fp_legacy / fp_plan, 2),
    }
    if method != "mc":
        out["legacy_block_build_s"] = round(seed_build_s, 5)
        out["block_build_speedup"] = round(
            seed_build_s / max(breakdown["block_build"], 1e-9), 2)
    return out


def bench_scheduler_compare(name, a, reps, maxiter):
    """coloring vs levelset rounds on the same (hbmc-ordered) system."""
    a = sp.csr_matrix(a)
    b = np.random.default_rng(2).normal(size=a.shape[0])
    out = []
    for scheduler in ("coloring", "levelset"):
        setup_s = schedule_s = float("inf")
        plan = None
        for _ in range(reps):
            plan = build_plan(a, method="hbmc", block_size=BS, w=W,
                              scheduler=scheduler)
            setup_s = min(setup_s, plan.timings.total)
            schedule_s = min(schedule_s, plan.timings.schedule)
        plan.solve(b, rtol=0.0, maxiter=maxiter)   # warm the jit cache
        solve_s, rep = _best(
            lambda: plan.solve(b, rtol=0.0, maxiter=maxiter), reps)
        out.append({
            "problem": name, "n": int(a.shape[0]), "method": "hbmc",
            "scheduler": scheduler,
            "setup_s": round(setup_s, 5),
            "schedule_s": round(schedule_s, 5),
            "n_rounds": int(plan.n_rounds),
            "warm_solve_s": round(solve_s, 5),
            "iterations": int(rep.result.iterations),
        })
    return out


def bench_large_n(reps):
    """n >= 250k cold setup through the vectorized pipeline.

    The committed row the legacy path could not reach: the seed block
    walk alone (one rep — it is the only legacy stage that finishes in
    comparable time at this size; the sequential IC(0) would take
    minutes) is compared against the full vectorized ordering stage.
    """
    a = sp.csr_matrix(laplace_2d(512, 512))
    breakdown = {"block_build": float("inf"), "color": float("inf"),
                 "aggregate": float("inf"), "ordering": float("inf"),
                 "factor": float("inf"), "pack": float("inf")}
    plan_s = float("inf")
    for _ in range(reps):
        plan = build_plan(a, method="hbmc", block_size=BS, w=W)
        plan_s = min(plan_s, plan.timings.total)
        for k in breakdown:
            breakdown[k] = min(breakdown[k], getattr(plan.timings, k))
    t0 = time.perf_counter()
    _seed_build_blocks(a, BS)
    seed_build_s = time.perf_counter() - t0
    return [{
        "problem": "lap2d_512", "n": int(a.shape[0]), "method": "hbmc",
        "plan_setup_s": round(plan_s, 5),
        "ordering_s": round(breakdown["ordering"], 5),
        "block_build_s": round(breakdown["block_build"], 5),
        "color_s": round(breakdown["color"], 5),
        "aggregate_s": round(breakdown["aggregate"], 5),
        "factor_s": round(breakdown["factor"], 5),
        "pack_s": round(breakdown["pack"], 5),
        "legacy_block_build_s": round(seed_build_s, 5),
        "block_build_speedup": round(
            seed_build_s / max(breakdown["block_build"], 1e-9), 2),
    }]


def bench_plan_reuse(name, a, reps, maxiter):
    """Cold solve_iccg vs warm plan.solve on the same system."""
    a = sp.csr_matrix(a)
    b = np.random.default_rng(0).normal(size=a.shape[0])
    kw = dict(method="hbmc", block_size=BS, w=W, rtol=0.0, maxiter=maxiter)

    cold_s, rep = _best(lambda: solve_iccg(a, b, **kw), reps)
    plan = build_plan(a, method="hbmc", block_size=BS, w=W)
    plan.solve(b, rtol=0.0, maxiter=maxiter)       # warm the jit cache
    warm_s, wrep = _best(lambda: plan.solve(b, rtol=0.0, maxiter=maxiter),
                         reps)
    warm_setup = wrep.setup_seconds
    setup_s = plan.timings.total
    gain = cold_s - warm_s
    return {
        "problem": name, "n": int(a.shape[0]), "maxiter": maxiter,
        "cold_solve_iccg_s": round(cold_s, 5),
        "warm_plan_solve_s": round(warm_s, 5),
        "warm_setup_s": round(warm_setup, 6),
        "plan_setup_s": round(setup_s, 5),
        "cold_over_warm": round(cold_s / warm_s, 2),
        # solves until holding the plan has paid for building it
        "breakeven_solves": (int(np.ceil(setup_s / gain))
                             if gain > 0 else None),
    }


def bench_refactor(name, a, reps):
    """plan.refactor (values change, same pattern) vs a fresh build_plan."""
    a = sp.csr_matrix(a)
    plan = build_plan(a, method="hbmc", block_size=BS, w=W)
    full_s = plan.timings.total
    for _ in range(max(reps - 1, 0)):
        full_s = min(full_s, build_plan(a, method="hbmc", block_size=BS,
                                        w=W).timings.total)
    a2 = (a + 0.1 * sp.diags(a.diagonal())).tocsr()
    b = np.random.default_rng(1).normal(size=a.shape[0])
    plan.solve(b, rtol=0.0, maxiter=5)            # trace the PCG once
    refac_s = post_s = float("inf")
    for _ in range(reps):
        refac_s = min(refac_s, plan.refactor(a2).total)
        # first solve after a refactor: operands are jit ARGUMENTS, so the
        # cached executable is reused — no retrace, no recompile
        rep = plan.solve(b, rtol=0.0, maxiter=5)
        post_s = min(post_s, rep.solve_seconds)
    return {
        "problem": name, "n": int(a.shape[0]),
        "full_setup_s": round(full_s, 5),
        "refactor_s": round(refac_s, 5),
        "post_refactor_solve_s": round(post_s, 5),
        "retraces": plan._trace_count,
        "full_over_refactor": round(full_s / refac_s, 2),
    }


def bench_validate_overhead(name, a, reps):
    """Cold build_plan with the static race detector on vs off.

    ``validate="cheap"`` (round/DAG audit only) must stay under 5% of the
    cold setup on lap3d_16_27 — the knob is meant to be affordable enough
    to leave on in serving admission control.  ``full`` (adds the packed
    table and IC(0) structure proofs) is reported for the trajectory."""
    a = sp.csr_matrix(a)
    kw = dict(method="hbmc", block_size=BS, w=W)
    off_s, _ = _best(lambda: build_plan(a, validate="off", **kw), reps)
    cheap_s, _ = _best(lambda: build_plan(a, validate="cheap", **kw), reps)
    full_s, _ = _best(lambda: build_plan(a, validate="full", **kw), reps)
    return {
        "problem": name, "n": int(a.shape[0]),
        "build_off_s": round(off_s, 5),
        "build_cheap_s": round(cheap_s, 5),
        "build_full_s": round(full_s, 5),
        "cheap_overhead_pct": round(100.0 * (cheap_s - off_s) / off_s, 2),
        "full_overhead_pct": round(100.0 * (full_s - off_s) / off_s, 2),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problems, fewer reps (CI)")
    ap.add_argument("--large-n", action="store_true",
                    help="also run the n >= 250k cold-setup row (the "
                         "host-side scaling tripwire)")
    ap.add_argument("--out", default="BENCH_setup.json")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--maxiter", type=int, default=None)
    args = ap.parse_args()

    reps = args.reps or (2 if args.smoke else 5)
    maxiter = args.maxiter or (10 if args.smoke else 40)

    problems = _problems(args.smoke)
    breakdown = [bench_setup_breakdown(name, a, method, reps)
                 for name, a in problems
                 for method in ("hbmc", "bmc", "mc")]
    schedulers = [row for name, a in problems
                  for row in bench_scheduler_compare(name, a, reps, maxiter)]
    large_n = bench_large_n(1 if args.smoke else 2) if args.large_n else []
    reuse = [bench_plan_reuse(name, a, reps, maxiter)
             for name, a in problems]
    refactor = [bench_refactor(name, a, reps) for name, a in problems]
    validate = [bench_validate_overhead(name, a, reps)
                for name, a in problems]

    doc = {
        "schema": "bench_setup/v2",
        "platform": jax.default_backend(),
        "smoke": bool(args.smoke),
        "block_size": BS,
        "w": W,
        "setup_breakdown": breakdown,
        "scheduler_compare": schedulers,
        "large_n": large_n,
        "plan_reuse": reuse,
        "refactor": refactor,
        "validate_overhead": validate,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")

    print(f"{'problem':14s} {'method':6s} {'plan s':>8s} {'legacy s':>9s} "
          f"{'total':>7s} {'fac+pack':>9s} {'blk-build':>10s}   "
          f"(build/color/agg | factor/pack)")
    for r in breakdown:
        bb = (f"{r['block_build_speedup']:8.1f}x"
              if "block_build_speedup" in r else " " * 9)
        print(f"{r['problem']:14s} {r['method']:6s} {r['plan_setup_s']:8.3f} "
              f"{r['legacy_setup_s']:9.3f} {r['legacy_over_plan']:6.1f}x "
              f"{r['factor_pack_speedup']:8.1f}x {bb}   "
              f"({r['block_build_s']:.3f}/{r['color_s']:.3f}/"
              f"{r['aggregate_s']:.3f} | "
              f"{r['factor_s']:.3f}/{r['pack_s']:.3f})")
    print(f"\n{'problem':14s} {'scheduler':9s} {'setup s':>8s} "
          f"{'sched s':>8s} {'rounds':>7s} {'solve s':>8s} {'iters':>6s}")
    for r in schedulers:
        print(f"{r['problem']:14s} {r['scheduler']:9s} {r['setup_s']:8.3f} "
              f"{r['schedule_s']:8.4f} {r['n_rounds']:7d} "
              f"{r['warm_solve_s']:8.4f} {r['iterations']:6d}")
    for r in large_n:
        print(f"\nlarge-n {r['problem']} (n={r['n']}): "
              f"setup {r['plan_setup_s']:.3f}s "
              f"(build {r['block_build_s']:.3f} / color {r['color_s']:.3f} "
              f"/ agg {r['aggregate_s']:.3f} / factor {r['factor_s']:.3f} "
              f"/ pack {r['pack_s']:.3f}); seed block walk "
              f"{r['legacy_block_build_s']:.3f}s "
              f"-> {r['block_build_speedup']:.1f}x")
    print(f"\n{'problem':14s} {'cold s':>8s} {'warm s':>8s} {'ratio':>6s} "
          f"{'warm setup s':>13s} {'breakeven':>10s}")
    for r in reuse:
        print(f"{r['problem']:14s} {r['cold_solve_iccg_s']:8.3f} "
              f"{r['warm_plan_solve_s']:8.3f} {r['cold_over_warm']:5.1f}x "
              f"{r['warm_setup_s']:13.6f} {str(r['breakeven_solves']):>10s}")
    print(f"\n{'problem':14s} {'full s':>8s} {'refactor s':>11s} "
          f"{'ratio':>6s} {'post-solve s':>13s} {'retraces':>9s}")
    for r in refactor:
        print(f"{r['problem']:14s} {r['full_setup_s']:8.3f} "
              f"{r['refactor_s']:11.3f} {r['full_over_refactor']:5.1f}x "
              f"{r['post_refactor_solve_s']:13.5f} {r['retraces']:9d}")
    print(f"\n{'problem':14s} {'off s':>8s} {'cheap s':>8s} {'full s':>8s} "
          f"{'cheap +%':>9s} {'full +%':>9s}")
    for r in validate:
        print(f"{r['problem']:14s} {r['build_off_s']:8.3f} "
              f"{r['build_cheap_s']:8.3f} {r['build_full_s']:8.3f} "
              f"{r['cheap_overhead_pct']:8.2f}% {r['full_overhead_pct']:8.2f}%")
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
