"""Solver-serving benchmark: continuous batching over a warm plan cache.

Three questions, one JSON answer (schema ``bench_serve/v1``):

  1. **Offline throughput vs slab width** — N requests against one warm
     cached plan, served through ``SolverService`` at B ∈ {1, 4, 8, 16}:
     RHS/sec, p50/p99 request latency, and mean slab occupancy per width.
     The acceptance comparison: warm slab serving at B >= 4 must beat the
     one-request-at-a-time **cold baseline** (build_plan + solve per
     request — what a client pays without the serving layer) on RHS/sec.
  2. **Server-style load** — seeded arrival pacing against the wall
     clock at the same widths: p50/p99 latency under queueing, not just
     back-to-back throughput.
  3. **Cache behavior** — hit/refactor/miss/eviction rates for a warm
     single-pattern stream vs a mixed-pattern stream with value changes
     (the time-stepping fleet) through a small-capacity ``PlanCache``.

    PYTHONPATH=src python -m benchmarks.bench_serve [--smoke]
        [--out BENCH_serve.json]

CI runs ``--smoke`` and uploads the artifact; the committed snapshot is
the tracked trajectory sample.  (This benchmark paces real submissions,
so unlike tier-1 tests it may sleep between arrivals.)
"""
from __future__ import annotations

import argparse
import json
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.core import build_plan  # noqa: E402
from repro.core.matrices import laplace_2d  # noqa: E402
from repro.serve import PlanCache, SolverService, VirtualClock  # noqa: E402

KNOBS = dict(method="hbmc", block_size=32, w=8)
QUANTUM = 16


def _mean_occupancy(svc) -> float:
    occ = [sum(r is not None for r in e["rids"]) / len(e["rids"])
           for e in svc.dispatch_log]
    return float(np.mean(occ)) if occ else 0.0


def _pcts(latencies):
    return (float(np.percentile(latencies, 50)),
            float(np.percentile(latencies, 99)))


def bench_offline(a, n_req, widths, cache):
    """Back-to-back serving throughput at each slab width, warm cache."""
    rng = np.random.default_rng(0)
    bs = [rng.standard_normal(a.shape[0]) for _ in range(n_req)]
    rows = []
    for width in widths:
        svc = SolverService(cache, slab_width=width, quantum=QUANTUM,
                            record_dispatches=True, **KNOBS)
        svc.submit(a, bs[0])
        svc.drain()                    # warm: plan cached, slab fn compiled
        svc = SolverService(cache, slab_width=width, quantum=QUANTUM,
                            record_dispatches=True, **KNOBS)
        t0 = time.perf_counter()
        for b in bs:
            svc.submit(a, b)
        done = svc.drain()
        elapsed = time.perf_counter() - t0
        lat = [c.latency for c in done]
        p50, p99 = _pcts(lat)
        assert all(c.converged for c in done)
        assert all(c.plan_status == "hit" for c in done)
        rows.append({
            "slab_width": width,
            "rhs_per_s": round(n_req / elapsed, 2),
            "elapsed_s": round(elapsed, 4),
            "p50_latency_s": round(p50, 5),
            "p99_latency_s": round(p99, 5),
            "mean_occupancy": round(_mean_occupancy(svc), 3),
            "mean_iterations": round(float(np.mean(
                [c.iterations for c in done])), 1),
        })
    return rows


def bench_cold_baseline(a, n_req):
    """One-request-at-a-time cold solves: build_plan + solve per request,
    no cache — the cost every client pays without the serving layer."""
    rng = np.random.default_rng(0)
    bs = [rng.standard_normal(a.shape[0]) for _ in range(n_req)]
    build_plan(a, **KNOBS).solve(bs[0])   # exclude one-time jit compile
    lat = []
    t0 = time.perf_counter()
    for b in bs:
        t1 = time.perf_counter()
        plan = build_plan(a, **KNOBS)
        rep = plan.solve(b)
        assert rep.result.converged
        lat.append(time.perf_counter() - t1)
    elapsed = time.perf_counter() - t0
    p50, p99 = _pcts(lat)
    return {
        "rhs_per_s": round(n_req / elapsed, 2),
        "elapsed_s": round(elapsed, 4),
        "p50_latency_s": round(p50, 5),
        "p99_latency_s": round(p99, 5),
    }


def bench_server(a, n_req, widths, cache, mean_gap):
    """Seeded arrivals paced against the wall clock: latency under load."""
    rng = np.random.default_rng(7)
    bs = [rng.standard_normal(a.shape[0]) for _ in range(n_req)]
    offsets = np.cumsum(rng.exponential(mean_gap, size=n_req))
    rows = []
    for width in widths:
        svc = SolverService(cache, slab_width=width, quantum=QUANTUM,
                            **KNOBS)
        svc.submit(a, bs[0])
        svc.drain()                    # warm
        svc = SolverService(cache, slab_width=width, quantum=QUANTUM,
                            **KNOBS)
        t0 = time.perf_counter()
        i = 0
        while i < n_req or svc.n_queued or svc.n_in_flight:
            now = time.perf_counter() - t0
            while i < n_req and offsets[i] <= now:
                svc.submit(a, bs[i])
                i += 1
            if svc.n_queued or svc.n_in_flight:
                svc.step()
            elif i < n_req:            # idle: wait for the next arrival
                time.sleep(max(min(offsets[i] - now, 0.001), 0.0))
        elapsed = time.perf_counter() - t0
        lat = [c.latency for c in svc.completed.values()]
        p50, p99 = _pcts(lat)
        rows.append({
            "slab_width": width,
            "mean_gap_s": mean_gap,
            "rhs_per_s": round(n_req / elapsed, 2),
            "p50_latency_s": round(p50, 5),
            "p99_latency_s": round(p99, 5),
        })
    return rows


def bench_cache(a, n_req):
    """Cache hit rates: warm single-pattern stream vs a mixed stream with
    value changes through a capacity-2 cache (deterministic virtual
    clock — only the cache counters matter here)."""
    rng = np.random.default_rng(3)

    def _stats(svc):
        s = svc.cache.stats
        return {"hits": s.hits, "misses": s.misses,
                "refactors": s.refactors, "evictions": s.evictions,
                "hit_rate": round(s.hit_rate, 3)}

    # gaps wider than a request's virtual service time, so each arrival
    # finds an empty service and must consult the cache anew
    gap = 5.0
    warm = SolverService(PlanCache(capacity=2), slab_width=4,
                         quantum=QUANTUM, clock=VirtualClock(), **KNOBS)
    for i in range(n_req):
        warm.submit(a, rng.standard_normal(a.shape[0]),
                    arrival_time=gap * i)
    warm.drain()

    mats = [a]
    a2 = laplace_2d(a.shape[0] // 16, 16)
    a3 = a.copy()
    a3.data = a3.data * 1.5            # same pattern, new values
    mats += [a2, a3]
    mixed = SolverService(PlanCache(capacity=2), slab_width=4,
                          quantum=QUANTUM, clock=VirtualClock(), **KNOBS)
    for i in range(n_req):
        m = mats[int(rng.integers(len(mats)))]
        mixed.submit(m, rng.standard_normal(m.shape[0]),
                     arrival_time=gap * i)
    mixed.drain()
    return {"warm_single_pattern": _stats(warm),
            "mixed_with_value_changes": _stats(mixed)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problem, fewer requests/widths (CI)")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--requests", type=int, default=None)
    args = ap.parse_args()

    if args.smoke:
        a, name = laplace_2d(12, 12), "lap2d_12"
        widths = [1, 4]
        n_req = args.requests or 6
        mean_gap = 0.02
    else:
        a, name = laplace_2d(32, 32), "lap2d_32"
        widths = [1, 4, 8, 16]
        n_req = args.requests or 48
        mean_gap = 0.01

    cache = PlanCache(capacity=4)
    offline = bench_offline(a, n_req, widths, cache)
    cold = bench_cold_baseline(a, n_req)
    for row in offline:
        row["speedup_vs_cold"] = round(row["rhs_per_s"]
                                       / cold["rhs_per_s"], 2)
    server = bench_server(a, n_req, widths, cache, mean_gap)
    cache_rates = bench_cache(a, max(n_req, 12))

    doc = {
        "schema": "bench_serve/v1",
        "platform": jax.default_backend(),
        "smoke": bool(args.smoke),
        "problem": {"name": name, "n": int(a.shape[0])},
        "n_requests": n_req,
        "quantum": QUANTUM,
        "knobs": {k: v for k, v in KNOBS.items()},
        "offline": offline,
        "cold_baseline": cold,
        "server": server,
        "cache": cache_rates,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")

    print(f"cold baseline: {cold['rhs_per_s']:8.2f} RHS/s  "
          f"(p50 {cold['p50_latency_s'] * 1e3:7.2f} ms, "
          f"p99 {cold['p99_latency_s'] * 1e3:7.2f} ms)")
    print(f"\n{'B':>3s} {'RHS/s':>9s} {'vs cold':>8s} {'p50 ms':>8s} "
          f"{'p99 ms':>8s} {'occupancy':>10s}")
    for r in offline:
        print(f"{r['slab_width']:3d} {r['rhs_per_s']:9.2f} "
              f"{r['speedup_vs_cold']:7.2f}x "
              f"{r['p50_latency_s'] * 1e3:8.2f} "
              f"{r['p99_latency_s'] * 1e3:8.2f} "
              f"{r['mean_occupancy']:10.3f}")
    print(f"\nserver (mean gap {mean_gap * 1e3:.0f} ms):")
    for r in server:
        print(f"  B={r['slab_width']:2d}  {r['rhs_per_s']:8.2f} RHS/s  "
              f"p50 {r['p50_latency_s'] * 1e3:7.2f} ms  "
              f"p99 {r['p99_latency_s'] * 1e3:7.2f} ms")
    for kind, s in cache_rates.items():
        print(f"cache[{kind}]: hit_rate {s['hit_rate']:.3f} "
              f"(h {s['hits']} / m {s['misses']} / r {s['refactors']} "
              f"/ e {s['evictions']})")
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
