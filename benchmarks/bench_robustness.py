"""Robustness benchmark: what does fault tolerance cost on the healthy path,
and how fast does the service shed an unhealthy solve?

Three questions, one JSON answer (schema ``bench_robustness/v1``):

  1. **Healthy-path monitoring overhead** — the in-loop health monitor
     (curvature / finiteness / divergence / stagnation guards) vs a
     reference unmonitored PCG loop (``pcg_iteration``, the pre-monitor
     body) over the *same* round-major trisolve + ELL SpMV operator, at a
     pinned iteration count.  The acceptance bar: < 5% per-iteration
     overhead.  (The guards are selects on scalars already in registers —
     the loop body is dominated by the two triangular sweeps + SpMV.)
  2. **Time to quarantine** — virtual-clock dispatches from submission to
     retirement for a NaN-RHS request (caught at slab entry) and an
     indefinite-matrix request (caught mid-iteration), vs the
     ``maxiter/quantum`` dispatch ceiling an unmonitored service would
     burn while the column iterated on garbage.
  3. **Fault-mix summary** — a seeded :class:`repro.serve.FaultInjector`
     trace drained to completion: status histogram per kind, quarantine
     count, and the wall-clock cost of the whole adversarial trace.

    PYTHONPATH=src python -m benchmarks.bench_robustness [--smoke]
        [--out BENCH_robustness.json]

CI runs ``--smoke`` and uploads the artifact; the committed snapshot is
the tracked trajectory sample.
"""
from __future__ import annotations

import argparse
import json
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import scipy.sparse as sp  # noqa: E402

from repro.core import ic0, pcg_iteration  # noqa: E402
from repro.core import sell  # noqa: E402
from repro.core.iccg import _pcg_device  # noqa: E402
from repro.core.matrices import laplace_2d  # noqa: E402
from repro.core.solvers import _order_system  # noqa: E402
from repro.core.trisolve import \
    build_round_major_preconditioner_from_rounds  # noqa: E402
from repro.serve import (FaultInjector, SolverService,  # noqa: E402
                         VirtualClock)
from repro.serve.faults import indefinite_matrix  # noqa: E402

KNOBS = dict(method="hbmc", block_size=8, w=4)


def _operator(a):
    """Round-major preconditioner + ELL SpMV closures for ``a`` — the same
    operator pair a SolverPlan lowers, built once for both loops."""
    sysd = _order_system(sp.csr_matrix(a), None, KNOBS["method"],
                         KNOBS["block_size"], KNOBS["w"])
    pre, rm = build_round_major_preconditioner_from_rounds(
        ic0(sysd.a_bar), sysd.fwd_rounds, sysd.bwd_rounds,
        drop_mask=sysd.drop)
    a_rm = sell.permute_round_major(sysd.a_bar, rm)
    cols, vals = sell.pack_ell(a_rm)
    vals_d, cols_d = jnp.asarray(vals), jnp.asarray(cols)

    def spmv(x):
        return jnp.einsum("rk,rk->r", vals_d, x[cols_d])

    b = np.random.default_rng(0).normal(size=a.shape[0])
    sysd_b = _order_system(sp.csr_matrix(a), b, KNOBS["method"],
                           KNOBS["block_size"], KNOBS["w"])
    return spmv, pre, jnp.asarray(rm.embed(sysd_b.b_bar))


def _time_best_pair(fn_a, fn_b, repeats):
    """Interleaved best-of timing of two callables (alternating draws, so
    machine-load drift hits both fairly)."""
    best_a = best_b = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a())
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b())
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a, best_b


def bench_monitor_overhead(a, n_iters, repeats=9):
    """Monitored vs reference unmonitored PCG at a pinned iteration count.

    ``rtol=0`` makes convergence unreachable, so both loops run exactly
    ``n_iters`` iterations (the default monitor windows are wider than
    the budget and never trip on this healthy system) — the timing ratio
    is a clean per-iteration overhead measurement.
    """
    spmv, pre, b = _operator(a)

    monitored = jax.jit(lambda q: _pcg_device(spmv, pre, q, rtol=0.0,
                                              maxiter=n_iters))

    # the pre-monitor loop body: pcg_iteration plus the carried ||r||
    # reduction the convergence cond always read
    step = pcg_iteration(spmv, pre)

    def reference(q):
        bnorm = jnp.linalg.norm(q)
        z0 = pre(q)

        def cond(s):
            return (s[4] / bnorm >= 0.0) & (s[5] < n_iters)

        def body(s):
            x, r, p, rz, _, it = s
            x, r, p, rz = step(x, r, p, rz)
            return (x, r, p, rz, jnp.linalg.norm(r), it + 1)

        state = (jnp.zeros_like(q), q, z0, jnp.vdot(q, z0),
                 jnp.linalg.norm(q), jnp.asarray(0))
        x, _, _, _, rnorm, it = jax.lax.while_loop(cond, body, state)
        return x, it, rnorm / bnorm

    reference = jax.jit(reference)

    jax.block_until_ready(monitored(b))   # compile
    jax.block_until_ready(reference(b))
    t_mon, t_ref = _time_best_pair(lambda: monitored(b),
                                   lambda: reference(b), repeats)
    it_mon = int(monitored(b)[1])
    assert it_mon == n_iters, f"monitored loop ran {it_mon} != {n_iters}"
    return {
        "n_iters": n_iters,
        "monitored_s": round(t_mon, 5),
        "reference_s": round(t_ref, 5),
        "monitored_us_per_iter": round(t_mon / n_iters * 1e6, 2),
        "reference_us_per_iter": round(t_ref / n_iters * 1e6, 2),
        "overhead_pct": round((t_mon / t_ref - 1.0) * 100.0, 2),
    }


def bench_time_to_quarantine(n_side, quantum=8, maxiter=3000):
    """Dispatches from submission to retirement for injected faults, vs
    the maxiter/quantum ceiling an unmonitored column would hold its slot.
    """
    inj = FaultInjector(seed=0, n_side=n_side)
    rows = {}
    for kind, mat, b in [
            ("nan_rhs", inj.base, None),
            ("indefinite", indefinite_matrix(n_side), None)]:
        svc = SolverService(slab_width=4, quantum=quantum, maxiter=maxiter,
                            clock=VirtualClock(), **KNOBS)
        fp = inj.make(kind) if b is None else None
        rid = svc.submit(mat, fp.b if fp else b)
        steps = 0
        while rid not in svc.completed and steps < 100_000:
            svc.step()
            steps += 1
        c = svc.completed[rid]
        rows[kind] = {
            "status": c.status,
            "dispatches_to_retire": steps,
            "iterations": c.iterations,
            "virtual_latency_s": round(c.latency, 5),
            "unmonitored_dispatch_ceiling": maxiter // quantum,
        }
        assert c.failed, f"{kind} unexpectedly reported {c.status}"
    return rows


def bench_fault_mix(n_side, n_requests):
    """A seeded mixed adversarial trace drained to completion."""
    inj = FaultInjector(seed=3, n_side=n_side)
    svc = SolverService(slab_width=4, quantum=8, maxiter=3000,
                        clock=VirtualClock(), max_queue=64, **KNOBS)
    t0 = time.perf_counter()
    rids, shed = inj.inject(svc, n_requests, spacing=0.01)
    svc.drain(max_steps=200_000)
    elapsed = time.perf_counter() - t0

    by_kind: dict[str, dict[str, int]] = {}
    violations = 0
    for rid, fp in rids.items():
        st = svc.completed[rid].status
        by_kind.setdefault(fp.kind, {}).setdefault(st, 0)
        by_kind[fp.kind][st] += 1
        if st not in fp.expected:
            violations += 1
    return {
        "n_requests": n_requests,
        "n_shed": len(shed),
        "n_quarantined": svc.n_quarantined,
        "out_of_contract": violations,
        "wall_s": round(elapsed, 3),
        "statuses_by_kind": {k: dict(sorted(v.items()))
                             for k, v in sorted(by_kind.items())},
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problem, fewer iterations/requests (CI)")
    ap.add_argument("--out", default="BENCH_robustness.json")
    args = ap.parse_args()

    if args.smoke:
        problems = [("lap2d_12", laplace_2d(12, 12), 100)]
        n_side, n_req = 6, 20
    else:
        # the monitor cost is O(1) scalars per iteration against an
        # O(nnz) loop body: measure a small serving-sized problem AND a
        # paper-representative size to show the overhead vanishing
        problems = [("lap2d_32", laplace_2d(32, 32), 400),
                    ("lap2d_64", laplace_2d(64, 64), 300)]
        n_side, n_req = 6, 60

    overhead = [dict(problem=name, n=int(a.shape[0]),
                     **bench_monitor_overhead(a, n_iters))
                for name, a, n_iters in problems]
    quarantine = bench_time_to_quarantine(n_side)
    mix = bench_fault_mix(n_side, n_req)

    doc = {
        "schema": "bench_robustness/v1",
        "platform": jax.default_backend(),
        "smoke": bool(args.smoke),
        "knobs": {k: v for k, v in KNOBS.items()},
        "monitor_overhead": overhead,
        "time_to_quarantine": quarantine,
        "fault_mix": mix,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")

    for row in overhead:
        print(f"monitor overhead[{row['problem']}]: "
              f"{row['overhead_pct']:+.2f}% "
              f"({row['monitored_us_per_iter']:.2f} vs "
              f"{row['reference_us_per_iter']:.2f} us/iter over "
              f"{row['n_iters']} iters)")
    for kind, r in quarantine.items():
        print(f"time-to-quarantine[{kind}]: {r['dispatches_to_retire']} "
              f"dispatch(es) -> {r['status']} "
              f"(unmonitored ceiling {r['unmonitored_dispatch_ceiling']})")
    print(f"fault mix: {mix['n_requests']} requests, "
          f"{mix['n_quarantined']} quarantined, {mix['n_shed']} shed, "
          f"{mix['out_of_contract']} out-of-contract, "
          f"{mix['wall_s']}s wall")
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
