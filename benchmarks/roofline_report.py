"""Render the roofline table (EXPERIMENTS.md §Roofline) from dry-run JSONs."""
from __future__ import annotations

import json
import os


def load_cells(dryrun_dir: str) -> list[dict]:
    cells = []
    for fn in sorted(os.listdir(dryrun_dir)):
        if fn.endswith(".json"):
            with open(os.path.join(dryrun_dir, fn)) as f:
                cells.append(json.load(f))
    return cells


SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
               "long_500k": 3}


def render_table(dryrun_dir: str, mesh: str = "single",
                 markdown: bool = False) -> str:
    cells = [c for c in load_cells(dryrun_dir)
             if (c["chips"] == 256) == (mesh == "single")]
    cells.sort(key=lambda c: (c["arch"], SHAPE_ORDER.get(c["shape"], 9)))
    sep = " | " if markdown else "  "
    hdr = ["arch", "shape", "t_comp(s)", "t_mem(s)", "t_coll(s)",
           "bound", "useful", "roofline%"]
    lines = []
    if markdown:
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    else:
        lines.append(f"{hdr[0]:18s}{sep}{hdr[1]:12s}{sep}{hdr[2]:>10s}{sep}"
                     f"{hdr[3]:>10s}{sep}{hdr[4]:>10s}{sep}{hdr[5]:>10s}"
                     f"{sep}{hdr[6]:>7s}{sep}{hdr[7]:>9s}")
    for c in cells:
        row = [c["arch"], c["shape"],
               f"{c['t_compute_s']:.4g}", f"{c['t_memory_s']:.4g}",
               f"{c['t_collective_s']:.4g}", c["dominant"],
               f"{c['useful_flops_ratio']:.3f}",
               f"{100*c.get('roofline_fraction', 0):.2f}%"]
        if markdown:
            lines.append("| " + " | ".join(row) + " |")
        else:
            lines.append(f"{row[0]:18s}{sep}{row[1]:12s}{sep}{row[2]:>10s}"
                         f"{sep}{row[3]:>10s}{sep}{row[4]:>10s}{sep}"
                         f"{row[5]:>10s}{sep}{row[6]:>7s}{sep}{row[7]:>9s}")
    return "\n".join(lines)


def render_detail(cell: dict) -> str:
    out = [f"### {cell['arch']} x {cell['shape']} ({cell['chips']} chips)"]
    out.append(f"- FLOPs/device: {cell['flops_per_device']:.3e} "
               f"(model: {cell['model_flops_per_device']:.3e}, "
               f"useful ratio {cell['useful_flops_ratio']:.3f})")
    out.append(f"- bytes/device: {cell['bytes_per_device']:.3e}")
    out.append(f"- collective bytes/device: "
               f"{cell['collective_bytes_per_device']:.3e} "
               f"{cell['collective_counts']}")
    out.append(f"- terms: compute {cell['t_compute_s']:.4g}s | memory "
               f"{cell['t_memory_s']:.4g}s | collective "
               f"{cell['t_collective_s']:.4g}s -> dominant: "
               f"**{cell['dominant']}**")
    return "\n".join(out)


if __name__ == "__main__":
    import sys
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    print(render_table(d, mesh="single"))
    print()
    print(render_table(d, mesh="multi"))
