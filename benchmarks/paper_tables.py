"""Benchmarks reproducing the paper's tables/figures.

Table 5.2  -> iterations_table():     #iterations MC / BMC / HBMC
Table 5.3  -> trisolve_table():       sparse-triangular-solver + SpMV timing
              (CPU-host analogue of the paper's per-node timings; the TPU
              projection lives in the dry-run roofline)
Fig  5.1   -> convergence_overlay():  BMC vs HBMC residual histories
§5.2.1     -> lane_occupancy_table(): vector-lane utilization (the SIMD-
              instruction-percentage analogue)
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import (block_multicolor_ordering, build_preconditioner,
                        hbmc_from_bmc, ic0, pad_system_hbmc, solve_iccg,
                        solve_iccg_batched)
from repro.core.matrices import PAPER_PROBLEMS, PAPER_SHIFTS, paper_problem
from repro.core.sell import pack_sell, pack_ell

BS, W = 8, 8          # block size / lane width used across tables
RTOL = 1e-7           # paper's convergence criterion


def _problems(scale):
    out = []
    for name in PAPER_PROBLEMS:
        a, desc = paper_problem(name, scale=scale)
        rng = np.random.default_rng(42)
        b = rng.normal(size=a.shape[0])
        out.append((name, a, b, PAPER_SHIFTS.get(name, 0.0)))
    return out


def iterations_table(scale="small"):
    rows = []
    for name, a, b, shift in _problems(scale):
        its = {}
        for m in ("mc", "bmc", "hbmc"):
            rep = solve_iccg(a, b, method=m, block_size=BS, w=W, shift=shift,
                             rtol=RTOL)
            its[m] = rep.result.iterations
        assert its["bmc"] == its["hbmc"], \
            f"equivalence violated on {name}: {its}"
        rows.append((name, a.shape[0], its["mc"], its["bmc"], its["hbmc"]))
    return rows


def trisolve_table(scale="small", reps=5):
    """Per-application timing of the triangular solve + SpMV variants."""
    rows = []
    for name, a, b, shift in _problems(scale):
        timings = {}
        for m in ("mc", "bmc", "hbmc"):
            rep = solve_iccg(a, b, method=m, block_size=BS, w=W, shift=shift,
                             rtol=RTOL, maxiter=30)   # fixed 30 iterations
            # per-iteration solver time (PCG = 1 precond + 1 spmv + O(n))
            timings[m] = rep.solve_seconds / max(rep.result.iterations, 1)
        rows.append((name, a.shape[0],
                     timings["mc"] * 1e6, timings["bmc"] * 1e6,
                     timings["hbmc"] * 1e6))
    return rows


def spmv_padding_table(scale="small"):
    """SELL-w padding overhead (the paper's Audikw_1 discussion, §5.2.2)."""
    rows = []
    for name, a, b, shift in _problems(scale):
        sm = pack_sell(a, W)
        cols, vals = pack_ell(a)
        ell_padded = vals.size
        rows.append((name, a.nnz,
                     sm.padded_nnz / a.nnz,      # SELL overhead factor
                     ell_padded / a.nnz))        # ELL (CRS-gather) overhead
    return rows


def convergence_overlay(name="g3_circuit", scale="small"):
    a, _ = paper_problem(name, scale=scale)
    b = np.random.default_rng(42).normal(size=a.shape[0])
    r1 = solve_iccg(a, b, method="bmc", block_size=BS, w=W, rtol=RTOL,
                    record_history=True)
    r2 = solve_iccg(a, b, method="hbmc", block_size=BS, w=W, rtol=RTOL,
                    record_history=True)
    h1, h2 = r1.result.history, r2.result.history
    m = ~np.isnan(h1) & ~np.isnan(h2)
    return h1[m], h2[m], float(np.max(np.abs(h1[m] - h2[m])))


def backend_table(scale="small", reps=3):
    """Per-apply preconditioner timing: XLA substitution vs Pallas kernel.

    NOTE: off-TPU the Pallas kernel runs in *interpret* mode, so its numbers
    here measure semantics and dispatch overhead, not TPU performance — the
    comparison that matters on hardware is re-run with ``interpret=False``.
    """
    rows = []
    for name, a, b, shift in _problems(scale):
        bmc = block_multicolor_ordering(a, BS)
        hb = hbmc_from_bmc(bmc, W)
        a_hb, b_hb = pad_system_hbmc(a, b, hb)
        l = ic0(a_hb, shift=shift)
        r = jnp.asarray(b_hb)
        timings = {}
        for backend in ("xla", "pallas"):
            pre = build_preconditioner(l, hb, backend=backend)
            pre(r).block_until_ready()          # compile + warm cache
            t0 = time.perf_counter()
            for _ in range(reps):
                pre(r).block_until_ready()
            timings[backend] = (time.perf_counter() - t0) / reps
        rows.append((name, a.shape[0], timings["xla"] * 1e6,
                     timings["pallas"] * 1e6))
    return rows


def batched_throughput_table(scale="small", batch=8, maxiter=40):
    """Per-RHS PCG-loop time: B sequential single-RHS runs vs one batched
    multi-RHS run (per-RHS convergence masking).  The batched loop runs
    max(iterations) rounds total instead of sum(iterations).

    Only ``solve_seconds`` is compared — host setup (ordering + IC(0) +
    packing) is identical for both paths, so charging B setups to the
    sequential side would inflate the speedup.  ``solve_seconds`` still
    includes per-call trace/dispatch of the while_loop (each solve builds
    fresh closures), which the batched side pays once and the sequential
    side pays B times; that amortization is a real benefit of batching but
    means the ratio is wall-clock, not pure device-loop throughput."""
    rows = []
    for name, a, b, shift in _problems(scale):
        rng = np.random.default_rng(7)
        bb = rng.normal(size=(a.shape[0], batch))
        # warm the compile caches with one throwaway solve of each shape
        solve_iccg(a, bb[:, 0], method="hbmc", block_size=BS, w=W,
                   shift=shift, maxiter=maxiter)
        solve_iccg_batched(a, bb, method="hbmc", block_size=BS, w=W,
                           shift=shift, maxiter=maxiter)
        single = [solve_iccg(a, bb[:, j], method="hbmc", block_size=BS, w=W,
                             shift=shift, maxiter=maxiter)
                  for j in range(batch)]
        t_single = sum(s.solve_seconds for s in single)
        rep_b = solve_iccg_batched(a, bb, method="hbmc", block_size=BS, w=W,
                                   shift=shift, maxiter=maxiter)
        t_batched = rep_b.solve_seconds
        # batched == single iteration counts is expected but float-sequence
        # dependent; warn (don't abort the whole run) if a backend diverges
        if any(int(s.result.iterations) != int(it)
               for s, it in zip(single, rep_b.result.iterations)):
            print(f"WARNING: {name}: batched iterations "
                  f"{list(rep_b.result.iterations)} != single "
                  f"{[s.result.iterations for s in single]}")
        rows.append((name, a.shape[0], batch,
                     t_single / batch * 1e6,        # us per RHS, sequential
                     t_batched / batch * 1e6,       # us per RHS, batched
                     t_single / max(t_batched, 1e-12)))
    return rows


def lane_occupancy_table(scale="small"):
    """HBMC rounds use w parallel lanes (occupancy ~1); BMC's in-block loop
    is sequential = 1/w of the lanes — the paper's 99.7% vs 12.7% packed-
    instruction measurement, reconstructed structurally."""
    rows = []
    for name, a, b, shift in _problems(scale):
        rep_h = solve_iccg(a, b, method="hbmc", block_size=BS, w=W,
                           shift=shift, maxiter=1)
        rows.append((name, rep_h.lane_occupancy, 1.0 / W,
                     rep_h.n_colors, rep_h.n_rounds))
    return rows
