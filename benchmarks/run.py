"""Benchmark harness: one function per paper table + roofline summary.

    PYTHONPATH=src python -m benchmarks.run [--scale small|bench]

Prints ``name,us_per_call,derived`` CSV rows per the harness contract, then
human-readable tables.
"""
from __future__ import annotations

import argparse
import os
import time

import jax

jax.config.update("jax_enable_x64", True)


def _timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small", choices=("tiny", "small",
                                                         "bench"))
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    args = ap.parse_args()

    from benchmarks import paper_tables as T

    csv_rows = []

    # ---- Table 5.2: iterations ------------------------------------------
    rows, us = _timed(T.iterations_table, scale=args.scale)
    csv_rows.append(("table5.2_iterations", us,
                     ";".join(f"{r[0]}:mc={r[2]}/bmc={r[3]}/hbmc={r[4]}"
                              for r in rows)))
    print("\n== Table 5.2 analogue: ICCG iterations (rtol 1e-7) ==")
    print(f"{'dataset':16s} {'n':>8s} {'MC':>6s} {'BMC':>6s} {'HBMC':>6s}")
    for name, n, mc, bmc, hbmc in rows:
        print(f"{name:16s} {n:8d} {mc:6d} {bmc:6d} {hbmc:6d}")
    print("BMC == HBMC on every dataset (equivalence, paper §4.2.1): OK")

    # ---- Table 5.3: solver timing ----------------------------------------
    rows, us = _timed(T.trisolve_table, scale=args.scale)
    csv_rows.append(("table5.3_solver_time", us,
                     ";".join(f"{r[0]}:{r[4]:.0f}us" for r in rows)))
    print("\n== Table 5.3 analogue: per-iteration solver time (us, CPU) ==")
    print(f"{'dataset':16s} {'n':>8s} {'MC':>10s} {'BMC':>10s} {'HBMC':>10s}")
    for name, n, mc, bmc, hbmc in rows:
        print(f"{name:16s} {n:8d} {mc:10.0f} {bmc:10.0f} {hbmc:10.0f}")

    # ---- SELL padding (Audikw_1 discussion) ------------------------------
    rows, us = _timed(T.spmv_padding_table, scale=args.scale)
    csv_rows.append(("sell_padding", us,
                     ";".join(f"{r[0]}:{r[2]:.2f}x" for r in rows)))
    print("\n== SELL-w padding overhead (paper §5.2.2) ==")
    print(f"{'dataset':16s} {'nnz':>10s} {'SELL/nnz':>9s} {'ELL/nnz':>9s}")
    for name, nnz, sell, ell in rows:
        print(f"{name:16s} {nnz:10d} {sell:9.2f} {ell:9.2f}")

    # ---- Fig 5.1: convergence overlay ------------------------------------
    (h1, h2, dmax), us = _timed(T.convergence_overlay, scale=args.scale)
    csv_rows.append(("fig5.1_convergence_overlay", us, f"maxdiff={dmax:.2e}"))
    print(f"\n== Fig 5.1 analogue: BMC vs HBMC residual overlay "
          f"({len(h1)} its, max |diff| = {dmax:.2e}) ==")

    # ---- Backend comparison: XLA vs Pallas trisolve ----------------------
    rows, us = _timed(T.backend_table, scale=args.scale)
    csv_rows.append(("backend_xla_vs_pallas", us,
                     ";".join(f"{r[0]}:xla={r[2]:.0f}us/pallas={r[3]:.0f}us"
                              for r in rows)))
    print("\n== Preconditioner apply: XLA vs Pallas backend "
          "(interpret mode off-TPU) ==")
    print(f"{'dataset':16s} {'n':>8s} {'XLA us':>10s} {'Pallas us':>10s}")
    for name, n, t_xla, t_pal in rows:
        print(f"{name:16s} {n:8d} {t_xla:10.0f} {t_pal:10.0f}")

    # ---- Batched multi-RHS throughput ------------------------------------
    rows, us = _timed(T.batched_throughput_table, scale=args.scale)
    csv_rows.append(("batched_multirhs", us,
                     ";".join(f"{r[0]}:B={r[2]}x{r[5]:.2f}x" for r in rows)))
    print("\n== Batched multi-RHS PCG (one while_loop, per-RHS masking) ==")
    print(f"{'dataset':16s} {'n':>8s} {'B':>4s} {'seq us/RHS':>11s} "
          f"{'bat us/RHS':>11s} {'speedup':>8s}")
    for name, n, bsz, us_seq, us_bat, speed in rows:
        print(f"{name:16s} {n:8d} {bsz:4d} {us_seq:11.0f} {us_bat:11.0f} "
              f"{speed:7.2f}x")

    # ---- §5.2.1: lane occupancy ------------------------------------------
    rows, us = _timed(T.lane_occupancy_table, scale=args.scale)
    csv_rows.append(("lane_occupancy", us,
                     ";".join(f"{r[0]}:{r[1]*100:.1f}%" for r in rows)))
    print("\n== Vector-lane occupancy (SIMD-utilization analogue) ==")
    print(f"{'dataset':16s} {'HBMC':>7s} {'BMC':>7s} {'colors':>7s} "
          f"{'rounds':>7s}")
    for name, occ, bmc_occ, ncol, nrounds in rows:
        print(f"{name:16s} {occ*100:6.1f}% {bmc_occ*100:6.1f}% "
              f"{ncol:7d} {nrounds:7d}")

    # ---- Roofline summary from the dry-run -------------------------------
    if os.path.isdir(args.dryrun_dir) and os.listdir(args.dryrun_dir):
        from benchmarks.roofline_report import render_table
        print("\n== Roofline (from multi-pod dry-run) ==")
        print(render_table(args.dryrun_dir))
        csv_rows.append(("roofline_cells", 0.0,
                         f"{len(os.listdir(args.dryrun_dir))} cells"))

    print("\n--- CSV ---")
    print("name,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
