"""Inject the dry-run summary + roofline tables into EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.update_experiments [dryrun_dir]
"""
from __future__ import annotations

import re
import sys

from benchmarks.roofline_report import load_cells, render_table


def dryrun_summary(dryrun_dir: str) -> str:
    cells = load_cells(dryrun_dir)
    singles = [c for c in cells if c["chips"] == 256]
    multis = [c for c in cells if c["chips"] == 512]
    lines = [
        f"Compiled cells: **{len(singles)} single-pod + {len(multis)} "
        f"multi-pod = {len(cells)}** (all runnable cells on both meshes).",
        "",
        "| arch | shape | mesh | µbatches | temps/dev (GiB) | args/dev (GiB) | compile (s) |",
        "|---|---|---|---|---|---|---|",
    ]
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"],
                                          c["chips"])):
        mesh = "2x16x16" if c["chips"] == 512 else "16x16"
        lines.append(
            f"| {c['arch']} | {c['shape']} | {mesh} | "
            f"{c.get('microbatches', '-')} | "
            f"{c.get('temp_size_in_bytes', 0)/2**30:.1f} | "
            f"{c.get('argument_size_in_bytes', 0)/2**30:.1f} | "
            f"{c.get('compile_seconds', 0):.0f} |")
    return "\n".join(lines)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    with open("EXPERIMENTS.md") as f:
        text = f.read()

    summary = dryrun_summary(d)
    table_s = render_table(d, mesh="single", markdown=True)
    table_m = render_table(d, mesh="multi", markdown=True)
    roof = ("### Single-pod (16x16 = 256 chips)\n\n" + table_s +
            "\n\n### Multi-pod (2x16x16 = 512 chips)\n\n" + table_m)

    text = re.sub(r"<!-- DRYRUN_SUMMARY -->.*?(?=\n## )",
                  "<!-- DRYRUN_SUMMARY -->\n" + summary + "\n\n",
                  text, flags=re.S)
    text = re.sub(r"<!-- ROOFLINE_TABLE -->.*?(?=\n## )",
                  "<!-- ROOFLINE_TABLE -->\n" + roof + "\n\n",
                  text, flags=re.S)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated from", d)


if __name__ == "__main__":
    main()
