"""Trisolve + SpMV hot-loop benchmark: layouts, backends, iteration parts.

Compares the two PCG-loop layouts (``layout="index"`` — the pre-refactor
path that gathers/scatters between index space and the solve layout on
every preconditioner apply — against ``layout="round_major"`` — the native
path where the whole loop lives in execution-order coordinates and the
fwd+bwd sweeps run fused), across backends and batch sizes, and breaks ONE
PCG iteration into its parts (SpMV, preconditioner apply, vector work —
dots/axpys/norm) per backend pair so the trajectory tracks the full
iteration, not just the apply.

    PYTHONPATH=src python -m benchmarks.bench_trisolve [--smoke]
        [--out BENCH_trisolve.json]

Emits machine-readable ``BENCH_trisolve.json`` (schema ``bench_trisolve/v2``)
so the perf trajectory is tracked PR over PR; CI runs ``--smoke`` and
uploads the file as an artifact.  Off-TPU the Pallas rows (trisolve AND
SpMV kernels) run in interpret mode — they measure semantics/dispatch, not
TPU performance (``derived`` speedups therefore come from the compiled XLA
rows).
"""
from __future__ import annotations

import argparse
import json
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import scipy.sparse as sp  # noqa: E402

from repro.core import (LAYOUTS, RoundMajorPreconditioner,  # noqa: E402
                        build_round_major_preconditioner_from_rounds, sell,
                        solve_iccg, solve_iccg_batched)
from repro.core.ic0 import ic0_refactor, ic0_structure  # noqa: E402
from repro.core.matrices import laplace_2d, laplace_3d  # noqa: E402
from repro.core.plan import _make_spmv  # noqa: E402
from repro.core.solvers import _build_operators, _order_system  # noqa: E402

BS, W = 8, 8
BATCHES = (1, 8)
SPMV_BACKENDS = ("xla", "pallas")


def _problems(smoke: bool):
    if smoke:
        return [("lap2d_tiny", laplace_2d(16, 14)),
                ("lap3d_tiny", laplace_3d(6, 6, 5))]
    return [("lap2d_64", laplace_2d(64, 64)),
            ("lap3d_16", laplace_3d(16, 16, 16))]


def _time_call(fn, args, reps):
    """Best-of-reps call time for a function returning any pytree (min is
    robust to scheduler noise)."""
    jax.block_until_ready(fn(*args))         # compile + warm cache
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _time_apply(apply_fn, r, reps):
    """Best-of-reps per-apply time."""
    return _time_call(apply_fn, (r,), reps)


@jax.jit
def _vec_work_single(x, r, p, ap, z, rz):
    """The non-SpMV, non-precond part of one PCG step (dots/axpys/norm)."""
    alpha = rz / jnp.vdot(p, ap)
    x = x + alpha * p
    r = r - alpha * ap
    rz_new = jnp.vdot(r, z)
    beta = rz_new / rz
    p = z + beta * p
    return x, r, p, rz_new, jnp.linalg.norm(r)


@jax.jit
def _vec_work_batched(x, r, p, ap, z, rz):
    pap = jnp.einsum("nb,nb->b", p, ap)
    alpha = rz / pap
    x = x + alpha[None, :] * p
    r = r - alpha[None, :] * ap
    rz_new = jnp.einsum("nb,nb->b", r, z)
    beta = rz_new / rz
    p = z + beta[None, :] * p
    return x, r, p, rz_new, jnp.linalg.norm(r, axis=0)


def bench_iteration_breakdown(name, a, *, reps):
    """One PCG iteration split into its parts, native round-major layout.

    Rows: (component ∈ {spmv, precond, vector}) × (backend ∈ {xla, pallas};
    vector work is always compiled XLA) × B ∈ {1, 8}, all on the SELL-w
    operand so the two SpMV backends price the same layout.
    """
    rng = np.random.default_rng(7)
    sysd = _order_system(sp.csr_matrix(a), None, "hbmc", BS, W)
    # factor + pack once; the two trisolve backends share the device tables
    st = ic0_structure(sysd.a_bar, sysd.fwd_rounds)
    l_bar = ic0_refactor(st, sysd.a_bar)
    pre_xla, rm = build_round_major_preconditioner_from_rounds(
        l_bar, sysd.fwd_rounds, sysd.bwd_rounds, drop_mask=sysd.drop)
    precs = {"xla": pre_xla,
             "pallas": RoundMajorPreconditioner(tables=pre_xla.tables,
                                                backend="pallas")}
    a_rm = sell.permute_round_major(sysd.a_bar, rm)
    sm = sell.pack_sell(a_rm, W)
    vals, cols = jnp.asarray(sm.vals), jnp.asarray(sm.cols)
    m = rm.m
    rows = []

    def row(component, backend, batch, us):
        rows.append({"problem": name, "n": int(a.shape[0]), "m": int(m),
                     "component": component, "backend": backend,
                     "B": batch, "us": round(us, 1)})

    for batch in BATCHES:
        shape = (m,) if batch == 1 else (m, batch)
        r = jnp.asarray(rng.normal(size=shape))
        for sb in SPMV_BACKENDS:
            spmv = jax.jit(_make_spmv("sell", m, vals, cols,
                                      batched=batch != 1, spmv_backend=sb))
            row("spmv", sb, batch, _time_apply(spmv, r, reps))
        for tb in SPMV_BACKENDS:
            apply_fn = precs[tb] if batch == 1 else precs[tb].apply_batched
            row("precond", tb, batch, _time_apply(apply_fn, r, reps))
        vw = _vec_work_single if batch == 1 else _vec_work_batched
        rz = jnp.asarray(1.0) if batch == 1 else jnp.ones(batch)
        row("vector", "xla", batch, _time_call(vw, (r, r, r, r, r, rz),
                                               reps))
    return rows


def bench_problem(name, a, *, maxiter, reps, smoke, backends):
    """One row per (layout, backend, B): precond-apply and PCG wall-clock."""
    rng = np.random.default_rng(42)
    n = a.shape[0]
    b1 = rng.normal(size=n)
    bb = rng.normal(size=(n, max(BATCHES)))
    rows = []
    sysd = _order_system(sp.csr_matrix(a), None, "hbmc", BS, W)
    for layout in LAYOUTS:
        for backend in backends:
            # --- raw preconditioner apply (the per-iteration hot spot) ----
            # one operator build serves both batch sizes (single-RHS apply
            # via __call__, multi-RHS via apply_batched)
            precond, _, rm = _build_operators(
                sysd, 0.0, "ell", W, jnp.float64, backend, None, layout,
                batched=False)
            dim = rm.m if rm is not None else sysd.n_padded
            apply_us = {}
            for batch in BATCHES:
                apply_fn = precond if batch == 1 else precond.apply_batched
                r = jnp.asarray(rng.normal(
                    size=(dim,) if batch == 1 else (dim, batch)))
                apply_us[batch] = _time_apply(apply_fn, r, reps)
            # --- full PCG loop at fixed maxiter (rtol=0 -> exact count) ---
            # Pallas solves off-TPU run the interpreter inside a while_loop;
            # skip them outside smoke mode (apply timing above still covers
            # the kernel), matching paper_tables.backend_table's caveat.
            solve_us = {}
            iterations = {}
            if backend == "xla" or smoke:
                for batch in BATCHES:
                    kw = dict(method="hbmc", block_size=BS, w=W, rtol=0.0,
                              maxiter=maxiter, backend=backend, layout=layout)
                    if batch == 1:
                        solve_iccg(a, b1, **kw)            # warm compile
                        rep = solve_iccg(a, b1, **kw)
                        its = rep.result.iterations
                    else:
                        bj = bb[:, :batch]
                        solve_iccg_batched(a, bj, **kw)
                        rep = solve_iccg_batched(a, bj, **kw)
                        its = int(np.max(rep.result.iterations))
                    solve_us[batch] = rep.solve_seconds * 1e6
                    iterations[batch] = int(its)
            for batch in BATCHES:
                rows.append({
                    "problem": name, "n": int(n), "layout": layout,
                    "backend": backend, "B": batch,
                    "apply_us": round(apply_us[batch], 1),
                    "solve_us": (round(solve_us[batch], 1)
                                 if batch in solve_us else None),
                    "iterations": iterations.get(batch),
                })
    return rows


def derive_speedups(rows):
    """round-major-native speedup over the index path, compiled XLA rows."""
    out = {}
    key = lambda r: (r["problem"], r["B"])
    index_rows = {key(r): r for r in rows
                  if r["layout"] == "index" and r["backend"] == "xla"}
    for r in rows:
        if r["layout"] != "round_major" or r["backend"] != "xla":
            continue
        base = index_rows.get(key(r))
        if base is None:
            continue
        entry = {"apply_speedup": round(base["apply_us"] / r["apply_us"], 3)}
        if base["solve_us"] and r["solve_us"]:
            entry["solve_speedup"] = round(base["solve_us"] / r["solve_us"],
                                           3)
        out[f"{r['problem']}_B{r['B']}"] = entry
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problems + interpret-mode pallas (CI)")
    ap.add_argument("--out", default="BENCH_trisolve.json")
    ap.add_argument("--maxiter", type=int, default=None)
    ap.add_argument("--reps", type=int, default=None)
    args = ap.parse_args()

    maxiter = args.maxiter or (10 if args.smoke else 60)
    reps = args.reps or (3 if args.smoke else 10)
    backends = ("xla", "pallas")

    rows = []
    breakdown = []
    for name, a in _problems(args.smoke):
        rows.extend(bench_problem(name, a, maxiter=maxiter, reps=reps,
                                  smoke=args.smoke, backends=backends))
        breakdown.extend(bench_iteration_breakdown(name, a, reps=reps))

    doc = {
        "schema": "bench_trisolve/v2",
        "platform": jax.default_backend(),
        "smoke": bool(args.smoke),
        "maxiter": maxiter,
        "block_size": BS,
        "w": W,
        "results": rows,
        "iteration_breakdown": breakdown,
        "derived": derive_speedups(rows),
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")

    hdr = (f"{'problem':12s} {'layout':12s} {'backend':7s} {'B':>2s} "
           f"{'apply us':>10s} {'solve us':>12s}")
    print(hdr)
    for r in rows:
        solve = f"{r['solve_us']:12.0f}" if r["solve_us"] else " " * 12
        print(f"{r['problem']:12s} {r['layout']:12s} {r['backend']:7s} "
              f"{r['B']:2d} {r['apply_us']:10.1f} {solve}")
    print("\nper-iteration breakdown (round-major, SELL operand):")
    print(f"{'problem':12s} {'component':10s} {'backend':7s} {'B':>2s} "
          f"{'us':>10s}")
    for r in breakdown:
        print(f"{r['problem']:12s} {r['component']:10s} {r['backend']:7s} "
              f"{r['B']:2d} {r['us']:10.1f}")

    print("\nround-major-native speedup over index layout (xla):")
    for k, v in doc["derived"].items():
        parts = [f"apply {v['apply_speedup']:.2f}x"]
        if "solve_speedup" in v:
            parts.append(f"solve {v['solve_speedup']:.2f}x")
        print(f"  {k:20s} {'  '.join(parts)}")
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
