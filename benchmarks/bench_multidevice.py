"""Multi-device solver benchmark: the mesh-aware SolverPlan across forced
host device counts.

JAX pins the device count at first init, so the parent process spawns one
child per device count (``XLA_FLAGS=--xla_force_host_platform_device_count``)
and merges their rows:

    PYTHONPATH=src python -m benchmarks.bench_multidevice [--smoke]
        [--out BENCH_multidevice.json]

Per device count d: a mesh plan over a (d,)-mesh for hbmc/bmc x B in
{1, 8}, timing the raw distributed preconditioner apply (the fused sweep,
one all-gather per round) and the warm ``plan.solve``/``solve_batched``
wall-clock at a fixed iteration count.  ``d=1`` additionally records the
meshless plan as the no-collectives baseline.

Emits ``BENCH_multidevice.json`` (schema ``bench_multidevice/v1``).  NOTE:
on a CPU host the "devices" are XLA host-platform threads, so the rows
track the COST of distribution (collective per round + replicated state)
rather than a speedup — the tripwire is that semantics hold (identical
iteration counts, see ``iters_equal``) and that per-round collective
overhead stays bounded.  On a real TPU/GPU mesh the same rows measure
genuine strong scaling of the sharded tables/operands.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

BS_DEFAULT, W_DEFAULT = 16, 8
BATCHES = (1, 8)
DEVICE_COUNTS = (1, 2, 4, 8)
METHODS = ("hbmc", "bmc")


# ---------------------------------------------------------------------------
# Child: runs under a forced device count, writes its rows to --child-out.
# ---------------------------------------------------------------------------

def _child(args) -> None:
    import jax

    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np

    from repro.core.matrices import laplace_2d
    from repro.core.plan import build_plan

    n_dev = args.devices
    assert len(jax.devices()) == n_dev, (len(jax.devices()), n_dev)
    if args.smoke:
        a, bs, w = laplace_2d(16, 14), 8, 4
    else:
        a, bs, w = laplace_2d(64, 64), BS_DEFAULT, W_DEFAULT
    n = a.shape[0]
    rng = np.random.default_rng(42)
    b1 = rng.normal(size=n)
    bb = rng.normal(size=(n, max(BATCHES)))
    mesh = jax.make_mesh((n_dev,), ("data",))

    def time_best(fn, reps):
        fn()                                   # compile + warm caches
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best * 1e6

    rows = []
    plans = {}
    for method in METHODS:
        plans[(method, True)] = build_plan(a, method=method, block_size=bs,
                                           w=w, mesh=mesh)
        if n_dev == 1:                         # meshless baseline
            plans[(method, False)] = build_plan(a, method=method,
                                                block_size=bs, w=w)
    for (method, meshed), plan in sorted(plans.items()):
        tab = plan._precond.tables
        dim = tab.n_steps * tab.lanes
        for batch in BATCHES:
            r = jnp.asarray(rng.normal(
                size=(dim,) if batch == 1 else (dim, batch)))
            if plan.mesh is not None:
                from jax.sharding import NamedSharding, PartitionSpec as P
                r = jax.device_put(r, NamedSharding(mesh, P()))
            apply_fn = (plan._precond if batch == 1
                        else plan._precond.apply_batched)
            # jit the apply: an eager shard_map closure would re-trace per
            # call, so the un-jitted number is compile time, not the sweep
            apply_jit = jax.jit(lambda rr, f=apply_fn: f(rr))
            apply_us = time_best(
                lambda: apply_jit(r).block_until_ready(), args.reps)
            # real tolerance (not rtol=0): the recorded iteration counts are
            # the actual Krylov trajectory, so `iters_equal` across device
            # counts is a meaningful semantics tripwire
            kw = dict(rtol=1e-7, maxiter=args.maxiter)
            if batch == 1:
                plan.solve(b1, **kw)           # warm compile
                rep = plan.solve(b1, **kw)
                its = int(rep.result.iterations)
            else:
                plan.solve_batched(bb[:, :batch], **kw)
                rep = plan.solve_batched(bb[:, :batch], **kw)
                its = int(np.max(rep.result.iterations))
            rows.append({
                "n_devices": n_dev, "mesh": meshed, "method": method,
                "B": batch, "n": int(n),
                "rounds": int(tab.n_steps), "lanes": int(tab.lanes),
                "apply_us": round(apply_us, 1),
                "solve_us": round(rep.solve_seconds * 1e6, 1),
                "iterations": its,
            })
    with open(args.child_out, "w") as f:
        json.dump(rows, f)


# ---------------------------------------------------------------------------
# Parent: one child per device count, merged doc + derived breakdown.
# ---------------------------------------------------------------------------

def _derived(rows):
    """Per-(method, B) apply/solve trajectory over device counts, relative
    to the 1-device mesh row, plus the semantics tripwire."""
    out = {}
    base = {(r["method"], r["B"]): r for r in rows
            if r["mesh"] and r["n_devices"] == 1}
    for r in rows:
        if not r["mesh"]:
            continue
        b = base.get((r["method"], r["B"]))
        if b is None:
            continue
        key = f"{r['method']}_B{r['B']}"
        entry = out.setdefault(key, {"apply_us_by_devices": {},
                                     "solve_us_by_devices": {},
                                     "iters_equal": True})
        d = str(r["n_devices"])
        entry["apply_us_by_devices"][d] = r["apply_us"]
        entry["solve_us_by_devices"][d] = r["solve_us"]
        entry["iters_equal"] &= (r["iterations"] == b["iterations"])
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problem + few reps (CI)")
    ap.add_argument("--out", default="BENCH_multidevice.json")
    ap.add_argument("--maxiter", type=int, default=None)
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--devices", type=int, default=None,
                    help="(child) forced device count")
    ap.add_argument("--child-out", default=None, help="(child) row file")
    args = ap.parse_args()
    # defaults sit ABOVE the convergence point of the bench problems (~8
    # iters smoke, ~43 full), so the recorded counts are the real Krylov
    # trajectory and `iters_equal` is a meaningful tripwire, never the cap
    args.maxiter = args.maxiter or (50 if args.smoke else 120)
    args.reps = args.reps or (3 if args.smoke else 10)

    if args.child_out is not None:
        _child(args)
        return

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    rows = []
    for n_dev in DEVICE_COUNTS:
        with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
            child_out = f.name
        env = dict(os.environ)
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_dev} "
                            + env.get("XLA_FLAGS", "")).strip()
        env["PYTHONPATH"] = (os.path.join(repo, "src") + os.pathsep
                             + env.get("PYTHONPATH", "")).rstrip(os.pathsep)
        cmd = [sys.executable, "-m", "benchmarks.bench_multidevice",
               "--devices", str(n_dev), "--child-out", child_out,
               "--maxiter", str(args.maxiter), "--reps", str(args.reps)]
        if args.smoke:
            cmd.append("--smoke")
        print(f"[bench_multidevice] devices={n_dev} ...", flush=True)
        proc = subprocess.run(cmd, env=env, cwd=repo, capture_output=True,
                              text=True, timeout=1800)
        if proc.returncode != 0:
            sys.stderr.write(proc.stderr[-4000:])
            raise SystemExit(f"child failed for devices={n_dev}")
        with open(child_out) as f:
            rows.extend(json.load(f))
        os.unlink(child_out)

    import jax  # parent only needs the platform tag

    doc = {
        "schema": "bench_multidevice/v1",
        "platform": jax.default_backend(),
        "smoke": bool(args.smoke),
        "maxiter": args.maxiter,
        "device_counts": list(DEVICE_COUNTS),
        "results": rows,
        "derived": _derived(rows),
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")

    hdr = (f"{'devices':>7s} {'mesh':>5s} {'method':7s} {'B':>2s} "
           f"{'apply us':>10s} {'solve us':>12s} {'iters':>6s}")
    print(hdr)
    for r in rows:
        print(f"{r['n_devices']:7d} {str(r['mesh']):>5s} {r['method']:7s} "
              f"{r['B']:2d} {r['apply_us']:10.1f} {r['solve_us']:12.0f} "
              f"{r['iterations']:6d}")
    for k, v in doc["derived"].items():
        flag = "OK" if v["iters_equal"] else "MISMATCH"
        print(f"  {k:12s} iters {flag}  apply {v['apply_us_by_devices']}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
