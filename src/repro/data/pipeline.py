"""Deterministic synthetic data pipeline, shardable and resumable.

Design points that matter at cluster scale:
  * **stateless indexing** — batch contents are a pure function of
    (seed, step, host), so restart-from-checkpoint resumes the exact
    stream with no pipeline state to persist beyond the step counter;
  * **per-host sharding** — each host materializes only its slice of the
    global batch (``host_slice``), the standard multi-pod input layout;
  * **straggler-free** — no host ever waits on a shared queue; generation
    is compute-trivial and prefetchable a step ahead.

The token distribution is a Zipfian mixture with a Markov overlay so models
actually learn during the example runs (loss visibly decreases), unlike
uniform-random tokens.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))


def host_slice(cfg: DataConfig) -> tuple[int, int]:
    per = cfg.global_batch // cfg.n_hosts
    return cfg.host_id * per, per


def sample_batch(cfg: DataConfig, step: int) -> dict:
    """Returns {"inputs": (b, S) int32, "labels": (b, S) int32} for this
    host's slice of the global batch."""
    rng = _batch_rng(cfg, step)
    _, per = host_slice(cfg)
    v = cfg.vocab
    # Zipf base distribution
    ranks = np.arange(1, v + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    base = rng.choice(v, size=(per, cfg.seq_len + 1), p=probs)
    # Markov overlay: with p=0.5, next token = f(prev) (learnable structure)
    mult = 6364136223846793005 % v
    prev = base[:, :-1]
    succ = (prev * mult + 12345) % v
    mask = rng.random((per, cfg.seq_len)) < 0.5
    seq = base.copy()
    seq[:, 1:][mask] = succ[mask]
    return {"inputs": seq[:, :-1].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32)}


def sample_embedding_batch(cfg: DataConfig, step: int, d_model: int) -> dict:
    """Frontend-stub batch for [vlm]/[audio] archs: precomputed frame/patch
    embeddings + token labels."""
    tok = sample_batch(cfg, step)
    rng = _batch_rng(cfg, step + 2**20)
    _, per = host_slice(cfg)
    emb = rng.normal(0, 0.5, size=(per, cfg.seq_len, d_model))
    return {"inputs": emb.astype(np.float32), "labels": tok["labels"]}
