"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(arch, shape)`` returns the abstract arguments of the step
function that the dry-run lowers, with NamedShardings attached.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import (batch_partition_spec, cache_partition_spec,
                                 params_shardings)
from repro.models import init_cache, param_specs
from repro.models.config import ArchConfig
from repro.train.optimizer import init_opt_state


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def with_shardings(tree, shardings):
    return jax.tree.map(
        lambda x, s: _sds(x.shape, x.dtype, s), tree, shardings)


def abstract_params(cfg: ArchConfig, mesh, dtype=jnp.bfloat16):
    specs = param_specs(cfg, dtype=dtype)
    return with_shardings(specs, params_shardings(specs, mesh))


def abstract_opt_state(params_abs, mesh):
    specs = jax.eval_shape(init_opt_state, params_abs)
    return with_shardings(specs, params_shardings(specs, mesh))


def train_batch_specs(cfg: ArchConfig, mesh, batch: int, seq: int):
    bspec2 = NamedSharding(mesh, batch_partition_spec(mesh, batch, ndim=2))
    if cfg.takes_embeddings:
        bspec3 = NamedSharding(mesh,
                               batch_partition_spec(mesh, batch, ndim=3))
        inputs = _sds((batch, seq, cfg.d_model), jnp.bfloat16, bspec3)
    else:
        inputs = _sds((batch, seq), jnp.int32, bspec2)
    labels = _sds((batch, seq), jnp.int32, bspec2)
    return {"inputs": inputs, "labels": labels}


def decode_specs(cfg: ArchConfig, mesh, batch: int, context: int,
                 cache_dtype=jnp.bfloat16):
    cache_shape = jax.eval_shape(
        lambda: init_cache(cfg, batch, max_len=context, dtype=cache_dtype))
    cache_shard = jax.tree.map(
        lambda x: NamedSharding(mesh, cache_partition_spec(mesh, x, batch)),
        cache_shape)
    cache = jax.tree.map(
        lambda x, s: _sds(x.shape, x.dtype, s), cache_shape, cache_shard)
    bspec = NamedSharding(mesh, batch_partition_spec(mesh, batch, ndim=2))
    if cfg.takes_embeddings:
        b3 = NamedSharding(mesh, batch_partition_spec(mesh, batch, ndim=3))
        tokens = _sds((batch, 1, cfg.d_model), jnp.bfloat16, b3)
    else:
        tokens = _sds((batch, 1), jnp.int32, bspec)
    cur_pos = _sds((), jnp.int32, NamedSharding(mesh, P()))
    return cache, tokens, cur_pos


def prefill_specs(cfg: ArchConfig, mesh, batch: int, seq: int):
    bspec = NamedSharding(mesh, batch_partition_spec(mesh, batch, ndim=2))
    if cfg.takes_embeddings:
        b3 = NamedSharding(mesh, batch_partition_spec(mesh, batch, ndim=3))
        return _sds((batch, seq, cfg.d_model), jnp.bfloat16, b3)
    return _sds((batch, seq), jnp.int32, bspec)
