"""Re-derive roofline terms from stored .hlo.zst artifacts — lets the HBM/
collective cost model evolve without recompiling 66 cells.

    PYTHONPATH=src python -m repro.launch.reanalyze results/dryrun
"""
from __future__ import annotations

import json
import os
import sys

import zstandard

from .hlo_analysis import analyze_hlo
from .mesh import HW


def reanalyze_cell(stem: str) -> dict:
    with open(stem + ".json") as f:
        terms = json.load(f)
    with open(stem + ".hlo.zst", "rb") as f:
        hlo = zstandard.ZstdDecompressor().decompress(f.read()).decode()
    a = analyze_hlo(hlo)
    t_compute = a["flops"] / HW["peak_flops"]
    t_memory = a["bytes"] / HW["hbm_bw"]
    t_coll = a["collective_wire_bytes"] / HW["ici_bw"]
    bound = max(t_compute, t_memory, t_coll)
    mf = terms["model_flops_per_device"]
    terms.update(
        flops_per_device=a["flops"], bytes_per_device=a["bytes"],
        collective_bytes_per_device=a["collective_wire_bytes"],
        collective_counts=a["collective_counts"],
        collective_bytes_by_kind=a["collective_bytes_by_kind"],
        t_compute_s=t_compute, t_memory_s=t_memory, t_collective_s=t_coll,
        dominant=max(("compute", t_compute), ("memory", t_memory),
                     ("collective", t_coll), key=lambda t: t[1])[0],
        useful_flops_ratio=(mf / a["flops"]) if a["flops"] else 0.0,
        roofline_bound_s=bound,
        roofline_fraction=(mf / HW["peak_flops"]) / bound if bound else 0.0,
    )
    with open(stem + ".json", "w") as f:
        json.dump(terms, f, indent=2, default=str)
    return terms


def main(dirpath: str):
    stems = sorted(set(
        os.path.join(dirpath, fn[:-len(".hlo.zst")])
        for fn in os.listdir(dirpath) if fn.endswith(".hlo.zst")))
    for s in stems:
        t = reanalyze_cell(s)
        print(f"{os.path.basename(s):55s} {t['dominant']:10s} "
              f"bound={t['roofline_bound_s']:.4g}s "
              f"roofline={100*t['roofline_fraction']:.2f}%")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
