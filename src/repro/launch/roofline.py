"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bw_per_chip
    collective = collective_bytes_per_device / ICI_link_bw

``compiled.cost_analysis()`` yields per-device FLOPs/bytes (the module is
the post-SPMD per-device program, so dividing the global roofline formula
by `chips` is already done).  Collective bytes are NOT in cost_analysis:
we parse the optimized HLO (``repro.analysis.hlo``, the shared parser) and
sum result-buffer sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
with an algorithmic multiplier (ring all-reduce moves ~2x its buffer;
all-gather/reduce-scatter move (n-1)/n ~ 1x; permute 1x).
"""
from __future__ import annotations

from repro.analysis.hlo import (CollectiveStats, analyze_hlo,
                                parse_collectives)

from .mesh import HW

__all__ = ["CollectiveStats", "parse_collectives", "roofline_terms"]


def roofline_terms(compiled, model_flops_global: float, chips: int) -> dict:
    """All three terms + bookkeeping, from a compiled jit artifact.

    Uses the trip-count-aware HLO walk (analysis.hlo) because XLA's
    ``cost_analysis()`` counts while-loop bodies once — fatally wrong for
    scan-over-layers models.  Raw cost_analysis numbers are kept in the
    record for comparison.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    a = analyze_hlo(hlo)
    flops = a["flops"]
    bytes_accessed = a["bytes"]

    t_compute = flops / HW["peak_flops"]
    t_memory = bytes_accessed / HW["hbm_bw"]
    t_coll = a["collective_wire_bytes"] / HW["ici_bw"]
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda t: t[1])[0]
    mf_per_dev = model_flops_global / chips
    mem = compiled.memory_analysis()
    bound = max(t_compute, t_memory, t_coll)
    out = {
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": a["collective_wire_bytes"],
        "collective_counts": a["collective_counts"],
        "collective_bytes_by_kind": a["collective_bytes_by_kind"],
        "cost_analysis_flops_raw": raw_flops,
        "cost_analysis_bytes_raw": raw_bytes,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_device": mf_per_dev,
        "useful_flops_ratio": (mf_per_dev / flops) if flops else 0.0,
        "roofline_bound_s": bound,
        # fraction of the roofline bound spent on useful model math — the
        # headline score: 1.0 = perfectly compute-bound on useful FLOPs
        "roofline_fraction": (mf_per_dev / HW["peak_flops"]) / bound
        if bound else 0.0,
    }
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                out[k] = int(v)
    return out
