"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bw_per_chip
    collective = collective_bytes_per_device / ICI_link_bw

``compiled.cost_analysis()`` yields per-device FLOPs/bytes (the module is
the post-SPMD per-device program, so dividing the global roofline formula
by `chips` is already done).  Collective bytes are NOT in cost_analysis:
we parse the optimized HLO and sum result-buffer sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
with an algorithmic multiplier (ring all-reduce moves ~2x its buffer;
all-gather/reduce-scatter move (n-1)/n ~ 1x; permute 1x).
"""
from __future__ import annotations

import dataclasses
import re

from .mesh import HW

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_MULTIPLier = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\w+\[[\d,]*\]\S*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def weighted_bytes(self) -> float:
        return sum(_MULTIPLier[k] * b for k, b in self.bytes_by_kind.items())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    bytes_by: dict = {k: 0 for k in _COLLECTIVES}
    count_by: dict = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        # async pairs appear as -start/-done; count the op once (at -start);
        # -done lines repeat the buffer
        line = m.group(0)
        if f"{kind}-done(" in line:
            continue
        bytes_by[kind] += _type_bytes(type_str)
        count_by[kind] += 1
    return CollectiveStats(bytes_by_kind=bytes_by, count_by_kind=count_by)


def roofline_terms(compiled, model_flops_global: float, chips: int) -> dict:
    """All three terms + bookkeeping, from a compiled jit artifact.

    Uses the trip-count-aware HLO walk (hlo_analysis.py) because XLA's
    ``cost_analysis()`` counts while-loop bodies once — fatally wrong for
    scan-over-layers models.  Raw cost_analysis numbers are kept in the
    record for comparison.
    """
    from .hlo_analysis import analyze_hlo

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    a = analyze_hlo(hlo)
    flops = a["flops"]
    bytes_accessed = a["bytes"]

    t_compute = flops / HW["peak_flops"]
    t_memory = bytes_accessed / HW["hbm_bw"]
    t_coll = a["collective_wire_bytes"] / HW["ici_bw"]
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda t: t[1])[0]
    mf_per_dev = model_flops_global / chips
    mem = compiled.memory_analysis()
    bound = max(t_compute, t_memory, t_coll)
    out = {
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": a["collective_wire_bytes"],
        "collective_counts": a["collective_counts"],
        "collective_bytes_by_kind": a["collective_bytes_by_kind"],
        "cost_analysis_flops_raw": raw_flops,
        "cost_analysis_bytes_raw": raw_bytes,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_device": mf_per_dev,
        "useful_flops_ratio": (mf_per_dev / flops) if flops else 0.0,
        "roofline_bound_s": bound,
        # fraction of the roofline bound spent on useful model math — the
        # headline score: 1.0 = perfectly compute-bound on useful FLOPs
        "roofline_fraction": (mf_per_dev / HW["peak_flops"]) / bound
        if bound else 0.0,
    }
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                out[k] = int(v)
    return out
