import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, print memory/cost analyses, extract roofline terms.

The two lines above MUST stay the very first statements of this module —
jax locks the device count on first initialization.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out results/dryrun
"""
import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, cells, get_config
from repro.launch import input_specs as ispec
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline_terms
from repro.serve.step import serve_step
from repro.train.optimizer import AdamWConfig
from repro.train.step import train_step
from repro.serve.step import prefill


# per-arch training knobs (microbatching for activation pressure)
MICROBATCHES = {"llama3-405b": 16, "qwen2-vl-72b": 8, "mixtral-8x22b": 4, "qwen3-14b": 4}


def lower_cell(arch_id: str, shape_name: str, mesh, *,
               param_dtype=jnp.bfloat16):
    cfg = get_config(arch_id)
    spec = SHAPES[shape_name]
    chips = mesh.devices.size
    params_abs = ispec.abstract_params(cfg, mesh, dtype=param_dtype)

    if spec.kind == "train":
        opt_abs = ispec.abstract_opt_state(params_abs, mesh)
        batch_abs = ispec.train_batch_specs(cfg, mesh, spec.global_batch,
                                            spec.seq_len)
        opt_cfg = AdamWConfig(
            mu_dtype=jnp.bfloat16 if arch_id == "llama3-405b"
            else jnp.float32)
        mb = MICROBATCHES.get(arch_id, 1)
        # divisibility guard (EXPERIMENTS P9): every microbatch must still
        # split over all DP shards or XLA replicates the step
        dp = chips // dict(zip(mesh.axis_names,
                               mesh.devices.shape)).get("model", 1)
        while mb > 1 and (spec.global_batch // mb) % dp:
            mb //= 2
        fn = partial(train_step, cfg=cfg, opt_cfg=opt_cfg, microbatches=mb)
        jitted = jax.jit(fn, donate_argnums=(0, 1))
        with mesh:
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        mf = cfg.model_flops(spec.global_batch, spec.seq_len)
    elif spec.kind == "prefill":
        inputs_abs = ispec.prefill_specs(cfg, mesh, spec.global_batch,
                                         spec.seq_len)
        jitted = jax.jit(
            lambda p, x: prefill(p, cfg, x, max_len=spec.seq_len))
        with mesh:
            lowered = jitted.lower(params_abs, inputs_abs)
        # prefill = forward-only pass: 2*N*D
        mf = cfg.model_flops(spec.global_batch, spec.seq_len) / 3.0
    else:  # decode
        cache_abs, tokens_abs, pos_abs = ispec.decode_specs(
            cfg, mesh, spec.global_batch, spec.seq_len)
        fn = partial(serve_step, cfg=cfg)
        jitted = jax.jit(fn, donate_argnums=(1,))
        with mesh:
            lowered = jitted.lower(params_abs, cache_abs, tokens_abs,
                                   pos_abs)
        mf = cfg.model_flops(spec.global_batch, spec.seq_len, decode=True)

    t0 = time.time()
    compiled = lowered.compile()
    terms = roofline_terms(compiled, mf, chips)
    terms.update(arch=arch_id, shape=shape_name, chips=chips,
                 mesh_axes=dict(zip(mesh.axis_names, mesh.devices.shape)),
                 compile_seconds=time.time() - t0,
                 kind=spec.kind)
    return compiled, terms


def run_cell(arch_id, shape_name, mesh_kind, outdir=None, verbose=True):
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    compiled, terms = lower_cell(arch_id, shape_name, mesh)
    if verbose:
        print(f"== {arch_id} x {shape_name} x {mesh_kind} "
              f"({terms['chips']} chips) ==")
        ma = compiled.memory_analysis()
        print(ma)
        ca = compiled.cost_analysis()
        keys = ("flops", "bytes accessed")
        print({k: ca.get(k) for k in keys} if hasattr(ca, "get") else ca)
        print(json.dumps({k: v for k, v in terms.items()
                          if k.startswith(("t_", "dominant", "useful"))},
                         indent=2, default=str))
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        stem = os.path.join(outdir, f"{arch_id}__{shape_name}__{mesh_kind}")
        with open(stem + ".json", "w") as f:
            json.dump(terms, f, indent=2, default=str)
        # compressed optimized HLO: re-derive roofline terms offline
        # (launch/reanalyze.py) without recompiling
        import zstandard
        with open(stem + ".hlo.zst", "wb") as f:
            f.write(zstandard.ZstdCompressor(level=6).compress(
                compiled.as_text().encode()))
    return terms


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = (("single", "multi") if args.mesh == "both" else (args.mesh,))
    todo = []
    if args.all:
        for arch, shape, skip in cells():
            if skip:
                print(f"SKIP {arch} x {shape} (quadratic attention at 512k; "
                      f"see DESIGN.md §7)")
                continue
            for mk in meshes:
                todo.append((arch, shape, mk))
    else:
        assert args.arch and args.shape
        todo = [(args.arch, args.shape, mk) for mk in meshes]

    failures = []
    for arch, shape, mk in todo:
        if args.skip_existing and args.out and os.path.exists(
                os.path.join(args.out, f"{arch}__{shape}__{mk}.json")):
            print(f"cached {arch} x {shape} x {mk}")
            continue
        try:
            run_cell(arch, shape, mk, outdir=args.out)
        except Exception as e:      # noqa: BLE001 — report all cell failures
            traceback.print_exc()
            failures.append((arch, shape, mk, repr(e)))
    if failures:
        print("FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"dry-run OK: {len(todo)} cells")


if __name__ == "__main__":
    main()
