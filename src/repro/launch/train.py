"""End-to-end training driver with checkpoint/restart.

On the CPU container this trains smoke-scale configs for real; on a cluster
the same driver runs the full configs — the mesh and shardings are the only
difference.  Fault tolerance: step-atomic checkpoints every
``--ckpt-every`` steps, ``--resume`` picks up the latest one (the data
pipeline is stateless-indexed, so the token stream continues exactly).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt --resume
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import (latest_checkpoint, load_checkpoint,
                                   save_checkpoint)
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data.pipeline import DataConfig, sample_batch, sample_embedding_batch
from repro.models import init_params
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.step import train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    dtype = jnp.float32 if args.smoke else jnp.bfloat16
    params = init_params(cfg, jax.random.PRNGKey(args.seed), dtype=dtype)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(1, args.steps // 20))
    opt_state = init_opt_state(params)
    start_step = 0

    if args.resume and args.ckpt_dir:
        ck = latest_checkpoint(args.ckpt_dir)
        if ck:
            (params, opt_state), start_step = load_checkpoint(
                ck, (params, opt_state))
            print(f"resumed from {ck} at step {start_step}")

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed)
    step_fn = jax.jit(partial(train_step, cfg=cfg, opt_cfg=opt_cfg,
                              microbatches=args.microbatches),
                      donate_argnums=(0, 1))

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        if cfg.takes_embeddings:
            batch = sample_embedding_batch(dcfg, step, cfg.d_model)
        else:
            batch = sample_batch(dcfg, step)
        batch = jax.tree.map(jnp.asarray, batch)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({time.time()-t0:.1f}s)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            f = save_checkpoint(args.ckpt_dir, (params, opt_state), step + 1)
            print(f"checkpoint -> {f}")

    print(f"final loss {np.mean(losses[-5:]):.4f} "
          f"(first {np.mean(losses[:5]):.4f})")
    return losses


if __name__ == "__main__":
    main()
