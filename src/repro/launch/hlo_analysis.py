"""Trip-count-aware cost analysis of optimized HLO.

The implementation lives in ``repro.analysis.hlo`` (shared with the
collective-structure and traffic analyzers); this module re-exports the
cost-walker surface so launch-side callers and stored-artifact tooling
(``dryrun``, ``reanalyze``) keep their historical import path.
"""
from __future__ import annotations

from repro.analysis.hlo import (COLL_WIRE, COLLECTIVES, Analyzer,
                                Computation, Op, analyze_hlo, parse_module,
                                shape_info)

__all__ = ["COLL_WIRE", "COLLECTIVES", "Analyzer", "Computation", "Op",
           "analyze_hlo", "parse_module", "shape_info"]
