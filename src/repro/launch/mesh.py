"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run process forces
512 host devices while tests/benches must see 1.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod.

    The dry-run process exposes 512 host devices; the single-pod mesh takes
    the first 256 of them.
    """
    import math
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"need {need} devices, found {len(devs)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            f"(launch/dryrun.py sets this automatically)")
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(devs[:need]).reshape(shape), axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke tests of the pjit code paths."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware model used by the roofline analysis
HW = dict(
    peak_flops=197e12,      # bf16 FLOP/s per chip
    hbm_bw=819e9,           # bytes/s per chip
    ici_bw=5.0e10,          # bytes/s per link (~50 GB/s)
    hbm_bytes=16 * 2**30,   # 16 GiB per chip
)
