"""StableLM-2-12B — dense, GQA kv=8, LayerNorm
[hf:stabilityai/stablelm-2-12b]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b", family="dense", n_layers=40, d_model=5120,
    n_heads=32, n_kv_heads=8, d_ff=13824, vocab=100352,
    rope_theta=1e4, norm="layernorm", act="silu")

SMOKE_CONFIG = ArchConfig(
    name="stablelm-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    norm="layernorm", act="silu")
