"""Qwen2.5-3B — dense, GQA kv=2, QKV bias [hf:Qwen/Qwen2.5-3B]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-3b", family="dense", n_layers=36, d_model=2048,
    n_heads=16, n_kv_heads=2, d_ff=11008, vocab=151936,
    qkv_bias=True, rope_theta=1e6, norm="rmsnorm", act="silu")

SMOKE_CONFIG = ArchConfig(
    name="qwen2.5-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    qkv_bias=True, norm="rmsnorm", act="silu")
