"""RecurrentGemma-2B (Griffin) — RG-LRU + local attention, 1:2 ratio
[arXiv:2402.19427].  26 layers = 2 repeats of a 13-block pattern with
attention at every third slot (8 attn + 18 recurrent, matching the
published stack)."""
from repro.models.config import ArchConfig

_PATTERN = ("rec", "rec", "attn") * 4 + ("rec",)   # x2 repeats = 26 layers

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid", n_layers=26, d_model=2560,
    n_heads=10, n_kv_heads=1, d_ff=7680, vocab=256000,
    block_pattern=_PATTERN, rnn_width=2560, attn_window=2048,
    head_dim=256, rope_theta=1e4, norm="rmsnorm", act="gelu",
    tie_embeddings=True)

SMOKE_CONFIG = ArchConfig(
    name="recurrentgemma-smoke", family="hybrid", n_layers=6, d_model=64,
    n_heads=2, n_kv_heads=1, d_ff=128, vocab=256,
    block_pattern=("rec", "rec", "attn"), rnn_width=64, attn_window=16,
    head_dim=32, norm="rmsnorm", act="gelu")
