"""MusicGen-medium backbone — decoder-only over EnCodec tokens; the EnCodec
frontend is a STUB (input_specs feeds precomputed frame embeddings)
[arXiv:2306.05284].  MHA (kv=24), LayerNorm, GELU, positions supplied by the
frontend (sinusoidal) so pos_emb="none"."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium", family="audio", n_layers=48, d_model=1536,
    n_heads=24, n_kv_heads=24, d_ff=6144, vocab=2048,
    pos_emb="none", frontend="audio", norm="layernorm", act="gelu")

SMOKE_CONFIG = ArchConfig(
    name="musicgen-smoke", family="audio", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=64,
    pos_emb="none", frontend="audio", norm="layernorm", act="gelu")
