"""OLMoE-1B-7B — 64-expert top-8 MoE [arXiv:2409.02060]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1024, vocab=50304,
    n_experts=64, moe_top_k=8, rope_theta=1e4, norm="rmsnorm", act="silu")

SMOKE_CONFIG = ArchConfig(
    name="olmoe-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=32, vocab=256,
    n_experts=8, moe_top_k=2, capacity_factor=0.0, norm="rmsnorm", act="silu")
