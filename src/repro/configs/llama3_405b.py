"""Llama-3.1-405B — dense, GQA kv=8, 128k vocab [arXiv:2407.21783]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b", family="dense", n_layers=126, d_model=16384,
    n_heads=128, n_kv_heads=8, d_ff=53248, vocab=128256,
    head_dim=128, rope_theta=5e5, norm="rmsnorm", act="silu",
    seq_parallel=False, remat_group=9)

SMOKE_CONFIG = ArchConfig(
    name="llama3-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    norm="rmsnorm", act="silu")
