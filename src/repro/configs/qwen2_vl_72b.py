"""Qwen2-VL-72B backbone — M-RoPE, dynamic-resolution vision frontend is a
STUB (input_specs feeds precomputed patch embeddings) [arXiv:2409.12191]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b", family="vlm", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=29568, vocab=152064,
    head_dim=128, m_rope=True, qkv_bias=True, rope_theta=1e6,
    frontend="vision", norm="rmsnorm", act="silu", remat_group=8)

SMOKE_CONFIG = ArchConfig(
    name="qwen2-vl-smoke", family="vlm", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    m_rope=True, qkv_bias=True, frontend="vision",
    norm="rmsnorm", act="silu")
