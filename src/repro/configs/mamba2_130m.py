"""Mamba2-130M — attention-free SSD (state-space duality)
[arXiv:2405.21060].  d_inner = 2*d_model, 24 heads of P=64, N=128."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m", family="ssm", n_layers=24, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=0, vocab=50280,
    block_pattern=("ssm",), ssm_state=128, ssm_head_dim=64, ssm_chunk=256,
    norm="rmsnorm", act="silu", tie_embeddings=True)

SMOKE_CONFIG = ArchConfig(
    name="mamba2-smoke", family="ssm", n_layers=2, d_model=64,
    n_heads=2, n_kv_heads=2, d_ff=0, vocab=256,
    block_pattern=("ssm",), ssm_state=16, ssm_head_dim=16, ssm_chunk=32,
    norm="rmsnorm", act="silu", tie_embeddings=True)
