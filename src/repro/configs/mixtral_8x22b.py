"""Mixtral-8x22B — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe", n_layers=56, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=16384, vocab=32768,
    n_experts=8, moe_top_k=2, attn_window=4096, rope_theta=1e6,
    norm="rmsnorm", act="silu", remat_group=7)

SMOKE_CONFIG = ArchConfig(
    name="mixtral-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    n_experts=4, moe_top_k=2, capacity_factor=0.0, attn_window=16, norm="rmsnorm", act="silu")
