"""Architecture registry: exact assigned configs + reduced smoke variants.

``get_config(arch_id)`` returns the full published config;
``get_smoke_config(arch_id)`` a tiny same-family variant for CPU tests.
"""
from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = (
    "olmoe-1b-7b", "mixtral-8x22b", "recurrentgemma-2b", "stablelm-12b",
    "qwen3-14b", "llama3-405b", "qwen2.5-3b", "qwen2-vl-72b",
    "musicgen-medium", "mamba2-130m",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def get_smoke_config(arch_id: str):
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.SMOKE_CONFIG


# ---------------------------------------------------------------------------
# input shapes assigned to the LM pool (seq_len x global_batch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: only SWA / local-attn / SSM archs
SUBQUADRATIC = {"mixtral-8x22b", "recurrentgemma-2b", "mamba2-130m"}


def cells():
    """All (arch, shape) dry-run cells, with skip annotations."""
    out = []
    for a in ARCH_IDS:
        for s in SHAPES.values():
            skip = (s.name == "long_500k" and a not in SUBQUADRATIC)
            out.append((a, s.name, skip))
    return out
