"""Mixture-of-experts block with capacity-based dispatch.

Top-k routing -> tokens scattered into a per-expert (E, C, d) buffer ->
dense per-expert GEMMs -> weighted combine.  Compute scales with
``tokens * top_k * capacity_factor`` (honest MoE FLOPs, unlike a dense
all-experts einsum), and the expert axis is shardable over the mesh `model`
axis (expert parallelism): under pjit the scatter/gather around the expert
GEMMs lowers to all-to-all pairs, which is exactly the EP collective pattern
the roofline analysis accounts for.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.constraints import constrain

from .layers import dense_init, mlp_params, mlp_apply


def moe_params(key, d, ff, n_experts, act, dtype):
    kr, ke = jax.random.split(key)
    expert_keys = jax.random.split(ke, n_experts)
    experts = jax.vmap(lambda k: mlp_params(k, d, ff, act, dtype))(expert_keys)
    return {"router": dense_init(kr, (d, n_experts), dtype, scale=0.02),
            "experts": experts}


def moe_apply(p, x, *, top_k: int, capacity_factor: float, act: str):
    """x: (B, S, d) -> (B, S, d)."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    n_experts = p["router"].shape[-1]
    logits = (xf @ p["router"]).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, top_k)                  # (T, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)      # renormalize

    if capacity_factor <= 0:      # exact mode: no token can ever be dropped
        capacity = t
    else:
        capacity = max(1, int(t * top_k * capacity_factor / n_experts))
    # position of each (token, slot) within its expert queue
    onehot = jax.nn.one_hot(idx, n_experts, dtype=jnp.int32)  # (T, k, E)
    flat = onehot.reshape(t * top_k, n_experts)
    pos = jnp.cumsum(flat, axis=0) - 1                        # (T*k, E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(t, top_k)      # (T, k)
    keep = pos < capacity
    gate = gate * keep

    # scatter tokens into (E, C, d)
    e_flat = idx.reshape(-1)
    c_flat = jnp.clip(pos.reshape(-1), 0, capacity - 1)
    buf = jnp.zeros((n_experts, capacity, d), dtype=x.dtype)
    src = jnp.repeat(xf, top_k, axis=0)
    w = keep.reshape(-1, 1).astype(x.dtype)
    buf = buf.at[e_flat, c_flat].add(src * w)
    # expert parallelism: the scatter above becomes an all-to-all into the
    # expert-sharded layout (dropped gracefully when E % model != 0)
    buf = constrain(buf, "model", None, None)

    # dense per-expert GEMMs
    out = jax.vmap(lambda ep, eb: mlp_apply(ep, eb, act))(p["experts"], buf)
    out = constrain(out, "model", None, None)

    # combine
    gathered = out[e_flat, c_flat]                            # (T*k, d)
    y = jnp.sum((gathered * gate.reshape(-1, 1).astype(x.dtype))
                .reshape(t, top_k, d), axis=1)
    return y.reshape(b, s, d), logits


def load_balancing_loss(router_logits: jax.Array, idx_top1: jax.Array | None
                        = None) -> jax.Array:
    """Switch-style auxiliary loss (mean prob * mean assignment)."""
    probs = jax.nn.softmax(router_logits, axis=-1)
    e = probs.shape[-1]
    frac_prob = jnp.mean(probs, axis=0)
    assign = jax.nn.one_hot(jnp.argmax(probs, axis=-1), e,
                            dtype=jnp.float32)
    frac_tokens = jnp.mean(assign, axis=0)
    return e * jnp.sum(frac_prob * frac_tokens)
