"""Generic decoder stack covering all 10 assigned architectures.

Layers are scanned over *pattern repeats*: the stack is
``cfg.block_pattern`` (e.g. ``("rec","rec","attn")`` for RecurrentGemma)
repeated ``cfg.pattern_repeats`` times, with every pattern position's params
stacked over repeats.  A single ``lax.scan`` keeps the HLO O(1) in depth —
required to compile llama3-405b x 512 devices in reasonable time.

Caches (decode) are pytrees stacked the same way, scanned as xs/ys.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.constraints import BATCH, constrain

from .config import ArchConfig
from . import layers as L
from .layers import (apply_norm, decode_attention, dense_init,
                     flash_attention, mlp_apply, mlp_params, norm_params,
                     apply_rope)
from .mamba2 import mamba2_apply, mamba2_params
from .moe import load_balancing_loss, moe_apply, moe_params
from .moe_shardmap import moe_apply_shardmap
from .rglru import rglru_apply, rglru_params

MROPE_SECTIONS = (16, 24, 24)   # Qwen2-VL mrope_section over head_dim/2


# ---------------------------------------------------------------------------
# per-block params
# ---------------------------------------------------------------------------

def _attn_params(cfg: ArchConfig, key, dtype):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    p = {
        "ln1": norm_params(ks[0], d, cfg.norm, dtype),
        "wq": dense_init(ks[1], (d, h * hd), dtype),
        "wk": dense_init(ks[2], (d, kv * hd), dtype),
        "wv": dense_init(ks[3], (d, kv * hd), dtype),
        "wo": dense_init(ks[4], (h * hd, d), dtype),
        "ln2": norm_params(ks[5], d, cfg.norm, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    if cfg.n_experts:
        p["moe"] = moe_params(ks[6], d, cfg.d_ff, cfg.n_experts, cfg.act,
                              dtype)
    else:
        p["mlp"] = mlp_params(ks[6], d, cfg.d_ff, cfg.act, dtype)
    return p


def _rec_params(cfg: ArchConfig, key, dtype):
    d = cfg.d_model
    rw = cfg.rnn_width or d
    ks = jax.random.split(key, 4)
    return {
        "ln1": norm_params(ks[0], d, cfg.norm, dtype),
        "lru": rglru_params(ks[1], d, rw, cfg.conv_width, dtype),
        "ln2": norm_params(ks[2], d, cfg.norm, dtype),
        "mlp": mlp_params(ks[3], d, cfg.d_ff, cfg.act, dtype),
    }


def _ssm_params(cfg: ArchConfig, key, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": norm_params(ks[0], cfg.d_model, cfg.norm, dtype),
        "ssm": mamba2_params(ks[1], cfg.d_model, cfg.ssm_state,
                             cfg.ssm_head_dim, cfg.conv_width, dtype),
    }


_BLOCK_INIT = {"attn": _attn_params, "rec": _rec_params, "ssm": _ssm_params}


def init_params(cfg: ArchConfig, key: jax.Array, dtype=jnp.bfloat16):
    keys = jax.random.split(key, 4)
    blocks = []
    for i, kind in enumerate(cfg.block_pattern):
        rep_keys = jax.random.split(jax.random.fold_in(keys[0], i),
                                    cfg.pattern_repeats)
        blocks.append(jax.vmap(
            lambda k: _BLOCK_INIT[kind](cfg, k, dtype))(rep_keys))
    p = {
        "embed": dense_init(keys[1], (cfg.vocab, cfg.d_model), dtype,
                            scale=0.02),
        "blocks": tuple(blocks),
        "ln_f": norm_params(keys[2], cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(keys[3], (cfg.d_model, cfg.vocab), dtype)
    return p


def param_specs(cfg: ArchConfig, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree of the params (no allocation)."""
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), dtype))


# ---------------------------------------------------------------------------
# per-block apply
# ---------------------------------------------------------------------------

def _project_qkv(cfg, p, h):
    b, s, _ = h.shape
    q = h @ p["wq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = L.rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _prefill_cache(cfg, k, v, positions, build_len):
    """Token-parallel cache construction (prefill): scatter the prompt's
    K/V into a fresh cache — ring layout for windowed attention."""
    b, s = k.shape[:2]
    cap = min(build_len, cfg.attn_window) if cfg.attn_window else build_len
    pos1d = (positions[0] if positions.ndim == 3 else positions)[0]  # (S,)
    if s >= cap:
        # keep exactly the last `cap` tokens, placed at slot p % cap
        start = s - cap
        j = jnp.arange(cap)
        src = start + (j - start) % cap          # position living in slot j
        kc = jnp.take(k, src, axis=1)
        vc = jnp.take(v, src, axis=1)
        pc = jnp.broadcast_to(jnp.take(pos1d, src)[None], (b, cap))
        return {"k": kc, "v": vc, "pos": pc.astype(jnp.int32)}
    kc = jnp.zeros((b, cap) + k.shape[2:], k.dtype).at[:, :s].set(k)
    vc = jnp.zeros((b, cap) + v.shape[2:], v.dtype).at[:, :s].set(v)
    # positions arrive as i64 under x64; the cache is i32 — scatter value
    # dtype must match (mixed-dtype scatter is a FutureWarning -> error)
    pc = jnp.full((b, cap), -1, jnp.int32).at[:, :s].set(
        jnp.broadcast_to(pos1d[None].astype(jnp.int32), (b, s)))
    return {"k": kc, "v": vc, "pos": pc}


def _attn_block(cfg: ArchConfig, p, x, positions, cache, cur_pos,
                build_len=None):
    """cache None -> training/prefill; else single-token decode."""
    b = x.shape[0]
    h = apply_norm(x, p["ln1"], cfg.norm, cfg.norm_eps)
    # Megatron-SP transition: all-gather the sequence dim here; heads are
    # model-sharded inside attention; the residual add reduce-scatters back
    h = constrain(h, BATCH, None, None)
    q, k, v = _project_qkv(cfg, p, h)
    # tensor-parallel attention: q heads sharded over `model` (dropped
    # gracefully when H % model != 0), k/v (small GQA heads) replicated —
    # scores/context tensors then shard over heads instead of being
    # computed redundantly on every model-axis device
    q = constrain(q, BATCH, None, "model", None)
    k = constrain(k, BATCH, None, None, None)
    v = constrain(v, BATCH, None, None, None)
    sections = MROPE_SECTIONS if cfg.m_rope else None
    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta, sections)
        k = apply_rope(k, positions, cfg.rope_theta, sections)
    aux = jnp.zeros((), jnp.float32)
    if cache is None:
        pos1d = positions[0] if positions.ndim == 3 else positions
        # prefill (no backward) of head-indivisible archs uses the
        # context-parallel forward path
        attn = flash_attention(q, k, v, pos1d[0], pos1d[0],
                               window=cfg.attn_window,
                               ctx_parallel=build_len is not None)
        new_cache = (None if build_len is None
                     else _prefill_cache(cfg, k, v, positions, build_len))
    else:
        cap = cache["k"].shape[1]
        slot = (cur_pos % cap).astype(jnp.int32)
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        kv_pos = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], jnp.full((b, 1), cur_pos, dtype=cache["pos"].dtype),
            slot, axis=1)
        qpos = jnp.full((b,), cur_pos, dtype=jnp.int32)
        attn = decode_attention(q, k_cache, v_cache, qpos, kv_pos,
                                window=cfg.attn_window)
        new_cache = {"k": k_cache, "v": v_cache, "pos": kv_pos}
    x = x + attn.reshape(*attn.shape[:2], -1) @ p["wo"]

    h2 = apply_norm(x, p["ln2"], cfg.norm, cfg.norm_eps)
    h2 = constrain(h2, BATCH, None, None)     # SP transition (MLP side)
    if cfg.n_experts:
        # decode never drops tokens (exact capacity); training uses the
        # configured capacity factor
        cf = 0.0 if cache is not None else cfg.capacity_factor
        res = None
        if cache is None:
            # production path: explicit shard_map dispatch (see
            # moe_shardmap.py); engages only under an active mesh
            res = moe_apply_shardmap(
                p["moe"], h2, top_k=cfg.moe_top_k, capacity_factor=cf,
                act=cfg.act)
        if res is None:
            res = moe_apply(p["moe"], h2, top_k=cfg.moe_top_k,
                            capacity_factor=cf, act=cfg.act)
        y, router_logits = res
        aux = load_balancing_loss(router_logits)
    else:
        y = mlp_apply(p["mlp"], h2, cfg.act)
    return x + y, new_cache, aux


def _rec_block(cfg: ArchConfig, p, x, positions, cache, cur_pos,
               build_len=None):
    h = apply_norm(x, p["ln1"], cfg.norm, cfg.norm_eps)
    h = constrain(h, BATCH, None, None)       # SP transition (recurrence)
    h0 = cache["h"] if cache is not None else None
    cs = cache["conv"] if cache is not None else None
    y, (h_new, cs_new) = rglru_apply(p["lru"], h, h0, cs)
    x = x + y
    h2 = apply_norm(x, p["ln2"], cfg.norm, cfg.norm_eps)
    h2 = constrain(h2, BATCH, None, None)     # SP transition (MLP side)
    x = x + mlp_apply(p["mlp"], h2, cfg.act)
    new_cache = ({"h": h_new, "conv": cs_new}
                 if (cache is not None or build_len is not None) else None)
    return x, new_cache, jnp.zeros((), jnp.float32)


def _ssm_block(cfg: ArchConfig, p, x, positions, cache, cur_pos,
               build_len=None):
    h = apply_norm(x, p["ln1"], cfg.norm, cfg.norm_eps)
    st = cache["state"] if cache is not None else None
    cs = cache["conv"] if cache is not None else None
    y, (st_new, cs_new) = mamba2_apply(
        p["ssm"], h, st, cs, d_model=cfg.d_model, ssm_state=cfg.ssm_state,
        head_dim=cfg.ssm_head_dim, chunk=cfg.ssm_chunk)
    new_cache = ({"state": st_new, "conv": cs_new}
                 if (cache is not None or build_len is not None) else None)
    return x + y, new_cache, jnp.zeros((), jnp.float32)


_BLOCK_APPLY = {"attn": _attn_block, "rec": _rec_block, "ssm": _ssm_block}


# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """Stacked-over-repeats cache pytree (tuple per pattern position)."""
    caches = []
    rw = cfg.rnn_width or cfg.d_model
    d_in = 2 * cfg.d_model
    conv_dim = d_in + 2 * cfg.ssm_state
    for kind in cfg.block_pattern:
        if kind == "attn":
            cap = min(max_len, cfg.attn_window) if cfg.attn_window else max_len
            c = {"k": jnp.zeros((batch, cap, cfg.n_kv_heads, cfg.head_dim),
                                dtype),
                 "v": jnp.zeros((batch, cap, cfg.n_kv_heads, cfg.head_dim),
                                dtype),
                 "pos": jnp.full((batch, cap), -1, jnp.int32)}
        elif kind == "rec":
            c = {"h": jnp.zeros((batch, rw), jnp.float32),
                 "conv": jnp.zeros((batch, cfg.conv_width - 1, rw), dtype)}
        else:
            nheads = d_in // cfg.ssm_head_dim
            c = {"state": jnp.zeros((batch, nheads, cfg.ssm_head_dim,
                                     cfg.ssm_state), jnp.float32),
                 "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim),
                                   dtype)}
        caches.append(jax.tree.map(
            lambda a: jnp.broadcast_to(
                a[None], (cfg.pattern_repeats,) + a.shape), c))
    return tuple(caches)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def forward(params, cfg: ArchConfig, inputs, positions,
            cache=None, cur_pos=None, remat: bool = True,
            build_cache_len: int | None = None,
            return_hidden: bool = False):
    """inputs: (B, S) int tokens, or (B, S, d) embeddings for frontend archs.

    ``build_cache_len``: token-parallel prefill — build a decode-ready cache
    of that capacity while processing the whole prompt at once.
    ``return_hidden``: skip the LM head and return final hidden states
    (the training loss fuses the head with a chunked cross entropy).

    Returns (logits_or_hidden, new_cache, aux_loss).
    """
    if cfg.takes_embeddings and inputs.ndim == 3:
        x = inputs
    else:
        x = jnp.take(params["embed"], inputs, axis=0)

    def superblock(x, rep_params, rep_cache):
        new_cache = []
        aux = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(cfg.block_pattern):
            c = None if rep_cache is None else rep_cache[i]
            # sequence-parallel residual stream (Megatron-SP): the saved
            # remat residual is (B, S/model, d) per layer — 16x less live
            # activation memory; XLA inserts the all-gather/reduce-scatter
            # pair at the block boundary.  constrain() drops the `model`
            # entry automatically when S == 1 (decode) or indivisible.
            sp = "model" if cfg.seq_parallel else None
            x = constrain(x, BATCH, sp, None)
            x, nc, a = _BLOCK_APPLY[kind](cfg, rep_params[i], x, positions,
                                          c, cur_pos,
                                          build_len=build_cache_len)
            new_cache.append(nc)
            aux = aux + a
        return x, tuple(new_cache), aux

    sb = jax.checkpoint(superblock) if remat and cache is None else superblock

    def scan_body(carry, xs):
        x, aux = carry
        rep_params, rep_cache = xs
        x, nc, a = sb(x, rep_params, rep_cache)
        return (x, aux + a), nc

    g = cfg.remat_group
    if remat and cache is None and g > 1 and cfg.pattern_repeats % g == 0:
        # nested (grouped) remat: checkpoint the carry only every g
        # superblocks — live residuals drop from O(repeats) to
        # O(repeats/g + g) at the cost of one extra forward per group
        n_groups = cfg.pattern_repeats // g

        def regroup(a):
            return a.reshape(n_groups, g, *a.shape[1:])

        blocks_g = jax.tree.map(regroup, params["blocks"])
        cache_g = (None if cache is None
                   else jax.tree.map(regroup, cache))

        @jax.checkpoint
        def group_body(carry, xs):
            gp, gc = xs
            return jax.lax.scan(scan_body, carry, (gp, gc))

        (x, aux), new_cache = jax.lax.scan(
            group_body, (x, jnp.zeros((), jnp.float32)),
            (blocks_g, cache_g))
        if new_cache is not None:
            new_cache = jax.tree.map(
                lambda a: a.reshape(-1, *a.shape[2:]), new_cache)
    else:
        (x, aux), new_cache = jax.lax.scan(
            scan_body, (x, jnp.zeros((), jnp.float32)),
            (params["blocks"], cache))

    x = apply_norm(x, params["ln_f"], cfg.norm, cfg.norm_eps)
    returns_cache = cache is not None or build_cache_len is not None
    if return_hidden:
        return x, (new_cache if returns_cache else None), aux
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head
    return logits, (new_cache if returns_cache else None), aux
