"""Mamba2 block — SSD (state-space duality) with chunked scan.

Training/prefill uses the SSD chunked algorithm: quadratic attention-like
compute *within* fixed-size chunks (dense, MXU-friendly) plus a sequential
inter-chunk state recurrence of length S / chunk (tiny lax.scan).  Decode
carries the (H, P, N) state: O(1) per token — the ``long_500k`` path.

The chunk decomposition is the SSD-paper analogue of HBMC's two-level
blocking: chunk = level-1 block (parallel axis), in-chunk lanes = level-2
rounds (dense vector work); see DESIGN.md §4.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.constraints import BATCH, constrain

from .layers import dense_init, rmsnorm


def mamba2_params(key, d, state, head_dim, conv_width, dtype):
    d_in = 2 * d
    nheads = d_in // head_dim
    conv_dim = d_in + 2 * state
    ks = jax.random.split(key, 5)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * state + nheads), dtype),
        "conv": (jax.random.normal(ks[1], (conv_width, conv_dim)) * 0.1
                 ).astype(dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(dtype),
        "d_skip": jnp.ones((nheads,), dtype),
        "dt_bias": jnp.zeros((nheads,), dtype),
        "norm": jnp.zeros((d_in,), dtype),
        "out_proj": dense_init(ks[4], (d_in, d), dtype),
    }


def _split_proj(p, u, d_in, state, nheads):
    zxbcdt = u @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * state], axis=-1)
    return z, xbc, dt


def _conv(xbc, w, conv_state=None):
    cw = w.shape[0]
    if conv_state is None:
        pad = jnp.pad(xbc, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([conv_state, xbc], axis=1)
    out = sum(pad[:, i:i + xbc.shape[1]] * w[i] for i in range(cw))
    new_state = pad[:, -(cw - 1):] if cw > 1 else None
    return jax.nn.silu(out), new_state


def ssd_chunked(x, dt, a, b_, c_, chunk: int):
    """SSD scan.  x: (B,L,H,P); dt: (B,L,H); a: (H,) negative;
    b_, c_: (B,L,N).  Returns y: (B,L,H,P) and final state (B,H,P,N)."""
    bsz, l, h, p = x.shape
    n = b_.shape[-1]
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_ = jnp.pad(b_, ((0, 0), (0, pad), (0, 0)))
        c_ = jnp.pad(c_, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk
    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h).astype(jnp.float32)
    bc = b_.reshape(bsz, nc, chunk, n)
    cc = c_.reshape(bsz, nc, chunk, n)

    # chunk axis = sequence parallelism over the TP mesh axis: intra-chunk
    # quadratic work is chunk-local, so the (B, nc, Q, Q, H) tensors shard
    # cleanly over `model`; only the tiny inter-chunk states cross it.
    xc = constrain(xc, BATCH, "model", None, None, None)
    bc = constrain(bc, BATCH, "model", None, None)
    cc = constrain(cc, BATCH, "model", None, None)
    dtc = constrain(dtc, BATCH, "model", None, None)

    da = dtc * a.astype(jnp.float32)                    # (B,nc,Q,H)
    cum = jnp.cumsum(da, axis=2)                        # inclusive
    seg = cum[:, :, -1:]                                # chunk total (B,nc,1,H)

    # intra-chunk (masked quadratic); mask BEFORE exp so the grad of the
    # masked-out (explosive) entries is exactly zero, not inf*0.
    # The (B,nc,Q,Q,H) tensors stay in the activation dtype (bf16 on TPU)
    # with f32 accumulation in the dots — exp factors are <= 1 so bf16 is
    # safe, and this halves the dominant HBM traffic (EXPERIMENTS §Perf).
    cdt = x.dtype
    diff = cum[:, :, :, None] - cum[:, :, None, :]      # (B,nc,Qi,Qj,H)
    iq = jnp.arange(chunk)
    mask = iq[:, None] >= iq[None, :]
    diff = jnp.where(mask[None, None, :, :, None], diff, -jnp.inf)
    lmat = jnp.exp(diff).astype(cdt)
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc,
                    preferred_element_type=jnp.float32).astype(cdt)
    w = cb[..., None] * lmat * dtc[:, :, None].astype(cdt)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xc,
                         preferred_element_type=jnp.float32)

    # per-chunk input states
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)     # (B,nc,Q,H)
    sc = jnp.einsum("bcqh,bcqn,bcqhp->bchpn",
                    (decay_to_end * dtc).astype(cdt), bc, xc,
                    preferred_element_type=jnp.float32)  # (B,nc,H,P,N)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(seg[:, :, 0])                 # (B,nc,H)

    def step(s_prev, ys):
        dcy, s_in = ys                                  # (B,H), (B,H,P,N)
        s_new = s_prev * dcy[:, :, None, None] + s_in
        return s_new, s_prev

    s0 = jnp.zeros((bsz, h, p, n), dtype=jnp.float32)
    s_last, s_prevs = jax.lax.scan(
        step, s0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(sc, 1, 0)))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)               # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp",
                         cc, s_prevs.astype(cdt),
                         jnp.exp(cum).astype(cdt),
                         preferred_element_type=jnp.float32)
    y = (y_intra + y_inter).reshape(bsz, nc * chunk, h, p)[:, :l]
    return y.astype(x.dtype), s_last


def mamba2_apply(p, u, state=None, conv_state=None, *, d_model, ssm_state,
                 head_dim, chunk):
    """u: (B, S, d).  Returns (y, (ssm_state, conv_state))."""
    d_in = 2 * d_model
    nheads = d_in // head_dim
    bsz, s, _ = u.shape
    z, xbc, dt = _split_proj(p, u, d_in, ssm_state, nheads)
    xbc, conv_state = _conv(xbc, p["conv"], conv_state)
    x, b_, c_ = jnp.split(xbc, [d_in, d_in + ssm_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = x.reshape(bsz, s, nheads, head_dim)

    if s == 1:                                          # decode fast path
        h_prev = (jnp.zeros((bsz, nheads, head_dim, ssm_state),
                            dtype=jnp.float32) if state is None else state)
        da = jnp.exp(dt[:, 0] * a)                      # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0],
                         b_[:, 0].astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32))
        h = h_prev * da[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", c_[:, 0].astype(jnp.float32), h)
        y = y[:, None].astype(u.dtype)
        state = h
    else:
        y, state = ssd_chunked(xh, dt, a, b_, c_, chunk)

    y = y + xh.astype(y.dtype) * p["d_skip"].astype(y.dtype)[None, None, :,
                                                             None]
    y = y.reshape(bsz, s, d_in)
    y = rmsnorm(y * jax.nn.silu(z.astype(y.dtype)), p["norm"])
    return y @ p["out_proj"], (state, conv_state)
