"""Shared layer library: norms, RoPE/M-RoPE, attention (full/windowed,
memory-chunked), MLPs.  Pure-functional: params are nested dicts of arrays.

Attention is implemented flash-style in plain JAX: an outer scan over query
chunks and an inner scan over key/value chunks with an online-softmax
accumulator, so no (S, S) score tensor is ever materialized — required for
the 32k prefill shapes and the production remat policy.  Windowed attention
(SWA / local) slices a *static-size* KV band per query chunk, making total
FLOPs linear in sequence length (this is what makes ``long_500k`` runnable
for mixtral/recurrentgemma).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.constraints import BATCH, constrain

# ---------------------------------------------------------------------------
# initializers / norms
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
              eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


def apply_norm(x, p, kind: str, eps: float):
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"], eps)
    return layernorm(x, p["scale"], p["bias"], eps)


def norm_params(key, d, kind: str, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


# ---------------------------------------------------------------------------
# RoPE (+ M-RoPE for Qwen2-VL)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               m_rope_sections: Optional[tuple] = None) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) or (3, B, S) for M-RoPE."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                      # (hd/2,)
    if positions.ndim == 3:                          # M-RoPE: (3, B, S)
        assert m_rope_sections is not None
        # section s of the hd/2 frequency slots takes angles from axis s
        sec_id = jnp.repeat(
            jnp.arange(len(m_rope_sections)),
            jnp.array(m_rope_sections),
            total_repeat_length=hd // 2)             # (hd/2,)
        ang_all = positions[..., None].astype(jnp.float32) * inv  # (3,B,S,hd/2)
        ang = jnp.take_along_axis(
            jnp.moveaxis(ang_all, 0, -1),            # (B,S,hd/2,3)
            sec_id[None, None, :, None], axis=-1)[..., 0]
    else:
        ang = positions[..., None].astype(jnp.float32) * inv      # (B,S,hd/2)
    # angles in f32; the rotation itself stays in the activation dtype so
    # no x-sized f32 tensors cross collective boundaries (measured: XLA
    # hoists all-gathers past the converts, doubling wire bytes)
    cos = jnp.cos(ang).astype(x.dtype)[:, :, None, :]   # (B,S,1,hd/2)
    sin = jnp.sin(ang).astype(x.dtype)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1)


# ---------------------------------------------------------------------------
# chunked (flash-style) attention
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _attend_block(q, k, v, qpos, kpos, window, scale):
    """One (q-chunk, kv-chunk) online-softmax block.

    q: (B, Tq, KV, G, hd); k/v: (B, Tk, KV, hd).
    Returns (scores_max, exp_sums, weighted_v) pieces for the accumulator.
    """
    s = jnp.einsum("btkgh,bukh->bkgtu", q, k) * scale   # (B,KV,G,Tq,Tk)
    mask = kpos[None, :] <= qpos[:, None]               # causal
    if window is not None:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    s = jnp.where(mask[None, None, None], s.astype(jnp.float32), NEG_INF)
    m = jnp.max(s, axis=-1)                             # (B,KV,G,Tq)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    pv = jnp.einsum("bkgtu,bukh->bkgth", p.astype(v.dtype), v)
    return m, l, pv


def _ctx_parallel_flash(q, k, v, qp, kp, window, scale):
    """Context-parallel forward: all query chunks advance together through
    the kv scan, with the *chunk axis* sharded over `model`.  Used for
    prefill of archs whose head count does not divide the TP axis (qwen3's
    40, musicgen's 24): head-sharding is impossible, so without this the
    partitioner replicates the whole attention across `model` (measured
    8-16x redundant FLOPs, EXPERIMENTS §Perf P10).

    q: (B, nq, Tq, KV, G, hd) pre-chunked; k/v: (nk, B, Tk, KV, hd);
    qp: (nq, Tq); kp: (nk, Tk).
    """
    b, nq, tq, kv, g, hd = q.shape
    q = constrain(q, BATCH, "model", None, None, None, None)

    def inner(acc, ys):
        kc, vc, kpc = ys
        m0, l0, o0 = acc
        s = jnp.einsum("bqtkgh,bukh->bkgqtu", q, kc) * scale
        mask = kpc[None, None, :] <= qp[:, :, None]
        if window is not None:
            mask = mask & (kpc[None, None, :] > (qp[:, :, None] - window))
        s = jnp.where(mask[None, None, None], s.astype(jnp.float32),
                      NEG_INF)
        m = jnp.maximum(m0, jnp.max(s, axis=-1))
        p = jnp.exp(s - m[..., None])
        a0 = jnp.exp(m0 - m)
        l = l0 * a0 + jnp.sum(p, axis=-1)
        o = o0 * a0[..., None] + jnp.einsum(
            "bkgqtu,bukh->bkgqth", p, vc.astype(jnp.float32))
        return (m, l, o), None

    con = lambda a: constrain(a, BATCH, None, None, "model",
                              *([None] * (a.ndim - 4)))
    acc0 = (con(jnp.full((b, kv, g, nq, tq), NEG_INF, jnp.float32)),
            con(jnp.zeros((b, kv, g, nq, tq), jnp.float32)),
            con(jnp.zeros((b, kv, g, nq, tq, hd), jnp.float32)))
    (m, l, o), _ = jax.lax.scan(inner, acc0, (k, v, kp))
    out = o / jnp.maximum(l, 1e-30)[..., None]
    # (B,KV,G,nq,Tq,hd) -> (B, nq*Tq, KV*G, hd)
    out = out.transpose(0, 3, 4, 1, 2, 5).reshape(b, nq * tq, kv * g, hd)
    return out.astype(v.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_positions: jax.Array, kv_positions: jax.Array,
                    *, window: Optional[int] = None,
                    q_chunk: int = 1024, kv_chunk: int = 4096,
                    ctx_parallel: bool = False) -> jax.Array:
    """Causal (optionally windowed) attention without materializing scores.

    q: (B, Sq, H, hd) with H = KV * G;  k, v: (B, Skv, KV, hd).
    q_positions: (Sq,) absolute positions;  kv_positions: (Skv,).
    ``ctx_parallel``: forward-only context-parallel path (see above).
    Returns (B, Sq, H, hd).
    """
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(hd)
    q = q.reshape(b, sq, kv, g, hd)

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq = -(-sq // q_chunk)
    nk = -(-skv // kv_chunk)
    # pad to whole chunks (padding keys get position +inf -> fully masked)
    qpad, kpad = nq * q_chunk - sq, nk * kv_chunk - skv
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, qpad))
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, kpad),
                               constant_values=2**30)

    qs = q.reshape(b, nq, q_chunk, kv, g, hd)
    qp = q_positions.reshape(nq, q_chunk)
    ks = k.reshape(b, nk, kv_chunk, kv, hd)
    vs = v.reshape(b, nk, kv_chunk, kv, hd)
    kp = kv_positions.reshape(nk, kv_chunk)

    band = (-(-((window or 0) + q_chunk) // kv_chunk) + 1) * kv_chunk
    if window is not None and nk * kv_chunk > band:
        # static-size KV band per query chunk: linear-in-S total work

        def per_qchunk(qc, qpc, qi):
            start = jnp.clip(qi * q_chunk + q_chunk - band,
                             0, nk * kv_chunk - band)
            kb = jax.lax.dynamic_slice_in_dim(
                k, start, band, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            kpb = jax.lax.dynamic_slice_in_dim(kv_positions, start, band)
            m, l, pv = _attend_block(qc, kb, vb, qpc, kpb, window, scale)
            out = pv / jnp.maximum(l, 1e-30)[..., None].astype(pv.dtype)
            return out                                   # (B,KV,G,Tq,hd)

        outs = jax.lax.map(
            lambda args: per_qchunk(*args),
            (jnp.moveaxis(qs, 1, 0), qp, jnp.arange(nq)))
        out = jnp.moveaxis(outs, 0, 1)                   # (B,nq,KV,G,Tq,hd)
        out = out.transpose(0, 1, 4, 2, 3, 5)            # -> B,nq,Tq,KV,G,hd
        out = out.reshape(b, nq * q_chunk, h, hd)
        return out[:, :sq]

    if ctx_parallel and nq > 1:
        out = _ctx_parallel_flash(qs, jnp.moveaxis(ks, 1, 0),
                                  jnp.moveaxis(vs, 1, 0), qp, kp,
                                  window, scale)
        return out[:, :sq]

    # full-causal path: custom-VJP flash core (chunk-recomputing backward —
    # default AD through the kv-scan stacks S^2-sized residuals, measured
    # as 34% of llama train HBM traffic; see EXPERIMENTS §Perf)
    from .flash_vjp import flash_core
    out5 = flash_core(q, k, v, q_positions, kv_positions, window,
                      q_chunk, kv_chunk)                 # (B,Sq,KV,G,hd)
    out = out5.reshape(b, nq * q_chunk, h, hd)
    return out[:, :sq]


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     q_position: jax.Array, kv_positions: jax.Array,
                     *, window: Optional[int] = None) -> jax.Array:
    """Single-token attention over a (possibly ring-buffer) KV cache.

    q: (B, 1, H, hd); caches: (B, C, KV, hd); kv_positions: (B, C) absolute
    positions of cache slots (-1 for empty).  Ring caches pass their slot
    position array; masking handles both validity and the window.
    """
    b, _, h, hd = q.shape
    c, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(hd)
    qr = q.reshape(b, kv, g, hd)
    s = jnp.einsum("bkgh,bukh->bkgu", qr, k_cache) * scale
    valid = (kv_positions >= 0) & (kv_positions <= q_position[:, None])
    if window is not None:
        valid &= kv_positions > (q_position[:, None] - window)
    s = jnp.where(valid[:, None, None], s.astype(jnp.float32), NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgu,bukh->bkgh", p.astype(v_cache.dtype), v_cache)
    return o.reshape(b, 1, h, hd)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_params(key, d, ff, act, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    if act == "silu":
        return {"gate": dense_init(k1, (d, ff), dtype),
                "up": dense_init(k2, (d, ff), dtype),
                "down": dense_init(k3, (ff, d), dtype)}
    return {"up": dense_init(k1, (d, ff), dtype),
            "down": dense_init(k2, (ff, d), dtype)}


def mlp_apply(p, x, act):
    if act == "silu":
        h = jax.nn.silu(x @ p["gate"]) * (x @ p["up"])
    else:
        h = jax.nn.gelu(x @ p["up"])
    return h @ p["down"]
