"""Model zoo: the 10 assigned architectures as one configurable decoder stack."""
from .config import ArchConfig
from .transformer import forward, init_cache, init_params, param_specs
