"""RG-LRU recurrent block (RecurrentGemma / Griffin).

    a_t = exp(-c * softplus(Lambda) * sigmoid(W_a x_t))
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (sigmoid(W_x x_t) * x_t)

Training/prefill uses an associative scan over (log a, b) pairs — O(log S)
depth, fully parallel across (batch, width) lanes.  Decode carries h as the
recurrent state: O(1) per token regardless of context length (this is what
makes ``long_500k`` decode trivial for this family).

Note the structural kinship with the paper: the recurrence is the solve of a
*bidiagonal lower-triangular system* (I - shift(a)) h = b; the associative
scan plays the role HBMC's round-parallelism plays for general sparsity
(see DESIGN.md §4 and examples/rnn_as_trisolve.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init

_C = 8.0


def rglru_params(key, d, rw, conv_width, dtype):
    ks = jax.random.split(key, 6)
    return {
        "in_x": dense_init(ks[0], (d, rw), dtype),
        "in_y": dense_init(ks[1], (d, rw), dtype),
        "conv": (jax.random.normal(ks[2], (conv_width, rw)) * 0.1).astype(dtype),
        "gate_a": dense_init(ks[3], (rw, rw), dtype),
        "gate_x": dense_init(ks[4], (rw, rw), dtype),
        "lamb": jnp.linspace(0.5, 4.0, rw).astype(dtype),   # Lambda init
        "out": dense_init(ks[5], (rw, d), dtype),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv.  x: (B, S, rw); w: (cw, rw).

    With ``state`` (B, cw-1, rw) performs the streaming step and returns the
    updated state (decode path).
    """
    cw = w.shape[0]
    if state is None:
        pad = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([state, x], axis=1)
    out = sum(pad[:, i:i + x.shape[1]] * w[i] for i in range(cw))
    new_state = pad[:, -(cw - 1):] if cw > 1 else None
    return out, new_state


def _rglru_core(u, ga, gx, lamb):
    """Shared gate math.  u: (..., rw) pre-activation input."""
    log_a = -_C * jax.nn.softplus(lamb.astype(jnp.float32)) \
        * jax.nn.sigmoid((u @ ga).astype(jnp.float32))
    gated = jax.nn.sigmoid((u @ gx).astype(jnp.float32)) * u.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated
    return log_a, b


def rglru_apply(p, x, h0=None, conv_state=None):
    """x: (B, S, d).  Returns (y, (h_last, conv_state)).

    h0: (B, rw) initial recurrent state (None = zeros).
    """
    bsz, s, _ = x.shape
    u = x @ p["in_x"]                                   # (B, S, rw)
    branch = jax.nn.gelu(x @ p["in_y"])
    u, conv_state = _causal_conv(u, p["conv"], conv_state)
    log_a, b = _rglru_core(u, p["gate_a"], p["gate_x"], p["lamb"])

    if s == 1:                                           # decode fast path
        h_prev = jnp.zeros_like(b[:, 0]) if h0 is None else h0
        h = jnp.exp(log_a[:, 0]) * h_prev + b[:, 0]
        hs = h[:, None]
    else:
        if h0 is not None:
            # fold the carried state in as a virtual step 0
            log_a = jnp.concatenate(
                [jnp.zeros_like(log_a[:, :1]), log_a], axis=1)
            b = jnp.concatenate([h0.astype(b.dtype)[:, None], b], axis=1)

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 + a2, jnp.exp(a2) * b1 + b2

        la, hs = jax.lax.associative_scan(combine, (log_a, b), axis=1)
        if h0 is not None:
            hs = hs[:, 1:]
        h = hs[:, -1]

    y = (hs.astype(x.dtype) * branch) @ p["out"]
    return y, (h, conv_state)
