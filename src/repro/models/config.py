"""Unified architecture configuration for the assigned model pool."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # defaults to d_model // n_heads

    # attention flavour
    rope_theta: float = 1e4
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_window: Optional[int] = None   # sliding/local window; None = full
    m_rope: bool = False                # Qwen2-VL multimodal RoPE
    pos_emb: str = "rope"               # rope | none (frontend supplies)

    # layer pattern for hybrid stacks; scanned over `pattern repeats`
    block_pattern: tuple = ("attn",)    # e.g. ("rec","rec","attn")

    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25

    # recurrent / ssm
    rnn_width: int = 0                  # RG-LRU lru width
    ssm_state: int = 0                  # Mamba2 N
    ssm_head_dim: int = 64              # Mamba2 P
    ssm_chunk: int = 256                # SSD chunk length
    conv_width: int = 4

    # distribution knobs
    seq_parallel: bool = True           # Megatron-SP residual stream
    remat_group: int = 1                # superblocks per outer remat group

    # misc
    norm: str = "rmsnorm"               # rmsnorm | layernorm
    act: str = "silu"                   # silu (swiglu) | gelu (plain mlp)
    frontend: Optional[str] = None      # vision | audio (stubbed)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_layers % len(self.block_pattern):
            raise ValueError("n_layers must divide by pattern length; pad the "
                             "pattern or adjust the tail in the stack module")

    @property
    def pattern_repeats(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def takes_embeddings(self) -> bool:
        """VLM/audio backbones consume precomputed frontend embeddings."""
        return self.frontend is not None

    # ------------------------------------------------------------------
    # analytic parameter / FLOP model (used for roofline MODEL_FLOPS)
    # ------------------------------------------------------------------
    def param_count(self, active_only: bool = False) -> int:
        d, h, kv, hd, ff = (self.d_model, self.n_heads, self.n_kv_heads,
                            self.head_dim, self.d_ff)
        per_layer = {}
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d   # q,k,v,o
        if self.qkv_bias:
            attn += (h + 2 * kv) * hd
        if self.act == "silu":
            mlp = 3 * d * ff                               # gate, up, down
        else:
            mlp = 2 * d * ff
        per_layer["attn"] = attn + 2 * d                   # + 2 norms
        if self.n_experts:
            experts = self.n_experts if not active_only else self.moe_top_k
            per_layer["attn"] += d * self.n_experts        # router
            per_layer["attn"] += experts * mlp - mlp       # replace dense mlp
        per_layer["attn"] += mlp
        # recurrent block (RG-LRU): in/out proj + conv + gates
        rw = self.rnn_width or d
        per_layer["rec"] = (2 * d * rw + rw * d + self.conv_width * rw
                            + 2 * rw * rw + 2 * d) + mlp + 2 * d
        # mamba2 block
        d_in = 2 * d
        nheads = d_in // self.ssm_head_dim if self.ssm_state else 0
        conv_dim = d_in + 2 * self.ssm_state
        per_layer["ssm"] = (d * (2 * d_in + 2 * self.ssm_state + nheads)
                            + conv_dim * self.conv_width + d_in * d
                            + d_in + 2 * nheads + 2 * d)
        total = 0
        for i in range(self.n_layers):
            kind = self.block_pattern[i % len(self.block_pattern)]
            total += per_layer[kind]
        emb = self.vocab * d
        total += emb + d                                   # + final norm
        if not self.tie_embeddings:
            total += self.vocab * d                        # lm head
        return total

    def model_flops(self, batch: int, seq: int, decode: bool = False) -> float:
        """6*N*D (dense) / 6*N_active*D (MoE) training FLOPs, or 2*N per
        decoded token for serve steps."""
        n_active = self.param_count(active_only=True)
        tokens = batch * (1 if decode else seq)
        return (2.0 if decode else 6.0) * n_active * tokens
