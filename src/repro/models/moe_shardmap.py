"""Explicit shard_map MoE: token-local dispatch + ff-sliced experts + one
psum — the production path for E < mesh_model (e.g. mixtral's 8 experts on
a 16-wide model axis).

Why: under plain pjit, the capacity-dispatch einsum MoE leaves the (E, C, d)
buffers replicated across `model`, and the partitioner all-reduces them —
~0.5 TB/device/step on mixtral train_4k (measured; see EXPERIMENTS §Perf).
Here every device:

  1. computes the (replicated) router for its batch shard,
  2. scatters its OWN tokens into a local (E, C_local, d) buffer — no
     communication at all,
  3. runs all experts' GEMMs on its ff-slice of every expert
     (Megatron-style tensor parallelism over `model`),
  4. combines back to token layout and psums the ff-partial outputs over
     `model` — the only collective, (T_local x d) sized.

The math is identical to ``moe_apply`` with per-device capacity
C_local = C / data_shards (routing is batch-local in both).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.dist.constraints import _current_mesh



def _local_moe(xf, router, gate_w, up_w, down_w, *, top_k, capacity_factor,
               act, model_axis):
    """Per-device body.  xf: (T_local, d); expert weights ff-sliced."""
    t, d = xf.shape
    n_experts = router.shape[-1]
    logits = (xf @ router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, top_k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    if capacity_factor <= 0:
        capacity = t
    else:
        capacity = max(1, int(t * top_k * capacity_factor / n_experts))
    onehot = jax.nn.one_hot(idx, n_experts, dtype=jnp.int32)
    flat = onehot.reshape(t * top_k, n_experts)
    pos = jnp.cumsum(flat, axis=0) - 1
    pos = jnp.sum(pos * flat, axis=-1).reshape(t, top_k)
    keep = pos < capacity
    gate = gate * keep

    e_flat = idx.reshape(-1)
    c_flat = jnp.clip(pos.reshape(-1), 0, capacity - 1)
    buf = jnp.zeros((n_experts, capacity, d), dtype=xf.dtype)
    src = jnp.repeat(xf, top_k, axis=0)
    w = keep.reshape(-1, 1).astype(xf.dtype)
    buf = buf.at[e_flat, c_flat].add(src * w)        # local scatter

    if act == "silu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, gate_w)) \
            * jnp.einsum("ecd,edf->ecf", buf, up_w)
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, up_w))
    out = jnp.einsum("ecf,efd->ecd", h, down_w)      # ff-partial

    gathered = out[e_flat, c_flat]
    y = jnp.sum((gathered * gate.reshape(-1, 1).astype(xf.dtype))
                .reshape(t, top_k, d), axis=1)
    y = jax.lax.psum(y, model_axis)                  # the one collective
    return y, logits


def moe_apply_shardmap(p, x, *, top_k: int, capacity_factor: float,
                       act: str):
    """Drop-in replacement for moe_apply when a mesh with a `model` axis is
    active and ff divides it.  Returns (y, router_logits_local)."""
    mesh = _current_mesh()
    b, s, d = x.shape
    ff = p["experts"]["down"].shape[1]
    if mesh is None or "model" not in mesh.axis_names:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if ff % sizes["model"] != 0:
        return None
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bdiv = 1
    for a in batch_axes:
        bdiv *= sizes[a]
    if b % bdiv != 0:
        batch_axes = tuple(a for a in batch_axes if b % sizes[a] == 0)[:1]
        if batch_axes and b % sizes[batch_axes[0]] != 0:
            batch_axes = ()

    bspec = batch_axes if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None)

    def body(xl, router, gw, uw, dw):
        t_l = xl.shape[0] * xl.shape[1]
        y, logits = _local_moe(
            xl.reshape(t_l, d), router, gw, uw, dw, top_k=top_k,
            capacity_factor=capacity_factor, act=act, model_axis="model")
        return y.reshape(xl.shape), logits

    gw, uw = p["experts"].get("gate"), p["experts"]["up"]
    dw = p["experts"]["down"]
    if gw is None:
        gw = uw   # gelu path ignores gate
    y, logits = shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, None), P(None, None),
                  P(None, None, "model"), P(None, None, "model"),
                  P(None, "model", None)),
        out_specs=(P(bspec, None, None), P(bspec, None)),
        check_rep=False,
    )(x, p["router"], gw, uw, dw)
    return y, logits
