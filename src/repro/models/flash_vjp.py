"""Flash attention with a custom VJP (chunk-recomputing backward).

Default JAX AD through the online-softmax kv-scan stacks per-chunk
residuals — S^2-sized HBM traffic that dominated the llama3-405b train
cell (34% of all bytes; see EXPERIMENTS §Perf P6).  The flash backward
recomputes p = exp(qk - lse) per (q-chunk, kv-chunk) tile instead, exactly
like the Pallas/TPU production kernels:

  forward residuals: q, k, v, o, lse            (all O(S), no S^2 term)
  backward:  D = rowsum(do * o)
             per tile: p   = exp(s - lse)
                       dv += p^T do
                       dp  = do v^T
                       ds  = p * (dp - D) * scale
                       dq += ds k ;  dk += ds^T q

Shapes follow layers.flash_attention: q (B,Sq,KV,G,hd), k/v (B,Skv,KV,hd),
already padded to whole chunks; positions carry the causal/window mask.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(qpos, kpos, window):
    m = kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= kpos[None, :] > (qpos[:, None] - window)
    return m


@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def flash_core(q, k, v, q_positions, kv_positions, window, q_chunk,
               kv_chunk):
    o, _ = _flash_fwd_impl(q, k, v, q_positions, kv_positions, window,
                           q_chunk, kv_chunk)
    return o


def _flash_fwd_impl(q, k, v, qpos, kpos, window, q_chunk, kv_chunk):
    b, sq, kv, g, hd = q.shape
    skv = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    nq, nk = sq // q_chunk, skv // kv_chunk
    qs = jnp.moveaxis(q.reshape(b, nq, q_chunk, kv, g, hd), 1, 0)
    qp = qpos.reshape(nq, q_chunk)
    ks = jnp.moveaxis(k.reshape(b, nk, kv_chunk, kv, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(b, nk, kv_chunk, kv, hd), 1, 0)
    kp = kpos.reshape(nk, kv_chunk)

    def per_q(carry, xs):
        qc, qpc = xs

        def inner(acc, ys):
            kc, vc, kpc = ys
            m0, l0, o0 = acc
            s = jnp.einsum("btkgh,bukh->bkgtu", qc, kc) * scale
            s = jnp.where(_mask(qpc, kpc, window)[None, None, None],
                          s.astype(jnp.float32), NEG_INF)
            m = jnp.maximum(m0, jnp.max(s, axis=-1))
            p = jnp.exp(s - m[..., None])
            a0 = jnp.exp(m0 - m)
            l = l0 * a0 + jnp.sum(p, axis=-1)
            o = o0 * a0[..., None] \
                + jnp.einsum("bkgtu,bukh->bkgth", p, vc.astype(jnp.float32))
            return (m, l, o), None

        acc0 = (jnp.full((b, kv, g, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((b, kv, g, q_chunk), jnp.float32),
                jnp.zeros((b, kv, g, q_chunk, hd), jnp.float32))
        (m, l, o), _ = jax.lax.scan(inner, acc0, (ks, vs, kp))
        l = jnp.maximum(l, 1e-30)
        out = (o / l[..., None]).astype(q.dtype)      # (B,KV,G,Tq,hd)
        lse = m + jnp.log(l)                          # (B,KV,G,Tq)
        return carry, (out, lse)

    _, (outs, lses) = jax.lax.scan(per_q, None, (qs, qp))
    # outs: (nq, B, KV, G, Tq, hd) -> (B, Sq, KV, G, hd)
    o = jnp.moveaxis(outs, 0, 1).transpose(0, 1, 4, 2, 3, 5) \
        .reshape(b, sq, kv, g, hd)
    # lses: (nq, B, KV, G, Tq) -> (B, Sq, KV, G)
    lse = jnp.moveaxis(lses, 0, 1).transpose(0, 1, 4, 2, 3) \
        .reshape(b, sq, kv, g)
    return o, lse


def _fwd(q, k, v, qpos, kpos, window, q_chunk, kv_chunk):
    o, lse = _flash_fwd_impl(q, k, v, qpos, kpos, window, q_chunk, kv_chunk)
    return o, (q, k, v, qpos, kpos, o, lse)


def _bwd(window, q_chunk, kv_chunk, res, do):
    q, k, v, qpos, kpos, o, lse = res
    b, sq, kv, g, hd = q.shape
    skv = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    nq, nk = sq // q_chunk, skv // kv_chunk

    # D = rowsum(do * o): (B,Sq,KV,G)
    d_ = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    qs = jnp.moveaxis(q.reshape(b, nq, q_chunk, kv, g, hd), 1, 0)
    dos = jnp.moveaxis(do.reshape(b, nq, q_chunk, kv, g, hd), 1, 0)
    ds_ = jnp.moveaxis(d_.reshape(b, nq, q_chunk, kv, g), 1, 0)
    lses = jnp.moveaxis(lse.reshape(b, nq, q_chunk, kv, g), 1, 0)
    qp = qpos.reshape(nq, q_chunk)
    ks = jnp.moveaxis(k.reshape(b, nk, kv_chunk, kv, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(b, nk, kv_chunk, kv, hd), 1, 0)
    kp = kpos.reshape(nk, kv_chunk)

    def per_q(carry, xs):
        dk_acc, dv_acc = carry                        # (nk,B,Tk,KV,hd) f32
        qc, doc, dc, lsec, qpc = xs

        def inner(dq, ys):
            kc, vc, kpc = ys
            s = jnp.einsum("btkgh,bukh->bkgtu", qc, kc) * scale
            msk = _mask(qpc, kpc, window)[None, None, None]
            s = jnp.where(msk, s.astype(jnp.float32), NEG_INF)
            # lsec: (B,Tq,KV,G) -> (B,KV,G,Tq)
            lse_t = lsec.transpose(0, 2, 3, 1)
            p = jnp.exp(s - lse_t[..., None])         # (B,KV,G,Tq,Tk)
            do_t = doc.transpose(0, 2, 3, 1, 4)       # (B,KV,G,Tq,hd)
            dv_c = jnp.einsum("bkgtu,bkgth->bukh", p,
                              do_t.astype(jnp.float32))
            dp = jnp.einsum("bkgth,bukh->bkgtu", do_t.astype(jnp.float32),
                            vc.astype(jnp.float32))
            d_t = dc.transpose(0, 2, 3, 1)            # (B,KV,G,Tq)
            dsx = p * (dp - d_t[..., None]) * scale
            dq = dq + jnp.einsum("bkgtu,bukh->btkgh", dsx,
                                 kc.astype(jnp.float32))
            dk_c = jnp.einsum("bkgtu,btkgh->bukh", dsx,
                              qc.astype(jnp.float32))
            return dq, (dk_c, dv_c)

        dq0 = jnp.zeros((b, q_chunk, kv, g, hd), jnp.float32)
        dq, (dk_cs, dv_cs) = jax.lax.scan(inner, dq0, (ks, vs, kp))
        return (dk_acc + dk_cs, dv_acc + dv_cs), dq

    dk0 = jnp.zeros((nk, b, kv_chunk, kv, hd), jnp.float32)
    dv0 = jnp.zeros((nk, b, kv_chunk, kv, hd), jnp.float32)
    (dk, dv), dqs = jax.lax.scan(per_q, (dk0, dv0),
                                 (qs, dos, ds_, lses, qp))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(b, sq, kv, g, hd).astype(q.dtype)
    dk = jnp.moveaxis(dk, 0, 1).reshape(b, skv, kv, hd).astype(k.dtype)
    dv = jnp.moveaxis(dv, 0, 1).reshape(b, skv, kv, hd).astype(v.dtype)
    return dq, dk, dv, None, None


flash_core.defvjp(_fwd, _bwd)
