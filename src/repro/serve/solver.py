"""Solver-as-a-service: continuous batching of RHS streams over warm plans.

The HBMC pipeline's expensive products — ordering, rounds, IC(0) factor,
packed tables — are all cached inside a ``SolverPlan``; this module
amortizes them across *clients*:

``PlanCache``
    LRU cache of built plans keyed by sparsity-pattern fingerprint (a hash
    of the CSR ``indptr``/``indices``) plus every build knob that changes
    the compiled solver (method, backend, dtype, ...).  A request whose
    pattern is cached but whose values changed takes the
    ``plan.refactor`` fast path — numeric factorization only, zero
    retrace — instead of a full rebuild.  Plans with in-flight slabs are
    *pinned* and never evicted.

``SolverService``
    A request queue that packs heterogeneous right-hand sides into
    resident PCG slabs of a configurable width (``plan.run_slab``) and
    advances each slab a bounded ``quantum`` of iterations per dispatch.
    Converged columns retire between dispatches — they report their
    iteration count, free their slot, and a fresh queued request is packed
    in on the next dispatch — so a slab never runs every column to the
    slowest straggler.

Numerical contract (pinned by tests/test_serve_solver.py): a request
served at slab width B in slot s is bitwise equal to the standalone
``plan.solve_slab(b, slab_width=B, slot=s)`` on a fresh plan —
independent of which requests shared its slab, of dispatch quantum, and
of retire/refill interleaving.  (Width and slot pin the lowered
reduction trees; at B = 1 the oracle coincides with
``plan.solve_batched(b[:, None])``.)  Iteration counts equal the
single-RHS ``plan.solve`` counts at every width and slot.

Scheduling is single-threaded and deterministic: ``step()`` advances the
whole service one admit → pack → dispatch → retire cycle, and a
``VirtualClock`` with an event cost model replaces wall time in tests (no
sleeps, no threads).
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict
from typing import Any, Callable

import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from repro.core.iccg import SlabState
from repro.core.plan import SolverPlan, build_plan

# ---------------------------------------------------------------------------
# Fingerprints and cache keys
# ---------------------------------------------------------------------------


def _as_csr(a: sp.spmatrix) -> sp.csr_matrix:
    a = sp.csr_matrix(a)
    a.sort_indices()
    return a


def pattern_fingerprint(a: sp.spmatrix) -> str:
    """Hash of the sparsity pattern only (shape + CSR indptr/indices)."""
    a = _as_csr(a)
    h = hashlib.sha1()
    h.update(np.asarray(a.shape, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(a.indptr, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(a.indices, dtype=np.int64).tobytes())
    return h.hexdigest()


def values_fingerprint(a: sp.spmatrix) -> str:
    """Hash of the numeric values (CSR data, canonical index order)."""
    a = _as_csr(a)
    return hashlib.sha1(np.ascontiguousarray(a.data).tobytes()).hexdigest()


@dataclasses.dataclass(frozen=True)
class PlanKey:
    """Everything that decides whether two requests can share one plan.

    Pattern fingerprint + the build knobs that change the compiled solver.
    Two matrices with equal keys but different values share the plan
    through ``refactor``; anything else is a distinct cache entry.
    """
    pattern: str
    n: int
    method: str
    block_size: int
    w: int
    shift: float
    spmv_format: str
    dtype: str
    backend: str
    spmv_backend: str
    layout: str
    interpret: bool | None
    lane_multiple: int

    @classmethod
    def from_matrix(cls, a: sp.spmatrix, *, method: str = "hbmc",
                    block_size: int = 32, w: int = 8, shift: float = 0.0,
                    spmv_format: str = "ell", dtype=jnp.float64,
                    backend: str = "xla", interpret: bool | None = None,
                    layout: str = "round_major", lane_multiple: int = 1,
                    spmv_backend: str = "xla",
                    **extra) -> tuple["PlanKey", sp.csr_matrix]:
        """Key for (a, knobs); also returns the canonicalized CSR matrix."""
        if extra.get("mesh") is not None:
            raise ValueError("mesh plans are not cacheable: a Mesh binds "
                             "the plan to a device set; serve single-device "
                             "plans (or shard outside the service)")
        extra.pop("mesh", None)
        if extra:
            raise TypeError(f"unknown plan knobs: {sorted(extra)}")
        a = _as_csr(a)
        key = cls(pattern=pattern_fingerprint(a), n=int(a.shape[0]),
                  method=method, block_size=int(block_size), w=int(w),
                  shift=float(shift), spmv_format=spmv_format,
                  dtype=str(np.dtype(jnp.dtype(dtype))), backend=backend,
                  spmv_backend=spmv_backend, layout=layout,
                  interpret=interpret,
                  lane_multiple=int(lane_multiple))
        return key, a


class PlanBusyError(RuntimeError):
    """Raised when a value-change refactor targets a pinned (in-flight)
    plan: refactoring would corrupt resident slab columns mid-solve."""


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    refactors: int = 0
    evictions: int = 0
    pinned_overflow: int = 0   # capacity exceeded but every entry pinned

    @property
    def requests(self) -> int:
        return self.hits + self.misses + self.refactors

    @property
    def hit_rate(self) -> float:
        n = self.requests
        # a refactor reuses the expensive setup products: count it warm
        return (self.hits + self.refactors) / n if n else 0.0


@dataclasses.dataclass
class _CacheEntry:
    plan: SolverPlan
    values_fp: str
    pins: int = 0


class PlanCache:
    """LRU cache of built ``SolverPlan``s with pin-aware eviction.

    ``get`` returns ``(plan, status)`` with status one of:

    * ``"hit"``       — pattern and values both cached
    * ``"refactor"``  — pattern cached, values renewed via the numeric
      fast path (raises ``PlanBusyError`` if the entry is pinned)
    * ``"miss"``      — full build (evicting LRU *unpinned* entries if
      over capacity; a ``pin=True`` newcomer is protected by its own pin,
      so when every resident is pinned the cache overflows temporarily
      and records ``pinned_overflow``, while an unpinned newcomer is
      simply not retained)

    ``pin``/``unpin`` bracket in-flight use (the ``SolverService`` pins a
    key while a slab group holds resident columns, via ``get(pin=True)``);
    pinned entries are never evicted and never refactored out from under
    their slabs.
    """

    def __init__(self, capacity: int = 8,
                 build: Callable[..., SolverPlan] = build_plan):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._build = build
        self._entries: OrderedDict[PlanKey, _CacheEntry] = OrderedDict()
        self.stats = CacheStats()

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: PlanKey) -> bool:
        return key in self._entries

    def keys(self):
        return list(self._entries)

    def pins(self, key: PlanKey) -> int:
        return self._entries[key].pins if key in self._entries else 0

    def get(self, a: sp.spmatrix, pin: bool = False,
            **knobs) -> tuple[SolverPlan, str]:
        """Plan for (a, knobs): cached, refactored, or freshly built.

        ``pin=True`` pins the entry atomically with the lookup/insert —
        the caller must balance it with ``unpin`` when its slab drains.
        """
        key, a = PlanKey.from_matrix(a, **knobs)
        vfp = values_fingerprint(a)
        entry = self._entries.get(key)
        if entry is not None:
            self._entries.move_to_end(key)
            if entry.values_fp == vfp:
                entry.pins += pin
                self.stats.hits += 1
                return entry.plan, "hit"
            if entry.pins:
                raise PlanBusyError(
                    f"plan {key.pattern[:12]} has {entry.pins} in-flight "
                    f"slab(s); refactoring now would corrupt resident "
                    f"columns — drain the slab first")
            entry.plan.refactor(a)
            entry.values_fp = vfp
            entry.pins += pin
            self.stats.refactors += 1
            return entry.plan, "refactor"
        plan = self._build(a, **knobs)
        self._entries[key] = _CacheEntry(plan=plan, values_fp=vfp,
                                         pins=int(pin))
        self.stats.misses += 1
        self._evict()
        return plan, "miss"

    def _evict(self) -> None:
        while len(self._entries) > self.capacity:
            victim = next((k for k, e in self._entries.items()
                           if e.pins == 0), None)
            if victim is None:
                self.stats.pinned_overflow += 1
                return
            del self._entries[victim]
            self.stats.evictions += 1

    def pin(self, key: PlanKey) -> None:
        self._entries[key].pins += 1

    def unpin(self, key: PlanKey) -> None:
        entry = self._entries[key]
        if entry.pins <= 0:
            raise RuntimeError(f"unpin without pin for {key.pattern[:12]}")
        entry.pins -= 1
        self._evict()   # a deferred eviction may now be possible


# ---------------------------------------------------------------------------
# Clocks
# ---------------------------------------------------------------------------


class WallClock:
    """Real time; event charges are no-ops (the events take real time)."""

    simulated = False

    def __init__(self):
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def charge(self, event: str, n: int = 1) -> None:
        pass


#: Default virtual event costs (arbitrary deterministic units): a build is
#: an order of magnitude above a refactor, which dwarfs per-dispatch work.
DEFAULT_COSTS = {
    "build": 1.0,
    "refactor": 0.1,
    "hit": 0.0,
    "dispatch": 0.05,
    "iteration": 0.01,
    "pack": 0.001,
    "retire": 0.001,
}


class VirtualClock:
    """Deterministic simulated time driven by an event cost model.

    Tests drive the service with seeded arrival traces against this clock:
    no wall-clock sleeps, no threads, and every latency/throughput number
    reproduces bit-for-bit across runs.
    """

    simulated = True

    def __init__(self, costs: dict[str, float] | None = None):
        self.t = 0.0
        self.costs = dict(DEFAULT_COSTS)
        if costs:
            self.costs.update(costs)

    def now(self) -> float:
        return self.t

    def charge(self, event: str, n: int = 1) -> None:
        self.t += n * self.costs.get(event, 0.0)

    def advance_to(self, t: float) -> None:
        if t > self.t:
            self.t = t


# ---------------------------------------------------------------------------
# Requests, slab groups, and the service
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Request:
    rid: int
    key: PlanKey
    values_fp: str
    a: sp.csr_matrix          # kept until packed (plan build / refactor)
    b: np.ndarray
    tag: Any
    arrival: float
    started: float = -1.0
    plan_status: str = ""     # cache status when its slab group resolved


@dataclasses.dataclass
class Completed:
    """A retired request: solution + solve metadata + timing."""
    rid: int
    tag: Any
    x: np.ndarray             # solution in the caller's original ordering
    iterations: int
    relres: float
    converged: bool
    arrival: float
    started: float
    finished: float
    plan_status: str          # "hit" | "refactor" | "miss"
    slab_width: int
    slot: int                 # slab column that served this request

    @property
    def latency(self) -> float:
        return self.finished - self.arrival

    @property
    def queue_wait(self) -> float:
        return self.started - self.arrival


class _SlabGroup:
    """One resident slab: a plan, its device state, and slot bookkeeping.

    All columns of a group share one (plan, values) pair by construction —
    a slab can never mix incompatible plans or matrices.
    """

    def __init__(self, key: PlanKey, plan: SolverPlan, values_fp: str,
                 width: int):
        self.key = key
        self.plan = plan
        self.values_fp = values_fp
        self.width = width
        self.state: SlabState = plan.new_slab_state(width)
        self.slots: list[_Request | None] = [None] * width

    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    @property
    def n_occupied(self) -> int:
        return sum(s is not None for s in self.slots)

    def pack(self, slot: int, req: _Request) -> None:
        if req.key != self.key or req.values_fp != self.values_fp:
            raise AssertionError("attempted to pack a request into a slab "
                                 "of a different plan/matrix")
        if self.slots[slot] is not None:
            raise AssertionError(f"slot {slot} is occupied")
        col = self.plan.embed_rhs(req.b)
        self.state = self.state._replace(
            r=self.state.r.at[:, slot].set(col),
            fresh=self.state.fresh.at[slot].set(True))
        self.slots[slot] = req

    def clear(self, slot: int) -> None:
        # a zero fresh column re-initializes inert (relres 0 < rtol)
        self.state = self.state._replace(
            r=self.state.r.at[:, slot].set(0.0),
            fresh=self.state.fresh.at[slot].set(True))
        self.slots[slot] = None


class SolverService:
    """Continuous-batching front end over a ``PlanCache``.

    ``submit(a, b)`` enqueues one right-hand side against matrix ``a``;
    ``step()`` advances the service one scheduling cycle; ``drain()``
    steps until everything admitted has completed.  See the module
    docstring for the lifecycle and the numerical contract.

    Scheduling is FIFO *per plan key*: a request that cannot be placed
    (its group is full, or its matrix values differ from the group's)
    blocks later requests of the same key — never requests of other keys.
    A value-change request therefore waits for the group to drain, then
    takes the ``refactor`` fast path.
    """

    def __init__(self, cache: PlanCache | None = None, *,
                 slab_width: int = 8, quantum: int = 16,
                 rtol: float = 1e-7, maxiter: int = 10_000,
                 clock=None, record_dispatches: bool = False,
                 **plan_knobs):
        if slab_width < 1:
            raise ValueError(f"slab_width must be >= 1, got {slab_width}")
        if quantum < 1:
            raise ValueError(f"quantum must be >= 1, got {quantum}")
        self.cache = cache if cache is not None else PlanCache()
        self.slab_width = slab_width
        self.quantum = quantum
        self.rtol = rtol
        self.maxiter = maxiter
        self.clock = clock if clock is not None else WallClock()
        self.plan_knobs = dict(plan_knobs)
        self._np_dtype = np.dtype(jnp.dtype(
            self.plan_knobs.get("dtype", jnp.float64)))
        self._next_rid = 0
        self._queue: list[_Request] = []          # admitted, FIFO
        self._pending: list[_Request] = []        # future arrivals (virtual)
        self._groups: "OrderedDict[PlanKey, _SlabGroup]" = OrderedDict()
        self.completed: dict[int, Completed] = {}
        self.record_dispatches = record_dispatches
        self.dispatch_log: list[dict] = []

    # -- submission ---------------------------------------------------------

    def submit(self, a: sp.spmatrix, b: np.ndarray, *,
               arrival_time: float | None = None, tag: Any = None) -> int:
        """Enqueue one RHS; returns a request id.

        ``arrival_time`` (simulated clocks only) defers admission until
        the virtual clock reaches it — the hook for seeded arrival traces.
        """
        b = np.asarray(b)
        if b.ndim != 1:
            raise ValueError(
                f"SolverService.submit takes one RHS of shape (n,), got "
                f"{b.shape}; the service packs requests into slabs itself "
                f"— submit columns individually")
        if b.shape[0] != a.shape[0]:
            raise ValueError(f"b has shape {b.shape} but a is "
                             f"{a.shape[0]}x{a.shape[1]}")
        if (np.issubdtype(b.dtype, np.floating)
                and b.dtype != self._np_dtype):
            raise TypeError(
                f"submit: b has dtype {b.dtype} but the service's plans "
                f"are {self._np_dtype}; cast b explicitly to opt in")
        key, a_csr = PlanKey.from_matrix(a, **self.plan_knobs)
        if arrival_time is None:
            arrival = self.clock.now()
        else:
            if not getattr(self.clock, "simulated", False):
                raise ValueError(
                    "arrival_time= requires a simulated clock "
                    "(VirtualClock); with a wall clock, pace submissions "
                    "from the caller instead")
            arrival = float(arrival_time)
        req = _Request(rid=self._next_rid, key=key,
                       values_fp=values_fingerprint(a_csr), a=a_csr,
                       b=np.asarray(b, dtype=self._np_dtype), tag=tag,
                       arrival=arrival)
        self._next_rid += 1
        if arrival_time is None:
            self._queue.append(req)
        else:
            self._pending.append(req)
            self._pending.sort(key=lambda r: (r.arrival, r.rid))
        return req.rid

    # -- scheduling ---------------------------------------------------------

    @property
    def n_in_flight(self) -> int:
        return sum(g.n_occupied for g in self._groups.values())

    @property
    def n_queued(self) -> int:
        return len(self._queue) + len(self._pending)

    def _admit_due(self) -> None:
        now = self.clock.now()
        while self._pending and self._pending[0].arrival <= now:
            self._queue.append(self._pending.pop(0))

    def _resolve_group(self, req: _Request) -> _SlabGroup | None:
        """Group able to take ``req`` now, creating one if possible.

        Returns None when the key is blocked this cycle: the live group is
        full, or holds different matrix values (refactor must wait for it
        to drain — tearing it down mid-flight would corrupt columns).
        """
        group = self._groups.get(req.key)
        if group is not None:
            if group.values_fp != req.values_fp:
                return None
            return group if group.free_slots() else None
        plan, status = self.cache.get(req.a, pin=True, **self.plan_knobs)
        self.clock.charge(status)   # build / refactor / hit cost
        group = _SlabGroup(req.key, plan, req.values_fp, self.slab_width)
        group.creation_status = status
        self._groups[req.key] = group
        return group

    def _pack_queue(self) -> None:
        """FIFO pass over the queue; per-key blocking preserves order
        within a key while other keys keep flowing."""
        blocked: set[PlanKey] = set()
        remaining: list[_Request] = []
        for req in self._queue:
            if req.key in blocked:
                remaining.append(req)
                continue
            group = self._resolve_group(req)
            if group is None:
                blocked.add(req.key)
                remaining.append(req)
                continue
            slot = group.free_slots()[0]
            req.started = self.clock.now()
            req.plan_status = getattr(group, "creation_status", "hit")
            # the group creator reports the cache status; later riders of
            # the live group are warm by definition
            group.creation_status = "hit"
            group.pack(slot, req)
            req.a = None    # matrix no longer needed; free the reference
            self.clock.charge("pack")
            if not group.free_slots():
                blocked.add(req.key)
        self._queue = remaining

    def _dispatch_and_retire(self) -> list[Completed]:
        done: list[Completed] = []
        for key in list(self._groups):
            group = self._groups[key]
            if group.n_occupied == 0:
                self._teardown(key)
                continue
            group.state, steps = group.plan.run_slab(
                group.state, rtol=self.rtol, maxiter=self.maxiter,
                quantum=self.quantum)
            steps = int(steps)
            self.clock.charge("dispatch")
            self.clock.charge("iteration", steps)
            if self.record_dispatches:
                self.dispatch_log.append({
                    "key": key, "values_fp": group.values_fp,
                    "rids": [s.rid if s is not None else None
                             for s in group.slots],
                    "steps": steps,
                })
            active = np.asarray(group.state.active)
            iters = np.asarray(group.state.iters)
            relres = np.asarray(group.state.relres)
            x_host = None
            for slot, req in enumerate(group.slots):
                if req is None or active[slot]:
                    continue
                if x_host is None:
                    x_host = np.asarray(group.state.x)
                self.clock.charge("retire")
                rr = float(relres[slot])
                done.append(Completed(
                    rid=req.rid, tag=req.tag,
                    x=group.plan.extract_solution(x_host[:, slot]),
                    iterations=int(iters[slot]), relres=rr,
                    converged=rr < self.rtol, arrival=req.arrival,
                    started=req.started, finished=self.clock.now(),
                    plan_status=req.plan_status,
                    slab_width=group.width, slot=slot))
                group.clear(slot)
            if group.n_occupied == 0:
                self._teardown(key)
        for c in done:
            self.completed[c.rid] = c
        return done

    def _teardown(self, key: PlanKey) -> None:
        del self._groups[key]
        self.cache.unpin(key)

    def step(self) -> list[Completed]:
        """One scheduling cycle: admit → pack → dispatch → retire.

        Returns the requests that completed this cycle.  With a virtual
        clock, an idle service (nothing queued or resident) jumps straight
        to the next pending arrival instead of spinning.
        """
        self._admit_due()
        if (not self._queue and self.n_in_flight == 0 and self._pending
                and getattr(self.clock, "simulated", False)):
            self.clock.advance_to(self._pending[0].arrival)
            self._admit_due()
        self._pack_queue()
        return self._dispatch_and_retire()

    def drain(self, max_steps: int = 100_000) -> list[Completed]:
        """Step until every admitted and pending request has completed."""
        done: list[Completed] = []
        for _ in range(max_steps):
            if not self._queue and not self._pending \
                    and self.n_in_flight == 0:
                return done
            done.extend(self.step())
        raise RuntimeError(
            f"drain did not converge in {max_steps} steps "
            f"({self.n_queued} queued, {self.n_in_flight} in flight)")
