"""Solver-as-a-service: continuous batching of RHS streams over warm plans.

The HBMC pipeline's expensive products — ordering, rounds, IC(0) factor,
packed tables — are all cached inside a ``SolverPlan``; this module
amortizes them across *clients*:

``PlanCache``
    LRU cache of built plans keyed by sparsity-pattern fingerprint (a hash
    of the CSR ``indptr``/``indices``) plus every build knob that changes
    the compiled solver (method, backend, dtype, ...).  A request whose
    pattern is cached but whose values changed takes the
    ``plan.refactor`` fast path — numeric factorization only, zero
    retrace — instead of a full rebuild.  Plans with in-flight slabs are
    *pinned* and never evicted.

``SolverService``
    A request queue that packs heterogeneous right-hand sides into
    resident PCG slabs of a configurable width (``plan.run_slab``) and
    advances each slab a bounded ``quantum`` of iterations per dispatch.
    Converged columns retire between dispatches — they report their
    iteration count, free their slot, and a fresh queued request is packed
    in on the next dispatch — so a slab never runs every column to the
    slowest straggler.

Numerical contract (pinned by tests/test_serve_solver.py): a request
served at slab width B in slot s is bitwise equal to the standalone
``plan.solve_slab(b, slab_width=B, slot=s)`` on a fresh plan —
independent of which requests shared its slab, of dispatch quantum, and
of retire/refill interleaving.  (Width and slot pin the lowered
reduction trees; at B = 1 the oracle coincides with
``plan.solve_batched(b[:, None])``.)  Iteration counts equal the
single-RHS ``plan.solve`` counts at every width and slot.

Scheduling is single-threaded and deterministic: ``step()`` advances the
whole service one admit → pack → dispatch → retire cycle, and a
``VirtualClock`` with an event cost model replaces wall time in tests (no
sleeps, no threads).
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict
from typing import Any, Callable

import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from repro.core.iccg import (DIVERGENCE_FACTOR, STAGNATION_WINDOW,
                             UNHEALTHY_STATUSES, SlabState, status_name)
from repro.core.ic0 import FactorBreakdownError
from repro.core.plan import SolverPlan, build_plan

# ---------------------------------------------------------------------------
# Fingerprints and cache keys
# ---------------------------------------------------------------------------


def _as_csr(a: sp.spmatrix) -> sp.csr_matrix:
    a = sp.csr_matrix(a)
    a.sum_duplicates()   # duplicate-entry CSR corrupts packing downstream
    a.sort_indices()
    return a


def pattern_fingerprint(a: sp.spmatrix) -> str:
    """Hash of the sparsity pattern only (shape + CSR indptr/indices)."""
    a = _as_csr(a)
    h = hashlib.sha1()
    h.update(np.asarray(a.shape, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(a.indptr, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(a.indices, dtype=np.int64).tobytes())
    return h.hexdigest()


def values_fingerprint(a: sp.spmatrix) -> str:
    """Hash of the numeric values (CSR data, canonical index order)."""
    a = _as_csr(a)
    return hashlib.sha1(np.ascontiguousarray(a.data).tobytes()).hexdigest()


@dataclasses.dataclass(frozen=True)
class PlanKey:
    """Everything that decides whether two requests can share one plan.

    Pattern fingerprint + the build knobs that change the compiled solver.
    Two matrices with equal keys but different values share the plan
    through ``refactor``; anything else is a distinct cache entry.
    """
    pattern: str
    n: int
    method: str
    block_size: int
    w: int
    shift: float
    spmv_format: str
    dtype: str
    backend: str
    spmv_backend: str
    layout: str
    interpret: bool | None
    lane_multiple: int
    on_breakdown: str = "clamp"
    scheduler: str = "coloring"

    @classmethod
    def from_matrix(cls, a: sp.spmatrix, *, method: str = "hbmc",
                    block_size: int = 32, w: int = 8, shift: float = 0.0,
                    spmv_format: str = "ell", dtype=jnp.float64,
                    backend: str = "xla", interpret: bool | None = None,
                    layout: str = "round_major", lane_multiple: int = 1,
                    spmv_backend: str = "xla", on_breakdown: str = "clamp",
                    scheduler: str = "coloring",
                    **extra) -> tuple["PlanKey", sp.csr_matrix]:
        """Key for (a, knobs); also returns the canonicalized CSR matrix."""
        if extra.get("mesh") is not None:
            raise ValueError("mesh plans are not cacheable: a Mesh binds "
                             "the plan to a device set; serve single-device "
                             "plans (or shard outside the service)")
        extra.pop("mesh", None)
        if extra:
            raise TypeError(f"unknown plan knobs: {sorted(extra)}")
        a = _as_csr(a)
        key = cls(pattern=pattern_fingerprint(a), n=int(a.shape[0]),
                  method=method, block_size=int(block_size), w=int(w),
                  shift=float(shift), spmv_format=spmv_format,
                  dtype=str(np.dtype(jnp.dtype(dtype))), backend=backend,
                  spmv_backend=spmv_backend, layout=layout,
                  interpret=interpret,
                  lane_multiple=int(lane_multiple),
                  on_breakdown=on_breakdown, scheduler=scheduler)
        return key, a


class PlanBusyError(RuntimeError):
    """Raised when a value-change refactor targets a pinned (in-flight)
    plan: refactoring would corrupt resident slab columns mid-solve."""


class QueueFullError(RuntimeError):
    """Backpressure: ``submit`` refused because the service's bounded
    queue (``max_queue``) is at capacity.  The caller should retry later
    or shed load — nothing was enqueued."""


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    refactors: int = 0
    evictions: int = 0
    pinned_overflow: int = 0   # capacity exceeded but every entry pinned

    @property
    def requests(self) -> int:
        return self.hits + self.misses + self.refactors

    @property
    def hit_rate(self) -> float:
        n = self.requests
        # a refactor reuses the expensive setup products: count it warm
        return (self.hits + self.refactors) / n if n else 0.0


@dataclasses.dataclass
class _CacheEntry:
    plan: SolverPlan
    values_fp: str
    pins: int = 0


class PlanCache:
    """LRU cache of built ``SolverPlan``s with pin-aware eviction.

    ``get`` returns ``(plan, status)`` with status one of:

    * ``"hit"``       — pattern and values both cached
    * ``"refactor"``  — pattern cached, values renewed via the numeric
      fast path (raises ``PlanBusyError`` if the entry is pinned)
    * ``"miss"``      — full build (evicting LRU *unpinned* entries if
      over capacity; a ``pin=True`` newcomer is protected by its own pin,
      so when every resident is pinned the cache overflows temporarily
      and records ``pinned_overflow``, while an unpinned newcomer is
      simply not retained)

    ``pin``/``unpin`` bracket in-flight use (the ``SolverService`` pins a
    key while a slab group holds resident columns, via ``get(pin=True)``);
    pinned entries are never evicted and never refactored out from under
    their slabs.

    ``validate`` gates cache admission: on a miss the freshly built plan
    is run through the static schedule race detector
    (``repro.analysis.assert_plan_valid``) at that depth before it is
    cached or returned — a plan with a provable schedule race raises
    ``ScheduleError`` and never enters the cache, so no later hit can
    dispatch it.  ``"deep"`` extends admission to the kernel checks and
    the dtype-flow precision-contract lint of every lowering path.
    ``"off"`` (default) admits unconditionally.
    """

    def __init__(self, capacity: int = 8,
                 build: Callable[..., SolverPlan] = build_plan,
                 validate: str = "off"):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        from repro.analysis.schedule import VALIDATE_MODES
        if validate not in VALIDATE_MODES:
            raise ValueError(f"validate must be one of {VALIDATE_MODES}, "
                             f"got {validate!r}")
        self.capacity = capacity
        self._build = build
        self.validate = validate
        self._entries: OrderedDict[PlanKey, _CacheEntry] = OrderedDict()
        self.stats = CacheStats()

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: PlanKey) -> bool:
        return key in self._entries

    def keys(self):
        return list(self._entries)

    def pins(self, key: PlanKey) -> int:
        return self._entries[key].pins if key in self._entries else 0

    def get(self, a: sp.spmatrix, pin: bool = False,
            **knobs) -> tuple[SolverPlan, str]:
        """Plan for (a, knobs): cached, refactored, or freshly built.

        ``pin=True`` pins the entry atomically with the lookup/insert —
        the caller must balance it with ``unpin`` when its slab drains.
        """
        key, a = PlanKey.from_matrix(a, **knobs)
        vfp = values_fingerprint(a)
        entry = self._entries.get(key)
        if entry is not None:
            self._entries.move_to_end(key)
            if entry.values_fp == vfp:
                entry.pins += pin
                self.stats.hits += 1
                return entry.plan, "hit"
            if entry.pins:
                raise PlanBusyError(
                    f"plan {key.pattern[:12]} has {entry.pins} in-flight "
                    f"slab(s); refactoring now would corrupt resident "
                    f"columns — drain the slab first")
            entry.plan.refactor(a)
            entry.values_fp = vfp
            entry.pins += pin
            self.stats.refactors += 1
            return entry.plan, "refactor"
        plan = self._build(a, **knobs)
        if self.validate != "off":
            # admission control: prove the schedule race-free before the
            # plan can be cached (and re-served on every later hit)
            from repro.analysis.schedule import assert_plan_valid
            assert_plan_valid(plan, self.validate,
                              context=f"PlanCache admission "
                                      f"{key.pattern[:12]}")
        self._entries[key] = _CacheEntry(plan=plan, values_fp=vfp,
                                         pins=int(pin))
        self.stats.misses += 1
        self._evict()
        return plan, "miss"

    def _evict(self) -> None:
        while len(self._entries) > self.capacity:
            victim = next((k for k, e in self._entries.items()
                           if e.pins == 0), None)
            if victim is None:
                self.stats.pinned_overflow += 1
                return
            del self._entries[victim]
            self.stats.evictions += 1

    def pin(self, key: PlanKey) -> None:
        self._entries[key].pins += 1

    def unpin(self, key: PlanKey) -> None:
        entry = self._entries[key]
        if entry.pins <= 0:
            raise RuntimeError(f"unpin without pin for {key.pattern[:12]}")
        entry.pins -= 1
        self._evict()   # a deferred eviction may now be possible


# ---------------------------------------------------------------------------
# Clocks
# ---------------------------------------------------------------------------


class WallClock:
    """Real time; event charges are no-ops (the events take real time)."""

    simulated = False

    def __init__(self):
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def charge(self, event: str, n: int = 1) -> None:
        pass


#: Default virtual event costs (arbitrary deterministic units): a build is
#: an order of magnitude above a refactor, which dwarfs per-dispatch work.
DEFAULT_COSTS = {
    "build": 1.0,
    "refactor": 0.1,
    "hit": 0.0,
    "dispatch": 0.05,
    "iteration": 0.01,
    "pack": 0.001,
    "retire": 0.001,
}


class VirtualClock:
    """Deterministic simulated time driven by an event cost model.

    Tests drive the service with seeded arrival traces against this clock:
    no wall-clock sleeps, no threads, and every latency/throughput number
    reproduces bit-for-bit across runs.
    """

    simulated = True

    def __init__(self, costs: dict[str, float] | None = None):
        self.t = 0.0
        self.costs = dict(DEFAULT_COSTS)
        if costs:
            self.costs.update(costs)

    def now(self) -> float:
        return self.t

    def charge(self, event: str, n: int = 1) -> None:
        self.t += n * self.costs.get(event, 0.0)

    def advance_to(self, t: float) -> None:
        if t > self.t:
            self.t = t


# ---------------------------------------------------------------------------
# Requests, slab groups, and the service
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Request:
    rid: int
    key: PlanKey
    values_fp: str
    a: sp.csr_matrix          # kept until packed (plan build / refactor)
    b: np.ndarray
    tag: Any
    arrival: float
    deadline: float = np.inf  # absolute service-clock time; inf = none
    started: float = -1.0
    plan_status: str = ""     # cache status when its slab group resolved


#: Terminal request statuses added by the serving layer on top of the
#: core taxonomy (``repro.core.STATUS_NAMES``).
SERVICE_STATUSES = ("CANCELLED", "DEADLINE")


@dataclasses.dataclass
class Completed:
    """A retired request: solution + solve metadata + timing.

    ``status`` is always definite: one of the core taxonomy
    (``CONVERGED | MAXITER | BREAKDOWN | DIVERGED | STAGNATED``) or a
    serving-layer terminal (``CANCELLED | DEADLINE``).  ``x`` is None for
    requests that never produced a usable iterate (cancellation before
    packing, factorization breakdown, unhealthy solves); a DEADLINE expiry
    of an in-flight column returns its best-effort partial iterate.
    """
    rid: int
    tag: Any
    x: np.ndarray | None      # solution in the caller's original ordering
    iterations: int
    relres: float
    converged: bool
    arrival: float
    started: float            # -1.0 if never packed into a slab
    finished: float
    plan_status: str          # "hit" | "refactor" | "miss" | "" (never packed)
    slab_width: int           # 0 if never packed
    slot: int                 # slab column that served this request; -1 if none
    status: str = "CONVERGED"

    @property
    def latency(self) -> float:
        return self.finished - self.arrival

    @property
    def queue_wait(self) -> float:
        return (self.started if self.started >= 0 else self.finished) \
            - self.arrival

    @property
    def failed(self) -> bool:
        return self.status not in ("CONVERGED", "MAXITER")


class _SlabGroup:
    """One resident slab: a plan, its device state, and slot bookkeeping.

    All columns of a group share one (plan, values) pair by construction —
    a slab can never mix incompatible plans or matrices.
    """

    def __init__(self, key: PlanKey, plan: SolverPlan, values_fp: str,
                 width: int):
        self.key = key
        self.plan = plan
        self.values_fp = values_fp
        self.width = width
        self.state: SlabState = plan.new_slab_state(width)
        self.slots: list[_Request | None] = [None] * width

    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    @property
    def n_occupied(self) -> int:
        return sum(s is not None for s in self.slots)

    def pack(self, slot: int, req: _Request) -> None:
        if req.key != self.key or req.values_fp != self.values_fp:
            raise AssertionError("attempted to pack a request into a slab "
                                 "of a different plan/matrix")
        if self.slots[slot] is not None:
            raise AssertionError(f"slot {slot} is occupied")
        col = self.plan.embed_rhs(req.b)
        self.state = self.state._replace(
            r=self.state.r.at[:, slot].set(col),
            fresh=self.state.fresh.at[slot].set(True))
        self.slots[slot] = req

    def clear(self, slot: int) -> None:
        # a zero fresh column re-initializes inert (relres 0 < rtol)
        self.state = self.state._replace(
            r=self.state.r.at[:, slot].set(0.0),
            fresh=self.state.fresh.at[slot].set(True))
        self.slots[slot] = None


class SolverService:
    """Continuous-batching front end over a ``PlanCache``.

    ``submit(a, b)`` enqueues one right-hand side against matrix ``a``;
    ``step()`` advances the service one scheduling cycle; ``drain()``
    steps until everything admitted has completed.  See the module
    docstring for the lifecycle and the numerical contract.

    Scheduling is FIFO *per plan key*: a request that cannot be placed
    (its group is full, or its matrix values differ from the group's)
    blocks later requests of the same key — never requests of other keys.
    A value-change request therefore waits for the group to drain, then
    takes the ``refactor`` fast path.

    Robustness: every request terminates with a definite ``status``.
    Columns whose slab health goes terminal-unhealthy (BREAKDOWN /
    DIVERGED / STAGNATED) retire the moment their dispatch ends —
    quarantined (``n_quarantined``), slot freed — instead of holding the
    slab for their full ``maxiter`` budget; their slab neighbours are
    untouched (bitwise — column ops never mix lanes).  A matrix whose
    factorization raises :class:`FactorBreakdownError` fails its request
    with status BREAKDOWN and poisons its (key, values) pair so follow-up
    requests fail fast without re-attempting the build.  ``max_queue``
    bounds admission (``QueueFullError``), ``timeout=``/``default_timeout``
    set per-request deadlines on the service clock, and ``cancel`` revokes
    queued or in-flight requests immediately.
    """

    def __init__(self, cache: PlanCache | None = None, *,
                 slab_width: int = 8, quantum: int = 16,
                 rtol: float = 1e-7, maxiter: int = 10_000,
                 clock=None, record_dispatches: bool = False,
                 max_queue: int | None = None,
                 default_timeout: float | None = None,
                 divergence_factor: float | None = DIVERGENCE_FACTOR,
                 stagnation_window: int | None = STAGNATION_WINDOW,
                 **plan_knobs):
        if slab_width < 1:
            raise ValueError(f"slab_width must be >= 1, got {slab_width}")
        if quantum < 1:
            raise ValueError(f"quantum must be >= 1, got {quantum}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.cache = cache if cache is not None else PlanCache()
        self.slab_width = slab_width
        self.quantum = quantum
        self.rtol = rtol
        self.maxiter = maxiter
        self.max_queue = max_queue
        self.default_timeout = default_timeout
        self.divergence_factor = divergence_factor
        self.stagnation_window = stagnation_window
        self.clock = clock if clock is not None else WallClock()
        self.plan_knobs = dict(plan_knobs)
        self._np_dtype = np.dtype(jnp.dtype(
            self.plan_knobs.get("dtype", jnp.float64)))
        self._next_rid = 0
        self._queue: list[_Request] = []          # admitted, FIFO
        self._pending: list[_Request] = []        # future arrivals (virtual)
        self._groups: "OrderedDict[PlanKey, _SlabGroup]" = OrderedDict()
        self.completed: dict[int, Completed] = {}
        self.record_dispatches = record_dispatches
        self.dispatch_log: list[dict] = []
        self.n_quarantined = 0
        # (key, values_fp) pairs whose factorization broke down terminally
        self._poisoned: set[tuple[PlanKey, str]] = set()

    # -- submission ---------------------------------------------------------

    def submit(self, a: sp.spmatrix, b: np.ndarray, *,
               arrival_time: float | None = None, tag: Any = None,
               timeout: float | None = None) -> int:
        """Enqueue one RHS; returns a request id.

        ``arrival_time`` (simulated clocks only) defers admission until
        the virtual clock reaches it — the hook for seeded arrival traces.
        ``timeout`` (service-clock seconds from arrival; defaults to the
        service's ``default_timeout``) sets the request's deadline: a
        request not finished by then terminates with status DEADLINE.
        Raises :class:`QueueFullError` when ``max_queue`` requests are
        already waiting (backpressure — nothing is enqueued).
        """
        if (self.max_queue is not None
                and len(self._queue) + len(self._pending) >= self.max_queue):
            raise QueueFullError(
                f"queue is at capacity ({self.max_queue} waiting); retry "
                f"later or shed load")
        b = np.asarray(b)
        if b.ndim != 1:
            raise ValueError(
                f"SolverService.submit takes one RHS of shape (n,), got "
                f"{b.shape}; the service packs requests into slabs itself "
                f"— submit columns individually")
        if b.shape[0] != a.shape[0]:
            raise ValueError(f"b has shape {b.shape} but a is "
                             f"{a.shape[0]}x{a.shape[1]}")
        if (np.issubdtype(b.dtype, np.floating)
                and b.dtype != self._np_dtype):
            raise TypeError(
                f"submit: b has dtype {b.dtype} but the service's plans "
                f"are {self._np_dtype}; cast b explicitly to opt in")
        key, a_csr = PlanKey.from_matrix(a, **self.plan_knobs)
        if arrival_time is None:
            arrival = self.clock.now()
        else:
            if not getattr(self.clock, "simulated", False):
                raise ValueError(
                    "arrival_time= requires a simulated clock "
                    "(VirtualClock); with a wall clock, pace submissions "
                    "from the caller instead")
            arrival = float(arrival_time)
        if timeout is None:
            timeout = self.default_timeout
        if timeout is not None and timeout <= 0:
            raise ValueError(f"timeout must be > 0, got {timeout}")
        deadline = np.inf if timeout is None else arrival + float(timeout)
        req = _Request(rid=self._next_rid, key=key,
                       values_fp=values_fingerprint(a_csr), a=a_csr,
                       b=np.asarray(b, dtype=self._np_dtype), tag=tag,
                       arrival=arrival, deadline=deadline)
        self._next_rid += 1
        if arrival_time is None:
            self._queue.append(req)
        else:
            self._pending.append(req)
            self._pending.sort(key=lambda r: (r.arrival, r.rid))
        return req.rid

    def cancel(self, rid: int) -> bool:
        """Revoke a request immediately; returns True if it was revoked.

        Works on pending, queued and in-flight requests: the request
        completes with status CANCELLED (``x = None``), an in-flight
        column's slot is freed at once.  Returns False when ``rid`` is
        unknown or already completed (too late to cancel).
        """
        for lst in (self._queue, self._pending):
            for i, req in enumerate(lst):
                if req.rid == rid:
                    del lst[i]
                    self._fail(req, "CANCELLED")
                    return True
        for key, group in self._groups.items():
            for slot, req in enumerate(group.slots):
                if req is not None and req.rid == rid:
                    group.clear(slot)
                    self._fail(req, "CANCELLED", slab_width=group.width,
                               slot=slot)
                    return True
        return False

    def _fail(self, req: _Request, status: str, *,
              x: np.ndarray | None = None, iterations: int = 0,
              relres: float = np.inf, slab_width: int = 0,
              slot: int = -1) -> Completed:
        """Terminate ``req`` with a non-success ``status`` right now."""
        c = Completed(rid=req.rid, tag=req.tag, x=x, iterations=iterations,
                      relres=relres, converged=False, arrival=req.arrival,
                      started=req.started, finished=self.clock.now(),
                      plan_status=req.plan_status, slab_width=slab_width,
                      slot=slot, status=status)
        self.completed[req.rid] = c
        return c

    def _reap_expired(self) -> list[Completed]:
        """Fail every waiting request whose deadline has passed."""
        now = self.clock.now()
        done: list[Completed] = []
        for lst in (self._queue, self._pending):
            expired = [r for r in lst if r.deadline <= now]
            if expired:
                lst[:] = [r for r in lst if r.deadline > now]
                done.extend(self._fail(r, "DEADLINE") for r in expired)
        return done

    # -- scheduling ---------------------------------------------------------

    @property
    def n_in_flight(self) -> int:
        return sum(g.n_occupied for g in self._groups.values())

    @property
    def n_queued(self) -> int:
        return len(self._queue) + len(self._pending)

    def _admit_due(self) -> None:
        now = self.clock.now()
        while self._pending and self._pending[0].arrival <= now:
            self._queue.append(self._pending.pop(0))

    def _resolve_group(self, req: _Request) -> _SlabGroup | None:
        """Group able to take ``req`` now, creating one if possible.

        Returns None when the key is blocked this cycle: the live group is
        full, or holds different matrix values (refactor must wait for it
        to drain — tearing it down mid-flight would corrupt columns).
        """
        group = self._groups.get(req.key)
        if group is not None:
            if group.values_fp != req.values_fp:
                return None
            return group if group.free_slots() else None
        plan, status = self.cache.get(req.a, pin=True, **self.plan_knobs)
        self.clock.charge(status)   # build / refactor / hit cost
        group = _SlabGroup(req.key, plan, req.values_fp, self.slab_width)
        group.creation_status = status
        self._groups[req.key] = group
        return group

    def _pack_queue(self) -> None:
        """FIFO pass over the queue; per-key blocking preserves order
        within a key while other keys keep flowing.

        A request whose plan build/refactor raises
        :class:`FactorBreakdownError` (the ``on_breakdown`` policy refused
        a degraded factor, or the matrix itself is non-finite) fails with
        status BREAKDOWN and poisons its (key, values) pair — identical
        follow-ups fail fast without re-running the factorization.
        """
        blocked: set[PlanKey] = set()
        remaining: list[_Request] = []
        for req in self._queue:
            if req.key in blocked:
                remaining.append(req)
                continue
            if (req.key, req.values_fp) in self._poisoned:
                self._fail(req, "BREAKDOWN")
                continue
            try:
                group = self._resolve_group(req)
            except FactorBreakdownError:
                self.clock.charge("build")   # the attempt was paid for
                self._poisoned.add((req.key, req.values_fp))
                self._fail(req, "BREAKDOWN")
                continue
            if group is None:
                blocked.add(req.key)
                remaining.append(req)
                continue
            slot = group.free_slots()[0]
            req.started = self.clock.now()
            req.plan_status = getattr(group, "creation_status", "hit")
            # the group creator reports the cache status; later riders of
            # the live group are warm by definition
            group.creation_status = "hit"
            group.pack(slot, req)
            req.a = None    # matrix no longer needed; free the reference
            self.clock.charge("pack")
            if not group.free_slots():
                blocked.add(req.key)
        self._queue = remaining

    def _dispatch_and_retire(self) -> list[Completed]:
        done: list[Completed] = []
        for key in list(self._groups):
            group = self._groups[key]
            if group.n_occupied == 0:
                self._teardown(key)
                continue
            group.state, steps = group.plan.run_slab(
                group.state, rtol=self.rtol, maxiter=self.maxiter,
                quantum=self.quantum,
                divergence_factor=self.divergence_factor,
                stagnation_window=self.stagnation_window)
            steps = int(steps)
            self.clock.charge("dispatch")
            self.clock.charge("iteration", steps)
            if self.record_dispatches:
                self.dispatch_log.append({
                    "key": key, "values_fp": group.values_fp,
                    "rids": [s.rid if s is not None else None
                             for s in group.slots],
                    "steps": steps,
                })
            active = np.asarray(group.state.active)
            iters = np.asarray(group.state.iters)
            relres = np.asarray(group.state.relres)
            codes = np.asarray(group.state.status)
            now = self.clock.now()
            x_host = None
            for slot, req in enumerate(group.slots):
                if req is None:
                    continue
                if active[slot]:
                    if req.deadline > now:
                        continue
                    # in-flight deadline expiry: terminate with the
                    # best-effort partial iterate, free the slot now
                    if x_host is None:
                        x_host = np.asarray(group.state.x)
                    self.clock.charge("retire")
                    done.append(self._fail(
                        req, "DEADLINE",
                        x=group.plan.extract_solution(x_host[:, slot]),
                        iterations=int(iters[slot]),
                        relres=float(relres[slot]),
                        slab_width=group.width, slot=slot))
                    group.clear(slot)
                    continue
                st = status_name(codes[slot])
                unhealthy = st in UNHEALTHY_STATUSES
                if unhealthy:
                    # quarantine: structured failure, slot freed this very
                    # dispatch — no iterate is returned (the column's last
                    # finite state is not a solution)
                    self.n_quarantined += 1
                    self.clock.charge("retire")
                    done.append(self._fail(
                        req, st, iterations=int(iters[slot]),
                        relres=float(relres[slot]),
                        slab_width=group.width, slot=slot))
                    group.clear(slot)
                    continue
                if x_host is None:
                    x_host = np.asarray(group.state.x)
                self.clock.charge("retire")
                rr = float(relres[slot])
                done.append(Completed(
                    rid=req.rid, tag=req.tag,
                    x=group.plan.extract_solution(x_host[:, slot]),
                    iterations=int(iters[slot]), relres=rr,
                    converged=rr < self.rtol, arrival=req.arrival,
                    started=req.started, finished=self.clock.now(),
                    plan_status=req.plan_status,
                    slab_width=group.width, slot=slot, status=st))
                group.clear(slot)
            if group.n_occupied == 0:
                self._teardown(key)
        for c in done:
            self.completed[c.rid] = c
        return done

    def _teardown(self, key: PlanKey) -> None:
        del self._groups[key]
        self.cache.unpin(key)

    def step(self) -> list[Completed]:
        """One scheduling cycle: reap → admit → pack → dispatch → retire.

        Returns the requests that completed this cycle (including ones
        terminated by deadline expiry or cancellation fallout).  With a
        virtual clock, an idle service (nothing queued or resident) jumps
        straight to the next pending arrival instead of spinning.
        """
        self._admit_due()
        if (not self._queue and self.n_in_flight == 0 and self._pending
                and getattr(self.clock, "simulated", False)):
            self.clock.advance_to(self._pending[0].arrival)
            self._admit_due()
        done = self._reap_expired()
        self._pack_queue()
        done.extend(self._dispatch_and_retire())
        return done

    def drain(self, max_steps: int = 100_000) -> list[Completed]:
        """Step until every admitted and pending request has completed."""
        done: list[Completed] = []
        for _ in range(max_steps):
            if not self._queue and not self._pending \
                    and self.n_in_flight == 0:
                return done
            done.extend(self.step())
        raise RuntimeError(
            f"drain did not converge in {max_steps} steps "
            f"({self.n_queued} queued, {self.n_in_flight} in flight)")
