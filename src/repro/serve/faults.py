"""Seeded fault injection for the serving simulation tier.

``FaultInjector`` generates deterministic adversarial request streams —
malformed right-hand sides (NaN/Inf/zero), matrices the ICCG method is not
entitled to (indefinite, semi-definite, near-singular, NaN-contaminated),
refactor-under-load value changes, and deadline storms — and drives them
into a :class:`repro.serve.SolverService` under a virtual clock.

Every fault kind carries the set of statuses a robust service may resolve
it to.  The harness contract (pinned by tests/test_fault_injection.py):

* every submitted request terminates with a *definite* status from its
  kind's expected set — no silent NaN solutions, no hung slots;
* the service stays live throughout — healthy requests interleaved with
  faults still converge to their bitwise oracle solutions;
* ``QueueFullError`` sheds load instead of corrupting state.

Everything is seeded: the same (seed, n_requests) trace reproduces
bit-for-bit, which is what makes the tier CI-able.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp

from repro.core.matrices import laplace_2d

from .solver import QueueFullError, SolverService

#: All injectable fault kinds, in trace-sampling order.
FAULT_KINDS = ("healthy", "zero_rhs", "nan_rhs", "inf_rhs", "indefinite",
               "semidefinite", "near_singular", "nan_matrix",
               "value_change", "deadline")

#: Statuses a robust service may resolve each kind to.  The degenerate
#: spectra (indefinite / semi-definite / near-singular) admit several
#: legitimate terminal diagnoses — which one fires depends on rtol,
#: maxiter and the monitor windows — but all are definite and none is a
#: silent NaN.
EXPECTED_STATUSES = {
    "healthy": frozenset({"CONVERGED"}),
    "zero_rhs": frozenset({"CONVERGED"}),
    "nan_rhs": frozenset({"BREAKDOWN"}),
    "inf_rhs": frozenset({"BREAKDOWN"}),
    "indefinite": frozenset({"BREAKDOWN", "DIVERGED", "STAGNATED",
                             "MAXITER", "CONVERGED"}),
    "semidefinite": frozenset({"BREAKDOWN", "DIVERGED", "STAGNATED",
                               "MAXITER", "CONVERGED"}),
    "near_singular": frozenset({"STAGNATED", "MAXITER", "CONVERGED"}),
    "nan_matrix": frozenset({"BREAKDOWN"}),
    "value_change": frozenset({"CONVERGED"}),
    "deadline": frozenset({"DEADLINE", "CONVERGED"}),
}


def _with_diagonal(a: sp.csr_matrix, new_diag: np.ndarray) -> sp.csr_matrix:
    """``a`` with its diagonal replaced, as a canonical duplicate-free CSR
    (sparse addition merges entries; ``lil.setdiag`` would leave duplicate
    diagonal entries behind, which corrupts CSR consumers downstream)."""
    d = sp.diags(np.asarray(new_diag) - a.diagonal())
    out = sp.csr_matrix(a + d)
    out.sum_duplicates()
    out.sort_indices()
    return out


def indefinite_matrix(n_side: int = 6, shift: float = 1.0) -> sp.csr_matrix:
    """SPD 5-point Laplacian made indefinite by a diagonal downshift
    exceeding its smallest eigenvalue (diagonals stay positive, so the
    factorization proceeds into clamps rather than failing structurally).
    """
    a = laplace_2d(n_side, n_side)
    return _with_diagonal(a, a.diagonal() - shift)


def semidefinite_matrix(n_side: int = 6) -> sp.csr_matrix:
    """Singular PSD matrix: the Laplacian with exact zero row sums (pure
    Neumann — constants span the kernel)."""
    a = laplace_2d(n_side, n_side)
    offdiag = np.asarray(a.sum(axis=1)).ravel() - a.diagonal()
    return _with_diagonal(a, -offdiag)


def near_singular_matrix(n_side: int = 6,
                         eps: float = 1e-10) -> sp.csr_matrix:
    """SPD but within ``eps`` of singular: the semi-definite matrix plus
    ``eps`` on the diagonal (condition number ~ 1/eps)."""
    a = semidefinite_matrix(n_side)
    return _with_diagonal(a, a.diagonal() + eps)


@dataclasses.dataclass
class FaultPlan:
    """One adversarial request: what to submit and what may come back."""
    kind: str
    a: sp.csr_matrix
    b: np.ndarray
    timeout: float | None
    expected: frozenset


class FaultInjector:
    """Deterministic adversarial trace generator over one base problem.

    All kinds share the healthy base matrix's size ``n`` (and, where the
    kind is an RHS fault or a value change, its sparsity pattern too — the
    worst case for the plan cache, which must keep the healthy entries
    clean while the poisoned values fail).
    """

    def __init__(self, seed: int = 0, n_side: int = 6,
                 kinds: tuple = FAULT_KINDS,
                 deadline_timeout: float = 0.02):
        unknown = set(kinds) - set(FAULT_KINDS)
        if unknown:
            raise ValueError(f"unknown fault kinds: {sorted(unknown)}")
        self.rng = np.random.default_rng(seed)
        self.kinds = tuple(kinds)
        self.deadline_timeout = float(deadline_timeout)
        self.base = laplace_2d(n_side, n_side)
        self.n = self.base.shape[0]
        # same pattern, scaled values: the refactor-under-load kind
        self.base_scaled = self.base.copy()
        self.base_scaled.data = self.base_scaled.data * 2.0
        # same pattern, one NaN value: poisons only its own values_fp
        self.base_nan = self.base.copy()
        self.base_nan.data = self.base_nan.data.copy()
        self.base_nan.data[0] = np.nan
        self.indefinite = indefinite_matrix(n_side)
        self.semidefinite = semidefinite_matrix(n_side)
        self.near_singular = near_singular_matrix(n_side)

    def _rhs(self) -> np.ndarray:
        return self.rng.standard_normal(self.n)

    def make(self, kind: str) -> FaultPlan:
        """One seeded request of the given kind."""
        a, b, timeout = self.base, self._rhs(), None
        if kind == "zero_rhs":
            b = np.zeros(self.n)
        elif kind == "nan_rhs":
            b[self.rng.integers(self.n)] = np.nan
        elif kind == "inf_rhs":
            b[self.rng.integers(self.n)] = np.inf
        elif kind == "indefinite":
            a = self.indefinite
        elif kind == "semidefinite":
            a = self.semidefinite
        elif kind == "near_singular":
            a = self.near_singular
        elif kind == "nan_matrix":
            a = self.base_nan
        elif kind == "value_change":
            a = self.base_scaled
        elif kind == "deadline":
            timeout = self.deadline_timeout
        elif kind != "healthy":
            raise ValueError(f"unknown fault kind {kind!r}")
        return FaultPlan(kind=kind, a=a, b=b, timeout=timeout,
                         expected=EXPECTED_STATUSES[kind])

    def trace(self, n_requests: int) -> list:
        """A seeded mixed trace of ``n_requests`` fault plans."""
        picks = self.rng.integers(len(self.kinds), size=n_requests)
        return [self.make(self.kinds[int(i)]) for i in picks]

    def inject(self, svc: SolverService, n_requests: int,
               spacing: float = 0.0
               ) -> tuple[dict, list]:
        """Submit a seeded trace into ``svc``; returns ``(rids, shed)``.

        ``rids`` maps request id -> :class:`FaultPlan`; ``shed`` lists the
        plans refused with :class:`QueueFullError` (backpressure is a
        valid robustness outcome, not a failure).  ``spacing`` staggers
        arrivals on a simulated clock.
        """
        simulated = getattr(svc.clock, "simulated", False)
        rids: dict[int, FaultPlan] = {}
        shed: list[FaultPlan] = []
        t0 = svc.clock.now()
        for i, fp in enumerate(self.trace(n_requests)):
            arrival = t0 + i * spacing if (simulated and spacing) else None
            try:
                rid = svc.submit(fp.a, fp.b, arrival_time=arrival,
                                 timeout=fp.timeout)
            except QueueFullError:
                shed.append(fp)
                continue
            rids[rid] = fp
        return rids, shed
