"""Serving layers: the solver service (solver.py) and LM steps (step.py).

``step`` is not imported here — it pulls in ``repro.models``; import it
explicitly (``from repro.serve import step``) when needed.
"""
from .faults import FAULT_KINDS, FaultInjector, FaultPlan
from .solver import (DEFAULT_COSTS, SERVICE_STATUSES, CacheStats, Completed,
                     PlanBusyError, PlanCache, PlanKey, QueueFullError,
                     SolverService, VirtualClock, WallClock,
                     pattern_fingerprint, values_fingerprint)

__all__ = [
    "DEFAULT_COSTS", "FAULT_KINDS", "SERVICE_STATUSES", "CacheStats",
    "Completed", "FaultInjector", "FaultPlan", "PlanBusyError", "PlanCache",
    "PlanKey", "QueueFullError", "SolverService", "VirtualClock",
    "WallClock", "pattern_fingerprint", "values_fingerprint",
]
