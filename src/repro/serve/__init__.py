"""Serving layers: the solver service (solver.py) and LM steps (step.py).

``step`` is not imported here — it pulls in ``repro.models``; import it
explicitly (``from repro.serve import step``) when needed.
"""
from .solver import (DEFAULT_COSTS, CacheStats, Completed, PlanBusyError,
                     PlanCache, PlanKey, SolverService, VirtualClock,
                     WallClock, pattern_fingerprint, values_fingerprint)

__all__ = [
    "DEFAULT_COSTS", "CacheStats", "Completed", "PlanBusyError",
    "PlanCache", "PlanKey", "SolverService", "VirtualClock", "WallClock",
    "pattern_fingerprint", "values_fingerprint",
]
