"""Serving steps: batched prefill and single-token decode.

``serve_step`` is what the ``decode_*`` / ``long_*`` dry-run shapes lower:
one new token against a KV cache of the stated context length.  Caches for
windowed-attention layers are ring buffers of the window size and recurrent
layers carry O(1) state — which is why ``long_500k`` is a small, runnable
step for the sub-quadratic archs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import forward
from repro.models.config import ArchConfig


def _decode_positions(cfg: ArchConfig, batch: int, cur_pos):
    p = jnp.broadcast_to(jnp.asarray(cur_pos)[None, None], (batch, 1))
    if cfg.m_rope:
        p = jnp.broadcast_to(p[None], (3, batch, 1))
    return p


def prefill(params, cfg: ArchConfig, inputs, *, max_len: int,
            cache_dtype=jnp.bfloat16):
    """Token-parallel prefill: run the whole prompt through the stack once
    (flash attention) while scattering K/V into a decode-ready cache —
    ring layout for windowed layers, carried states for rec/ssm layers."""
    b, s = inputs.shape[:2]
    if cfg.m_rope:
        positions = jnp.broadcast_to(jnp.arange(s)[None, None], (3, b, s))
    else:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    logits, cache, _ = forward(params, cfg, inputs, positions,
                               build_cache_len=max_len, remat=False)
    cache = jax.tree.map(
        lambda c: c.astype(cache_dtype)
        if c.dtype in (jnp.float32, jnp.bfloat16) and c.ndim >= 4 else c,
        cache)
    return cache, logits                        # logits: (B, S, vocab)


def serve_step(params, cache, tokens, cur_pos, *, cfg: ArchConfig):
    """One decode step.  tokens: (B, 1) int32 (or (B,1,d) embeddings);
    cur_pos: scalar int32 absolute position.  Returns (logits, new_cache)."""
    b = tokens.shape[0]
    logits, cache, _ = forward(params, cfg, tokens,
                               _decode_positions(cfg, b, cur_pos),
                               cache=cache, cur_pos=cur_pos)
    return logits[:, 0], cache


def greedy_generate(params, cfg: ArchConfig, prompt, n_new: int,
                    *, max_len: int, cache_dtype=jnp.bfloat16):
    """Tiny reference sampler used by the examples and tests."""
    cache, logits = prefill(params, cfg, prompt, max_len=max_len,
                            cache_dtype=cache_dtype)
    b, s = prompt.shape[:2]
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]

    def step(carry, t):
        cache, tok = carry
        lg, cache = serve_step(params, cache, tok, t, cfg=cfg)
        nxt = jnp.argmax(lg, axis=-1)[:, None]
        return (cache, nxt), nxt[:, 0]

    (_, _), toks = jax.lax.scan(step, (cache, tok), s + jnp.arange(n_new))
    return jnp.moveaxis(toks, 0, 1)            # (B, n_new)
