"""Pallas TPU kernels for the paper's compute hot-spots.

hbmc_trisolve — the HBMC forward/backward substitution (the paper's core
kernel, Fig 4.6 TPU adaptation): round-major layout, sequential grid over
rounds, VMEM-resident solution vector, VPU gathers, contiguous stores.

sell_spmv — SELL-w sparse matrix-vector product (paper §4.4.2).

Both ship ops.py jit wrappers and ref.py pure-jnp oracles, and are
validated in interpret mode across (shape, b_s, w, dtype) sweeps
(tests/test_trisolve.py).
"""
from .config import default_interpret, resolve_interpret
from .hbmc_trisolve import (hbmc_trisolve, hbmc_trisolve_batched,
                            hbmc_trisolve_fused, hbmc_trisolve_fused_batched)
from .sell_spmv import sell_spmv
from .ops import DeviceRoundMajorTables, build_kernel_preconditioner
from .ref import (hbmc_trisolve_batched_ref, hbmc_trisolve_fused_batched_ref,
                  hbmc_trisolve_fused_ref, hbmc_trisolve_ref, sell_spmv_ref)
