"""Pallas TPU kernels for the paper's compute hot-spots.

hbmc_trisolve — the HBMC forward/backward substitution (the paper's core
kernel, Fig 4.6 TPU adaptation): round-major layout, sequential grid over
rounds, VMEM-resident solution vector, VPU gathers, contiguous stores.

sell_spmv — SELL-w sparse matrix-vector product family (paper §5.2):
single-RHS, batched multi-RHS, and the shard_map-compatible per-device
block variant consumed by the mesh-sharded SpMV.

Both families ship ref.py pure-jnp oracles (bitwise in interpret mode) and
the same interpret-by-backend defaulting (config.resolve_interpret), and
are validated across (shape, w, dtype, batch) sweeps
(tests/test_trisolve.py, tests/test_spmv.py).
"""
from .config import DEFAULT_SLICE_TILE, default_interpret, resolve_interpret
from .hbmc_trisolve import (hbmc_trisolve, hbmc_trisolve_batched,
                            hbmc_trisolve_fused, hbmc_trisolve_fused_batched)
from .sell_spmv import sell_spmv, sell_spmv_batched, sell_spmv_block
from .ops import DeviceRoundMajorTables, build_kernel_preconditioner
from .ref import (hbmc_trisolve_batched_ref, hbmc_trisolve_fused_batched_ref,
                  hbmc_trisolve_fused_ref, hbmc_trisolve_ref,
                  sell_spmv_batched_ref, sell_spmv_ref)
