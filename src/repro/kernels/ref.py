"""Pure-jnp oracles for the Pallas kernels (bit-exact semantics)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hbmc_trisolve_ref(cols: jax.Array, vals: jax.Array, dinv: jax.Array,
                      q: jax.Array) -> jax.Array:
    """Round-major triangular solve, fori_loop + dynamic_update_slice."""
    s_, r_, k_ = cols.shape
    y0 = jnp.zeros((s_ * r_,), dtype=vals.dtype)

    def body(s, y):
        g = jnp.take(y, cols[s], axis=0, fill_value=0)     # (R, K)
        acc = jnp.sum(vals[s] * g, axis=-1)
        t = (q[s] - acc) * dinv[s]
        return jax.lax.dynamic_update_slice(y, t, (s * r_,))

    return jax.lax.fori_loop(0, s_, body, y0)


def hbmc_trisolve_batched_ref(cols: jax.Array, vals: jax.Array,
                              dinv: jax.Array, q: jax.Array) -> jax.Array:
    """Multi-RHS round-major triangular solve.  q: (S, R, B) -> (S*R, B)."""
    s_, r_, k_ = cols.shape
    b_ = q.shape[-1]
    y0 = jnp.zeros((s_ * r_, b_), dtype=vals.dtype)

    def body(s, y):
        g = jnp.take(y, cols[s], axis=0, fill_value=0)     # (R, K, B)
        acc = jnp.sum(vals[s][..., None] * g, axis=1)      # (R, B)
        t = (q[s] - acc) * dinv[s][:, None]
        return jax.lax.dynamic_update_slice(y, t, (s * r_, 0))

    return jax.lax.fori_loop(0, s_, body, y0)


def sell_spmv_ref(vals: jax.Array, cols: jax.Array, x: jax.Array) -> jax.Array:
    """SELL-w SpMV oracle.  vals/cols: (n_slices, K, w); x: (n,)."""
    g = jnp.take(x, cols, axis=0, fill_value=0)            # (S, K, w)
    return jnp.einsum("skw,skw->sw", vals, g).reshape(-1)


def sell_spmv_batched_ref(vals: jax.Array, cols: jax.Array,
                          x: jax.Array) -> jax.Array:
    """Multi-RHS SELL-w SpMV oracle.  x: (n, B) -> (n_slices*w, B)."""
    g = jnp.take(x, cols, axis=0, fill_value=0)            # (S, K, w, B)
    return jnp.einsum("skw,skwb->swb", vals, g).reshape(-1, x.shape[-1])


def hbmc_trisolve_fused_ref(cols: jax.Array, vals: jax.Array,
                            dinv: jax.Array, q: jax.Array) -> jax.Array:
    """Fused fwd+bwd round-major solve oracle.  cols: (2S, R, K); q: (S, R).

    Mirrors the fused kernel step for step: one buffer, forward half fills
    y slice by slice, backward half overwrites it in place in reverse slice
    order (see kernels/hbmc_trisolve.py for why that is safe).

    Deliberately NOT shared with core.trisolve._substitute_fused: this
    oracle reproduces the kernel's exact op order (elementwise multiply +
    jnp.sum -> bit-exact in interpret mode, asserted in tests), while the
    XLA production path contracts with einsum, which is faster on CPU but
    reassociates the K-reduction.
    """
    s2, r_, k_ = cols.shape
    s_ = s2 // 2
    y0 = jnp.zeros((s_ * r_,), dtype=vals.dtype)

    def body(g, y):
        g_fwd = jnp.take(y, cols[g], axis=0, fill_value=0)     # (R, K)
        acc = jnp.sum(vals[g] * g_fwd, axis=-1)
        dest = jnp.where(g < s_, g, s2 - 1 - g) * r_
        q_cur = jnp.where(g < s_, q[jnp.minimum(g, s_ - 1)],
                          jax.lax.dynamic_slice(y, (dest,), (r_,)))
        t = (q_cur - acc) * dinv[g]
        return jax.lax.dynamic_update_slice(y, t, (dest,))

    return jax.lax.fori_loop(0, s2, body, y0)


def hbmc_trisolve_fused_batched_ref(cols: jax.Array, vals: jax.Array,
                                    dinv: jax.Array, q: jax.Array
                                    ) -> jax.Array:
    """Multi-RHS fused oracle.  cols: (2S, R, K); q: (S, R, B) -> (S*R, B)."""
    s2, r_, k_ = cols.shape
    s_ = s2 // 2
    b_ = q.shape[-1]
    y0 = jnp.zeros((s_ * r_, b_), dtype=vals.dtype)

    def body(g, y):
        g_fwd = jnp.take(y, cols[g], axis=0, fill_value=0)     # (R, K, B)
        acc = jnp.sum(vals[g][..., None] * g_fwd, axis=1)      # (R, B)
        dest = jnp.where(g < s_, g, s2 - 1 - g) * r_
        zero = jnp.zeros_like(dest)
        q_cur = jnp.where(g < s_, q[jnp.minimum(g, s_ - 1)],
                          jax.lax.dynamic_slice(y, (dest, zero), (r_, b_)))
        t = (q_cur - acc) * dinv[g][:, None]
        return jax.lax.dynamic_update_slice(y, t, (dest, zero))

    return jax.lax.fori_loop(0, s2, body, y0)
