"""Pure-jnp oracles for the Pallas kernels (bit-exact semantics)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hbmc_trisolve_ref(cols: jax.Array, vals: jax.Array, dinv: jax.Array,
                      q: jax.Array) -> jax.Array:
    """Round-major triangular solve, fori_loop + dynamic_update_slice."""
    s_, r_, k_ = cols.shape
    y0 = jnp.zeros((s_ * r_,), dtype=vals.dtype)

    def body(s, y):
        g = jnp.take(y, cols[s], axis=0, fill_value=0)     # (R, K)
        acc = jnp.sum(vals[s] * g, axis=-1)
        t = (q[s] - acc) * dinv[s]
        return jax.lax.dynamic_update_slice(y, t, (s * r_,))

    return jax.lax.fori_loop(0, s_, body, y0)


def hbmc_trisolve_batched_ref(cols: jax.Array, vals: jax.Array,
                              dinv: jax.Array, q: jax.Array) -> jax.Array:
    """Multi-RHS round-major triangular solve.  q: (S, R, B) -> (S*R, B)."""
    s_, r_, k_ = cols.shape
    b_ = q.shape[-1]
    y0 = jnp.zeros((s_ * r_, b_), dtype=vals.dtype)

    def body(s, y):
        g = jnp.take(y, cols[s], axis=0, fill_value=0)     # (R, K, B)
        acc = jnp.sum(vals[s][..., None] * g, axis=1)      # (R, B)
        t = (q[s] - acc) * dinv[s][:, None]
        return jax.lax.dynamic_update_slice(y, t, (s * r_, 0))

    return jax.lax.fori_loop(0, s_, body, y0)


def sell_spmv_ref(vals: jax.Array, cols: jax.Array, x: jax.Array) -> jax.Array:
    """SELL-w SpMV oracle.  vals/cols: (n_slices, K, w); x: (n,)."""
    g = jnp.take(x, cols, axis=0, fill_value=0)            # (S, K, w)
    return jnp.einsum("skw,skw->sw", vals, g).reshape(-1)
