"""Pallas TPU kernel for the HBMC triangular substitution.

TPU adaptation of the paper's AVX-512 inner loop (Fig. 4.6).  The rounds of
the HBMC substitution are laid out *round-major*: the R lanes of round ``s``
occupy the contiguous slice ``y[s*R : (s+1)*R]``.  Laying the vector out in
execution order turns the paper's per-block strided stores into dense
contiguous VMEM stores; the ``_mm512_i32logather_pd`` gather maps to a VPU
gather from the VMEM-resident solution vector.  Round-major layout is itself
an equivalent reordering (same argument as HBMC <- BMC: lanes of one round
are mutually independent), so convergence is untouched.

Grid: one (sequential) grid step per round — TPU grid steps execute in
order, which realizes the round -> round dependency without extra
synchronization, mirroring "one thread barrier per color" in the paper.

Memory plan per grid step (VMEM):
  cols  (1, R, K) int32   - blocked over rounds via BlockSpec
  vals  (1, R, K) dtype   - blocked over rounds
  dinv  (1, R)    dtype   - blocked over rounds
  q     (1, R)    dtype   - blocked over rounds (round-major RHS)
  y     (S*R_pad,) dtype  - full vector, input/output aliased accumulator

The working set of one grid step is R*K*(4+dtype) + O(R) bytes; with the
production tile R = 2048 lanes, K <= 32, f32 that is ~0.5 MiB, far below
VMEM, leaving the full y vector resident for gathers (y of 8M lanes f32 =
32 MiB; larger problems shard rounds across devices first — see
core/partition.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _trisolve_kernel(cols_ref, vals_ref, dinv_ref, q_ref, y_in_ref, y_ref):
    s = pl.program_id(0)
    r = cols_ref.shape[1]
    cols = cols_ref[0]            # (R, K) int32, round-major coords
    vals = vals_ref[0]            # (R, K)
    dinv = dinv_ref[0]            # (R,)
    q = q_ref[0]                  # (R,)
    y = y_ref[...]                # full (S*R (+pad),) vector, aliased in/out
    gathered = jnp.take(y, cols, axis=0, fill_value=0)   # (R, K) VPU gather
    acc = jnp.sum(vals * gathered, axis=-1)              # (R,)
    t = (q - acc) * dinv
    y_ref[pl.ds(s * r, r)] = t            # dense contiguous store


def _trisolve_batched_kernel(cols_ref, vals_ref, dinv_ref, q_ref, y_in_ref,
                             y_ref):
    """Multi-RHS variant: the B right-hand sides share one gather of the
    column coordinates, so the extra RHS columns ride the same VMEM traffic
    for cols/vals/dinv — this is what makes batched solves cheaper per RHS
    than B sequential solves."""
    s = pl.program_id(0)
    r = cols_ref.shape[1]
    cols = cols_ref[0]            # (R, K) int32, round-major coords
    vals = vals_ref[0]            # (R, K)
    dinv = dinv_ref[0]            # (R,)
    q = q_ref[0]                  # (R, B)
    y = y_ref[...]                # (S*R (+pad), B), aliased in/out
    gathered = jnp.take(y, cols, axis=0, fill_value=0)   # (R, K, B)
    acc = jnp.sum(vals[..., None] * gathered, axis=1)    # (R, B)
    t = (q - acc) * dinv[:, None]
    y_ref[pl.ds(s * r, r), :] = t         # dense contiguous store


@functools.partial(jax.jit, static_argnames=("interpret",))
def hbmc_trisolve(cols: jax.Array, vals: jax.Array, dinv: jax.Array,
                  q: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Solve the round-major packed triangular system.

    Args:
      cols: (S, R, K) int32 — column indices in round-major coordinates;
        padding must point at a slot whose matching ``vals`` entry is 0.
      vals: (S, R, K) — off-diagonal values (0 on padding).
      dinv: (S, R) — inverse diagonal (0 on padding lanes).
      q:    (S, R) — right-hand side in round-major layout.

    Returns:
      y: (S*R,) solution in round-major layout.
    """
    s_, r_, k_ = cols.shape
    dtype = vals.dtype
    y0 = jnp.zeros((s_ * r_,), dtype=dtype)
    grid = (s_,)
    return pl.pallas_call(
        _trisolve_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, r_, k_), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, r_, k_), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, r_), lambda s: (s, 0)),
            pl.BlockSpec((1, r_), lambda s: (s, 0)),
            pl.BlockSpec((s_ * r_,), lambda s: (0,)),   # y (aliased input)
        ],
        out_specs=pl.BlockSpec((s_ * r_,), lambda s: (0,)),
        out_shape=jax.ShapeDtypeStruct((s_ * r_,), dtype),
        input_output_aliases={4: 0},
        interpret=interpret,
    )(cols, vals, dinv, q, y0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def hbmc_trisolve_batched(cols: jax.Array, vals: jax.Array, dinv: jax.Array,
                          q: jax.Array, *, interpret: bool = True
                          ) -> jax.Array:
    """Solve the round-major packed triangular system for B RHS at once.

    Args:
      cols: (S, R, K) int32 — column indices in round-major coordinates.
      vals: (S, R, K) — off-diagonal values (0 on padding).
      dinv: (S, R) — inverse diagonal (0 on padding lanes).
      q:    (S, R, B) — right-hand sides in round-major layout.

    Returns:
      y: (S*R, B) solutions in round-major layout.
    """
    s_, r_, k_ = cols.shape
    b_ = q.shape[-1]
    dtype = vals.dtype
    y0 = jnp.zeros((s_ * r_, b_), dtype=dtype)
    grid = (s_,)
    return pl.pallas_call(
        _trisolve_batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, r_, k_), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, r_, k_), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, r_), lambda s: (s, 0)),
            pl.BlockSpec((1, r_, b_), lambda s: (s, 0, 0)),
            pl.BlockSpec((s_ * r_, b_), lambda s: (0, 0)),  # y (aliased)
        ],
        out_specs=pl.BlockSpec((s_ * r_, b_), lambda s: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((s_ * r_, b_), dtype),
        input_output_aliases={4: 0},
        interpret=interpret,
    )(cols, vals, dinv, q, y0)
