"""Pallas TPU kernel for the HBMC triangular substitution.

TPU adaptation of the paper's AVX-512 inner loop (Fig. 4.6).  The rounds of
the HBMC substitution are laid out *round-major*: the R lanes of round ``s``
occupy the contiguous slice ``y[s*R : (s+1)*R]``.  Laying the vector out in
execution order turns the paper's per-block strided stores into dense
contiguous VMEM stores; the ``_mm512_i32logather_pd`` gather maps to a VPU
gather from the VMEM-resident solution vector.  Round-major layout is itself
an equivalent reordering (same argument as HBMC <- BMC: lanes of one round
are mutually independent), so convergence is untouched.

Grid: one (sequential) grid step per round — TPU grid steps execute in
order, which realizes the round -> round dependency without extra
synchronization, mirroring "one thread barrier per color" in the paper.

Memory plan per grid step (VMEM):
  cols  (1, R, K) int32   - blocked over rounds via BlockSpec
  vals  (1, R, K) dtype   - blocked over rounds
  dinv  (1, R)    dtype   - blocked over rounds
  q     (1, R)    dtype   - blocked over rounds (round-major RHS)
  y     (S*R_pad,) dtype  - full vector, input/output aliased accumulator

The working set of one grid step is R*K*(4+dtype) + O(R) bytes; with the
production tile R = 2048 lanes, K <= 32, f32 that is ~0.5 MiB, far below
VMEM, leaving the full y vector resident for gathers (y of 8M lanes f32 =
32 MiB; larger problems shard rounds across devices first — see
core/partition.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .config import resolve_interpret


def _trisolve_kernel(cols_ref, vals_ref, dinv_ref, q_ref, y_in_ref, y_ref):
    s = pl.program_id(0)
    r = cols_ref.shape[1]
    cols = cols_ref[0]            # (R, K) int32, round-major coords
    vals = vals_ref[0]            # (R, K)
    dinv = dinv_ref[0]            # (R,)
    q = q_ref[0]                  # (R,)
    y = y_ref[...]                # full (S*R (+pad),) vector, aliased in/out
    gathered = jnp.take(y, cols, axis=0, fill_value=0)   # (R, K) VPU gather
    acc = jnp.sum(vals * gathered, axis=-1)              # (R,)
    t = (q - acc) * dinv
    y_ref[pl.ds(s * r, r)] = t            # dense contiguous store


def _trisolve_batched_kernel(cols_ref, vals_ref, dinv_ref, q_ref, y_in_ref,
                             y_ref):
    """Multi-RHS variant: the B right-hand sides share one gather of the
    column coordinates, so the extra RHS columns ride the same VMEM traffic
    for cols/vals/dinv — this is what makes batched solves cheaper per RHS
    than B sequential solves."""
    s = pl.program_id(0)
    r = cols_ref.shape[1]
    cols = cols_ref[0]            # (R, K) int32, round-major coords
    vals = vals_ref[0]            # (R, K)
    dinv = dinv_ref[0]            # (R,)
    q = q_ref[0]                  # (R, B)
    y = y_ref[...]                # (S*R (+pad), B), aliased in/out
    gathered = jnp.take(y, cols, axis=0, fill_value=0)   # (R, K, B)
    acc = jnp.sum(vals[..., None] * gathered, axis=1)    # (R, B)
    t = (q - acc) * dinv[:, None]
    y_ref[pl.ds(s * r, r), :] = t         # dense contiguous store


@functools.partial(jax.jit, static_argnames=("interpret",))
def hbmc_trisolve(cols: jax.Array, vals: jax.Array, dinv: jax.Array,
                  q: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Solve the round-major packed triangular system.

    Args:
      cols: (S, R, K) int32 — column indices in round-major coordinates;
        padding must point at a slot whose matching ``vals`` entry is 0.
      vals: (S, R, K) — off-diagonal values (0 on padding).
      dinv: (S, R) — inverse diagonal (0 on padding lanes).
      q:    (S, R) — right-hand side in round-major layout.

    Returns:
      y: (S*R,) solution in round-major layout.
    """
    interpret = resolve_interpret(interpret)
    s_, r_, k_ = cols.shape
    dtype = vals.dtype
    y0 = jnp.zeros((s_ * r_,), dtype=dtype)
    grid = (s_,)
    return pl.pallas_call(
        _trisolve_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, r_, k_), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, r_, k_), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, r_), lambda s: (s, 0)),
            pl.BlockSpec((1, r_), lambda s: (s, 0)),
            pl.BlockSpec((s_ * r_,), lambda s: (0,)),   # y (aliased input)
        ],
        out_specs=pl.BlockSpec((s_ * r_,), lambda s: (0,)),
        out_shape=jax.ShapeDtypeStruct((s_ * r_,), dtype),
        input_output_aliases={4: 0},
        interpret=interpret,
    )(cols, vals, dinv, q, y0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def hbmc_trisolve_batched(cols: jax.Array, vals: jax.Array, dinv: jax.Array,
                          q: jax.Array, *, interpret: bool | None = None
                          ) -> jax.Array:
    """Solve the round-major packed triangular system for B RHS at once.

    Args:
      cols: (S, R, K) int32 — column indices in round-major coordinates.
      vals: (S, R, K) — off-diagonal values (0 on padding).
      dinv: (S, R) — inverse diagonal (0 on padding lanes).
      q:    (S, R, B) — right-hand sides in round-major layout.

    Returns:
      y: (S*R, B) solutions in round-major layout.
    """
    interpret = resolve_interpret(interpret)
    s_, r_, k_ = cols.shape
    b_ = q.shape[-1]
    dtype = vals.dtype
    y0 = jnp.zeros((s_ * r_, b_), dtype=dtype)
    grid = (s_,)
    return pl.pallas_call(
        _trisolve_batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, r_, k_), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, r_, k_), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, r_), lambda s: (s, 0)),
            pl.BlockSpec((1, r_, b_), lambda s: (s, 0, 0)),
            pl.BlockSpec((s_ * r_, b_), lambda s: (0, 0)),  # y (aliased)
        ],
        out_specs=pl.BlockSpec((s_ * r_, b_), lambda s: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((s_ * r_, b_), dtype),
        input_output_aliases={4: 0},
        interpret=interpret,
    )(cols, vals, dinv, q, y0)


# ---------------------------------------------------------------------------
# Fused forward+backward sweep: ONE pallas_call, 2S sequential grid steps.
# ---------------------------------------------------------------------------
#
# The backward rounds are the forward rounds reversed (lane order included),
# so in forward round-major coordinates the backward sweep's stores are ALSO
# dense contiguous slices: step g >= S writes slice (2S-1-g)*R.  One VMEM
# buffer therefore carries the whole preconditioner apply: the forward half
# fills it with y = L^{-1} q, the backward half overwrites it in place with
# z = L^{-T} y in reverse slice order (each backward gather touches only
# already-overwritten z slices; the current slice's y is read just before its
# store).  Compared with two pallas_calls this halves kernel launches and
# keeps y VMEM-resident across the fwd->bwd handoff instead of round-tripping
# through HBM.


def _fused_kernel(cols_ref, vals_ref, dinv_ref, q_ref, y_in_ref, y_ref):
    g = pl.program_id(0)
    s_half = q_ref.shape[0]       # S (rounds per sweep); grid is 2S
    r = cols_ref.shape[1]
    y = y_ref[...]                # (S*R,) aliased in/out accumulator
    gathered = jnp.take(y, cols_ref[0], axis=0, fill_value=0)   # (R, K)
    acc = jnp.sum(vals_ref[0] * gathered, axis=-1)              # (R,)
    dest = jnp.where(g < s_half, g, 2 * s_half - 1 - g) * r
    # forward RHS comes from q; backward RHS is the y slice being overwritten
    q_fwd = q_ref[pl.ds(jnp.minimum(g, s_half - 1), 1), :][0]   # (R,)
    q_bwd = jax.lax.dynamic_slice(y, (dest,), (r,))
    q_cur = jnp.where(g < s_half, q_fwd, q_bwd)
    t = (q_cur - acc) * dinv_ref[0]
    y_ref[pl.ds(dest, r)] = t             # dense contiguous store, both halves


def _fused_batched_kernel(cols_ref, vals_ref, dinv_ref, q_ref, y_in_ref,
                          y_ref):
    g = pl.program_id(0)
    s_half = q_ref.shape[0]
    r = cols_ref.shape[1]
    b = q_ref.shape[-1]
    y = y_ref[...]                # (S*R, B) aliased in/out
    gathered = jnp.take(y, cols_ref[0], axis=0, fill_value=0)   # (R, K, B)
    acc = jnp.sum(vals_ref[0][..., None] * gathered, axis=1)    # (R, B)
    dest = jnp.where(g < s_half, g, 2 * s_half - 1 - g) * r
    q_fwd = q_ref[pl.ds(jnp.minimum(g, s_half - 1), 1), :, :][0]   # (R, B)
    q_bwd = jax.lax.dynamic_slice(y, (dest, jnp.zeros_like(dest)), (r, b))
    q_cur = jnp.where(g < s_half, q_fwd, q_bwd)
    t = (q_cur - acc) * dinv_ref[0][:, None]
    y_ref[pl.ds(dest, r), :] = t


@functools.partial(jax.jit, static_argnames=("interpret",))
def hbmc_trisolve_fused(cols: jax.Array, vals: jax.Array, dinv: jax.Array,
                        q: jax.Array, *, interpret: bool | None = None
                        ) -> jax.Array:
    """z = (L L^T)^{-1} q in round-major coordinates, one kernel launch.

    Args:
      cols: (2S, R, K) int32 — forward round-major gather positions; rows
        0..S-1 are the forward rounds, S..2S-1 the backward rounds in
        backward execution order (``sell.fuse_round_major``).
      vals: (2S, R, K) — off-diagonal values (0 on padding).
      dinv: (2S, R) — inverse diagonal (0 on padding lanes).
      q:    (S, R) — right-hand side in round-major layout.

    Returns:
      z: (S*R,) solution in round-major layout (holes stay 0).
    """
    s2, r_, k_ = cols.shape
    s_ = s2 // 2
    if q.shape != (s_, r_):
        raise ValueError(f"q shape {q.shape} != rounds shape {(s_, r_)}")
    interpret = resolve_interpret(interpret)
    dtype = vals.dtype
    y0 = jnp.zeros((s_ * r_,), dtype=dtype)
    return pl.pallas_call(
        _fused_kernel,
        grid=(s2,),
        in_specs=[
            pl.BlockSpec((1, r_, k_), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, r_, k_), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, r_), lambda g: (g, 0)),
            pl.BlockSpec((s_, r_), lambda g: (0, 0)),   # q fully resident
            pl.BlockSpec((s_ * r_,), lambda g: (0,)),   # y (aliased input)
        ],
        out_specs=pl.BlockSpec((s_ * r_,), lambda g: (0,)),
        out_shape=jax.ShapeDtypeStruct((s_ * r_,), dtype),
        input_output_aliases={4: 0},
        interpret=interpret,
    )(cols, vals, dinv, q, y0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def hbmc_trisolve_fused_batched(cols: jax.Array, vals: jax.Array,
                                dinv: jax.Array, q: jax.Array, *,
                                interpret: bool | None = None) -> jax.Array:
    """Multi-RHS fused solve.  q: (S, R, B) -> z: (S*R, B).

    The B right-hand sides share every gather of cols/vals/dinv across BOTH
    sweeps, and the fwd->bwd handoff never leaves VMEM.
    """
    s2, r_, k_ = cols.shape
    s_ = s2 // 2
    b_ = q.shape[-1]
    if q.shape != (s_, r_, b_):
        raise ValueError(f"q shape {q.shape} != {(s_, r_, b_)}")
    interpret = resolve_interpret(interpret)
    dtype = vals.dtype
    y0 = jnp.zeros((s_ * r_, b_), dtype=dtype)
    return pl.pallas_call(
        _fused_batched_kernel,
        grid=(s2,),
        in_specs=[
            pl.BlockSpec((1, r_, k_), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, r_, k_), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, r_), lambda g: (g, 0)),
            pl.BlockSpec((s_, r_, b_), lambda g: (0, 0, 0)),
            pl.BlockSpec((s_ * r_, b_), lambda g: (0, 0)),  # y (aliased)
        ],
        out_specs=pl.BlockSpec((s_ * r_, b_), lambda g: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((s_ * r_, b_), dtype),
        input_output_aliases={4: 0},
        interpret=interpret,
    )(cols, vals, dinv, q, y0)
