"""Pallas TPU kernel for SELL-w sparse matrix-vector multiplication (§4.4.2).

SELL-C-sigma with C = w: each slice holds w rows column-major so one VPU
load covers one (k, lane) plane.  The kernel tiles slices over the grid;
x stays VMEM-resident for gathers (same residency argument as the trisolve
kernel).  Slices are zero-padded to the slice-max row length, matching the
paper's SELL cost model (the Audikw_1 40%-padding discussion in §5.2.2 is
reproduced by ``benchmarks/trisolve_bench.py`` via the padded_nnz counter).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .config import resolve_interpret


def _sell_spmv_kernel(vals_ref, cols_ref, x_ref, y_ref):
    vals = vals_ref[...]          # (T, K, w) tile of slices
    cols = cols_ref[...]          # (T, K, w)
    x = x_ref[...]                # (n_pad,)
    g = jnp.take(x, cols, axis=0, fill_value=0)
    y_ref[...] = jnp.einsum("skw,skw->sw", vals, g)


@functools.partial(jax.jit, static_argnames=("slice_tile", "interpret"))
def sell_spmv(vals: jax.Array, cols: jax.Array, x: jax.Array,
              *, slice_tile: int = 256,
              interpret: bool | None = None) -> jax.Array:
    """y = A x with A in SELL-w layout.

    Args:
      vals: (n_slices, K, w) slice-packed values (0 padding).
      cols: (n_slices, K, w) int32 column indices (padding -> any index whose
        vals entry is 0; fill_value guards out-of-range).
      x:    (n_pad,) input vector (padded to n_slices*w).
      slice_tile: slices per grid step (VMEM tile height).

    Returns:
      y: (n_slices * w,) in slice-row-major order.
    """
    interpret = resolve_interpret(interpret)
    n_slices, k_, w_ = vals.shape
    t = min(slice_tile, n_slices)
    # pad slice count to a multiple of the tile
    pad = (-n_slices) % t
    if pad:
        vals = jnp.pad(vals, ((0, pad), (0, 0), (0, 0)))
        cols = jnp.pad(cols, ((0, pad), (0, 0), (0, 0)))
    ns = vals.shape[0]
    grid = (ns // t,)
    y = pl.pallas_call(
        _sell_spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, k_, w_), lambda i: (i, 0, 0)),
            pl.BlockSpec((t, k_, w_), lambda i: (i, 0, 0)),
            pl.BlockSpec((x.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((t, w_), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ns, w_), vals.dtype),
        interpret=interpret,
    )(vals, cols, x)
    return y.reshape(-1)[:n_slices * w_]
