"""Pallas TPU kernel family for SELL-w sparse matrix-vector products (§5.2).

SELL-C-sigma with C = w: each slice holds w rows column-major so one VPU
load covers one (k, lane) plane.  The kernels tile slices over the grid;
x stays VMEM-resident for gathers (same residency argument as the trisolve
kernel).  Slices are zero-padded to the slice-max row length, matching the
paper's SELL cost model (the Audikw_1 40%-padding discussion in §5.2.2 is
reproduced by ``benchmarks/bench_trisolve.py`` via the padded_nnz counter).

Three entry points sharing one kernel body:

  * ``sell_spmv``          — single RHS, x (n_pad,) -> y (n_slices*w,)
  * ``sell_spmv_batched``  — B RHS, x (n_pad, B) -> y (n_slices*w, B); the
    B columns share every gather of the column-index plane, the same
    amortization as the batched trisolve kernel
  * ``sell_spmv_block``    — shard_map-compatible per-device block variant:
    consumes the LOCAL slice shard of the operands plus the replicated
    vector and returns the local row block (no slicing to n — the caller
    all-gathers; see ``core.iccg.make_sharded_spmv``)

All outputs are in slice-row-major order, padded to ``n_slices * w`` rows;
callers slice to the matrix dimension (``core.plan._make_spmv`` does).  The
gather semantics (``jnp.take(..., fill_value=0)``) against zero-padded
``vals`` make padding lanes contribute exact zeros, so results match the
jnp oracles in ``ref.py`` bit for bit in interpret mode (asserted in
tests/test_spmv.py).  ``interpret`` defaults from the backend
(``config.resolve_interpret``): compiled on TPU, interpreted elsewhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .config import DEFAULT_SLICE_TILE, resolve_interpret


def _sell_spmv_kernel(vals_ref, cols_ref, x_ref, y_ref):
    vals = vals_ref[...]          # (T, K, w) tile of slices
    cols = cols_ref[...]          # (T, K, w)
    x = x_ref[...]                # (n_pad,)
    g = jnp.take(x, cols, axis=0, fill_value=0)
    y_ref[...] = jnp.einsum("skw,skw->sw", vals, g)


def _sell_spmv_batched_kernel(vals_ref, cols_ref, x_ref, y_ref):
    vals = vals_ref[...]          # (T, K, w)
    cols = cols_ref[...]          # (T, K, w)
    x = x_ref[...]                # (n_pad, B)
    g = jnp.take(x, cols, axis=0, fill_value=0)       # (T, K, w, B)
    y_ref[...] = jnp.einsum("skw,skwb->swb", vals, g)


def _pad_slices(vals: jax.Array, cols: jax.Array, slice_tile: int
                ) -> tuple[jax.Array, jax.Array, int]:
    """Pad the slice axis to a multiple of the grid tile (zero slices)."""
    n_slices = vals.shape[0]
    t = min(slice_tile, n_slices)
    pad = (-n_slices) % t
    if pad:
        widths = ((0, pad),) + ((0, 0),) * (vals.ndim - 1)
        vals = jnp.pad(vals, widths)
        cols = jnp.pad(cols, widths)
    return vals, cols, t


@functools.partial(jax.jit, static_argnames=("slice_tile", "interpret"))
def sell_spmv(vals: jax.Array, cols: jax.Array, x: jax.Array,
              *, slice_tile: int = DEFAULT_SLICE_TILE,
              interpret: bool | None = None) -> jax.Array:
    """y = A x with A in SELL-w layout.

    Args:
      vals: (n_slices, K, w) slice-packed values (0 padding).
      cols: (n_slices, K, w) int32 column indices (padding -> any index whose
        vals entry is 0; fill_value guards out-of-range).
      x:    (n_pad,) input vector.
      slice_tile: slices per grid step (VMEM tile height).

    Returns:
      y: (n_slices * w,) in slice-row-major order.
    """
    interpret = resolve_interpret(interpret)
    n_slices, k_, w_ = vals.shape
    vals, cols, t = _pad_slices(vals, cols, slice_tile)
    ns = vals.shape[0]
    y = pl.pallas_call(
        _sell_spmv_kernel,
        grid=(ns // t,),
        in_specs=[
            pl.BlockSpec((t, k_, w_), lambda i: (i, 0, 0)),
            pl.BlockSpec((t, k_, w_), lambda i: (i, 0, 0)),
            pl.BlockSpec((x.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((t, w_), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ns, w_), vals.dtype),
        interpret=interpret,
    )(vals, cols, x)
    return y.reshape(-1)[:n_slices * w_]


@functools.partial(jax.jit, static_argnames=("slice_tile", "interpret"))
def sell_spmv_batched(vals: jax.Array, cols: jax.Array, x: jax.Array,
                      *, slice_tile: int = DEFAULT_SLICE_TILE,
                      interpret: bool | None = None) -> jax.Array:
    """Y = A X for B column vectors at once.  x: (n_pad, B).

    One gather of the (K, w) column-index plane serves all B columns; the
    K-reduction per (row, column) matches ``sell_spmv`` exactly, keeping
    batched and single-RHS PCG arithmetic identical.

    Returns:
      y: (n_slices * w, B) in slice-row-major order.
    """
    interpret = resolve_interpret(interpret)
    n_slices, k_, w_ = vals.shape
    b_ = x.shape[-1]
    vals, cols, t = _pad_slices(vals, cols, slice_tile)
    ns = vals.shape[0]
    y = pl.pallas_call(
        _sell_spmv_batched_kernel,
        grid=(ns // t,),
        in_specs=[
            pl.BlockSpec((t, k_, w_), lambda i: (i, 0, 0)),
            pl.BlockSpec((t, k_, w_), lambda i: (i, 0, 0)),
            pl.BlockSpec((x.shape[0], b_), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((t, w_, b_), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((ns, w_, b_), vals.dtype),
        interpret=interpret,
    )(vals, cols, x)
    return y.reshape(-1, b_)[:n_slices * w_]


def sell_spmv_block(vals: jax.Array, cols: jax.Array, x: jax.Array,
                    *, slice_tile: int = DEFAULT_SLICE_TILE,
                    interpret: bool | None = None) -> jax.Array:
    """Per-device block SpMV for use inside ``shard_map``.

    ``vals``/``cols`` are the device-LOCAL slice shard ((s_loc, K, w));
    ``x`` is the replicated input vector ((n_pad,) or (n_pad, B)) indexed
    by GLOBAL positions, so the local gather needs no index translation.
    Returns the local row block ((s_loc * w,) or (s_loc * w, B)) — the
    caller assembles the full result with one tiled all-gather
    (``core.iccg.make_sharded_spmv``), mirroring the xla sharded path.
    """
    if x.ndim == 2:
        return sell_spmv_batched(vals, cols, x, slice_tile=slice_tile,
                                 interpret=interpret)
    return sell_spmv(vals, cols, x, slice_tile=slice_tile,
                     interpret=interpret)
