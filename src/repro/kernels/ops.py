"""jit'd wrappers bridging core StepTables to the Pallas kernels.

The kernels operate on the *round-major* layout (see hbmc_trisolve.py).
``RoundMajorTables.from_steps`` converts a host-side ``StepTables`` once at
setup; ``apply`` runs one triangular solve and returns the result in the
original (HBMC) index space.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sell import StepTables
from .hbmc_trisolve import hbmc_trisolve
from .ref import hbmc_trisolve_ref


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RoundMajorTables:
    cols: jax.Array    # (S, R, K) int32, round-major coords
    vals: jax.Array    # (S, R, K)
    dinv: jax.Array    # (S, R)
    rows: jax.Array    # (S, R) int32 — HBMC index of each lane (pad-> n_slots-1)
    n_slots: int

    def tree_flatten(self):
        return (self.cols, self.vals, self.dinv, self.rows), (self.n_slots,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, n_slots=aux[0])

    @classmethod
    def from_steps(cls, t: StepTables, dtype=jnp.float64) -> "RoundMajorTables":
        s_, r_ = t.rows.shape
        k_ = t.cols.shape[-1]
        # position map: HBMC index -> round-major position (unassigned -> S*R,
        # which jnp.take(fill_value=0) turns into a harmless 0 read)
        pos = np.full(t.n_slots, s_ * r_, dtype=np.int64)
        lane = np.arange(s_ * r_).reshape(s_, r_)
        live_mask = t.rows != (t.n_slots - 1)
        pos[t.rows[live_mask]] = lane[live_mask]
        cols_rm = pos[t.cols].astype(np.int32)
        return cls(cols=jnp.asarray(cols_rm),
                   vals=jnp.asarray(t.vals, dtype=dtype),
                   dinv=jnp.asarray(t.dinv, dtype=dtype),
                   rows=jnp.asarray(t.rows.astype(np.int32)),
                   n_slots=t.n_slots)

    def apply(self, q: jax.Array, *, use_kernel: bool = True,
              interpret: bool = True) -> jax.Array:
        """One triangular solve.  q, result: (n_slots-1,) in HBMC order."""
        s_, r_ = self.dinv.shape
        qp = jnp.concatenate([q, jnp.zeros((1,), dtype=q.dtype)])
        q_rm = qp[self.rows]                         # (S, R)
        if use_kernel:
            y_rm = hbmc_trisolve(self.cols, self.vals, self.dinv, q_rm,
                                 interpret=interpret)
        else:
            y_rm = hbmc_trisolve_ref(self.cols, self.vals, self.dinv, q_rm)
        y = jnp.zeros((self.n_slots,), dtype=q.dtype)
        y = y.at[self.rows.reshape(-1)].set(y_rm)    # pad lanes hit slot -1
        return y[:-1]


@dataclasses.dataclass(frozen=True)
class KernelPreconditioner:
    """IC(0) apply (L L^T)^{-1} using the Pallas kernels end to end."""
    fwd: RoundMajorTables
    bwd: RoundMajorTables
    use_kernel: bool = True
    interpret: bool = True

    def __call__(self, r: jax.Array) -> jax.Array:
        y = self.fwd.apply(r, use_kernel=self.use_kernel,
                           interpret=self.interpret)
        return self.bwd.apply(y, use_kernel=self.use_kernel,
                              interpret=self.interpret)


def build_kernel_preconditioner(fwd: StepTables, bwd: StepTables,
                                dtype=jnp.float64, use_kernel: bool = True,
                                interpret: bool = True) -> KernelPreconditioner:
    return KernelPreconditioner(
        fwd=RoundMajorTables.from_steps(fwd, dtype=dtype),
        bwd=RoundMajorTables.from_steps(bwd, dtype=dtype),
        use_kernel=use_kernel, interpret=interpret)
