"""jit'd wrappers bridging core StepTables to the Pallas kernels.

The kernels operate on the *round-major* layout (see hbmc_trisolve.py).
``DeviceRoundMajorTables.from_steps`` converts a host-side ``StepTables`` once at
setup; ``apply`` runs one triangular solve and returns the result in the
original (HBMC) index space.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import sell
from repro.core.sell import StepTables
from .hbmc_trisolve import hbmc_trisolve, hbmc_trisolve_batched
from .ref import hbmc_trisolve_batched_ref, hbmc_trisolve_ref


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceRoundMajorTables:
    """Device-resident round-major tables (see core.sell.RoundMajorTables
    for the layout contract; this class only moves them to device and runs
    the kernels)."""
    cols: jax.Array    # (S, R, K) int32, round-major coords
    vals: jax.Array    # (S, R, K)
    dinv: jax.Array    # (S, R)
    rows: jax.Array    # (S, R) int32 — HBMC index of each lane (pad-> n_slots-1)
    n_slots: int

    def tree_flatten(self):
        return (self.cols, self.vals, self.dinv, self.rows), (self.n_slots,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, n_slots=aux[0])

    @classmethod
    def from_host(cls, h: sell.RoundMajorTables,
                  dtype=jnp.float64) -> "DeviceRoundMajorTables":
        return cls(cols=jnp.asarray(h.cols),
                   vals=jnp.asarray(h.vals, dtype=dtype),
                   dinv=jnp.asarray(h.dinv, dtype=dtype),
                   rows=jnp.asarray(h.rows),
                   n_slots=h.n_slots)

    @classmethod
    def from_steps(cls, t: StepTables, dtype=jnp.float64) -> "DeviceRoundMajorTables":
        return cls.from_host(sell.to_round_major(t), dtype=dtype)

    def apply(self, q: jax.Array, *, use_kernel: bool = True,
              interpret: bool | None = None) -> jax.Array:
        """One triangular solve.  q, result: (n_slots-1,) in HBMC order."""
        qp = jnp.concatenate([q, jnp.zeros((1,), dtype=q.dtype)])
        q_rm = qp[self.rows]                         # (S, R)
        if use_kernel:
            y_rm = hbmc_trisolve(self.cols, self.vals, self.dinv, q_rm,
                                 interpret=interpret)
        else:
            y_rm = hbmc_trisolve_ref(self.cols, self.vals, self.dinv, q_rm)
        y = jnp.zeros((self.n_slots,), dtype=q.dtype)
        y = y.at[self.rows.reshape(-1)].set(y_rm)    # pad lanes hit slot -1
        return y[:-1]

    def apply_batched(self, q: jax.Array, *, use_kernel: bool = True,
                      interpret: bool | None = None) -> jax.Array:
        """Multi-RHS triangular solve.  q, result: (n_slots-1, B)."""
        qp = jnp.concatenate(
            [q, jnp.zeros((1, q.shape[1]), dtype=q.dtype)], axis=0)
        q_rm = qp[self.rows]                         # (S, R, B)
        if use_kernel:
            y_rm = hbmc_trisolve_batched(self.cols, self.vals, self.dinv,
                                         q_rm, interpret=interpret)
        else:
            y_rm = hbmc_trisolve_batched_ref(self.cols, self.vals, self.dinv,
                                             q_rm)
        y = jnp.zeros((self.n_slots, q.shape[1]), dtype=q.dtype)
        y = y.at[self.rows.reshape(-1)].set(y_rm)
        return y[:-1]


@dataclasses.dataclass(frozen=True)
class KernelPreconditioner:
    """IC(0) apply (L L^T)^{-1} using the Pallas kernels end to end."""
    fwd: DeviceRoundMajorTables
    bwd: DeviceRoundMajorTables
    use_kernel: bool = True
    interpret: bool | None = None

    def __call__(self, r: jax.Array) -> jax.Array:
        y = self.fwd.apply(r, use_kernel=self.use_kernel,
                           interpret=self.interpret)
        return self.bwd.apply(y, use_kernel=self.use_kernel,
                              interpret=self.interpret)

    def apply_batched(self, r: jax.Array) -> jax.Array:
        """Multi-RHS apply: r (n, B) -> (n, B)."""
        y = self.fwd.apply_batched(r, use_kernel=self.use_kernel,
                                   interpret=self.interpret)
        return self.bwd.apply_batched(y, use_kernel=self.use_kernel,
                                      interpret=self.interpret)


def build_kernel_preconditioner(fwd: StepTables, bwd: StepTables,
                                dtype=jnp.float64, use_kernel: bool = True,
                                interpret: bool | None = None
                                ) -> KernelPreconditioner:
    return KernelPreconditioner(
        fwd=DeviceRoundMajorTables.from_steps(fwd, dtype=dtype),
        bwd=DeviceRoundMajorTables.from_steps(bwd, dtype=dtype),
        use_kernel=use_kernel, interpret=interpret)
