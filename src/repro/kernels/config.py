"""Shared kernel configuration helpers.

``interpret`` used to default to ``True`` at every Pallas call site, which
meant real-TPU runs silently got the (slow) interpreter unless the caller
threaded ``interpret=False`` through every layer.  All kernel entry points
now take ``interpret=None`` and resolve it here: compiled on TPU,
interpreted everywhere else (CPU/GPU development and CI).
"""
from __future__ import annotations

import jax

# Slices per grid step of the SELL SpMV kernels (VMEM tile height): one
# tile is slice_tile * K * w values + as many int32 columns — ~0.5 MiB at
# the production K <= 32, w = 8, f32, far below VMEM alongside the
# resident x vector.
DEFAULT_SLICE_TILE = 256


def default_interpret() -> bool:
    """True iff Pallas kernels should run in interpret mode (no TPU)."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve an ``interpret`` argument: ``None`` -> backend default."""
    return default_interpret() if interpret is None else bool(interpret)
