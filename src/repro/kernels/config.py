"""Shared kernel configuration helpers.

``interpret`` used to default to ``True`` at every Pallas call site, which
meant real-TPU runs silently got the (slow) interpreter unless the caller
threaded ``interpret=False`` through every layer.  All kernel entry points
now take ``interpret=None`` and resolve it here: compiled on TPU,
interpreted everywhere else (CPU/GPU development and CI).
"""
from __future__ import annotations

import jax


def default_interpret() -> bool:
    """True iff Pallas kernels should run in interpret mode (no TPU)."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve an ``interpret`` argument: ``None`` -> backend default."""
    return default_interpret() if interpret is None else bool(interpret)
