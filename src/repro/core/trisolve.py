"""Vectorized forward/backward substitution over HBMC step tables (§4.3).

The solve is ``S = n_c * b_s`` sequential rounds; each round is a dense,
fully-parallel gather / fused-multiply-subtract / scale over all live lanes
(every level-1 block of the color x w lanes).  On TPU the per-round work is
pure VPU element-wise + gather; rounds are a ``lax.fori_loop`` so the HLO is
O(1) in problem size.

Two device backends, selected by ``build_preconditioner(..., backend=...)``:
  * ``"xla"``    — ``forward_solve`` / ``backward_solve``, pure jnp
    (``fori_loop`` + scatter), the production fallback and the oracle the
    Pallas kernel is validated against.
  * ``"pallas"`` — ``repro.kernels.hbmc_trisolve`` operating on the dense
    round-major repacking (``sell.to_round_major``), with explicit VMEM
    blocking; contiguous stores instead of scatters.  ``interpret``
    defaults from the runtime (compiled on TPU, interpreted elsewhere).

And two PCG-loop layouts:
  * ``HBMCPreconditioner`` (``layout="index"``) applies in permuted-matrix
    index space — the solve layout is re-gathered/scattered per apply.
  * ``RoundMajorPreconditioner`` (``layout="round_major"``, the default
    solver path) applies natively on round-major vectors with both sweeps
    fused into one 2S-step pass; zero per-apply permutations.

All variants expose a multi-RHS path (``apply_batched``) consumed by the
batched PCG front-end (``iccg.pcg_batched``).

``DistributedRoundMajorPreconditioner`` shards the fused round-major
apply over a device mesh axis (lane axis sharded, state replicated, one
all-gather per round — paper §4.4.3 one level up); ``SolverPlan`` wires
it in via ``build_plan(..., mesh=)``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .hbmc import HBMCOrdering
from .sell import (FusedRoundMajorTables, RoundMajorLayout, StepTables,
                   fuse_round_major, pack_factor_hbmc)

BACKENDS = ("xla", "pallas")
LAYOUTS = ("round_major", "index")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceTables:
    """StepTables moved to device as a pytree."""
    rows: jax.Array   # (S, R) int32
    cols: jax.Array   # (S, R, K) int32
    vals: jax.Array   # (S, R, K)
    dinv: jax.Array   # (S, R)
    n_slots: int

    def tree_flatten(self):
        return (self.rows, self.cols, self.vals, self.dinv), (self.n_slots,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, n_slots=aux[0])

    @classmethod
    def from_host(cls, t: StepTables, dtype=jnp.float64) -> "DeviceTables":
        return cls(rows=jnp.asarray(t.rows), cols=jnp.asarray(t.cols),
                   vals=jnp.asarray(t.vals, dtype=dtype),
                   dinv=jnp.asarray(t.dinv, dtype=dtype), n_slots=t.n_slots)


def _substitute(tables: DeviceTables, q: jax.Array,
                x0: jax.Array | None = None) -> jax.Array:
    """Run all rounds of one triangular solve.  q has length n_slots-1.

    With ``x0`` the vector starts from an existing iterate and the rounds
    overwrite it in place — this is a Gauss-Seidel sweep when the tables
    hold the FULL off-diagonal part of A (see gauss_seidel_sweep)."""
    n_slots = tables.n_slots
    if x0 is None:
        y0 = jnp.zeros((n_slots,), dtype=q.dtype)
    else:
        y0 = jnp.concatenate([x0, jnp.zeros((1,), dtype=q.dtype)])
    qp = jnp.concatenate([q, jnp.zeros((1,), dtype=q.dtype)])
    S = tables.rows.shape[0]

    def body(s, y):
        rows = tables.rows[s]                       # (R,)
        gathered = y[tables.cols[s]]                # (R, K)
        acc = jnp.einsum("rk,rk->r", tables.vals[s], gathered)
        t = (qp[rows] - acc) * tables.dinv[s]
        return y.at[rows].set(t)

    y = jax.lax.fori_loop(0, S, body, y0)
    return y[:-1]


def _substitute_batched(tables: DeviceTables, q: jax.Array) -> jax.Array:
    """Multi-RHS variant of ``_substitute``.  q: (n_slots-1, B).

    Per-column arithmetic follows the single-RHS path (same gather, same
    K-reduction) up to XLA's reassociation of the einsum, so each column
    agrees with the corresponding single-RHS solve to rounding — tight
    enough that batched PCG reproduces single-RHS iteration counts.
    """
    n_slots = tables.n_slots
    b = q.shape[1]
    y0 = jnp.zeros((n_slots, b), dtype=q.dtype)
    qp = jnp.concatenate([q, jnp.zeros((1, b), dtype=q.dtype)], axis=0)
    S = tables.rows.shape[0]

    def body(s, y):
        rows = tables.rows[s]                       # (R,)
        gathered = y[tables.cols[s]]                # (R, K, B)
        acc = jnp.einsum("rk,rkb->rb", tables.vals[s], gathered)
        t = (qp[rows] - acc) * tables.dinv[s][:, None]
        return y.at[rows].set(t)

    y = jax.lax.fori_loop(0, S, body, y0)
    return y[:-1]


@jax.jit
def forward_solve(tables: DeviceTables, q: jax.Array) -> jax.Array:
    """y = L^{-1} q over the packed forward tables (eq. 4.12-4.18)."""
    return _substitute(tables, q)


@jax.jit
def backward_solve(tables: DeviceTables, y: jax.Array) -> jax.Array:
    """z = L^{-T} y over the packed backward tables."""
    return _substitute(tables, y)


@jax.jit
def forward_solve_batched(tables: DeviceTables, q: jax.Array) -> jax.Array:
    """Y = L^{-1} Q over the packed forward tables.  Q: (n, B)."""
    return _substitute_batched(tables, q)


@jax.jit
def backward_solve_batched(tables: DeviceTables, y: jax.Array) -> jax.Array:
    """Z = L^{-T} Y over the packed backward tables.  Y: (n, B)."""
    return _substitute_batched(tables, y)


# ---------------------------------------------------------------------------
# Round-major-native path: the PCG state itself lives in round-major
# coordinates, so the preconditioner apply performs ZERO permutations and
# both sweeps run as one fused pass (2S steps over one buffer).
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceFusedTables:
    """sell.FusedRoundMajorTables moved to device as a pytree.

    Row ``g`` of each array drives fused step ``g``: forward rounds for
    ``g < S``, backward rounds (backward execution order) for ``g >= S``.
    """
    cols: jax.Array   # (2S, R, K) int32 — fwd-round-major gather positions
    vals: jax.Array   # (2S, R, K)
    dinv: jax.Array   # (2S, R)

    def tree_flatten(self):
        return (self.cols, self.vals, self.dinv), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n_steps(self) -> int:
        """Rounds per sweep (the fused loop runs 2 * n_steps steps)."""
        return self.dinv.shape[0] // 2

    @property
    def lanes(self) -> int:
        return self.dinv.shape[1]

    @classmethod
    def from_host(cls, f: FusedRoundMajorTables,
                  dtype=jnp.float64) -> "DeviceFusedTables":
        return cls(cols=jnp.asarray(f.cols),
                   vals=jnp.asarray(f.vals, dtype=dtype),
                   dinv=jnp.asarray(f.dinv, dtype=dtype))


def _substitute_fused(tables: DeviceFusedTables, q: jax.Array) -> jax.Array:
    """Fused fwd+bwd substitution in round-major coordinates.  q: (S, R).

    The round-major ``_substitute``: each step's store is a dense
    ``lax.dynamic_update_slice`` instead of the ``y.at[rows].set`` scatter
    of the index-space path — the backward half overwrites the forward
    result in place, in reverse slice order (see kernels/hbmc_trisolve.py
    for the safety argument).  Zero scatter ops in the jaxpr.
    """
    s_, r_ = q.shape
    s2 = 2 * s_
    y0 = jnp.zeros((s_ * r_,), dtype=q.dtype)

    def body(g, y):
        gathered = jnp.take(y, tables.cols[g], axis=0, fill_value=0)  # (R, K)
        # einsum (not elementwise-multiply + sum): XLA contracts it directly
        # instead of materializing the product — measurably faster on CPU.
        # The kernel-exact op order lives in kernels/ref.py instead.
        acc = jnp.einsum("rk,rk->r", tables.vals[g], gathered)
        dest = jnp.where(g < s_, g, s2 - 1 - g) * r_
        q_cur = jnp.where(g < s_, q[jnp.minimum(g, s_ - 1)],
                          jax.lax.dynamic_slice(y, (dest,), (r_,)))
        t = (q_cur - acc) * tables.dinv[g]
        return jax.lax.dynamic_update_slice(y, t, (dest,))

    return jax.lax.fori_loop(0, s2, body, y0)


def _substitute_fused_batched(tables: DeviceFusedTables,
                              q: jax.Array) -> jax.Array:
    """Multi-RHS fused substitution.  q: (S, R, B) -> (S*R, B)."""
    s_, r_, b_ = q.shape
    s2 = 2 * s_
    y0 = jnp.zeros((s_ * r_, b_), dtype=q.dtype)

    def body(g, y):
        gathered = jnp.take(y, tables.cols[g], axis=0, fill_value=0)
        acc = jnp.einsum("rk,rkb->rb", tables.vals[g], gathered)
        dest = jnp.where(g < s_, g, s2 - 1 - g) * r_
        q_cur = jnp.where(g < s_, q[jnp.minimum(g, s_ - 1)],
                          jax.lax.dynamic_slice(y, (dest, jnp.zeros_like(dest)), (r_, b_)))
        t = (q_cur - acc) * tables.dinv[g][:, None]
        return jax.lax.dynamic_update_slice(y, t, (dest, jnp.zeros_like(dest)))

    return jax.lax.fori_loop(0, s2, body, y0)


@jax.jit
def fused_solve(tables: DeviceFusedTables, q: jax.Array) -> jax.Array:
    """z = (L L^T)^{-1} q, round-major in and out.  q: (S, R) -> (S*R,)."""
    return _substitute_fused(tables, q)


@jax.jit
def fused_solve_batched(tables: DeviceFusedTables, q: jax.Array) -> jax.Array:
    """Multi-RHS fused apply.  q: (S, R, B) -> (S*R, B)."""
    return _substitute_fused_batched(tables, q)


# ---------------------------------------------------------------------------
# Mesh-sharded fused substitution: the lane axis R is sharded over one mesh
# axis, the solution vector is replicated, and each fused step ends in ONE
# tiled all-gather of the lane updates — the distributed analogue of the
# paper's "one synchronization per color" (§4.4.3), one level up: level-1
# blocks -> devices, w lanes -> the vector unit within a device.
# ---------------------------------------------------------------------------

def _dist_substitute_fused(mesh: Mesh, axis: str, m: int,
                           cols: jax.Array, vals: jax.Array,
                           dinv: jax.Array, q: jax.Array,
                           batched: bool) -> jax.Array:
    """Fused fwd+bwd sweep with the lane axis sharded over ``axis``.

    ``cols``/``vals``: (2S, R, K) with R a multiple of the axis size;
    ``dinv``: (2S, R); ``q``: (S, R) (or (S, R, B)).  Per fused step, every
    device computes its own lane block's updates (gathering from its
    replica of y) and one ``all_gather(tiled=True)`` assembles the round's
    dense slice before the store — the per-lane arithmetic is exactly
    ``_substitute_fused``'s, so results are bitwise identical to the
    single-device sweep over the same tables.
    """
    r_full = dinv.shape[1]
    t_spec = (P(None, axis, None), P(None, axis, None), P(None, axis))
    q_spec = P(None, axis, None) if batched else P(None, axis)

    @partial(shard_map, mesh=mesh, in_specs=t_spec + (q_spec,),
             out_specs=P(), check_rep=False)
    def solve(cols_l, vals_l, dinv_l, q_l):
        s_ = q_l.shape[0]
        r_loc = dinv_l.shape[1]
        s2 = 2 * s_
        tail = q_l.shape[2:]                      # () or (B,)
        y0 = jnp.zeros((m,) + tail, dtype=q_l.dtype)
        i = jax.lax.axis_index(axis)
        eq = "rk,rkb->rb" if batched else "rk,rk->r"

        def body(g, y):
            gathered = jnp.take(y, cols_l[g], axis=0, fill_value=0)
            acc = jnp.einsum(eq, vals_l[g], gathered)
            # pin the index dtype: the loop counter is weakly typed and
            # axis_index is i32 — mixing them flips dtypes between the
            # dynamic_slice index operands
            dest = (jnp.where(g < s_, g, s2 - 1 - g) * r_full
                    ).astype(jnp.int32)
            zeros = (jnp.zeros_like(dest),) * len(tail)
            # forward half reads its lane block of q; backward half reads
            # the y slice it is about to overwrite (see _substitute_fused)
            q_cur = jnp.where(
                g < s_, q_l[jnp.minimum(g, s_ - 1)],
                jax.lax.dynamic_slice(
                    y, (dest + i * r_loc,) + zeros, (r_loc,) + tail))
            d = dinv_l[g][:, None] if batched else dinv_l[g]
            t = (q_cur - acc) * d
            t_full = jax.lax.all_gather(t, axis, tiled=True)
            return jax.lax.dynamic_update_slice(y, t_full, (dest,) + zeros)

        return jax.lax.fori_loop(0, s2, body, y0)

    return solve(cols, vals, dinv, q)


@dataclasses.dataclass(frozen=True)
class DistributedRoundMajorPreconditioner:
    """``RoundMajorPreconditioner`` sharded over a device mesh axis.

    ``tables`` hold the fused round-major form with the LANE axis sharded
    over ``mesh``/``axis`` (``NamedSharding(mesh, P(None, axis, None))``
    for cols/vals, ``P(None, axis)`` for dinv) — the heavy data is fully
    distributed; the (m,) state vectors stay replicated.  The apply is the
    fused single-pass 2S-step sweep with one collective per round.
    """
    tables: DeviceFusedTables
    mesh: Mesh
    axis: str = "data"

    @property
    def n_rounds(self) -> int:
        return self.tables.n_steps

    @property
    def m(self) -> int:
        return self.tables.n_steps * self.tables.lanes

    def _reshape(self, r: jax.Array, batched: bool) -> jax.Array:
        s_, lanes = self.tables.n_steps, self.tables.lanes
        shape = (s_, lanes) + ((r.shape[-1],) if batched else ())
        return r.reshape(shape)

    def __call__(self, r: jax.Array) -> jax.Array:
        t = self.tables
        return _dist_substitute_fused(self.mesh, self.axis, self.m, t.cols,
                                      t.vals, t.dinv,
                                      self._reshape(r, batched=False),
                                      batched=False)

    def apply_batched(self, r: jax.Array) -> jax.Array:
        t = self.tables
        return _dist_substitute_fused(self.mesh, self.axis, self.m, t.cols,
                                      t.vals, t.dinv,
                                      self._reshape(r, batched=True),
                                      batched=True)


def shard_fused_tables(tables: DeviceFusedTables, mesh: Mesh,
                       axis: str = "data") -> DeviceFusedTables:
    """Place fused tables with the lane axis sharded over ``axis``.

    The lane axis must already be a multiple of the axis size — build the
    plan/tables with ``lane_multiple = mesh.shape[axis]``
    (``pack_steps(..., lane_multiple=...)``) rather than re-padding here,
    so every round-major position stays valid.
    """
    n_dev = mesh.shape[axis]
    if tables.lanes % n_dev != 0:
        raise ValueError(
            f"lane axis ({tables.lanes}) is not a multiple of mesh axis "
            f"{axis!r} ({n_dev}); pack with lane_multiple={n_dev}")
    sh3 = NamedSharding(mesh, P(None, axis, None))
    sh2 = NamedSharding(mesh, P(None, axis))
    return DeviceFusedTables(cols=jax.device_put(tables.cols, sh3),
                             vals=jax.device_put(tables.vals, sh3),
                             dinv=jax.device_put(tables.dinv, sh2))


@dataclasses.dataclass(frozen=True)
class RoundMajorPreconditioner:
    """IC(0) apply operating natively on round-major (m,) state vectors.

    Unlike ``HBMCPreconditioner`` (which gathers/scatters between index
    space and the solve layout on every apply), this preconditioner's input
    and output ARE round-major: the only permutations of a solve happen in
    ``RoundMajorLayout.embed``/``extract``, once each, outside the PCG loop.

    ``backend="xla"`` runs ``fused_solve`` (fori_loop, dynamic slices);
    ``backend="pallas"`` runs ``kernels.hbmc_trisolve_fused`` (one
    pallas_call, 2S-step sequential grid, y VMEM-resident across sweeps).
    """
    tables: DeviceFusedTables
    backend: str = "xla"
    interpret: bool | None = None

    @property
    def n_rounds(self) -> int:
        return self.tables.n_steps

    @property
    def m(self) -> int:
        return self.tables.n_steps * self.tables.lanes

    def _reshape(self, r: jax.Array, batched: bool) -> jax.Array:
        s_, lanes = self.tables.n_steps, self.tables.lanes
        shape = (s_, lanes) + ((r.shape[-1],) if batched else ())
        return r.reshape(shape)

    def __call__(self, r: jax.Array) -> jax.Array:
        q = self._reshape(r, batched=False)
        if self.backend == "pallas":
            from repro.kernels.hbmc_trisolve import hbmc_trisolve_fused
            return hbmc_trisolve_fused(self.tables.cols, self.tables.vals,
                                       self.tables.dinv, q,
                                       interpret=self.interpret)
        return fused_solve(self.tables, q)

    def apply_batched(self, r: jax.Array) -> jax.Array:
        q = self._reshape(r, batched=True)
        if self.backend == "pallas":
            from repro.kernels.hbmc_trisolve import hbmc_trisolve_fused_batched
            return hbmc_trisolve_fused_batched(
                self.tables.cols, self.tables.vals, self.tables.dinv, q,
                interpret=self.interpret)
        return fused_solve_batched(self.tables, q)


def build_round_major_preconditioner_from_rounds(
        l_final: sp.csr_matrix, fwd_rounds, bwd_rounds, drop_mask=None,
        dtype=jnp.float64, backend: str = "xla",
        interpret: bool | None = None, lane_multiple: int = 1
        ) -> tuple[RoundMajorPreconditioner, RoundMajorLayout]:
    """Pack a factor into the fused round-major form; returns the native
    preconditioner plus the layout (the b-in / x-out permutation pair).

    ``lane_multiple`` pads the lane axis so it shards evenly over a mesh
    axis of that size (see ``DistributedRoundMajorPreconditioner``)."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of "
                         f"{BACKENDS}")
    from .sell import pack_factor
    fwd_h, bwd_h = pack_factor(l_final, fwd_rounds, bwd_rounds, drop_mask,
                               lane_multiple)
    fused_h = fuse_round_major(fwd_h, bwd_h)
    pre = RoundMajorPreconditioner(
        tables=DeviceFusedTables.from_host(fused_h, dtype=dtype),
        backend=backend, interpret=interpret)
    return pre, fused_h.layout


def build_round_major_preconditioner(
        l_final: sp.csr_matrix, ordering: HBMCOrdering, dtype=jnp.float64,
        backend: str = "xla", interpret: bool | None = None
        ) -> tuple[RoundMajorPreconditioner, RoundMajorLayout]:
    from .sell import rounds_hbmc
    return build_round_major_preconditioner_from_rounds(
        l_final, rounds_hbmc(ordering, reverse=False),
        rounds_hbmc(ordering, reverse=True), drop_mask=ordering.is_dummy,
        dtype=dtype, backend=backend, interpret=interpret)


@dataclasses.dataclass(frozen=True)
class HBMCPreconditioner:
    """IC(0) preconditioner  M^{-1} r = (L L^T)^{-1} r  in HBMC order.

    ``backend`` selects the triangular-solve implementation:
      * ``"xla"``    — fori_loop substitution over ``fwd``/``bwd``
        (``kernel`` is None);
      * ``"pallas"`` — the round-major Pallas kernel held in ``kernel``
        (a ``repro.kernels.ops.KernelPreconditioner``); ``fwd``/``bwd``
        are None so the (S, R, K) tables live on device only once.  The
        legacy index-space dry-run path (core.partition.shard_tables /
        lower_solver_step) consumes DeviceTables, i.e. the "xla" layout;
        the production distributed apply is
        ``DistributedRoundMajorPreconditioner``.
    """
    fwd: DeviceTables | None
    bwd: DeviceTables | None
    n_final: int
    backend: str = "xla"
    kernel: Any = None

    @property
    def n_rounds(self) -> int:
        t = self.fwd if self.fwd is not None else self.kernel.fwd
        return int(t.rows.shape[0])

    def __call__(self, r: jax.Array) -> jax.Array:
        if self.backend == "pallas":
            return self.kernel(r)
        y = forward_solve(self.fwd, r)
        return backward_solve(self.bwd, y)

    def apply_batched(self, r: jax.Array) -> jax.Array:
        """Multi-RHS apply: r (n, B) -> (n, B), columns independent."""
        if self.backend == "pallas":
            return self.kernel.apply_batched(r)
        y = forward_solve_batched(self.fwd, r)
        return backward_solve_batched(self.bwd, y)


def _assemble_preconditioner(fwd_h: StepTables, bwd_h: StepTables,
                             n_final: int, dtype, backend: str,
                             interpret: bool | None) -> HBMCPreconditioner:
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of "
                         f"{BACKENDS}")
    if backend == "pallas":
        # deferred import: repro.kernels.ops itself imports repro.core.sell
        from repro.kernels.ops import build_kernel_preconditioner
        kernel = build_kernel_preconditioner(fwd_h, bwd_h, dtype=dtype,
                                             use_kernel=True,
                                             interpret=interpret)
        return HBMCPreconditioner(fwd=None, bwd=None, n_final=n_final,
                                  backend=backend, kernel=kernel)
    return HBMCPreconditioner(
        fwd=DeviceTables.from_host(fwd_h, dtype=dtype),
        bwd=DeviceTables.from_host(bwd_h, dtype=dtype),
        n_final=n_final, backend=backend, kernel=None)


def build_preconditioner(l_final: sp.csr_matrix, ordering: HBMCOrdering,
                         dtype=jnp.float64, backend: str = "xla",
                         interpret: bool | None = None) -> HBMCPreconditioner:
    fwd_h, bwd_h = pack_factor_hbmc(l_final, ordering)
    return _assemble_preconditioner(fwd_h, bwd_h, ordering.n_final, dtype,
                                    backend, interpret)


def build_preconditioner_from_rounds(
        l_final: sp.csr_matrix, fwd_rounds, bwd_rounds,
        drop_mask=None, dtype=jnp.float64, backend: str = "xla",
        interpret: bool | None = None) -> HBMCPreconditioner:
    """Generic variant: MC / BMC / natural solvers share the machinery."""
    from .sell import pack_factor
    fwd_h, bwd_h = pack_factor(l_final, fwd_rounds, bwd_rounds, drop_mask)
    return _assemble_preconditioner(fwd_h, bwd_h, l_final.shape[0], dtype,
                                    backend, interpret)


# ---------------------------------------------------------------------------
# Sequential oracle (host) — used by tests to pin down exact semantics.
# ---------------------------------------------------------------------------

def sequential_forward(l: sp.csr_matrix, q: np.ndarray) -> np.ndarray:
    return sp.linalg.spsolve_triangular(sp.csr_matrix(l), q, lower=True)


def sequential_backward(l: sp.csr_matrix, y: np.ndarray) -> np.ndarray:
    return sp.linalg.spsolve_triangular(sp.csr_matrix(l).T.tocsr(), y,
                                        lower=False)
