"""Vectorized forward/backward substitution over HBMC step tables (§4.3).

The solve is ``S = n_c * b_s`` sequential rounds; each round is a dense,
fully-parallel gather / fused-multiply-subtract / scale over all live lanes
(every level-1 block of the color x w lanes).  On TPU the per-round work is
pure VPU element-wise + gather; rounds are a ``lax.fori_loop`` so the HLO is
O(1) in problem size.

Two device paths:
  * ``forward_solve`` / ``backward_solve`` — pure jnp (XLA), the production
    fallback and the oracle for the Pallas kernel.
  * ``repro.kernels.hbmc_trisolve`` — Pallas kernel with explicit VMEM
    blocking (see kernels/), validated against this module.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from .hbmc import HBMCOrdering
from .sell import StepTables, pack_factor_hbmc


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceTables:
    """StepTables moved to device as a pytree."""
    rows: jax.Array   # (S, R) int32
    cols: jax.Array   # (S, R, K) int32
    vals: jax.Array   # (S, R, K)
    dinv: jax.Array   # (S, R)
    n_slots: int

    def tree_flatten(self):
        return (self.rows, self.cols, self.vals, self.dinv), (self.n_slots,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, n_slots=aux[0])

    @classmethod
    def from_host(cls, t: StepTables, dtype=jnp.float64) -> "DeviceTables":
        return cls(rows=jnp.asarray(t.rows), cols=jnp.asarray(t.cols),
                   vals=jnp.asarray(t.vals, dtype=dtype),
                   dinv=jnp.asarray(t.dinv, dtype=dtype), n_slots=t.n_slots)


def _substitute(tables: DeviceTables, q: jax.Array,
                x0: jax.Array | None = None) -> jax.Array:
    """Run all rounds of one triangular solve.  q has length n_slots-1.

    With ``x0`` the vector starts from an existing iterate and the rounds
    overwrite it in place — this is a Gauss-Seidel sweep when the tables
    hold the FULL off-diagonal part of A (see gauss_seidel_sweep)."""
    n_slots = tables.n_slots
    if x0 is None:
        y0 = jnp.zeros((n_slots,), dtype=q.dtype)
    else:
        y0 = jnp.concatenate([x0, jnp.zeros((1,), dtype=q.dtype)])
    qp = jnp.concatenate([q, jnp.zeros((1,), dtype=q.dtype)])
    S = tables.rows.shape[0]

    def body(s, y):
        rows = tables.rows[s]                       # (R,)
        gathered = y[tables.cols[s]]                # (R, K)
        acc = jnp.einsum("rk,rk->r", tables.vals[s], gathered)
        t = (qp[rows] - acc) * tables.dinv[s]
        return y.at[rows].set(t)

    y = jax.lax.fori_loop(0, S, body, y0)
    return y[:-1]


@jax.jit
def forward_solve(tables: DeviceTables, q: jax.Array) -> jax.Array:
    """y = L^{-1} q over the packed forward tables (eq. 4.12-4.18)."""
    return _substitute(tables, q)


@jax.jit
def backward_solve(tables: DeviceTables, y: jax.Array) -> jax.Array:
    """z = L^{-T} y over the packed backward tables."""
    return _substitute(tables, y)


@dataclasses.dataclass(frozen=True)
class HBMCPreconditioner:
    """IC(0) preconditioner  M^{-1} r = (L L^T)^{-1} r  in HBMC order."""
    fwd: DeviceTables
    bwd: DeviceTables
    n_final: int

    def __call__(self, r: jax.Array) -> jax.Array:
        y = forward_solve(self.fwd, r)
        return backward_solve(self.bwd, y)


def build_preconditioner(l_final: sp.csr_matrix, ordering: HBMCOrdering,
                         dtype=jnp.float64) -> HBMCPreconditioner:
    fwd_h, bwd_h = pack_factor_hbmc(l_final, ordering)
    return HBMCPreconditioner(
        fwd=DeviceTables.from_host(fwd_h, dtype=dtype),
        bwd=DeviceTables.from_host(bwd_h, dtype=dtype),
        n_final=ordering.n_final)


def build_preconditioner_from_rounds(
        l_final: sp.csr_matrix, fwd_rounds, bwd_rounds,
        drop_mask=None, dtype=jnp.float64) -> HBMCPreconditioner:
    """Generic variant: MC / BMC / natural solvers share the machinery."""
    from .sell import pack_factor
    fwd_h, bwd_h = pack_factor(l_final, fwd_rounds, bwd_rounds, drop_mask)
    return HBMCPreconditioner(
        fwd=DeviceTables.from_host(fwd_h, dtype=dtype),
        bwd=DeviceTables.from_host(bwd_h, dtype=dtype),
        n_final=l_final.shape[0])


# ---------------------------------------------------------------------------
# Sequential oracle (host) — used by tests to pin down exact semantics.
# ---------------------------------------------------------------------------

def sequential_forward(l: sp.csr_matrix, q: np.ndarray) -> np.ndarray:
    return sp.linalg.spsolve_triangular(sp.csr_matrix(l), q, lower=True)


def sequential_backward(l: sp.csr_matrix, y: np.ndarray) -> np.ndarray:
    return sp.linalg.spsolve_triangular(sp.csr_matrix(l).T.tocsr(), y,
                                        lower=False)
