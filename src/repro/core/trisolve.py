"""Vectorized forward/backward substitution over HBMC step tables (§4.3).

The solve is ``S = n_c * b_s`` sequential rounds; each round is a dense,
fully-parallel gather / fused-multiply-subtract / scale over all live lanes
(every level-1 block of the color x w lanes).  On TPU the per-round work is
pure VPU element-wise + gather; rounds are a ``lax.fori_loop`` so the HLO is
O(1) in problem size.

Two device backends, selected by ``build_preconditioner(..., backend=...)``:
  * ``"xla"``    — ``forward_solve`` / ``backward_solve``, pure jnp
    (``fori_loop`` + scatter), the production fallback and the oracle the
    Pallas kernel is validated against.
  * ``"pallas"`` — ``repro.kernels.hbmc_trisolve`` operating on the dense
    round-major repacking (``sell.to_round_major``), with explicit VMEM
    blocking; contiguous stores instead of scatters.  Pass
    ``interpret=False`` on real TPU hardware.

Both backends expose a multi-RHS path (``apply_batched``) consumed by the
batched PCG front-end (``iccg.pcg_batched``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from .hbmc import HBMCOrdering
from .sell import StepTables, pack_factor_hbmc

BACKENDS = ("xla", "pallas")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceTables:
    """StepTables moved to device as a pytree."""
    rows: jax.Array   # (S, R) int32
    cols: jax.Array   # (S, R, K) int32
    vals: jax.Array   # (S, R, K)
    dinv: jax.Array   # (S, R)
    n_slots: int

    def tree_flatten(self):
        return (self.rows, self.cols, self.vals, self.dinv), (self.n_slots,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, n_slots=aux[0])

    @classmethod
    def from_host(cls, t: StepTables, dtype=jnp.float64) -> "DeviceTables":
        return cls(rows=jnp.asarray(t.rows), cols=jnp.asarray(t.cols),
                   vals=jnp.asarray(t.vals, dtype=dtype),
                   dinv=jnp.asarray(t.dinv, dtype=dtype), n_slots=t.n_slots)


def _substitute(tables: DeviceTables, q: jax.Array,
                x0: jax.Array | None = None) -> jax.Array:
    """Run all rounds of one triangular solve.  q has length n_slots-1.

    With ``x0`` the vector starts from an existing iterate and the rounds
    overwrite it in place — this is a Gauss-Seidel sweep when the tables
    hold the FULL off-diagonal part of A (see gauss_seidel_sweep)."""
    n_slots = tables.n_slots
    if x0 is None:
        y0 = jnp.zeros((n_slots,), dtype=q.dtype)
    else:
        y0 = jnp.concatenate([x0, jnp.zeros((1,), dtype=q.dtype)])
    qp = jnp.concatenate([q, jnp.zeros((1,), dtype=q.dtype)])
    S = tables.rows.shape[0]

    def body(s, y):
        rows = tables.rows[s]                       # (R,)
        gathered = y[tables.cols[s]]                # (R, K)
        acc = jnp.einsum("rk,rk->r", tables.vals[s], gathered)
        t = (qp[rows] - acc) * tables.dinv[s]
        return y.at[rows].set(t)

    y = jax.lax.fori_loop(0, S, body, y0)
    return y[:-1]


def _substitute_batched(tables: DeviceTables, q: jax.Array) -> jax.Array:
    """Multi-RHS variant of ``_substitute``.  q: (n_slots-1, B).

    Per-column arithmetic follows the single-RHS path (same gather, same
    K-reduction) up to XLA's reassociation of the einsum, so each column
    agrees with the corresponding single-RHS solve to rounding — tight
    enough that batched PCG reproduces single-RHS iteration counts.
    """
    n_slots = tables.n_slots
    b = q.shape[1]
    y0 = jnp.zeros((n_slots, b), dtype=q.dtype)
    qp = jnp.concatenate([q, jnp.zeros((1, b), dtype=q.dtype)], axis=0)
    S = tables.rows.shape[0]

    def body(s, y):
        rows = tables.rows[s]                       # (R,)
        gathered = y[tables.cols[s]]                # (R, K, B)
        acc = jnp.einsum("rk,rkb->rb", tables.vals[s], gathered)
        t = (qp[rows] - acc) * tables.dinv[s][:, None]
        return y.at[rows].set(t)

    y = jax.lax.fori_loop(0, S, body, y0)
    return y[:-1]


@jax.jit
def forward_solve(tables: DeviceTables, q: jax.Array) -> jax.Array:
    """y = L^{-1} q over the packed forward tables (eq. 4.12-4.18)."""
    return _substitute(tables, q)


@jax.jit
def backward_solve(tables: DeviceTables, y: jax.Array) -> jax.Array:
    """z = L^{-T} y over the packed backward tables."""
    return _substitute(tables, y)


@jax.jit
def forward_solve_batched(tables: DeviceTables, q: jax.Array) -> jax.Array:
    """Y = L^{-1} Q over the packed forward tables.  Q: (n, B)."""
    return _substitute_batched(tables, q)


@jax.jit
def backward_solve_batched(tables: DeviceTables, y: jax.Array) -> jax.Array:
    """Z = L^{-T} Y over the packed backward tables.  Y: (n, B)."""
    return _substitute_batched(tables, y)


@dataclasses.dataclass(frozen=True)
class HBMCPreconditioner:
    """IC(0) preconditioner  M^{-1} r = (L L^T)^{-1} r  in HBMC order.

    ``backend`` selects the triangular-solve implementation:
      * ``"xla"``    — fori_loop substitution over ``fwd``/``bwd``
        (``kernel`` is None);
      * ``"pallas"`` — the round-major Pallas kernel held in ``kernel``
        (a ``repro.kernels.ops.KernelPreconditioner``); ``fwd``/``bwd``
        are None so the (S, R, K) tables live on device only once.  The
        sharded path (core.partition) consumes DeviceTables, i.e. the
        "xla" layout.
    """
    fwd: DeviceTables | None
    bwd: DeviceTables | None
    n_final: int
    backend: str = "xla"
    kernel: Any = None

    @property
    def n_rounds(self) -> int:
        t = self.fwd if self.fwd is not None else self.kernel.fwd
        return int(t.rows.shape[0])

    def __call__(self, r: jax.Array) -> jax.Array:
        if self.backend == "pallas":
            return self.kernel(r)
        y = forward_solve(self.fwd, r)
        return backward_solve(self.bwd, y)

    def apply_batched(self, r: jax.Array) -> jax.Array:
        """Multi-RHS apply: r (n, B) -> (n, B), columns independent."""
        if self.backend == "pallas":
            return self.kernel.apply_batched(r)
        y = forward_solve_batched(self.fwd, r)
        return backward_solve_batched(self.bwd, y)


def _assemble_preconditioner(fwd_h: StepTables, bwd_h: StepTables,
                             n_final: int, dtype, backend: str,
                             interpret: bool) -> HBMCPreconditioner:
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of "
                         f"{BACKENDS}")
    if backend == "pallas":
        # deferred import: repro.kernels.ops itself imports repro.core.sell
        from repro.kernels.ops import build_kernel_preconditioner
        kernel = build_kernel_preconditioner(fwd_h, bwd_h, dtype=dtype,
                                             use_kernel=True,
                                             interpret=interpret)
        return HBMCPreconditioner(fwd=None, bwd=None, n_final=n_final,
                                  backend=backend, kernel=kernel)
    return HBMCPreconditioner(
        fwd=DeviceTables.from_host(fwd_h, dtype=dtype),
        bwd=DeviceTables.from_host(bwd_h, dtype=dtype),
        n_final=n_final, backend=backend, kernel=None)


def build_preconditioner(l_final: sp.csr_matrix, ordering: HBMCOrdering,
                         dtype=jnp.float64, backend: str = "xla",
                         interpret: bool = True) -> HBMCPreconditioner:
    fwd_h, bwd_h = pack_factor_hbmc(l_final, ordering)
    return _assemble_preconditioner(fwd_h, bwd_h, ordering.n_final, dtype,
                                    backend, interpret)


def build_preconditioner_from_rounds(
        l_final: sp.csr_matrix, fwd_rounds, bwd_rounds,
        drop_mask=None, dtype=jnp.float64, backend: str = "xla",
        interpret: bool = True) -> HBMCPreconditioner:
    """Generic variant: MC / BMC / natural solvers share the machinery."""
    from .sell import pack_factor
    fwd_h, bwd_h = pack_factor(l_final, fwd_rounds, bwd_rounds, drop_mask)
    return _assemble_preconditioner(fwd_h, bwd_h, l_final.shape[0], dtype,
                                    backend, interpret)


# ---------------------------------------------------------------------------
# Sequential oracle (host) — used by tests to pin down exact semantics.
# ---------------------------------------------------------------------------

def sequential_forward(l: sp.csr_matrix, q: np.ndarray) -> np.ndarray:
    return sp.linalg.spsolve_triangular(sp.csr_matrix(l), q, lower=True)


def sequential_backward(l: sp.csr_matrix, y: np.ndarray) -> np.ndarray:
    return sp.linalg.spsolve_triangular(sp.csr_matrix(l).T.tocsr(), y,
                                        lower=False)
