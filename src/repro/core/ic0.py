"""Shifted IC(0) — zero-fill incomplete Cholesky factorization (paper §2).

A ~= L L^T where L is lower triangular with the same nonzero pattern as the
lower triangular part of A.  The *shifted* variant factorizes
diag-scaled  A + alpha diag(A)  (paper §5.1 uses alpha = 0.3 for Ieej) which
guards against breakdown on semi-definite systems.

This is host-side setup code (numpy; one-time cost amortized over the CG
iterations), exactly as the reordering itself.  The factor is returned in CSR
so the SELL packing (``sell.py``) can slice it per HBMC step.
"""
from __future__ import annotations

import numpy as np
import scipy.sparse as sp


def ic0(a: sp.spmatrix, shift: float = 0.0, breakdown_eps: float = 1e-13
        ) -> sp.csr_matrix:
    """Return L (CSR, lower triangular incl. diagonal) with A ~= L L^T.

    Row-oriented up-looking factorization restricted to pattern(tril(A)).
    Sorted-merge intersection of row patterns keeps it O(sum row^2) which is
    fine for the stencil-type matrices used in the paper.
    """
    a = sp.csr_matrix(a).astype(np.float64)
    n = a.shape[0]
    low = sp.tril(a, format="csr")
    low.sort_indices()
    indptr, indices, data = low.indptr, low.indices, low.data.copy()
    if shift != 0.0:
        diag = a.diagonal()
        for i in range(n):
            last = indptr[i + 1] - 1
            # diagonal is the last entry of the sorted lower row
            data[last] = diag[i] * (1.0 + shift)

    # L rows stored as (col array, val array), built in place over `data`
    lcols: list[np.ndarray] = [None] * n  # type: ignore[list-item]
    lvals: list[np.ndarray] = [None] * n  # type: ignore[list-item]
    diag_l = np.empty(n, dtype=np.float64)

    for i in range(n):
        s, e = indptr[i], indptr[i + 1]
        cols_i = indices[s:e]
        vals_i = data[s:e]
        if cols_i[-1] != i:
            raise ValueError(f"missing diagonal in row {i}")
        row_vals = np.empty(e - s, dtype=np.float64)
        for t in range(e - s):
            j = cols_i[t]
            v = vals_i[t]
            # v -= sum_k l_ik * l_jk over shared k < j (merge of sorted rows)
            cj, vj = (lcols[j], lvals[j]) if j < i else (cols_i[:t], row_vals[:t])
            ci, vi = cols_i[:t], row_vals[:t]
            pi = pj = 0
            acc = 0.0
            li, lj = len(ci), len(cj)
            while pi < li and pj < lj:
                a_, b_ = ci[pi], cj[pj]
                if a_ == b_:
                    if a_ >= j:
                        break
                    acc += vi[pi] * vj[pj]
                    pi += 1; pj += 1
                elif a_ < b_:
                    pi += 1
                else:
                    pj += 1
            v -= acc
            if j < i:
                row_vals[t] = v / diag_l[j]
            else:  # diagonal
                if v <= breakdown_eps:
                    v = breakdown_eps  # breakdown guard
                row_vals[t] = np.sqrt(v)
                diag_l[i] = row_vals[t]
        lcols[i] = cols_i
        lvals[i] = row_vals
        data[s:e] = row_vals

    return sp.csr_matrix((data, indices, indptr), shape=(n, n))


def ic0_error(a: sp.spmatrix, l: sp.csr_matrix) -> float:
    """|| proj_pattern(A - L L^T) ||_F / ||A||_F — zero for exact IC(0) on the
    pattern (sanity check used by tests)."""
    a = sp.csr_matrix(a).astype(np.float64)
    prod = (l @ l.T).tocsr()
    pattern = (a != 0)
    diff = (a - prod.multiply(pattern))
    return float(sp.linalg.norm(diff) / sp.linalg.norm(a))


def sequential_ic_solve(l: sp.csr_matrix, r: np.ndarray) -> np.ndarray:
    """Oracle preconditioner application z = (L L^T)^{-1} r, sequential scipy."""
    y = sp.linalg.spsolve_triangular(l.tocsr(), r, lower=True)
    z = sp.linalg.spsolve_triangular(l.T.tocsr(), y, lower=False)
    return z
