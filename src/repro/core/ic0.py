"""Shifted IC(0) — zero-fill incomplete Cholesky factorization (paper §2).

A ~= L L^T where L is lower triangular with the same nonzero pattern as the
lower triangular part of A.  The *shifted* variant factorizes

    A + alpha * diag(A)

(the diagonal scaled by ``1 + alpha``); this is the paper's §5.1 shifted IC
(alpha = 0.3 for Ieej) written without the diagonal scaling: factorizing the
diagonally scaled matrix  D^{-1/2} A D^{-1/2} + alpha I  yields exactly
``D^{-1/2} L`` where ``L`` is the factor of ``A + alpha diag(A)``, so the two
formulations produce the same preconditioned operator up to a symmetric
diagonal similarity (pinned by tests/test_setup_plan.py on the Ieej
generator).  The shift guards against breakdown on semi-definite systems.

Two implementations of the same factorization:

  * ``ic0`` — the sequential up-looking row loop (the semantics oracle).
  * ``ic0_rounds`` / ``ic0_structure`` + ``ic0_refactor`` — the
    round-parallel setup pipeline.  Rows within a multi-color round are
    mutually independent (the same property the triangular solve exploits),
    so every dependency of a row's factorization — its lower neighbors and
    their rows — lives in a strictly earlier round.  The factorization
    therefore runs as ``sum_s max_rowlen(round_s)`` vectorized numpy steps:
    all rows of a round advance one entry position per step as one batch.
    ``ic0_structure`` does the pattern-only analysis once; ``ic0_refactor``
    re-runs just the numeric phase (the factor-once / solve-many workload of
    ``core.plan.SolverPlan``).

Host-side setup code (numpy; one-time cost amortized over the CG
iterations), exactly as the reordering itself.  Factors are returned in CSR
so the SELL packing (``sell.py``) can slice them per HBMC step.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp

from .graph import ragged_arange


class FactorBreakdownError(RuntimeError):
    """The IC(0) factorization broke down (clamped pivots or non-finite
    factor data) and the caller's ``on_breakdown`` policy forbids using the
    degraded factor.  Carries ``clamped_pivots`` and the ``shift_schedule``
    of attempted (shift, clamped_pivots) pairs when raised from the plan's
    escalation loop."""

    def __init__(self, msg: str, clamped_pivots: int = 0,
                 shift_schedule: list | None = None):
        super().__init__(msg)
        self.clamped_pivots = clamped_pivots
        self.shift_schedule = shift_schedule or []


def ic0(a: sp.spmatrix, shift: float = 0.0, breakdown_eps: float = 1e-13
        ) -> sp.csr_matrix:
    """Return L (CSR, lower triangular incl. diagonal) with A ~= L L^T.

    Row-oriented up-looking factorization restricted to pattern(tril(A)).
    Sorted-merge intersection of row patterns keeps it O(sum row^2) which is
    fine for the stencil-type matrices used in the paper.  ``shift`` applies
    the diagonal scaling ``a_ii -> a_ii * (1 + shift)`` before factorizing
    (see the module docstring for the relation to the paper's diagonally
    scaled formulation).

    The returned CSR carries ``clamped_pivots`` — how many diagonal pivots
    hit the ``breakdown_eps`` guard (a nonzero count means the factor is
    degraded: A was not positive definite enough for IC(0) at this shift).
    A NaN pivot is NOT a clamp (NaN comparisons are false; it propagates
    into the factor data, detectable via ``np.isfinite``) — the
    round-parallel path behaves identically.
    """
    a = sp.csr_matrix(a).astype(np.float64)
    n = a.shape[0]
    low = sp.tril(a, format="csr")
    low.sort_indices()
    indptr, indices, data = low.indptr, low.indices, low.data.copy()
    if shift != 0.0:
        diag = a.diagonal()
        for i in range(n):
            last = indptr[i + 1] - 1
            # diagonal is the last entry of the sorted lower row
            data[last] = diag[i] * (1.0 + shift)

    # L rows stored as (col array, val array), built in place over `data`
    lcols: list[np.ndarray] = [None] * n  # type: ignore[list-item]
    lvals: list[np.ndarray] = [None] * n  # type: ignore[list-item]
    diag_l = np.empty(n, dtype=np.float64)
    clamped = 0

    for i in range(n):
        s, e = indptr[i], indptr[i + 1]
        cols_i = indices[s:e]
        vals_i = data[s:e]
        if cols_i[-1] != i:
            raise ValueError(f"missing diagonal in row {i}")
        row_vals = np.empty(e - s, dtype=np.float64)
        for t in range(e - s):
            j = cols_i[t]
            v = vals_i[t]
            # v -= sum_k l_ik * l_jk over shared k < j (merge of sorted rows)
            cj, vj = (lcols[j], lvals[j]) if j < i else (cols_i[:t], row_vals[:t])
            ci, vi = cols_i[:t], row_vals[:t]
            pi = pj = 0
            acc = 0.0
            li, lj = len(ci), len(cj)
            while pi < li and pj < lj:
                a_, b_ = ci[pi], cj[pj]
                if a_ == b_:
                    if a_ >= j:
                        break
                    acc += vi[pi] * vj[pj]
                    pi += 1; pj += 1
                elif a_ < b_:
                    pi += 1
                else:
                    pj += 1
            v -= acc
            if j < i:
                row_vals[t] = v / diag_l[j]
            else:  # diagonal
                if v <= breakdown_eps:
                    v = breakdown_eps  # breakdown guard
                    clamped += 1
                row_vals[t] = np.sqrt(v)
                diag_l[i] = row_vals[t]
        lcols[i] = cols_i
        lvals[i] = row_vals
        data[s:e] = row_vals

    l = sp.csr_matrix((data, indices, indptr), shape=(n, n))
    l.clamped_pivots = clamped
    return l


# ---------------------------------------------------------------------------
# Round-parallel IC(0): symbolic analysis once, vectorized numeric per call.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class IC0Structure:
    """Pattern-only analysis of a round-parallel IC(0) factorization.

    The factorization is scheduled as ``n_steps`` sequential *steps*; step
    ``(round s, in-row offset t)`` computes entry ``t`` of every row of
    round ``s`` as one numpy batch.  Entry values within a row depend on the
    row's earlier entries (smaller ``t``, earlier step) and on rows of
    strictly earlier rounds — both finished by construction, which
    ``ic0_structure`` validates.

    ``steps[s]`` is the fully precomputed work list of step ``s``:
    ``(pos, n_off, dep_off, rows_di, pair_ab, n_pair, pair_tgt)`` where
    ``pos`` holds the entry positions computed this step (off-diagonals
    first, then diagonals — ``n_off`` splits them), ``dep_off`` the row
    whose diagonal divides each off-diagonal, ``rows_di`` the rows whose
    diagonal is produced, and ``pair_ab`` the inner-product operand
    positions (``n_pair`` l_ik positions followed by ``n_pair`` matching
    l_jk positions; ``pair_tgt`` the target entry, local within ``pos``),
    sorted per target by ascending ``k`` so the accumulation order — and
    hence the floats — match the sequential ``ic0`` merge exactly.
    """
    n: int
    n_steps: int
    indptr: np.ndarray       # lower pattern (incl. diagonal, sorted)
    indices: np.ndarray
    steps: list

    @property
    def nnz(self) -> int:
        return int(self.indices.size)

    @property
    def n_pairs(self) -> int:
        return sum(s[5] for s in self.steps)


def ic0_structure(a: sp.spmatrix, rounds: list[np.ndarray]) -> IC0Structure:
    """Analyze pattern(tril(A)) for the round-parallel factorization.

    ``rounds`` must partition the rows in execution order with all lower
    neighbors of a row in strictly earlier rounds (exactly the property the
    MC/BMC/HBMC forward rounds provide) — validated here, ValueError
    otherwise.
    """
    a = sp.csr_matrix(a)
    n = a.shape[0]
    low = sp.tril(a, format="csr")
    low.sort_indices()
    indptr, indices = low.indptr, low.indices.astype(np.int64)
    lens = np.diff(indptr)
    nnz = int(indices.size)
    if not np.array_equal(indices[indptr[1:] - 1], np.arange(n)):
        missing = np.nonzero(indices[indptr[1:] - 1] != np.arange(n))[0]
        raise ValueError(f"missing diagonal in row {missing[0]}")

    round_id = np.full(n, -1, dtype=np.int64)
    total = 0
    for s, r in enumerate(rounds):
        round_id[r] = s
        total += len(r)
    if total != n or (round_id < 0).any():
        raise ValueError("rounds must partition the rows exactly once")
    row_of = np.repeat(np.arange(n), lens)
    strict = indices < row_of
    if not np.all(round_id[indices[strict]] < round_id[row_of[strict]]):
        raise ValueError("rounds are not dependency-ordered: some row has a "
                         "lower neighbor in the same or a later round")

    # --- step schedule: step(entry) = step_base[round(row)] + offset -------
    maxlen = np.fromiter((lens[r].max() if len(r) else 0 for r in rounds),
                         dtype=np.int64, count=len(rounds))
    step_base = np.concatenate([[0], np.cumsum(maxlen)])
    n_steps = int(step_base[-1])
    offs = ragged_arange(lens)
    step_of = step_base[round_id[row_of]] + offs
    isdiag = indices == row_of

    # entries ordered by (step, off-diagonals-before-diagonals, position):
    # single composite key + stable sort (position order is preserved)
    ent_order = np.argsort((step_of * 2 + isdiag).astype(np.int32),
                           kind="stable")
    ent_counts = np.bincount(step_of, minlength=n_steps)
    ent_indptr = np.concatenate([[0], np.cumsum(ent_counts)])
    # local index of every entry position within its step
    local_of_pos = np.empty(nnz, dtype=np.int32)
    local_of_pos[ent_order] = ragged_arange(ent_counts, dtype=np.int32)

    # --- inner-product pairs: for entry (i, j) at offset t, every shared
    # k < j contributes l_ik (offset s2 < t of row i) * l_jk (row j).
    # candidates: a target at CSR position p (in-row offset t) pairs with
    # its row's earlier entries — the contiguous positions p-t .. p-1.  One
    # ragged enumeration replaces any per-(t, s2) Python loop, int32 when
    # the candidate count allows (halves the memory traffic), int64 beyond;
    # enumerating the targets in STEP-MAJOR order (ent_order) makes the
    # surviving pairs come out already grouped by step — target-major,
    # sources ascending, i.e. the one order that matters: pairs of any
    # single target stay k-ascending, the sequential merge order — so no
    # post-hoc sort is needed.
    n_cand = int(offs.sum())
    if n_cand:
        cdt = (np.int32 if max(n_cand, nnz) < np.iinfo(np.int32).max
               else np.int64)
        entc = ent_order.astype(cdt)
        offs_sm = offs.astype(cdt)[ent_order]        # offsets, step-major
        pt = np.repeat(entc, offs_sm)
        seq = ragged_arange(offs_sm, dtype=cdt)
        pa = np.repeat(entc - offs_sm, offs_sm) + seq
        # (j, k) -> position lookup: one binary search over the globally
        # sorted key row*n + col
        key_dt = np.int32 if n * n < np.iinfo(np.int32).max else np.int64
        idxk = indices.astype(key_dt)
        nk = key_dt(n)
        keys = row_of.astype(key_dt) * nk + idxk
        key = idxk[pt] * nk + idxk[pa]
        q = np.searchsorted(keys, key).astype(cdt)
        ok = np.flatnonzero((q < nnz)
                            & (keys[np.minimum(q, nnz - 1)] == key))
        pt, pa, pb = pt[ok], pa[ok], q[ok]
        pair_counts = np.bincount(step_of[pt], minlength=n_steps)
    else:
        pt = pa = pb = np.zeros(0, dtype=np.int64)
        pair_counts = np.zeros(n_steps, dtype=np.int64)
    pair_indptr = np.concatenate([[0], np.cumsum(pair_counts)])
    pair_tgt = local_of_pos[pt]

    # pa/pb interleaved per step ([pa_s | pb_s] at [2*p0, 2*p1)) so the
    # numeric sweep gathers both product operands with ONE fancy index per
    # step; built with a single ragged scatter, sliced as views below
    n_pairs = len(pt)
    pab = np.empty(2 * n_pairs, dtype=pt.dtype if n_pairs else np.int64)
    if n_pairs:
        rag = ragged_arange(pair_counts)
        base = np.repeat(2 * pair_indptr[:-1], pair_counts) + rag
        pab[base] = pa
        pab[base + np.repeat(pair_counts, pair_counts)] = pb

    # --- assemble the per-step work lists ----------------------------------
    ent_pos = ent_order
    ent_dep = indices[ent_order].astype(np.int32)
    off_counts = np.bincount(step_of[~isdiag], minlength=n_steps).tolist()
    ei = ent_indptr.tolist()
    pi = pair_indptr.tolist()
    steps = []
    for s in range(n_steps):
        e0, e1 = ei[s], ei[s + 1]
        n_off = off_counts[s]
        p0, p1 = pi[s], pi[s + 1]
        if p1 > p0:
            steps.append((ent_pos[e0:e1], n_off, ent_dep[e0:e0 + n_off],
                          ent_dep[e0 + n_off:e1], pab[2 * p0:2 * p1],
                          p1 - p0, pair_tgt[p0:p1]))
        else:
            steps.append((ent_pos[e0:e1], n_off, ent_dep[e0:e0 + n_off],
                          ent_dep[e0 + n_off:e1], None, 0, None))

    return IC0Structure(n=n, n_steps=n_steps, indptr=indptr, indices=indices,
                        steps=steps)


def ic0_refactor(st: IC0Structure, a: sp.spmatrix, shift: float = 0.0,
                 breakdown_eps: float = 1e-13) -> sp.csr_matrix:
    """Numeric-only factorization of a matrix matching ``st``'s pattern.

    This is the refactor path of ``SolverPlan``: same sparsity structure,
    new values — no ordering, no symbolic analysis, just the vectorized
    per-step sweep.  Raises ValueError if the pattern differs.

    Like ``ic0``, the returned CSR carries ``clamped_pivots`` (NaN pivots
    excluded — ``v <= eps`` is false for NaN in both paths, so the
    sequential and round-parallel counts agree exactly).
    """
    a = sp.csr_matrix(a)
    low = sp.tril(a, format="csr")
    low.sort_indices()
    if (low.shape[0] != st.n
            or not np.array_equal(low.indptr, st.indptr)
            or not np.array_equal(low.indices, st.indices)):
        raise ValueError("matrix sparsity pattern differs from the analyzed "
                         "structure; rebuild the plan/structure instead")
    data = low.data.astype(np.float64, copy=True)
    if shift != 0.0:
        dpos = st.indptr[1:] - 1
        data[dpos] = data[dpos] * (1.0 + shift)

    diag_l = np.empty(st.n, dtype=np.float64)
    clamped = 0
    bincount, sqrt, maximum = np.bincount, np.sqrt, np.maximum
    for pos, n_off, dep_off, rows_di, pab, npair, tgt in st.steps:
        v = data[pos]
        if pab is not None:
            # bincount accumulates in input order == (target, k) sorted, so
            # the partial sums match the sequential merge bit for bit
            g = data[pab]
            v = v - bincount(tgt, weights=g[:npair] * g[npair:],
                             minlength=len(pos))
        # breakdown guard: v <= eps -> eps (maximum is the same map; NaN
        # passes through both — `<=` is false, maximum propagates it)
        vd = v[n_off:]
        clamped += int(np.count_nonzero(vd <= breakdown_eps))
        sq = sqrt(maximum(vd, breakdown_eps))
        data[pos[:n_off]] = v[:n_off] / diag_l[dep_off]
        data[pos[n_off:]] = sq
        diag_l[rows_di] = sq

    l = sp.csr_matrix((data, st.indices.copy(), st.indptr.copy()),
                      shape=(st.n, st.n))
    l.clamped_pivots = clamped
    return l


def ic0_rounds(a: sp.spmatrix, rounds: list[np.ndarray], shift: float = 0.0,
               breakdown_eps: float = 1e-13) -> sp.csr_matrix:
    """Round-parallel IC(0): ``ic0`` computed as vectorized per-round batches.

    Produces the same factor as the sequential ``ic0`` (same accumulation
    order per entry — tested to tight tolerance across all orderings) in
    ``sum_s max_rowlen(round_s)`` numpy steps instead of a per-entry Python
    loop.  ``rounds`` are the forward rounds of any dependency-ordered
    multi-color ordering (``sell.rounds_mc`` / ``rounds_bmc`` /
    ``rounds_hbmc`` / ``rounds_natural``).
    """
    st = ic0_structure(a, rounds)
    return ic0_refactor(st, a, shift=shift, breakdown_eps=breakdown_eps)


def ic0_error(a: sp.spmatrix, l: sp.csr_matrix) -> float:
    """|| proj_pattern(A - L L^T) ||_F / ||A||_F — zero for exact IC(0) on the
    pattern (sanity check used by tests)."""
    a = sp.csr_matrix(a).astype(np.float64)
    prod = (l @ l.T).tocsr()
    pattern = (a != 0)
    diff = (a - prod.multiply(pattern))
    return float(sp.linalg.norm(diff) / sp.linalg.norm(a))


def sequential_ic_solve(l: sp.csr_matrix, r: np.ndarray) -> np.ndarray:
    """Oracle preconditioner application z = (L L^T)^{-1} r, sequential scipy."""
    y = sp.linalg.spsolve_triangular(l.tocsr(), r, lower=True)
    z = sp.linalg.spsolve_triangular(l.T.tocsr(), y, lower=False)
    return z
