"""Core library: the paper's contribution (HBMC ordering + parallel ICCG)."""
from .coloring import (BlockPartition, BMCOrdering, MCOrdering,
                       block_multicolor_ordering, build_blocks, color_blocks,
                       multicolor_ordering, pad_system)
from .graph import (check_er_condition, invert_perm, level_sets,
                    ordering_digraph_edges, permute_system)
from .hbmc import (HBMCOrdering, hbmc_from_bmc, hbmc_ordering,
                   pad_system_hbmc, verify_level2_structure)
from .ic0 import (FactorBreakdownError, IC0Structure, ic0, ic0_error,
                  ic0_refactor, ic0_rounds, ic0_structure,
                  sequential_ic_solve)
from .iccg import (BREAKDOWN, CONVERGED, DIVERGED, DIVERGENCE_FACTOR,
                   MAXITER, RUNNING, STAGNATED, STAGNATION_WINDOW,
                   STATUS_NAMES, UNHEALTHY_STATUSES, BatchedPCGResult,
                   PCGResult, SlabState, make_sharded_spmv, pcg,
                   pcg_batched, pcg_iteration, spmv_ell, spmv_ell_batched,
                   spmv_sell, spmv_sell_batched, status_name)
from .matrices import PAPER_PROBLEMS, PAPER_SHIFTS, paper_problem
from .plan import (ON_BREAKDOWN, SCHEDULERS, SetupBreakdown, SolverPlan,
                   build_plan)
from .sell import (FusedRoundMajorTables, PackingIndexError, RoundMajorLayout,
                   RoundMajorTables,
                   SellMatrix, StepTables, fuse_round_major, pack_ell,
                   pack_factor, pack_factor_hbmc, pack_sell, pack_steps,
                   permute_round_major, round_major_layout, rounds_bmc,
                   rounds_hbmc, rounds_levelset, rounds_mc, rounds_natural,
                   to_round_major)
from .smoothers import GSSmoother, build_gs_smoother, gs_solve
from .solvers import (BatchedICCGReport, ICCGReport, solve_iccg,
                      solve_iccg_batched)
from .trisolve import (BACKENDS, LAYOUTS, DeviceFusedTables, DeviceTables,
                       DistributedRoundMajorPreconditioner,
                       HBMCPreconditioner, RoundMajorPreconditioner,
                       backward_solve, backward_solve_batched,
                       build_preconditioner, build_preconditioner_from_rounds,
                       build_round_major_preconditioner,
                       build_round_major_preconditioner_from_rounds,
                       forward_solve, forward_solve_batched, fused_solve,
                       fused_solve_batched, sequential_backward,
                       sequential_forward, shard_fused_tables)
