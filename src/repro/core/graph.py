"""Ordering-graph utilities for parallel orderings (paper §3).

The ordering graph of a symmetric sparse matrix A is the undirected adjacency
structure; an *ordering* directs every edge from the smaller to the larger
index.  Two orderings are equivalent (ER condition, eq. 3.5) iff they induce
the same directed graph, i.e. sgn(i1 - i2) == sgn(pi(i1) - pi(i2)) for every
edge (i1, i2).
"""
from __future__ import annotations

import numpy as np
import scipy.sparse as sp


def ragged_arange(counts: np.ndarray, dtype=np.int64) -> np.ndarray:
    """Segment-relative indices: ``[0..counts[0]), [0..counts[1]), ...``.

    The shared ragged-enumeration idiom of the vectorized setup pipeline
    (step packing, ELL scatters, round-parallel IC(0) candidates): one flat
    array holding, for every segment ``i``, the run ``0..counts[i]-1``.
    ``dtype`` must be able to hold ``counts.sum()``.
    """
    counts = np.asarray(counts, dtype=dtype)
    total = int(counts.sum())
    return (np.arange(total, dtype=dtype)
            - np.repeat(np.cumsum(counts) - counts, counts))


def symmetrize_pattern(a: sp.spmatrix) -> sp.csr_matrix:
    """Return the symmetrized (pattern-wise) CSR form of ``a``."""
    a = sp.csr_matrix(a)
    pattern = (a != 0).astype(np.int8)
    sym = ((pattern + pattern.T) != 0).astype(np.int8)
    sym.setdiag(0)
    sym.eliminate_zeros()
    return sp.csr_matrix(sym)


def adjacency_lists(a: sp.spmatrix) -> tuple[np.ndarray, np.ndarray]:
    """Return (indptr, indices) of the symmetrized off-diagonal adjacency."""
    sym = symmetrize_pattern(a)
    return sym.indptr, sym.indices


def check_er_condition(a: sp.spmatrix, perm_old_to_new: np.ndarray) -> bool:
    """Check the ER condition (eq. 3.5) of ``perm`` w.r.t. matrix ``a``.

    ``perm_old_to_new[i]`` is the new index pi(i) of old unknown i.
    Returns True iff the reordering is equivalent (preserves the ordering
    graph): for every edge (i1, i2), sgn(i1-i2) == sgn(pi(i1)-pi(i2)).
    """
    coo = sp.coo_matrix(symmetrize_pattern(a))
    i1, i2 = coo.row, coo.col
    mask = i1 != i2
    i1, i2 = i1[mask], i2[mask]
    p = np.asarray(perm_old_to_new)
    return bool(np.all(np.sign(i1 - i2) == np.sign(p[i1] - p[i2])))


def permute_system(
    a: sp.spmatrix, b: np.ndarray | None, perm_old_to_new: np.ndarray
) -> tuple[sp.csr_matrix, np.ndarray | None]:
    """Apply reordering: A_bar = P A P^T, b_bar = P b (eq. 3.3).

    With ``perm_old_to_new[i] = pi(i)``, row i of A becomes row pi(i) of
    A_bar.  scipy indexing wants the gather form new->old.
    """
    n = a.shape[0]
    p = np.asarray(perm_old_to_new)
    gather = np.empty(n, dtype=np.int64)  # gather[new] = old
    gather[p] = np.arange(n)
    a = sp.csr_matrix(a)
    a_bar = a[gather][:, gather].tocsr()
    b_bar = None if b is None else np.asarray(b)[gather]
    return a_bar, b_bar


def invert_perm(perm: np.ndarray) -> np.ndarray:
    out = np.empty_like(perm)
    out[perm] = np.arange(perm.shape[0])
    return out


def level_sets(a: sp.spmatrix) -> tuple[np.ndarray, np.ndarray]:
    """Dependency levels of the forward triangular solve on ``a``.

    ``level[i] = 1 + max(level[j] for j in strict lower row i)`` (0 for
    rows with an empty strict-lower part): the classical level-set
    schedule of SpTRSV.  Rows of equal level have no lower-triangular
    coupling, so they form legal parallel rounds — the minimal-round
    legal schedule for the pattern.  The *stored* strict-lower pattern is
    used (no ``eliminate_zeros``), matching what the substitution kernels
    and the ``repro.analysis.schedule`` race detector consider an edge.

    Returns ``(level, counts)``: level id per row (0-based) and rows per
    level.  Computed as a vectorized level-synchronous Kahn sweep: pop
    all rows with in-degree 0, decrement their out-neighbors' in-degrees
    with one ``bincount`` per level, repeat.
    """
    n = a.shape[0]
    low = sp.tril(sp.csr_matrix(a), k=-1, format="csr")
    indeg = np.diff(low.indptr)                  # strict-lower nnz per row
    out = sp.csr_matrix(low.T)                   # row j -> rows i that need j
    outdeg = np.diff(out.indptr)
    level = np.zeros(n, dtype=np.int64)
    frontier = np.flatnonzero(indeg == 0)
    lev = 0
    counts = []
    remaining = n
    # per-level work is O(edges out of the frontier), not O(n): the next
    # frontier is read off the rows whose in-degree was touched
    while frontier.size:
        level[frontier] = lev
        counts.append(frontier.size)
        remaining -= frontier.size
        cnt = outdeg[frontier]
        heads = out.indices[np.repeat(out.indptr[frontier], cnt)
                            + ragged_arange(cnt)]
        if heads.size:
            touched, dec = np.unique(heads, return_counts=True)
            indeg[touched] -= dec
            frontier = touched[indeg[touched] == 0]
        else:
            frontier = heads
        lev += 1
    if remaining:                                # cannot happen for tril
        raise ValueError("level_sets: dependency graph has a cycle")
    return level, np.asarray(counts, dtype=np.int64)


def ordering_digraph_edges(a: sp.spmatrix, perm_old_to_new: np.ndarray | None = None):
    """Directed edge set of the ordering graph under a permutation.

    Returns a set of (min_node, max_node, direction) triples keyed by the
    *original* node ids, where direction is +1 if the lower-original-id node
    precedes the other in the ordering.  Identical sets <=> equivalent
    orderings.
    """
    coo = sp.coo_matrix(symmetrize_pattern(a))
    n = a.shape[0]
    p = np.arange(n) if perm_old_to_new is None else np.asarray(perm_old_to_new)
    edges = set()
    for i, j in zip(coo.row, coo.col):
        if i >= j:
            continue
        edges.add((int(i), int(j), int(np.sign(p[j] - p[i]))))
    return edges
