"""Preconditioned conjugate gradient (ICCG when preconditioner = IC(0)).

Device-side PCG with a ``lax.while_loop``; every kernel other than the
triangular solver (SpMV, dots, axpys) is embarrassingly parallel, exactly as
the paper notes in §2.  SpMV comes in the paper's two flavours:

  * ``spmv_ell``  — row-major gather (the paper's "crs_spmv" analogue)
  * ``spmv_sell`` — slice-packed SELL-w (the paper's "sell_spmv")

Convergence criterion: relative residual 2-norm < rtol (paper: 1e-7).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def spmv_ell(vals: jax.Array, cols: jax.Array, x: jax.Array) -> jax.Array:
    """(n, K) row-major ELL SpMV: y_i = sum_k vals[i,k] * x[cols[i,k]]."""
    return jnp.einsum("rk,rk->r", vals, x[cols])


def spmv_sell(vals: jax.Array, cols: jax.Array, x: jax.Array,
              n: int) -> jax.Array:
    """SELL-w SpMV.  vals/cols: (n_slices, max_k, w)."""
    g = x[cols]                              # (n_slices, max_k, w)
    y = jnp.einsum("skw,skw->sw", vals, g)   # reduce over k
    return y.reshape(-1)[:n]


@dataclasses.dataclass
class PCGResult:
    x: np.ndarray
    iterations: int
    relres: float
    converged: bool
    history: np.ndarray   # relative residual norm per iteration (padded NaN)


def pcg(spmv: Callable[[jax.Array], jax.Array],
        precond: Callable[[jax.Array], jax.Array],
        b: jax.Array,
        rtol: float = 1e-7,
        maxiter: int = 10_000,
        record_history: bool = False) -> PCGResult:
    """Standard PCG; runs fully on device, one while_loop iteration per CG step."""
    b = jnp.asarray(b)
    bnorm = jnp.linalg.norm(b)
    bnorm = jnp.where(bnorm == 0, 1.0, bnorm)

    x0 = jnp.zeros_like(b)
    r0 = b
    z0 = precond(r0)
    p0 = z0
    rz0 = jnp.vdot(r0, z0)
    hist0 = (jnp.full((maxiter + 1,), jnp.nan, dtype=b.dtype)
             if record_history else jnp.zeros((0,), dtype=b.dtype))
    if record_history:
        hist0 = hist0.at[0].set(jnp.linalg.norm(r0) / bnorm)

    def cond(state):
        _, r, _, _, it, _ = state
        return (jnp.linalg.norm(r) / bnorm >= rtol) & (it < maxiter)

    def body(state):
        x, r, p, rz, it, hist = state
        ap = spmv(p)
        alpha = rz / jnp.vdot(p, ap)
        x = x + alpha * p
        r = r - alpha * ap
        z = precond(r)
        rz_new = jnp.vdot(r, z)
        beta = rz_new / rz
        p = z + beta * p
        it = it + 1
        if record_history:
            hist = hist.at[it].set(jnp.linalg.norm(r) / bnorm)
        return (x, r, p, rz_new, it, hist)

    state = (x0, r0, p0, rz0, jnp.asarray(0), hist0)
    x, r, _, _, it, hist = jax.lax.while_loop(cond, body, state)
    relres = float(jnp.linalg.norm(r) / bnorm)
    return PCGResult(x=np.asarray(x), iterations=int(it), relres=relres,
                     converged=relres < rtol, history=np.asarray(hist))
