"""Preconditioned conjugate gradient (ICCG when preconditioner = IC(0)).

Device-side PCG with a ``lax.while_loop``; every kernel other than the
triangular solver (SpMV, dots, axpys) is embarrassingly parallel, exactly as
the paper notes in §2.  SpMV comes in the paper's two flavours:

  * ``spmv_ell``  — row-major gather (the paper's "crs_spmv" analogue)
  * ``spmv_sell`` — slice-packed SELL-w (the paper's "sell_spmv")

Convergence criterion: relative residual 2-norm < rtol (paper: 1e-7).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


# ---------------------------------------------------------------------------
# Solve-status taxonomy.
#
# Every PCG front end (single-RHS, batched, slab) reports how it terminated
# as one of these codes instead of a bare converged bool.  Small ints so the
# codes live inside the jitted loops (int32 state) and cross the host
# boundary cheaply; ``STATUS_NAMES`` maps code -> name for reports.
#
#   RUNNING    — still iterating (only ever visible mid-slab, between
#                dispatch quanta; never a final status of pcg/pcg_batched)
#   CONVERGED  — relative residual dropped below rtol
#   MAXITER    — iteration budget exhausted with a finite, healthy state
#   BREAKDOWN  — non-positive curvature (p^T A p <= 0: the matrix is not
#                SPD on this Krylov space) or a non-finite residual /
#                pairing (NaN/Inf input, overflow, poisoned factor); the
#                reported iterate is the last *finite* one
#   DIVERGED   — relres grew past ``divergence_factor`` times its best
#   STAGNATED  — no new best relres for ``stagnation_window`` iterations
#
# Detection is select-based (``jnp.where``): on healthy inputs every guard
# selects the identical update the unguarded loop computed, so the float
# sequences — and therefore all parity/iteration-count pins — are
# bitwise-unchanged.
# ---------------------------------------------------------------------------

RUNNING, CONVERGED, MAXITER, BREAKDOWN, DIVERGED, STAGNATED = range(6)
STATUS_NAMES = ("RUNNING", "CONVERGED", "MAXITER", "BREAKDOWN", "DIVERGED",
                "STAGNATED")
#: statuses that mean "stop — more iterations cannot help" (the serving
#: layer quarantines slab columns that reach one of these)
UNHEALTHY_STATUSES = ("BREAKDOWN", "DIVERGED", "STAGNATED")

#: default divergence band: relres > factor * best-so-far trips DIVERGED.
#: PCG residuals oscillate, so the band is wide; healthy solves never
#: wander eight orders of magnitude above their best.
DIVERGENCE_FACTOR = 1e8
#: default stagnation window: iterations without a new best relres before
#: STAGNATED trips.  Healthy ICCG improves its best every few iterations.
STAGNATION_WINDOW = 1000


def status_name(code) -> str:
    """Human-readable name of a solve-status code."""
    return STATUS_NAMES[int(code)]


def spmv_ell(vals: jax.Array, cols: jax.Array, x: jax.Array) -> jax.Array:
    """(n, K) row-major ELL SpMV: y_i = sum_k vals[i,k] * x[cols[i,k]]."""
    return jnp.einsum("rk,rk->r", vals, x[cols])


def spmv_sell(vals: jax.Array, cols: jax.Array, x: jax.Array,
              n: int) -> jax.Array:
    """SELL-w SpMV.  vals/cols: (n_slices, max_k, w)."""
    g = x[cols]                              # (n_slices, max_k, w)
    y = jnp.einsum("skw,skw->sw", vals, g)   # reduce over k
    return y.reshape(-1)[:n]


def spmv_ell_batched(vals: jax.Array, cols: jax.Array,
                     x: jax.Array) -> jax.Array:
    """ELL SpMV over B column vectors at once.  x: (n, B) -> (n, B).

    One gather of the column indices serves all B vectors; the reduction
    over K matches ``spmv_ell`` per column (same order), keeping batched
    and single-RHS PCG arithmetic identical."""
    return jnp.einsum("rk,rkb->rb", vals, x[cols])


def spmv_sell_batched(vals: jax.Array, cols: jax.Array, x: jax.Array,
                      n: int) -> jax.Array:
    """SELL-w SpMV over B column vectors.  x: (n, B) -> (n, B)."""
    g = x[cols]                                    # (n_slices, max_k, w, B)
    y = jnp.einsum("skw,skwb->swb", vals, g)
    return y.reshape(-1, x.shape[1])[:n]


# ---------------------------------------------------------------------------
# Mesh-sharded SpMV: operand rows (ELL) / slices (SELL) live sharded over one
# mesh axis, the vector is replicated, and the row results are all-gathered —
# one collective per SpMV, the distributed analogue of the paper's
# embarrassingly-parallel matrix-vector kernel.
# ---------------------------------------------------------------------------

def make_sharded_spmv(spmv_format: str, n: int, mesh: Mesh, axis: str,
                      vals: jax.Array, cols: jax.Array,
                      batched: bool, spmv_backend: str = "xla",
                      interpret: bool | None = None
                      ) -> Callable[[jax.Array], jax.Array]:
    """Distributed SpMV closure over mesh-sharded packed operands.

    ``vals``/``cols`` must be sharded over ``axis`` along their leading
    (row / slice) dimension, with that dimension a multiple of the axis
    size; the input vector is replicated and the output is replicated
    (each device computes its row block, one tiled all-gather assembles
    the full result).  Per-row arithmetic is identical to the
    single-device ``spmv_ell``/``spmv_sell`` paths, so the distributed
    PCG reproduces their float sequences bitwise.

    ``spmv_backend="pallas"`` (SELL only) computes each device's row block
    with the per-device block kernel (``kernels.sell_spmv_block``) instead
    of the jnp gather — the collective structure (one tiled all-gather) is
    unchanged, and the kernel's interpret-mode arithmetic matches the jnp
    path bitwise.
    """
    if spmv_backend not in ("xla", "pallas"):
        raise ValueError(f"unknown spmv backend {spmv_backend!r}; expected "
                         "'xla' or 'pallas'")
    if spmv_backend == "pallas" and spmv_format != "sell":
        raise ValueError("spmv_backend='pallas' requires spmv_format='sell' "
                         "(the kernel family is SELL-w)")
    if spmv_format == "ell":
        row_eq = "rk,rkb->rb" if batched else "rk,rk->r"

        @partial(shard_map, mesh=mesh,
                 in_specs=(P(axis, None), P(axis, None), P()),
                 out_specs=P(), check_rep=False)
        def ell_block(v, c, x):
            y_loc = jnp.einsum(row_eq, v, x[c])
            return jax.lax.all_gather(y_loc, axis, tiled=True)

        return lambda x: ell_block(vals, cols, x)

    if spmv_format == "sell":
        slice_eq = "skw,skwb->swb" if batched else "skw,skw->sw"
        use_kernel = spmv_backend == "pallas"
        if use_kernel:
            # deferred: repro.kernels.__init__ imports repro.core
            from repro.kernels.sell_spmv import sell_spmv_block

        @partial(shard_map, mesh=mesh,
                 in_specs=(P(axis, None, None), P(axis, None, None), P()),
                 out_specs=P(), check_rep=False)
        def sell_block(v, c, x):
            if use_kernel:
                y_loc = sell_spmv_block(v, c, x, interpret=interpret)
            else:
                y_loc = jnp.einsum(slice_eq, v, x[c])  # (s, w) or (s, w, B)
                y_loc = y_loc.reshape((-1,) + y_loc.shape[2:])
            return jax.lax.all_gather(y_loc, axis, tiled=True)

        return lambda x: sell_block(vals, cols, x)[:n]

    raise ValueError(f"unknown spmv format {spmv_format!r}")


def pcg_iteration(spmv: Callable[[jax.Array], jax.Array],
                  precond: Callable[[jax.Array], jax.Array]):
    """One PCG step with the PRECONDITIONED pairings, as a pure function.

    The carried state is ``(x, r, p, rz)`` with ``rz = (r, z)`` from the
    previous step — exactly the body of ``_pcg_device``:

        alpha = (r, z) / (p, A p)        beta = (r2, z2) / (r, z)

    (NOT the unpreconditioned ``(r, r)`` pairings — using those lowers a
    plain-CG kernel whose roofline misses both triangular sweeps' traffic.)
    Used by ``core.partition.lower_solver_step`` for mesh dry-runs; tested
    against ``pcg`` iterates in tests/test_multidevice.py.
    """
    def step(x, r, p, rz):
        ap = spmv(p)
        alpha = rz / jnp.vdot(p, ap)
        x = x + alpha * p
        r = r - alpha * ap
        z = precond(r)
        rz_new = jnp.vdot(r, z)
        beta = rz_new / rz
        p = z + beta * p
        return x, r, p, rz_new
    return step


@dataclasses.dataclass
class PCGResult:
    x: np.ndarray
    iterations: int
    relres: float
    converged: bool
    history: np.ndarray   # relative residual norm per iteration (padded NaN)
    # how the solve terminated — one of STATUS_NAMES[1:] (see the taxonomy
    # at the top of this module); ``converged`` stays as the legacy bool
    status: str = "CONVERGED"


def _pcg_device(spmv: Callable[[jax.Array], jax.Array],
                precond: Callable[[jax.Array], jax.Array],
                b: jax.Array,
                rtol: float = 1e-7,
                maxiter: int = 10_000,
                record_history: bool = False,
                divergence_factor: float | None = DIVERGENCE_FACTOR,
                stagnation_window: int | None = STAGNATION_WINDOW):
    """Device core of ``pcg``: pure jax in / jax out, jittable.

    ``rtol``/``maxiter``/``record_history`` and the monitoring knobs are
    Python values (static under jit).  Returns ``(x, iterations, relres,
    status, history)`` as jax arrays; ``SolverPlan`` wraps this in a cached
    ``jax.jit`` so warm solves skip retracing entirely.

    Health monitoring runs inside the loop: a non-SPD pairing
    (``p^T A p <= 0``) or a non-finite residual/pairing stops the loop with
    ``BREAKDOWN`` *before* the poisoned update replaces the last finite
    iterate; ``relres`` growing past ``divergence_factor * best`` stops
    with ``DIVERGED``; ``stagnation_window`` iterations without a new best
    stop with ``STAGNATED``.  All guards are selects, so the healthy-path
    float sequence is bitwise-identical to the unguarded loop.
    """
    if divergence_factor is None:
        divergence_factor = float("inf")
    if stagnation_window is None:
        stagnation_window = maxiter + 1
    b = jnp.asarray(b)
    bnorm = jnp.linalg.norm(b)
    bnorm = jnp.where(bnorm == 0, 1.0, bnorm)

    x0 = jnp.zeros_like(b)
    r0 = b
    z0 = precond(r0)
    p0 = z0
    rz0 = jnp.vdot(r0, z0)
    # carry ||r|| in the loop state: one full-vector reduction per step
    # (cond reads the carried value instead of recomputing the norm)
    rnorm0 = jnp.linalg.norm(r0)
    relres0 = rnorm0 / bnorm
    # a non-finite initial state (NaN/Inf in b, or a preconditioner that
    # produced one) is a breakdown before the first iteration
    init_ok = jnp.isfinite(relres0) & jnp.isfinite(rz0)
    status0 = jnp.where(init_ok, RUNNING, BREAKDOWN).astype(jnp.int32)
    hist0 = (jnp.full((maxiter + 1,), jnp.nan, dtype=b.dtype)
             if record_history else jnp.zeros((0,), dtype=b.dtype))
    if record_history:
        hist0 = hist0.at[0].set(relres0)

    def cond(state):
        _, _, _, _, _, rnorm, it, status, _, _, _ = state
        return ((rnorm / bnorm >= rtol) & (it < maxiter)
                & (status == RUNNING))

    def body(state):
        x, _, r, p, rz, rnorm, it, status, best, since_best, hist = state
        ap = spmv(p)
        pap = jnp.vdot(p, ap)
        alpha = rz / pap
        x2 = x + alpha * p
        r2 = r - alpha * ap
        z = precond(r2)
        rz2 = jnp.vdot(r2, z)
        beta = rz2 / rz
        p2 = z + beta * p
        rnorm2 = jnp.linalg.norm(r2)
        relres2 = rnorm2 / bnorm
        # pap > 0 is False for NaN pap too; a step that still produced a
        # non-finite residual/pairing (overflow) is equally a breakdown.
        # Broken steps are DISCARDED: a broken step makes cond False
        # immediately (status leaves RUNNING) and the loop outputs read
        # the carried scalars, never the poisoned r or p — so no vector
        # select runs inside the loop at all.  The previous iterate rides
        # along as x_prev (pure buffer rotation, no copy) and the single
        # rollback select happens once, after the loop.
        ok = (pap > 0) & jnp.isfinite(rnorm2) & jnp.isfinite(rz2)
        rz = jnp.where(ok, rz2, rz)
        rnorm = jnp.where(ok, rnorm2, rnorm)
        it = jnp.where(ok, it + 1, it)
        improved = relres2 < best
        diverged = ok & (relres2 > divergence_factor * best)
        since_best = jnp.where(ok, jnp.where(improved, 0, since_best + 1),
                               since_best)
        stagnated = ok & (since_best >= stagnation_window)
        best = jnp.where(ok, jnp.minimum(best, relres2), best)
        status = jnp.where(~ok, BREAKDOWN,
                           jnp.where(diverged, DIVERGED,
                                     jnp.where(stagnated, STAGNATED,
                                               status))).astype(jnp.int32)
        if record_history:
            hist = jnp.where(ok, hist.at[it].set(relres2), hist)
        return (x2, x, r2, p2, rz, rnorm, it, status, best, since_best,
                hist)

    state = (x0, x0, r0, p0, rz0, rnorm0, jnp.asarray(0), status0, relres0,
             jnp.asarray(0, dtype=jnp.int32), hist0)
    (x, x_prev, _, _, _, rnorm, it, status, _, _, hist) = jax.lax.while_loop(
        cond, body, state)
    # a BREAKDOWN exit left the poisoned update in x; report the last
    # finite iterate instead (healthy exits select x — identical bits)
    x = jnp.where(status == BREAKDOWN, x_prev, x)
    relres = rnorm / bnorm
    status = jnp.where(status == RUNNING,
                       jnp.where(relres < rtol, CONVERGED, MAXITER),
                       status).astype(jnp.int32)
    return x, it, relres, status, hist


def pcg(spmv: Callable[[jax.Array], jax.Array],
        precond: Callable[[jax.Array], jax.Array],
        b: jax.Array,
        rtol: float = 1e-7,
        maxiter: int = 10_000,
        record_history: bool = False,
        divergence_factor: float | None = DIVERGENCE_FACTOR,
        stagnation_window: int | None = STAGNATION_WINDOW) -> PCGResult:
    """Standard PCG; runs fully on device, one while_loop iteration per CG step.

    Terminates with a definite ``result.status`` on every input: healthy
    systems report ``CONVERGED``/``MAXITER`` exactly as before (bitwise —
    the monitoring is select-based), a zero RHS converges immediately with
    ``x = 0``, and NaN/Inf inputs, non-SPD pairings, divergence, and
    stagnation stop early instead of silently iterating on garbage (the
    reported ``x`` is the last finite iterate).
    """
    x, it, relres, status, hist = _pcg_device(
        spmv, precond, b, rtol=rtol, maxiter=maxiter,
        record_history=record_history, divergence_factor=divergence_factor,
        stagnation_window=stagnation_window)
    relres = float(relres)
    return PCGResult(x=np.asarray(x), iterations=int(it), relres=relres,
                     converged=relres < rtol, history=np.asarray(hist),
                     status=STATUS_NAMES[int(status)])


# ---------------------------------------------------------------------------
# Batched multi-RHS PCG (one while_loop for B right-hand sides).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BatchedPCGResult:
    x: np.ndarray           # (n, B) solutions
    iterations: np.ndarray  # (B,) per-RHS iteration counts
    relres: np.ndarray      # (B,) final relative residual norms
    converged: np.ndarray   # (B,) bool
    n_steps: int            # while_loop trips = max(iterations)
    # (maxiter+1, B) per-column relative residual norms (NaN once a column
    # has converged — matching the single-RHS ``pcg`` histories column for
    # column); empty when record_history=False
    history: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0, 0)))
    # (B,) per-column termination codes (indices into STATUS_NAMES)
    status: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0,), dtype=np.int32))

    @property
    def status_names(self) -> list[str]:
        """Per-column status names (``STATUS_NAMES[code]`` per column)."""
        return [STATUS_NAMES[int(s)] for s in self.status]


def _pcg_batched_device(spmv: Callable[[jax.Array], jax.Array],
                        precond: Callable[[jax.Array], jax.Array],
                        b: jax.Array,
                        rtol: float = 1e-7,
                        maxiter: int = 10_000,
                        record_history: bool = False,
                        divergence_factor: float | None = DIVERGENCE_FACTOR,
                        stagnation_window: int | None = STAGNATION_WINDOW):
    """Device core of ``pcg_batched``; returns jax arrays, jittable.

    Per-column health monitoring mirrors ``_pcg_device``: a column whose
    pairing goes non-positive is frozen BEFORE the division poisons it
    (``alpha = 0``, exactly how converged columns freeze), a column whose
    update still produced a non-finite residual rolls back to its last
    finite iterate, and divergence/stagnation trip per column.  A broken
    column deactivates with an explicit terminal status — never the old
    silent NaN-comparison fallout — while its healthy slab neighbours'
    float sequences stay bitwise-untouched (all guards are selects).
    """
    if divergence_factor is None:
        divergence_factor = float("inf")
    if stagnation_window is None:
        stagnation_window = maxiter + 1
    b = jnp.asarray(b)
    if b.ndim == 1:
        raise ValueError(
            f"pcg_batched expects b of shape (n, B), got a 1-D vector of "
            f"shape {b.shape}; a single RHS must be passed as a one-column "
            f"slab b[:, None] (B = 1), or use pcg")
    if b.ndim != 2:
        raise ValueError(f"pcg_batched expects b of shape (n, B), got "
                         f"{b.shape}")
    nb = b.shape[1]
    bnorm = jnp.linalg.norm(b, axis=0)
    bnorm = jnp.where(bnorm == 0, 1.0, bnorm)

    def relres_of(r):
        return jnp.linalg.norm(r, axis=0) / bnorm

    x0 = jnp.zeros_like(b)
    r0 = b
    z0 = precond(r0)
    p0 = z0
    rz0 = jnp.einsum("nb,nb->b", r0, z0)
    relres0 = relres_of(r0)
    # non-finite init (NaN/Inf b, poisoned factor): BREAKDOWN before the
    # first step.  NaN relres already failed `>= rtol`; the explicit
    # finiteness mask also catches Inf relres (which would pass) and pins
    # the deactivation to a status instead of a comparison accident.
    finite0 = jnp.isfinite(relres0) & jnp.isfinite(rz0)
    active0 = (relres0 >= rtol) & finite0
    status0 = jnp.where(finite0,
                        jnp.where(relres0 < rtol, CONVERGED, RUNNING),
                        BREAKDOWN).astype(jnp.int32)
    iters0 = jnp.zeros(nb, dtype=jnp.int32)
    since0 = jnp.zeros(nb, dtype=jnp.int32)
    hist0 = (jnp.full((maxiter + 1, nb), jnp.nan, dtype=b.dtype)
             if record_history else jnp.zeros((0, nb), dtype=b.dtype))
    if record_history:
        hist0 = hist0.at[0].set(relres0)

    def cond(state):
        _, _, _, _, active, _, step, _, _, _, _ = state
        return jnp.any(active) & (step < maxiter)

    def body(state):
        x, r, p, rz, active, iters, step, status, best, since, hist = state
        ap = spmv(p)
        pap = jnp.einsum("nb,nb->b", p, ap)
        # non-positive / non-finite curvature freezes the column BEFORE
        # the rz/pap division (alpha = 0, exactly how a converged column
        # freezes); for healthy columns `upd` equals `active` bitwise
        upd = active & (pap > 0)
        alpha = jnp.where(upd, rz / pap, 0.0)
        x2 = x + alpha[None, :] * p
        r2 = r - alpha[None, :] * ap
        z = precond(r2)
        rz2 = jnp.einsum("nb,nb->b", r2, z)
        beta = jnp.where(upd, rz2 / rz, 0.0)
        p2 = jnp.where(upd[None, :], z + beta[None, :] * p, p)
        relres2 = relres_of(r2)
        # a column whose update still produced a non-finite residual /
        # pairing (overflow) rolls back to its last finite iterate
        ok = upd & jnp.isfinite(relres2) & jnp.isfinite(rz2)
        broke = active & ~ok
        x = jnp.where(ok[None, :], x2, x)
        r = jnp.where(ok[None, :], r2, r)
        p = jnp.where(ok[None, :], p2, p)
        rz = jnp.where(ok, rz2, rz)
        iters = iters + ok.astype(jnp.int32)
        if record_history:
            # a column records its residual at row == its own iteration
            # count while healthy-active; frozen columns keep their NaN
            # padding, matching the single-RHS history shape one for one
            # (the lane index dtype must match `iters` — mixed i64/i32
            # scatter indices are a FutureWarning on the way to an error)
            lanes = jnp.arange(nb, dtype=iters.dtype)
            hist = hist.at[iters, lanes].set(
                jnp.where(ok, relres2, hist[iters, lanes]))
        improved = relres2 < best
        diverged = ok & (relres2 > divergence_factor * best)
        since = jnp.where(ok, jnp.where(improved, 0, since + 1), since)
        stagnated = ok & (since >= stagnation_window) & ~diverged
        best = jnp.where(ok, jnp.minimum(best, relres2), best)
        status = jnp.where(broke, BREAKDOWN,
                           jnp.where(diverged, DIVERGED,
                                     jnp.where(stagnated, STAGNATED,
                                               status))).astype(jnp.int32)
        active = ok & (relres2 >= rtol) & ~diverged & ~stagnated
        return (x, r, p, rz, active, iters, step + 1, status, best, since,
                hist)

    state = (x0, r0, p0, rz0, active0, iters0, jnp.asarray(0), status0,
             relres0, since0, hist0)
    (x, r, _, _, _, iters, step, status, _, _, hist) = jax.lax.while_loop(
        cond, body, state)
    relres = relres_of(r)
    # columns still RUNNING terminated healthily: converged or out of
    # budget (terminal codes set inside the loop are kept)
    status = jnp.where(status == RUNNING,
                       jnp.where(relres < rtol, CONVERGED, MAXITER),
                       status).astype(jnp.int32)
    return x, iters, relres, step, status, hist


def pcg_batched(spmv: Callable[[jax.Array], jax.Array],
                precond: Callable[[jax.Array], jax.Array],
                b: jax.Array,
                rtol: float = 1e-7,
                maxiter: int = 10_000,
                record_history: bool = False,
                divergence_factor: float | None = DIVERGENCE_FACTOR,
                stagnation_window: int | None = STAGNATION_WINDOW
                ) -> BatchedPCGResult:
    """PCG over B right-hand sides in ONE device while_loop.

    ``spmv`` and ``precond`` map (n, B) -> (n, B) column-wise (e.g.
    ``spmv_ell_batched`` and ``HBMCPreconditioner.apply_batched``).

    Per-RHS convergence masking: a column whose relative residual drops
    below ``rtol`` gets ``alpha = beta = 0`` from then on, freezing its
    ``x``/``r``/``p``/``rz`` exactly (0 * p adds exact zeros), while the
    remaining columns keep iterating.  Each column therefore performs the
    same arithmetic sequence as a single-RHS ``pcg`` on that column up to
    XLA's reduction-order rounding, and the per-RHS iteration counts match
    the single-RHS counts one for one.

    ``record_history=True`` additionally returns per-column residual
    histories ((maxiter+1, B), NaN-padded): column j's history is frozen
    the moment it converges, matching the single-RHS ``pcg`` history of
    that column in shape and NaN pattern exactly and in values up to
    reduction-order rounding (the batched dots reduce via
    ``einsum('nb,nb->b')`` rather than ``vdot``).

    The loop runs until every column has converged (or ``maxiter``): total
    wall-clock is max(iterations) rounds, with the S sequential trisolve
    rounds amortized over all live columns — the multi-RHS workload the
    round-major kernel was built for.

    Per-column termination is reported in ``result.status`` (codes into
    ``STATUS_NAMES``; names via ``result.status_names``): a column whose
    residual goes NaN — or that hits non-positive curvature, divergence,
    or stagnation — deactivates with an explicit ``BREAKDOWN`` /
    ``DIVERGED`` / ``STAGNATED`` code instead of silently falling out of
    the active mask mid-garbage, and its healthy neighbours are bitwise
    unaffected.
    """
    x, iters, relres, step, status, hist = _pcg_batched_device(
        spmv, precond, b, rtol=rtol, maxiter=maxiter,
        record_history=record_history,
        divergence_factor=divergence_factor,
        stagnation_window=stagnation_window)
    relres = np.asarray(relres)
    return BatchedPCGResult(x=np.asarray(x), iterations=np.asarray(iters),
                            relres=relres, converged=relres < rtol,
                            n_steps=int(step), history=np.asarray(hist),
                            status=np.asarray(status))


# ---------------------------------------------------------------------------
# Slab PCG: quantum-stepped batched PCG with slot-level entry/retirement.
#
# The serving layer (repro.serve) keeps B independent PCG solves resident in
# one (n, B) slab and advances them a bounded number of while_loop trips per
# dispatch.  Between dispatches the host retires converged columns and packs
# fresh right-hand sides into the freed slots; a ``fresh`` mask tells the
# next dispatch which columns to (re)initialize.  Continuing columns are
# carried through ``jnp.where`` untouched, so quantum boundaries do not
# perturb their float sequences: a column sees the exact same arithmetic it
# would in one uninterrupted ``_pcg_batched_device`` run at the same width.
# ---------------------------------------------------------------------------


class SlabState(NamedTuple):
    """Device-side carry of a resident PCG slab ((m, B) state vectors).

    ``fresh[j]`` marks column j for (re)initialization at the next dispatch:
    its ``r`` must already hold the embedded RHS (or zeros for an empty
    slot — zero residual initializes to ``relres = 0 < rtol``, i.e. inert).
    All other per-column entries of a fresh column are ignored and
    overwritten at dispatch entry.

    ``status[j]`` carries the per-column termination code (index into
    ``STATUS_NAMES``): ``RUNNING`` while iterating, resolved at the
    dispatch where the column deactivates.  An inactive column's status is
    always definite — the serving layer retires on it (and quarantines
    ``BREAKDOWN``/``DIVERGED``/``STAGNATED`` columns immediately instead
    of letting them hold a slot for their full ``maxiter`` budget).
    ``best``/``since_best`` are the divergence/stagnation monitor carry
    (best relres so far, iterations since it improved) — slab-resident so
    the monitoring is seamless across dispatch boundaries.
    """
    x: jax.Array        # (m, B) iterates
    r: jax.Array        # (m, B) residuals (RHS for fresh columns)
    p: jax.Array        # (m, B) search directions
    rz: jax.Array       # (B,)   carried (r, z) inner products
    bnorm: jax.Array    # (B,)   ||b|| per column (1.0 for zero columns)
    active: jax.Array   # (B,)   still iterating
    iters: jax.Array    # (B,)   per-column iteration counts (int32)
    relres: jax.Array   # (B,)   last relative residual norms
    fresh: jax.Array    # (B,)   initialize at next dispatch entry
    status: jax.Array   # (B,)   per-column termination codes (int32)
    best: jax.Array     # (B,)   best relres so far (monitor carry)
    since_best: jax.Array  # (B,) iterations since best improved (int32)


def _pcg_slab_device(spmv: Callable[[jax.Array], jax.Array],
                     precond: Callable[[jax.Array], jax.Array],
                     state: SlabState,
                     rtol: float = 1e-7,
                     maxiter: int = 10_000,
                     quantum: int = 16,
                     divergence_factor: float | None = DIVERGENCE_FACTOR,
                     stagnation_window: int | None = STAGNATION_WINDOW):
    """Advance a PCG slab by at most ``quantum`` iterations; jittable.

    Entry initialization applies only to columns with ``fresh`` set (their
    ``r`` holds the embedded RHS): exactly the ``_pcg_batched_device`` init
    per column — including its health screen (a non-finite fresh RHS is
    ``BREAKDOWN`` on entry, a zero RHS is ``CONVERGED``/inert).  The loop
    body performs the identical arithmetic sequence as
    ``_pcg_batched_device`` — converged/inert/broken columns are frozen by
    ``alpha = beta = 0``, breakdown/divergence/stagnation deactivate a
    column with its terminal status — with one addition: a per-column
    ``iters < maxiter`` cutoff (columns enter the slab at different times,
    so the global step counter cannot bound them).  Returns
    ``(SlabState, steps)`` with ``fresh`` cleared, every inactive column's
    ``status`` definite, and ``steps`` the number of while_loop trips
    taken this dispatch.
    """
    if divergence_factor is None:
        divergence_factor = float("inf")
    if stagnation_window is None:
        stagnation_window = maxiter + 1
    (x, r, p, rz, bnorm, active, iters, relres, fresh, status, best,
     since_best) = state

    # per-column init for fresh columns; continuing columns pass through
    # every `where` bitwise-untouched (the precond/einsum results for them
    # are computed and discarded — column-wise ops, no cross-column flow)
    z = precond(r)
    rz0 = jnp.einsum("nb,nb->b", r, z)
    nrm0 = jnp.linalg.norm(r, axis=0)
    bnorm0 = jnp.where(nrm0 == 0, 1.0, nrm0)
    relres0 = nrm0 / bnorm0
    finite0 = jnp.isfinite(relres0) & jnp.isfinite(rz0)
    x = jnp.where(fresh[None, :], jnp.zeros_like(x), x)
    p = jnp.where(fresh[None, :], z, p)
    rz = jnp.where(fresh, rz0, rz)
    bnorm = jnp.where(fresh, bnorm0, bnorm)
    iters = jnp.where(fresh, 0, iters)
    relres = jnp.where(fresh, relres0, relres)
    active = jnp.where(fresh, (relres0 >= rtol) & finite0, active)
    status = jnp.where(fresh,
                       jnp.where(finite0,
                                 jnp.where(relres0 < rtol, CONVERGED,
                                           RUNNING),
                                 BREAKDOWN),
                       status).astype(jnp.int32)
    best = jnp.where(fresh, relres0, best)
    since_best = jnp.where(fresh, 0, since_best).astype(jnp.int32)

    def relres_of(rr):
        return jnp.linalg.norm(rr, axis=0) / bnorm

    def cond(carry):
        _, _, _, _, active_, _, _, _, _, _, step = carry
        return jnp.any(active_) & (step < quantum)

    def body(carry):
        x, r, p, rz, active, iters, relres, status, best, since, step = \
            carry
        ap = spmv(p)
        pap = jnp.einsum("nb,nb->b", p, ap)
        # same per-column guards as _pcg_batched_device: freeze before a
        # bad division, roll back a non-finite update, monitor
        # divergence/stagnation — healthy columns select identical floats
        upd = active & (pap > 0)
        alpha = jnp.where(upd, rz / pap, 0.0)
        x2 = x + alpha[None, :] * p
        r2 = r - alpha[None, :] * ap
        z = precond(r2)
        rz2 = jnp.einsum("nb,nb->b", r2, z)
        beta = jnp.where(upd, rz2 / rz, 0.0)
        p2 = jnp.where(upd[None, :], z + beta[None, :] * p, p)
        relres2 = relres_of(r2)
        ok = upd & jnp.isfinite(relres2) & jnp.isfinite(rz2)
        broke = active & ~ok
        x = jnp.where(ok[None, :], x2, x)
        r = jnp.where(ok[None, :], r2, r)
        p = jnp.where(ok[None, :], p2, p)
        rz = jnp.where(ok, rz2, rz)
        iters = iters + ok.astype(jnp.int32)
        relres = jnp.where(ok, relres2, relres)
        improved = relres2 < best
        diverged = ok & (relres2 > divergence_factor * best)
        since = jnp.where(ok, jnp.where(improved, 0, since + 1), since)
        stagnated = ok & (since >= stagnation_window) & ~diverged
        best = jnp.where(ok, jnp.minimum(best, relres2), best)
        status = jnp.where(broke, BREAKDOWN,
                           jnp.where(diverged, DIVERGED,
                                     jnp.where(stagnated, STAGNATED,
                                               status))).astype(jnp.int32)
        active = (ok & (relres2 >= rtol) & (iters < maxiter)
                  & ~diverged & ~stagnated)
        return (x, r, p, rz, active, iters, relres, status, best, since,
                step + 1)

    carry = (x, r, p, rz, active, iters, relres, status, best, since_best,
             jnp.asarray(0))
    (x, r, p, rz, active, iters, relres, status, best, since_best,
     step) = jax.lax.while_loop(cond, body, carry)
    # every inactive column leaves the dispatch with a definite status:
    # terminal codes set in the loop are kept; an inactive RUNNING column
    # terminated healthily (converged, or out of per-column budget)
    status = jnp.where(active | (status != RUNNING), status,
                       jnp.where(relres < rtol, CONVERGED,
                                 MAXITER)).astype(jnp.int32)
    out = SlabState(x=x, r=r, p=p, rz=rz, bnorm=bnorm, active=active,
                    iters=iters, relres=relres,
                    fresh=jnp.zeros_like(fresh), status=status, best=best,
                    since_best=since_best)
    return out, step
