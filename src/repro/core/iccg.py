"""Preconditioned conjugate gradient (ICCG when preconditioner = IC(0)).

Device-side PCG with a ``lax.while_loop``; every kernel other than the
triangular solver (SpMV, dots, axpys) is embarrassingly parallel, exactly as
the paper notes in §2.  SpMV comes in the paper's two flavours:

  * ``spmv_ell``  — row-major gather (the paper's "crs_spmv" analogue)
  * ``spmv_sell`` — slice-packed SELL-w (the paper's "sell_spmv")

Convergence criterion: relative residual 2-norm < rtol (paper: 1e-7).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def spmv_ell(vals: jax.Array, cols: jax.Array, x: jax.Array) -> jax.Array:
    """(n, K) row-major ELL SpMV: y_i = sum_k vals[i,k] * x[cols[i,k]]."""
    return jnp.einsum("rk,rk->r", vals, x[cols])


def spmv_sell(vals: jax.Array, cols: jax.Array, x: jax.Array,
              n: int) -> jax.Array:
    """SELL-w SpMV.  vals/cols: (n_slices, max_k, w)."""
    g = x[cols]                              # (n_slices, max_k, w)
    y = jnp.einsum("skw,skw->sw", vals, g)   # reduce over k
    return y.reshape(-1)[:n]


def spmv_ell_batched(vals: jax.Array, cols: jax.Array,
                     x: jax.Array) -> jax.Array:
    """ELL SpMV over B column vectors at once.  x: (n, B) -> (n, B).

    One gather of the column indices serves all B vectors; the reduction
    over K matches ``spmv_ell`` per column (same order), keeping batched
    and single-RHS PCG arithmetic identical."""
    return jnp.einsum("rk,rkb->rb", vals, x[cols])


def spmv_sell_batched(vals: jax.Array, cols: jax.Array, x: jax.Array,
                      n: int) -> jax.Array:
    """SELL-w SpMV over B column vectors.  x: (n, B) -> (n, B)."""
    g = x[cols]                                    # (n_slices, max_k, w, B)
    y = jnp.einsum("skw,skwb->swb", vals, g)
    return y.reshape(-1, x.shape[1])[:n]


# ---------------------------------------------------------------------------
# Mesh-sharded SpMV: operand rows (ELL) / slices (SELL) live sharded over one
# mesh axis, the vector is replicated, and the row results are all-gathered —
# one collective per SpMV, the distributed analogue of the paper's
# embarrassingly-parallel matrix-vector kernel.
# ---------------------------------------------------------------------------

def make_sharded_spmv(spmv_format: str, n: int, mesh: Mesh, axis: str,
                      vals: jax.Array, cols: jax.Array,
                      batched: bool, spmv_backend: str = "xla",
                      interpret: bool | None = None
                      ) -> Callable[[jax.Array], jax.Array]:
    """Distributed SpMV closure over mesh-sharded packed operands.

    ``vals``/``cols`` must be sharded over ``axis`` along their leading
    (row / slice) dimension, with that dimension a multiple of the axis
    size; the input vector is replicated and the output is replicated
    (each device computes its row block, one tiled all-gather assembles
    the full result).  Per-row arithmetic is identical to the
    single-device ``spmv_ell``/``spmv_sell`` paths, so the distributed
    PCG reproduces their float sequences bitwise.

    ``spmv_backend="pallas"`` (SELL only) computes each device's row block
    with the per-device block kernel (``kernels.sell_spmv_block``) instead
    of the jnp gather — the collective structure (one tiled all-gather) is
    unchanged, and the kernel's interpret-mode arithmetic matches the jnp
    path bitwise.
    """
    if spmv_backend not in ("xla", "pallas"):
        raise ValueError(f"unknown spmv backend {spmv_backend!r}; expected "
                         "'xla' or 'pallas'")
    if spmv_backend == "pallas" and spmv_format != "sell":
        raise ValueError("spmv_backend='pallas' requires spmv_format='sell' "
                         "(the kernel family is SELL-w)")
    if spmv_format == "ell":
        row_eq = "rk,rkb->rb" if batched else "rk,rk->r"

        @partial(shard_map, mesh=mesh,
                 in_specs=(P(axis, None), P(axis, None), P()),
                 out_specs=P(), check_rep=False)
        def ell_block(v, c, x):
            y_loc = jnp.einsum(row_eq, v, x[c])
            return jax.lax.all_gather(y_loc, axis, tiled=True)

        return lambda x: ell_block(vals, cols, x)

    if spmv_format == "sell":
        slice_eq = "skw,skwb->swb" if batched else "skw,skw->sw"
        use_kernel = spmv_backend == "pallas"
        if use_kernel:
            # deferred: repro.kernels.__init__ imports repro.core
            from repro.kernels.sell_spmv import sell_spmv_block

        @partial(shard_map, mesh=mesh,
                 in_specs=(P(axis, None, None), P(axis, None, None), P()),
                 out_specs=P(), check_rep=False)
        def sell_block(v, c, x):
            if use_kernel:
                y_loc = sell_spmv_block(v, c, x, interpret=interpret)
            else:
                y_loc = jnp.einsum(slice_eq, v, x[c])  # (s, w) or (s, w, B)
                y_loc = y_loc.reshape((-1,) + y_loc.shape[2:])
            return jax.lax.all_gather(y_loc, axis, tiled=True)

        return lambda x: sell_block(vals, cols, x)[:n]

    raise ValueError(f"unknown spmv format {spmv_format!r}")


def pcg_iteration(spmv: Callable[[jax.Array], jax.Array],
                  precond: Callable[[jax.Array], jax.Array]):
    """One PCG step with the PRECONDITIONED pairings, as a pure function.

    The carried state is ``(x, r, p, rz)`` with ``rz = (r, z)`` from the
    previous step — exactly the body of ``_pcg_device``:

        alpha = (r, z) / (p, A p)        beta = (r2, z2) / (r, z)

    (NOT the unpreconditioned ``(r, r)`` pairings — using those lowers a
    plain-CG kernel whose roofline misses both triangular sweeps' traffic.)
    Used by ``core.partition.lower_solver_step`` for mesh dry-runs; tested
    against ``pcg`` iterates in tests/test_multidevice.py.
    """
    def step(x, r, p, rz):
        ap = spmv(p)
        alpha = rz / jnp.vdot(p, ap)
        x = x + alpha * p
        r = r - alpha * ap
        z = precond(r)
        rz_new = jnp.vdot(r, z)
        beta = rz_new / rz
        p = z + beta * p
        return x, r, p, rz_new
    return step


@dataclasses.dataclass
class PCGResult:
    x: np.ndarray
    iterations: int
    relres: float
    converged: bool
    history: np.ndarray   # relative residual norm per iteration (padded NaN)


def _pcg_device(spmv: Callable[[jax.Array], jax.Array],
                precond: Callable[[jax.Array], jax.Array],
                b: jax.Array,
                rtol: float = 1e-7,
                maxiter: int = 10_000,
                record_history: bool = False):
    """Device core of ``pcg``: pure jax in / jax out, jittable.

    ``rtol``/``maxiter``/``record_history`` are Python values (static under
    jit).  Returns ``(x, iterations, relres, history)`` as jax arrays;
    ``SolverPlan`` wraps this in a cached ``jax.jit`` so warm solves skip
    retracing entirely.
    """
    b = jnp.asarray(b)
    bnorm = jnp.linalg.norm(b)
    bnorm = jnp.where(bnorm == 0, 1.0, bnorm)

    x0 = jnp.zeros_like(b)
    r0 = b
    z0 = precond(r0)
    p0 = z0
    rz0 = jnp.vdot(r0, z0)
    # carry ||r|| in the loop state: one full-vector reduction per step
    # (cond reads the carried value instead of recomputing the norm)
    rnorm0 = jnp.linalg.norm(r0)
    hist0 = (jnp.full((maxiter + 1,), jnp.nan, dtype=b.dtype)
             if record_history else jnp.zeros((0,), dtype=b.dtype))
    if record_history:
        hist0 = hist0.at[0].set(rnorm0 / bnorm)

    def cond(state):
        _, _, _, _, rnorm, it, _ = state
        return (rnorm / bnorm >= rtol) & (it < maxiter)

    def body(state):
        x, r, p, rz, _, it, hist = state
        ap = spmv(p)
        alpha = rz / jnp.vdot(p, ap)
        x = x + alpha * p
        r = r - alpha * ap
        z = precond(r)
        rz_new = jnp.vdot(r, z)
        beta = rz_new / rz
        p = z + beta * p
        it = it + 1
        rnorm = jnp.linalg.norm(r)
        if record_history:
            hist = hist.at[it].set(rnorm / bnorm)
        return (x, r, p, rz_new, rnorm, it, hist)

    state = (x0, r0, p0, rz0, rnorm0, jnp.asarray(0), hist0)
    x, r, _, _, rnorm, it, hist = jax.lax.while_loop(cond, body, state)
    return x, it, rnorm / bnorm, hist


def pcg(spmv: Callable[[jax.Array], jax.Array],
        precond: Callable[[jax.Array], jax.Array],
        b: jax.Array,
        rtol: float = 1e-7,
        maxiter: int = 10_000,
        record_history: bool = False) -> PCGResult:
    """Standard PCG; runs fully on device, one while_loop iteration per CG step."""
    x, it, relres, hist = _pcg_device(spmv, precond, b, rtol=rtol,
                                      maxiter=maxiter,
                                      record_history=record_history)
    relres = float(relres)
    return PCGResult(x=np.asarray(x), iterations=int(it), relres=relres,
                     converged=relres < rtol, history=np.asarray(hist))


# ---------------------------------------------------------------------------
# Batched multi-RHS PCG (one while_loop for B right-hand sides).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BatchedPCGResult:
    x: np.ndarray           # (n, B) solutions
    iterations: np.ndarray  # (B,) per-RHS iteration counts
    relres: np.ndarray      # (B,) final relative residual norms
    converged: np.ndarray   # (B,) bool
    n_steps: int            # while_loop trips = max(iterations)
    # (maxiter+1, B) per-column relative residual norms (NaN once a column
    # has converged — matching the single-RHS ``pcg`` histories column for
    # column); empty when record_history=False
    history: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0, 0)))


def _pcg_batched_device(spmv: Callable[[jax.Array], jax.Array],
                        precond: Callable[[jax.Array], jax.Array],
                        b: jax.Array,
                        rtol: float = 1e-7,
                        maxiter: int = 10_000,
                        record_history: bool = False):
    """Device core of ``pcg_batched``; returns jax arrays, jittable."""
    b = jnp.asarray(b)
    if b.ndim == 1:
        raise ValueError(
            f"pcg_batched expects b of shape (n, B), got a 1-D vector of "
            f"shape {b.shape}; a single RHS must be passed as a one-column "
            f"slab b[:, None] (B = 1), or use pcg")
    if b.ndim != 2:
        raise ValueError(f"pcg_batched expects b of shape (n, B), got "
                         f"{b.shape}")
    nb = b.shape[1]
    bnorm = jnp.linalg.norm(b, axis=0)
    bnorm = jnp.where(bnorm == 0, 1.0, bnorm)

    def relres_of(r):
        return jnp.linalg.norm(r, axis=0) / bnorm

    x0 = jnp.zeros_like(b)
    r0 = b
    z0 = precond(r0)
    p0 = z0
    rz0 = jnp.einsum("nb,nb->b", r0, z0)
    relres0 = relres_of(r0)
    active0 = relres0 >= rtol
    iters0 = jnp.zeros(nb, dtype=jnp.int32)
    hist0 = (jnp.full((maxiter + 1, nb), jnp.nan, dtype=b.dtype)
             if record_history else jnp.zeros((0, nb), dtype=b.dtype))
    if record_history:
        hist0 = hist0.at[0].set(relres0)

    def cond(state):
        _, _, _, _, active, _, step, _ = state
        return jnp.any(active) & (step < maxiter)

    def body(state):
        x, r, p, rz, active, iters, step, hist = state
        ap = spmv(p)
        pap = jnp.einsum("nb,nb->b", p, ap)
        alpha = jnp.where(active, rz / pap, 0.0)
        x = x + alpha[None, :] * p
        r = r - alpha[None, :] * ap
        z = precond(r)
        rz_new = jnp.einsum("nb,nb->b", r, z)
        beta = jnp.where(active, rz_new / rz, 0.0)
        p = jnp.where(active[None, :], z + beta[None, :] * p, p)
        rz = jnp.where(active, rz_new, rz)
        iters = iters + active.astype(jnp.int32)
        relres = relres_of(r)
        if record_history:
            # a column records its residual at row == its own iteration
            # count while active; frozen columns keep their NaN padding,
            # matching the single-RHS history shape one for one (the lane
            # index dtype must match `iters` — mixed i64/i32 scatter
            # indices are a FutureWarning on the way to a hard error)
            lanes = jnp.arange(nb, dtype=iters.dtype)
            hist = hist.at[iters, lanes].set(
                jnp.where(active, relres, hist[iters, lanes]))
        active = active & (relres >= rtol)
        return (x, r, p, rz, active, iters, step + 1, hist)

    state = (x0, r0, p0, rz0, active0, iters0, jnp.asarray(0), hist0)
    x, r, _, _, _, iters, step, hist = jax.lax.while_loop(cond, body, state)
    return x, iters, relres_of(r), step, hist


def pcg_batched(spmv: Callable[[jax.Array], jax.Array],
                precond: Callable[[jax.Array], jax.Array],
                b: jax.Array,
                rtol: float = 1e-7,
                maxiter: int = 10_000,
                record_history: bool = False) -> BatchedPCGResult:
    """PCG over B right-hand sides in ONE device while_loop.

    ``spmv`` and ``precond`` map (n, B) -> (n, B) column-wise (e.g.
    ``spmv_ell_batched`` and ``HBMCPreconditioner.apply_batched``).

    Per-RHS convergence masking: a column whose relative residual drops
    below ``rtol`` gets ``alpha = beta = 0`` from then on, freezing its
    ``x``/``r``/``p``/``rz`` exactly (0 * p adds exact zeros), while the
    remaining columns keep iterating.  Each column therefore performs the
    same arithmetic sequence as a single-RHS ``pcg`` on that column up to
    XLA's reduction-order rounding, and the per-RHS iteration counts match
    the single-RHS counts one for one.

    ``record_history=True`` additionally returns per-column residual
    histories ((maxiter+1, B), NaN-padded): column j's history is frozen
    the moment it converges, matching the single-RHS ``pcg`` history of
    that column in shape and NaN pattern exactly and in values up to
    reduction-order rounding (the batched dots reduce via
    ``einsum('nb,nb->b')`` rather than ``vdot``).

    The loop runs until every column has converged (or ``maxiter``): total
    wall-clock is max(iterations) rounds, with the S sequential trisolve
    rounds amortized over all live columns — the multi-RHS workload the
    round-major kernel was built for.
    """
    x, iters, relres, step, hist = _pcg_batched_device(
        spmv, precond, b, rtol=rtol, maxiter=maxiter,
        record_history=record_history)
    relres = np.asarray(relres)
    return BatchedPCGResult(x=np.asarray(x), iterations=np.asarray(iters),
                            relres=relres, converged=relres < rtol,
                            n_steps=int(step), history=np.asarray(hist))


# ---------------------------------------------------------------------------
# Slab PCG: quantum-stepped batched PCG with slot-level entry/retirement.
#
# The serving layer (repro.serve) keeps B independent PCG solves resident in
# one (n, B) slab and advances them a bounded number of while_loop trips per
# dispatch.  Between dispatches the host retires converged columns and packs
# fresh right-hand sides into the freed slots; a ``fresh`` mask tells the
# next dispatch which columns to (re)initialize.  Continuing columns are
# carried through ``jnp.where`` untouched, so quantum boundaries do not
# perturb their float sequences: a column sees the exact same arithmetic it
# would in one uninterrupted ``_pcg_batched_device`` run at the same width.
# ---------------------------------------------------------------------------


class SlabState(NamedTuple):
    """Device-side carry of a resident PCG slab ((m, B) state vectors).

    ``fresh[j]`` marks column j for (re)initialization at the next dispatch:
    its ``r`` must already hold the embedded RHS (or zeros for an empty
    slot — zero residual initializes to ``relres = 0 < rtol``, i.e. inert).
    All other per-column entries of a fresh column are ignored and
    overwritten at dispatch entry.
    """
    x: jax.Array        # (m, B) iterates
    r: jax.Array        # (m, B) residuals (RHS for fresh columns)
    p: jax.Array        # (m, B) search directions
    rz: jax.Array       # (B,)   carried (r, z) inner products
    bnorm: jax.Array    # (B,)   ||b|| per column (1.0 for zero columns)
    active: jax.Array   # (B,)   still iterating
    iters: jax.Array    # (B,)   per-column iteration counts (int32)
    relres: jax.Array   # (B,)   last relative residual norms
    fresh: jax.Array    # (B,)   initialize at next dispatch entry


def _pcg_slab_device(spmv: Callable[[jax.Array], jax.Array],
                     precond: Callable[[jax.Array], jax.Array],
                     state: SlabState,
                     rtol: float = 1e-7,
                     maxiter: int = 10_000,
                     quantum: int = 16):
    """Advance a PCG slab by at most ``quantum`` iterations; jittable.

    Entry initialization applies only to columns with ``fresh`` set (their
    ``r`` holds the embedded RHS): exactly the ``_pcg_batched_device`` init
    per column.  The loop body performs the identical arithmetic sequence
    as ``_pcg_batched_device`` — converged/inert columns are frozen by
    ``alpha = beta = 0`` — with one addition: a per-column
    ``iters < maxiter`` cutoff (columns enter the slab at different times,
    so the global step counter cannot bound them).  Returns
    ``(SlabState, steps)`` with ``fresh`` cleared and ``steps`` the number
    of while_loop trips taken this dispatch.
    """
    x, r, p, rz, bnorm, active, iters, relres, fresh = state

    # per-column init for fresh columns; continuing columns pass through
    # every `where` bitwise-untouched (the precond/einsum results for them
    # are computed and discarded — column-wise ops, no cross-column flow)
    z = precond(r)
    rz0 = jnp.einsum("nb,nb->b", r, z)
    nrm0 = jnp.linalg.norm(r, axis=0)
    bnorm0 = jnp.where(nrm0 == 0, 1.0, nrm0)
    relres0 = nrm0 / bnorm0
    x = jnp.where(fresh[None, :], jnp.zeros_like(x), x)
    p = jnp.where(fresh[None, :], z, p)
    rz = jnp.where(fresh, rz0, rz)
    bnorm = jnp.where(fresh, bnorm0, bnorm)
    iters = jnp.where(fresh, 0, iters)
    relres = jnp.where(fresh, relres0, relres)
    active = jnp.where(fresh, relres0 >= rtol, active)

    def relres_of(rr):
        return jnp.linalg.norm(rr, axis=0) / bnorm

    def cond(carry):
        _, _, _, _, active_, _, _, step = carry
        return jnp.any(active_) & (step < quantum)

    def body(carry):
        x, r, p, rz, active, iters, _, step = carry
        ap = spmv(p)
        pap = jnp.einsum("nb,nb->b", p, ap)
        alpha = jnp.where(active, rz / pap, 0.0)
        x = x + alpha[None, :] * p
        r = r - alpha[None, :] * ap
        z = precond(r)
        rz_new = jnp.einsum("nb,nb->b", r, z)
        beta = jnp.where(active, rz_new / rz, 0.0)
        p = jnp.where(active[None, :], z + beta[None, :] * p, p)
        rz = jnp.where(active, rz_new, rz)
        iters = iters + active.astype(jnp.int32)
        relres = relres_of(r)
        active = active & (relres >= rtol) & (iters < maxiter)
        return (x, r, p, rz, active, iters, relres, step + 1)

    carry = (x, r, p, rz, active, iters, relres, jnp.asarray(0))
    x, r, p, rz, active, iters, relres, step = jax.lax.while_loop(
        cond, body, carry)
    out = SlabState(x=x, r=r, p=p, rz=rz, bnorm=bnorm, active=active,
                    iters=iters, relres=relres,
                    fresh=jnp.zeros_like(fresh))
    return out, step
