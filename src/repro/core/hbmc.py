"""Hierarchical block multi-color ordering (paper §4).

HBMC = BMC + a secondary, *local* reordering inside level-1 blocks.

Level-1 block = ``w`` consecutive BMC blocks of one color (eq. 4.1); the
secondary reordering interleaves their unknowns: round l picks the l-th
unknown of each of the w member blocks (Fig. 4.3).  The resulting matrix has
``w x w`` *diagonal* level-2 diagonal blocks (eq. 4.7), so the forward /
backward substitution becomes ``b_s`` sequential steps of ``w`` independent
lanes per level-1 block (eq. 4.17-4.18) — the SIMD/vector axis.

Colors whose block count is not a multiple of ``w`` are padded with whole
dummy blocks (paper §4.3: "the assumption is satisfied using some dummy
unknowns").
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp

from .coloring import BMCOrdering, block_multicolor_ordering
from .graph import ragged_arange


def _validate_w(w, who: str) -> int:
    """Entry-point guard: ``w`` must be a positive int.

    ``w=0`` used to emit divide-by-zero RuntimeWarnings from the padded
    block-count arithmetic and then die with an opaque ``IndexError``
    deep in the secondary-permutation scatter.
    """
    if isinstance(w, bool) or not isinstance(w, (int, np.integer)):
        raise ValueError(
            f"{who}: w must be an int, got {type(w).__name__} ({w!r})")
    if w < 1:
        raise ValueError(
            f"{who}: w must be >= 1, got {w} "
            f"(w < 1 divides by zero in the level-1 aggregation)")
    return int(w)


@dataclasses.dataclass(frozen=True)
class HBMCOrdering:
    """Complete HBMC ordering over the padded system.

    ``perm`` maps *original* old indices -> final HBMC indices.
    ``secondary_perm`` maps BMC-padded indices -> final indices (this is the
    paper's pi, used in the equivalence tests).
    """
    perm: np.ndarray
    secondary_perm: np.ndarray
    n: int                       # original dimension
    n_final: int                 # padded dimension (multiple of b_s * w)
    block_size: int              # b_s
    w: int                       # SIMD width / lane count
    n_colors: int
    lev1_per_color: np.ndarray   # \bar n(c): level-1 blocks per color
    color_start: np.ndarray      # first final index of each color (len n_c+1)
    is_dummy: np.ndarray         # bool per final index
    bmc: BMCOrdering


def hbmc_ordering(a: sp.spmatrix, block_size: int, w: int) -> HBMCOrdering:
    w = _validate_w(w, "hbmc_ordering")   # fail before the block build
    bmc = block_multicolor_ordering(a, block_size)
    return hbmc_from_bmc(bmc, w)


def hbmc_from_bmc(bmc: BMCOrdering, w: int) -> HBMCOrdering:
    w = _validate_w(w, "hbmc_from_bmc")
    b_s = bmc.block_size
    n_colors = bmc.n_colors
    m = bmc.blocks_per_color                      # blocks per color (real)
    m_pad = ((m + w - 1) // w) * w                # padded to a multiple of w
    lev1 = m_pad // w                             # \bar n(c)
    color_sizes = m_pad * b_s
    color_start = np.concatenate([[0], np.cumsum(color_sizes)])
    n_final = int(color_start[-1])

    # --- secondary reordering: BMC-padded index -> final index -------------
    # BMC padded layout: color-major, block-major, in-block offset t.
    # Final layout: color-major, level-1-block-major, round l, lane j
    #   (k-th block of a color sits at lane j = k % w of level-1 block k // w;
    #    its t-th unknown lands in round l = t).
    # One segmented expression over all (color, block) pairs at once: the
    # per-block BMC/final bases are (total_blocks,) vectors, the in-block
    # offset t broadcasts along the second axis.
    bmc_color_start = np.concatenate(
        [[0], np.cumsum(bmc.blocks_per_color * b_s)])
    secondary = np.empty(bmc.n_padded, dtype=np.int64)
    color_of = np.repeat(np.arange(n_colors), m)   # per real block
    k = ragged_arange(m)                           # block index within color
    base_bmc = bmc_color_start[color_of] + k * b_s
    base_fin = color_start[color_of] + (k // w) * (b_s * w) + (k % w)
    t = np.arange(b_s)[None, :]                    # offset inside the block
    secondary[(base_bmc[:, None] + t).ravel()] = (
        base_fin[:, None] + t * w).ravel()

    perm = secondary[bmc.perm]          # old -> bmc-padded -> final

    is_dummy = np.ones(n_final, dtype=bool)
    is_dummy[perm] = False
    # unknowns that were dummies already at BMC padding stage remain dummy
    bmc_dummy_final = secondary[np.nonzero(bmc.is_dummy)[0]]
    is_dummy[bmc_dummy_final] = True

    return HBMCOrdering(
        perm=perm, secondary_perm=secondary, n=bmc.n, n_final=n_final,
        block_size=b_s, w=w, n_colors=n_colors,
        lev1_per_color=lev1.astype(np.int64), color_start=color_start,
        is_dummy=is_dummy, bmc=bmc)


def pad_system_hbmc(a: sp.spmatrix, b: np.ndarray | None, ordering: HBMCOrdering
                    ) -> tuple[sp.csr_matrix, np.ndarray | None]:
    """Apply the full HBMC permutation, embedding into the padded system."""
    npad = ordering.n_final
    coo = sp.coo_matrix(a)
    p = ordering.perm
    rows, cols = p[coo.row], p[coo.col]
    data = coo.data                # keep the caller's dtype (f32 stays f32)
    if not np.issubdtype(data.dtype, np.floating):
        data = data.astype(np.float64)
    dummy_idx = np.nonzero(ordering.is_dummy)[0]
    rows = np.concatenate([rows, dummy_idx])
    cols = np.concatenate([cols, dummy_idx])
    data = np.concatenate([data, np.ones(len(dummy_idx), dtype=data.dtype)])
    a_bar = sp.coo_matrix((data, (rows, cols)), shape=(npad, npad)).tocsr()
    b_bar = None
    if b is not None:
        b = np.asarray(b)          # keep the caller's dtype (f32 stays f32)
        if not np.issubdtype(b.dtype, np.floating):
            # same promotion rule as the matrix data: an int RHS must not
            # flow into the float solve un-promoted
            b = b.astype(np.float64)
        b_bar = np.zeros(npad, dtype=b.dtype)
        b_bar[p] = b
    return a_bar, b_bar


def verify_level2_structure(a_bar: sp.csr_matrix, ordering: HBMCOrdering) -> bool:
    """Check eq. (4.7): every w x w level-2 diagonal block of A_bar is diagonal.

    Equivalently: unknowns occupying the same round l of the same level-1
    block (a contiguous run of w final indices) are mutually independent.
    """
    w = ordering.w
    coo = sp.coo_matrix(a_bar)
    r, c = coo.row, coo.col
    mask = (r // w == c // w) & (r != c) & (coo.data != 0)
    return not bool(mask.any())
