"""End-to-end parallel ICCG solvers: MC / BMC / HBMC (paper §5 solvers).

``solve_iccg(a, b, method=...)`` performs the full pipeline:
ordering -> permuted (padded) system -> shifted IC(0) -> step packing ->
device PCG -> solution mapped back to the original order.
"""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from . import sell
from .coloring import block_multicolor_ordering, multicolor_ordering, pad_system
from .graph import invert_perm, permute_system
from .hbmc import hbmc_from_bmc, pad_system_hbmc
from .ic0 import ic0
from .iccg import PCGResult, pcg, spmv_ell, spmv_sell
from .trisolve import build_preconditioner_from_rounds


@dataclasses.dataclass
class ICCGReport:
    method: str
    result: PCGResult
    n: int
    n_padded: int
    n_colors: int
    n_rounds: int           # sequential rounds per triangular solve
    setup_seconds: float
    solve_seconds: float
    lane_occupancy: float   # mean live lanes / padded lanes per round
    x: np.ndarray           # solution in ORIGINAL ordering


def _report(method, res, n, npad, ncol, tables, t_setup, t_solve, x):
    live = tables.live.astype(np.float64)
    occ = float(np.mean(live / tables.rows.shape[1])) if len(live) else 1.0
    return ICCGReport(method=method, result=res, n=n, n_padded=npad,
                      n_colors=ncol, n_rounds=int(tables.rows.shape[0]),
                      setup_seconds=t_setup, solve_seconds=t_solve,
                      lane_occupancy=occ, x=x)


def solve_iccg(a: sp.spmatrix, b: np.ndarray, method: str = "hbmc",
               block_size: int = 32, w: int = 8, shift: float = 0.0,
               rtol: float = 1e-7, maxiter: int = 10_000,
               spmv_format: str = "ell", dtype=jnp.float64,
               record_history: bool = False) -> ICCGReport:
    a = sp.csr_matrix(a)
    n = a.shape[0]
    b = np.asarray(b, dtype=np.float64)
    t0 = time.perf_counter()

    if method == "mc":
        mc = multicolor_ordering(a)
        a_bar, b_bar = permute_system(a, b, mc.perm)
        perm = mc.perm
        npad, ncol = n, mc.n_colors
        fwd_rounds = sell.rounds_mc(mc, reverse=False)
        bwd_rounds = sell.rounds_mc(mc, reverse=True)
        drop = None
    elif method == "bmc":
        bmc = block_multicolor_ordering(a, block_size)
        a_bar, b_bar = pad_system(a, b, bmc)
        perm = bmc.perm
        npad, ncol = bmc.n_padded, bmc.n_colors
        fwd_rounds = sell.rounds_bmc(bmc, reverse=False)
        bwd_rounds = sell.rounds_bmc(bmc, reverse=True)
        drop = bmc.is_dummy
    elif method == "hbmc":
        bmc = block_multicolor_ordering(a, block_size)
        hb = hbmc_from_bmc(bmc, w)
        a_bar, b_bar = pad_system_hbmc(a, b, hb)
        perm = hb.perm
        npad, ncol = hb.n_final, hb.n_colors
        fwd_rounds = sell.rounds_hbmc(hb, reverse=False)
        bwd_rounds = sell.rounds_hbmc(hb, reverse=True)
        drop = hb.is_dummy
    elif method == "natural":
        a_bar, b_bar = a, b
        perm = np.arange(n)
        npad, ncol = n, n
        fwd_rounds = sell.rounds_natural(n, reverse=False)
        bwd_rounds = sell.rounds_natural(n, reverse=True)
        drop = None
    else:
        raise ValueError(f"unknown method {method!r}")

    l_bar = ic0(a_bar, shift=shift)
    precond = build_preconditioner_from_rounds(
        l_bar, fwd_rounds, bwd_rounds, drop_mask=drop, dtype=dtype)

    if spmv_format == "sell":
        sm = sell.pack_sell(a_bar, w)
        vals = jnp.asarray(sm.vals, dtype=dtype)
        cols = jnp.asarray(sm.cols)
        spmv = lambda x: spmv_sell(vals, cols, x, sm.n)
    else:
        cols_h, vals_h = sell.pack_ell(a_bar)
        vals = jnp.asarray(vals_h, dtype=dtype)
        cols = jnp.asarray(cols_h)
        spmv = lambda x: spmv_ell(vals, cols, x)

    b_dev = jnp.asarray(b_bar, dtype=dtype)
    t1 = time.perf_counter()
    res = pcg(spmv, precond, b_dev, rtol=rtol, maxiter=maxiter,
              record_history=record_history)
    t2 = time.perf_counter()

    x = np.zeros(n, dtype=np.float64)
    x[:] = res.x[perm]  # res.x is in new order; x_orig[i] = x_bar[perm[i]]
    return _report(method, res, n, npad, ncol, precond.fwd_host_live
                   if hasattr(precond, "fwd_host_live") else _LiveShim(
                       fwd_rounds, drop),
                   t1 - t0, t2 - t1, x)


class _LiveShim:
    """Adapter exposing .live and .rows like StepTables for reporting."""
    def __init__(self, rounds, drop):
        if drop is not None:
            rounds = [r[~drop[r]] for r in rounds]
            rounds = [r for r in rounds if len(r)]
        self.live = np.array([len(r) for r in rounds], dtype=np.int32)
        rmax = int(self.live.max(initial=1))
        self.rows = np.zeros((len(rounds), rmax), dtype=np.int32)
