"""End-to-end parallel ICCG solvers: MC / BMC / HBMC (paper §5 solvers).

``solve_iccg(a, b, method=..., backend=..., layout=...)`` performs the full
pipeline: ordering -> permuted (padded) system -> shifted round-parallel
IC(0) -> vectorized step packing -> device PCG -> solution mapped back to
the original order.  Both front-ends are thin wrappers over
``core.plan.SolverPlan`` (build a plan, solve once); workloads that solve
against one matrix repeatedly should hold the plan instead:

    plan = build_plan(a, method="hbmc", block_size=16, w=8)
    rep = plan.solve(b)            # zero host-side setup after the first
    rep = plan.solve_batched(bb)   # (n, B) multi-RHS, same cached setup
    plan.refactor(a_new)           # new values, same pattern: numeric only

``backend`` picks the triangular-solve implementation ("xla" substitution
or the Pallas kernel); ``layout`` picks the coordinate system of the PCG
loop ("round_major" native hot loop, "index" the pre-refactor baseline).

Reports carry the solution in the CALLER's ordering in both ``report.x``
and ``report.result.x`` (shape (n,) / (n, B)); the internal padded
round-major state never leaks out of the plan.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

# re-exported so existing imports (benchmarks, tests) keep working
from .plan import (BatchedICCGReport, ICCGReport, SolverPlan,  # noqa: F401
                   _build_operators, _occupancy_from_rounds, _order_system,
                   _System, build_plan)


def solve_iccg(a: sp.spmatrix, b: np.ndarray, method: str = "hbmc",
               block_size: int = 32, w: int = 8, shift: float = 0.0,
               rtol: float = 1e-7, maxiter: int = 10_000,
               spmv_format: str = "ell", dtype=jnp.float64,
               record_history: bool = False, backend: str = "xla",
               interpret: bool | None = None,
               layout: str = "round_major", mesh=None,
               mesh_axis: str = "data",
               lane_multiple: int = 1,
               spmv_backend: str = "xla",
               scheduler: str = "coloring") -> ICCGReport:
    """One-shot solve: build a ``SolverPlan``, solve, fold setup into the
    report's ``setup_seconds``.  ``mesh=`` distributes the solve (see
    ``build_plan``); ``spmv_backend="pallas"`` (with
    ``spmv_format="sell"``) runs the SpMV through the Pallas SELL-w
    kernel family."""
    plan = build_plan(a, method=method, block_size=block_size, w=w,
                      shift=shift, spmv_format=spmv_format, dtype=dtype,
                      backend=backend, interpret=interpret, layout=layout,
                      mesh=mesh, mesh_axis=mesh_axis,
                      lane_multiple=lane_multiple,
                      spmv_backend=spmv_backend, scheduler=scheduler)
    rep = plan.solve(b, rtol=rtol, maxiter=maxiter,
                     record_history=record_history)
    rep.setup_seconds += plan.timings.total
    return rep


def solve_iccg_batched(a: sp.spmatrix, b: np.ndarray, method: str = "hbmc",
                       block_size: int = 32, w: int = 8, shift: float = 0.0,
                       rtol: float = 1e-7, maxiter: int = 10_000,
                       spmv_format: str = "ell", dtype=jnp.float64,
                       backend: str = "xla", interpret: bool | None = None,
                       layout: str = "round_major",
                       record_history: bool = False, mesh=None,
                       mesh_axis: str = "data",
                       lane_multiple: int = 1,
                       spmv_backend: str = "xla",
                       scheduler: str = "coloring") -> BatchedICCGReport:
    """Solve A x_j = b_j for all columns of ``b`` ((n, B)) in one PCG loop."""
    # the caller named `dtype=` explicitly, so casting b to it here is the
    # documented opt-in; plan.solve_batched itself rejects float-dtype
    # mismatches rather than silently casting
    b = np.asarray(b, dtype=np.dtype(jnp.dtype(dtype)))
    if b.ndim != 2:
        raise ValueError(f"solve_iccg_batched expects b of shape (n, B), "
                         f"got {b.shape}")
    plan = build_plan(a, method=method, block_size=block_size, w=w,
                      shift=shift, spmv_format=spmv_format, dtype=dtype,
                      backend=backend, interpret=interpret, layout=layout,
                      mesh=mesh, mesh_axis=mesh_axis,
                      lane_multiple=lane_multiple,
                      spmv_backend=spmv_backend, scheduler=scheduler)
    rep = plan.solve_batched(b, rtol=rtol, maxiter=maxiter,
                             record_history=record_history)
    rep.setup_seconds += plan.timings.total
    return rep
