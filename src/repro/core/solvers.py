"""End-to-end parallel ICCG solvers: MC / BMC / HBMC (paper §5 solvers).

``solve_iccg(a, b, method=..., backend=..., layout=...)`` performs the full
pipeline: ordering -> permuted (padded) system -> shifted IC(0) -> step
packing -> device PCG -> solution mapped back to the original order.
``backend`` picks the triangular-solve implementation ("xla" substitution
or the Pallas kernel); ``layout`` picks the coordinate system of the PCG
loop:

  * ``"round_major"`` (default) — the WHOLE loop (SpMV operands, both
    triangular sweeps, all PCG state) lives in execution-order round-major
    coordinates.  Permutation happens exactly twice per solve (b in, x
    out); the preconditioner is one fused fwd+bwd pass.
  * ``"index"`` — the pre-refactor path: state in permuted-matrix index
    order, the solve layout re-gathered/scattered on every apply.  Kept as
    the benchmark baseline and for the sharded path (core/partition.py).

``solve_iccg_batched(a, b2d, ...)`` is the multi-RHS front-end: all B
right-hand sides advance through ONE device while_loop with per-RHS
convergence masking, sharing every gather of the packed tables.
"""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from . import sell
from .coloring import block_multicolor_ordering, multicolor_ordering, pad_system
from .graph import permute_system
from .hbmc import hbmc_from_bmc, pad_system_hbmc
from .ic0 import ic0
from .iccg import (BatchedPCGResult, PCGResult, pcg, pcg_batched, spmv_ell,
                   spmv_ell_batched, spmv_sell, spmv_sell_batched)
from .trisolve import (LAYOUTS, build_preconditioner_from_rounds,
                       build_round_major_preconditioner_from_rounds)


@dataclasses.dataclass
class ICCGReport:
    method: str
    result: PCGResult
    n: int
    n_padded: int
    n_colors: int
    n_rounds: int           # sequential rounds per triangular solve
    setup_seconds: float
    solve_seconds: float
    lane_occupancy: float   # mean live lanes / padded lanes per round
    x: np.ndarray           # solution in ORIGINAL ordering
    backend: str = "xla"
    layout: str = "round_major"


@dataclasses.dataclass
class BatchedICCGReport:
    method: str
    result: BatchedPCGResult
    n: int
    n_padded: int
    n_colors: int
    n_rounds: int
    setup_seconds: float
    solve_seconds: float
    lane_occupancy: float
    x: np.ndarray           # (n, B) solutions in ORIGINAL ordering
    backend: str = "xla"
    layout: str = "round_major"


@dataclasses.dataclass
class _System:
    """Ordered/padded system plus everything needed to run + undo it."""
    a_bar: sp.csr_matrix
    b_bar: np.ndarray | None
    perm: np.ndarray        # original index -> padded-ordered index
    n: int
    n_padded: int
    n_colors: int
    fwd_rounds: list
    bwd_rounds: list
    drop: np.ndarray | None


def _order_system(a: sp.csr_matrix, b: np.ndarray | None, method: str,
                  block_size: int, w: int) -> _System:
    n = a.shape[0]
    if method == "mc":
        mc = multicolor_ordering(a)
        a_bar, b_bar = permute_system(a, b, mc.perm)
        return _System(a_bar, b_bar, mc.perm, n, n, mc.n_colors,
                       sell.rounds_mc(mc, reverse=False),
                       sell.rounds_mc(mc, reverse=True), None)
    if method == "bmc":
        bmc = block_multicolor_ordering(a, block_size)
        a_bar, b_bar = pad_system(a, b, bmc)
        return _System(a_bar, b_bar, bmc.perm, n, bmc.n_padded, bmc.n_colors,
                       sell.rounds_bmc(bmc, reverse=False),
                       sell.rounds_bmc(bmc, reverse=True), bmc.is_dummy)
    if method == "hbmc":
        bmc = block_multicolor_ordering(a, block_size)
        hb = hbmc_from_bmc(bmc, w)
        a_bar, b_bar = pad_system_hbmc(a, b, hb)
        return _System(a_bar, b_bar, hb.perm, n, hb.n_final, hb.n_colors,
                       sell.rounds_hbmc(hb, reverse=False),
                       sell.rounds_hbmc(hb, reverse=True), hb.is_dummy)
    if method == "natural":
        return _System(a, b, np.arange(n), n, n, n,
                       sell.rounds_natural(n, reverse=False),
                       sell.rounds_natural(n, reverse=True), None)
    raise ValueError(f"unknown method {method!r}")


def _build_spmv(a_bar, spmv_format: str, w: int, dtype, batched: bool):
    if spmv_format == "sell":
        sm = sell.pack_sell(a_bar, w)
        vals = jnp.asarray(sm.vals, dtype=dtype)
        cols = jnp.asarray(sm.cols)
        if batched:
            return lambda x: spmv_sell_batched(vals, cols, x, sm.n)
        return lambda x: spmv_sell(vals, cols, x, sm.n)
    cols_h, vals_h = sell.pack_ell(a_bar)
    vals = jnp.asarray(vals_h, dtype=dtype)
    cols = jnp.asarray(cols_h)
    if batched:
        return lambda x: spmv_ell_batched(vals, cols, x)
    return lambda x: spmv_ell(vals, cols, x)


def _build_operators(sysd: _System, shift: float, spmv_format: str, w: int,
                     dtype, backend: str, interpret: bool | None,
                     layout: str, batched: bool):
    """IC(0) + preconditioner + SpMV in the requested layout.

    Returns ``(precond, spmv_fn, rm_layout)``: the preconditioner object
    (callable for single RHS, ``.apply_batched`` for multi-RHS) and, for
    layout "round_major", the b-in/x-out permutation pair (None for the
    index-space path).  ``batched`` selects the SpMV variant only.
    """
    if layout not in LAYOUTS:
        raise ValueError(f"unknown layout {layout!r}; expected one of "
                         f"{LAYOUTS}")
    l_bar = ic0(sysd.a_bar, shift=shift)
    if layout == "round_major":
        precond, rm = build_round_major_preconditioner_from_rounds(
            l_bar, sysd.fwd_rounds, sysd.bwd_rounds, drop_mask=sysd.drop,
            dtype=dtype, backend=backend, interpret=interpret)
        a_op = sell.permute_round_major(sysd.a_bar, rm)
    else:
        precond, rm = build_preconditioner_from_rounds(
            l_bar, sysd.fwd_rounds, sysd.bwd_rounds, drop_mask=sysd.drop,
            dtype=dtype, backend=backend, interpret=interpret), None
        a_op = sysd.a_bar
    spmv = _build_spmv(a_op, spmv_format, w, dtype, batched=batched)
    return precond, spmv, rm


def solve_iccg(a: sp.spmatrix, b: np.ndarray, method: str = "hbmc",
               block_size: int = 32, w: int = 8, shift: float = 0.0,
               rtol: float = 1e-7, maxiter: int = 10_000,
               spmv_format: str = "ell", dtype=jnp.float64,
               record_history: bool = False, backend: str = "xla",
               interpret: bool | None = None,
               layout: str = "round_major") -> ICCGReport:
    a = sp.csr_matrix(a)
    b = np.asarray(b, dtype=np.dtype(jnp.dtype(dtype)))
    t0 = time.perf_counter()

    sysd = _order_system(a, b, method, block_size, w)
    precond, spmv, rm = _build_operators(
        sysd, shift, spmv_format, w, dtype, backend, interpret, layout,
        batched=False)

    b_host = rm.embed(sysd.b_bar) if rm is not None else sysd.b_bar
    b_dev = jnp.asarray(b_host, dtype=dtype)
    t1 = time.perf_counter()
    res = pcg(spmv, precond, b_dev, rtol=rtol, maxiter=maxiter,
              record_history=record_history)
    t2 = time.perf_counter()

    x_bar = rm.extract(res.x) if rm is not None else res.x
    x = np.asarray(x_bar[sysd.perm])  # x_orig[i] = x_bar[perm[i]]
    return ICCGReport(
        method=method, result=res, n=sysd.n, n_padded=sysd.n_padded,
        n_colors=sysd.n_colors, n_rounds=precond.n_rounds,
        setup_seconds=t1 - t0, solve_seconds=t2 - t1,
        lane_occupancy=_occupancy_from_rounds(sysd.fwd_rounds, sysd.drop),
        x=x, backend=backend, layout=layout)


def solve_iccg_batched(a: sp.spmatrix, b: np.ndarray, method: str = "hbmc",
                       block_size: int = 32, w: int = 8, shift: float = 0.0,
                       rtol: float = 1e-7, maxiter: int = 10_000,
                       spmv_format: str = "ell", dtype=jnp.float64,
                       backend: str = "xla", interpret: bool | None = None,
                       layout: str = "round_major") -> BatchedICCGReport:
    """Solve A x_j = b_j for all columns of ``b`` ((n, B)) in one PCG loop."""
    a = sp.csr_matrix(a)
    np_dtype = np.dtype(jnp.dtype(dtype))
    b = np.asarray(b, dtype=np_dtype)
    if b.ndim != 2:
        raise ValueError(f"solve_iccg_batched expects b of shape (n, B), "
                         f"got {b.shape}")
    t0 = time.perf_counter()

    sysd = _order_system(a, None, method, block_size, w)
    precond, spmv, rm = _build_operators(
        sysd, shift, spmv_format, w, dtype, backend, interpret, layout,
        batched=True)

    b_bar = np.zeros((sysd.n_padded, b.shape[1]), dtype=np_dtype)
    b_bar[sysd.perm] = b                  # embed every RHS into padded order
    b_host = rm.embed(b_bar) if rm is not None else b_bar
    b_dev = jnp.asarray(b_host, dtype=dtype)
    t1 = time.perf_counter()
    res = pcg_batched(spmv, precond.apply_batched, b_dev, rtol=rtol,
                      maxiter=maxiter)
    t2 = time.perf_counter()

    x_bar = rm.extract(res.x) if rm is not None else res.x
    x = np.asarray(x_bar[sysd.perm])      # (n, B) back in original order
    return BatchedICCGReport(
        method=method, result=res, n=sysd.n, n_padded=sysd.n_padded,
        n_colors=sysd.n_colors, n_rounds=precond.n_rounds,
        setup_seconds=t1 - t0, solve_seconds=t2 - t1,
        lane_occupancy=_occupancy_from_rounds(sysd.fwd_rounds, sysd.drop),
        x=x, backend=backend, layout=layout)


def _occupancy_from_rounds(rounds, drop) -> float:
    if drop is not None:
        rounds = [r[~drop[r]] for r in rounds]
        rounds = [r for r in rounds if len(r)]
    live = np.array([len(r) for r in rounds], dtype=np.float64)
    rmax = live.max(initial=1.0)
    return float(np.mean(live / rmax)) if len(live) else 1.0
