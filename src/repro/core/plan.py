"""Reusable solver plan: factor once, solve many (the setup pipeline).

``SolverPlan`` owns everything ``solve_iccg`` used to rebuild from scratch
on every call:

    ordering            MC / BMC / HBMC permutation + padded system
    rounds              execution-ordered independent row sets
    IC(0) structure     pattern-only analysis (``ic0_structure``)
    IC(0) factor        round-parallel numeric phase (``ic0_refactor``)
    packed tables       vectorized ``pack_factor`` + fused round-major form
    SpMV operand        ELL / SELL packing of the (round-major) matrix
    jitted PCG          one cached ``jax.jit`` per (batched, rtol, maxiter,
                        record_history) signature

``plan.solve(b)`` / ``plan.solve_batched(B)`` perform ZERO host-side setup:
the only per-solve host work is embedding ``b`` into the solve layout and
extracting ``x`` back out.  ``plan.refactor(a_new)`` re-runs only the
numeric factorization + numeric repack for a matrix with the identical
sparsity pattern (the implicit time-stepping workload — see
``examples/timestepping.py``), skipping ordering, rounds, and symbolic
analysis entirely.

``solve_iccg`` / ``solve_iccg_batched`` (core/solvers.py) are thin wrappers:
build a plan, solve once.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import sell
from .coloring import (_validate_block_size, build_blocks, color_blocks,
                       multicolor_ordering, pad_system)
from .graph import adjacency_lists, level_sets, permute_system
from .hbmc import _validate_w, hbmc_from_bmc, pad_system_hbmc
from .ic0 import FactorBreakdownError, ic0_refactor, ic0_structure
from .iccg import (DIVERGENCE_FACTOR, STAGNATION_WINDOW,
                   BatchedPCGResult, PCGResult, SlabState,
                   _pcg_batched_device, _pcg_device, _pcg_slab_device,
                   make_sharded_spmv, spmv_ell, spmv_ell_batched, spmv_sell,
                   spmv_sell_batched, status_name)
from .trisolve import (BACKENDS, LAYOUTS, DistributedRoundMajorPreconditioner,
                       HBMCPreconditioner, RoundMajorPreconditioner,
                       build_preconditioner_from_rounds,
                       build_round_major_preconditioner_from_rounds,
                       shard_fused_tables)


@dataclasses.dataclass
class ICCGReport:
    method: str
    result: PCGResult       # result.x is in the caller's (original) ordering
    n: int
    n_padded: int
    n_colors: int
    n_rounds: int           # sequential rounds per triangular solve
    setup_seconds: float
    solve_seconds: float
    lane_occupancy: float   # mean live lanes / padded lanes per round
    x: np.ndarray           # solution in ORIGINAL ordering (== result.x)
    backend: str = "xla"
    layout: str = "round_major"
    spmv_backend: str = "xla"
    scheduler: str = "coloring"


@dataclasses.dataclass
class BatchedICCGReport:
    method: str
    result: BatchedPCGResult  # result.x is (n, B) in the caller's ordering
    n: int
    n_padded: int
    n_colors: int
    n_rounds: int
    setup_seconds: float
    solve_seconds: float
    lane_occupancy: float
    x: np.ndarray           # (n, B) solutions in ORIGINAL ordering (== result.x)
    backend: str = "xla"
    layout: str = "round_major"
    spmv_backend: str = "xla"
    scheduler: str = "coloring"


@dataclasses.dataclass
class SetupBreakdown:
    """Host-side setup wall-clock, by pipeline stage (seconds).

    The ordering stage splits further (``ordering`` is their sum plus
    the permute/pad assembly): ``block_build`` is the BMC block growth,
    ``color`` the quotient-graph coloring + permutation assembly,
    ``aggregate`` the HBMC level-1 interleaving, ``schedule`` the
    level-set sweep of ``scheduler="levelset"`` plans.  Stages a method
    or scheduler does not run stay 0.0.
    """
    ordering: float
    factor: float           # IC(0): structure analysis + numeric phase
    pack: float             # step packing + fuse + SpMV operand + transfer
    total: float
    block_build: float = 0.0
    color: float = 0.0
    aggregate: float = 0.0
    schedule: float = 0.0


@dataclasses.dataclass
class _System:
    """Ordered/padded system plus everything needed to run + undo it."""
    a_bar: sp.csr_matrix
    b_bar: np.ndarray | None
    perm: np.ndarray        # original index -> padded-ordered index
    n: int
    n_padded: int
    n_colors: int
    fwd_rounds: list
    bwd_rounds: list
    drop: np.ndarray | None
    # re-applies the SAME ordering to a new matrix (refactor path)
    apply_ordering: Callable[[sp.spmatrix], sp.csr_matrix] | None = None
    # per-stage wall clock of the ordering pipeline (SetupBreakdown keys)
    ordering_stages: dict[str, float] | None = None


# Round-schedule backends behind ``build_plan(scheduler=...)``.  Every
# scheduler fills the same fwd/bwd-rounds contract of ``_System`` (bwd is
# exactly the reversed fwd round list), so everything downstream — IC(0)
# structure, StepTables, the fused sweep, sharding — is scheduler-blind.
SCHEDULERS = ("coloring", "levelset")


def _levelset_rounds(a_bar: sp.spmatrix) -> tuple[list, list, float]:
    """Replace color rounds with dependency-level rounds on ``a_bar``.

    Level sets are the minimal-round legal schedule for the (already
    ordered/padded) pattern: on patterns where coloring degrades to many
    thin rounds, levels recover the widest legal parallelism.  Dummy
    rows are diagonal-only, land in level 0, and stay masked by the
    plan's drop mask.  Returns (fwd_rounds, bwd_rounds, seconds).
    """
    t0 = time.perf_counter()
    level, counts = level_sets(a_bar)
    fwd = sell.rounds_levelset(level, counts)
    return fwd, fwd[::-1], time.perf_counter() - t0


def _order_system(a: sp.csr_matrix, b: np.ndarray | None, method: str,
                  block_size: int, w: int,
                  scheduler: str = "coloring") -> _System:
    n = a.shape[0]
    stages: dict[str, float] = {}

    def _bmc_stages():
        # shared symmetrized adjacency: computed once, reused by both
        # stages (the block build and the quotient-graph contraction)
        t0 = time.perf_counter()
        adjacency = adjacency_lists(a)
        part = build_blocks(a, block_size, adjacency=adjacency)
        t1 = time.perf_counter()
        bmc = color_blocks(a, part, block_size, adjacency=adjacency)
        stages["block_build"] = t1 - t0
        stages["color"] = time.perf_counter() - t1
        return bmc

    if method == "mc":
        mc = multicolor_ordering(a)
        a_bar, b_bar = permute_system(a, b, mc.perm)
        sysd = _System(a_bar, b_bar, mc.perm, n, n, mc.n_colors,
                       sell.rounds_mc(mc, reverse=False),
                       sell.rounds_mc(mc, reverse=True), None,
                       lambda a2: permute_system(a2, None, mc.perm)[0])
    elif method == "bmc":
        bmc = _bmc_stages()
        a_bar, b_bar = pad_system(a, b, bmc)
        sysd = _System(a_bar, b_bar, bmc.perm, n, bmc.n_padded, bmc.n_colors,
                       sell.rounds_bmc(bmc, reverse=False),
                       sell.rounds_bmc(bmc, reverse=True), bmc.is_dummy,
                       lambda a2: pad_system(a2, None, bmc)[0])
    elif method == "hbmc":
        bmc = _bmc_stages()
        t0 = time.perf_counter()
        hb = hbmc_from_bmc(bmc, w)
        stages["aggregate"] = time.perf_counter() - t0
        a_bar, b_bar = pad_system_hbmc(a, b, hb)
        sysd = _System(a_bar, b_bar, hb.perm, n, hb.n_final, hb.n_colors,
                       sell.rounds_hbmc(hb, reverse=False),
                       sell.rounds_hbmc(hb, reverse=True), hb.is_dummy,
                       lambda a2: pad_system_hbmc(a2, None, hb)[0])
    elif method == "natural":
        sysd = _System(a, b, np.arange(n), n, n, n,
                       sell.rounds_natural(n, reverse=False),
                       sell.rounds_natural(n, reverse=True), None,
                       lambda a2: sp.csr_matrix(a2))
    else:
        raise ValueError(f"unknown method {method!r}")

    if scheduler == "levelset":
        # keep the method's ordering/padding (and so its cache-locality
        # and fill properties) but re-derive the rounds from the actual
        # dependency levels of the ordered pattern
        fwd, bwd, secs = _levelset_rounds(sysd.a_bar)
        sysd.fwd_rounds, sysd.bwd_rounds = fwd, bwd
        stages["schedule"] = secs
    elif scheduler != "coloring":
        raise ValueError(f"unknown scheduler {scheduler!r}; expected one "
                         f"of {SCHEDULERS}")
    sysd.ordering_stages = stages
    return sysd


def _pack_spmv(a_op: sp.spmatrix, spmv_format: str, w: int, dtype
               ) -> tuple[jax.Array, jax.Array, int]:
    """Pack a matrix for SpMV; returns (vals, cols, n) device operands."""
    if spmv_format == "sell":
        sm = sell.pack_sell(a_op, w)
        return (jnp.asarray(sm.vals, dtype=dtype), jnp.asarray(sm.cols),
                sm.n)
    cols_h, vals_h = sell.pack_ell(a_op)
    return (jnp.asarray(vals_h, dtype=dtype), jnp.asarray(cols_h),
            a_op.shape[0])


def _make_spmv(spmv_format: str, n: int, vals, cols, batched: bool,
               spmv_backend: str = "xla",
               interpret: bool | None = None) -> Callable:
    """SpMV closure over (possibly traced) packed operands.

    ``spmv_backend="pallas"`` (SELL only) routes through the
    ``kernels.sell_spmv`` family instead of the jnp gather/einsum path —
    bitwise-identical arithmetic in interpret mode, dense slice-tiled VMEM
    traffic when compiled on TPU.
    """
    if spmv_backend == "pallas":
        if spmv_format != "sell":
            raise ValueError("spmv_backend='pallas' requires "
                             "spmv_format='sell' (the kernel family is "
                             "SELL-w)")
        # deferred: repro.kernels.__init__ imports repro.core
        from repro.kernels.sell_spmv import sell_spmv, sell_spmv_batched
        if batched:
            return lambda x: sell_spmv_batched(vals, cols, x,
                                               interpret=interpret)[:n]
        return lambda x: sell_spmv(vals, cols, x, interpret=interpret)[:n]
    if spmv_format == "sell":
        if batched:
            return lambda x: spmv_sell_batched(vals, cols, x, n)
        return lambda x: spmv_sell(vals, cols, x, n)
    if batched:
        return lambda x: spmv_ell_batched(vals, cols, x)
    return lambda x: spmv_ell(vals, cols, x)


def _build_spmv_ops(a_op: sp.spmatrix, spmv_format: str, w: int, dtype,
                    spmv_backend: str = "xla",
                    interpret: bool | None = None
                    ) -> tuple[Callable, Callable]:
    """Pack a matrix for SpMV; returns (single-RHS, multi-RHS) closures
    sharing one set of device operands."""
    vals, cols, n = _pack_spmv(a_op, spmv_format, w, dtype)
    return (_make_spmv(spmv_format, n, vals, cols, batched=False,
                       spmv_backend=spmv_backend, interpret=interpret),
            _make_spmv(spmv_format, n, vals, cols, batched=True,
                       spmv_backend=spmv_backend, interpret=interpret))


def _build_preconditioner(l_bar, sysd: _System, dtype, backend: str,
                          interpret: bool | None, layout: str,
                          lane_multiple: int = 1):
    """Factor -> preconditioner (+ layout object for round_major)."""
    if layout == "round_major":
        return build_round_major_preconditioner_from_rounds(
            l_bar, sysd.fwd_rounds, sysd.bwd_rounds, drop_mask=sysd.drop,
            dtype=dtype, backend=backend, interpret=interpret,
            lane_multiple=lane_multiple)
    return build_preconditioner_from_rounds(
        l_bar, sysd.fwd_rounds, sysd.bwd_rounds, drop_mask=sysd.drop,
        dtype=dtype, backend=backend, interpret=interpret), None


# Manteuffel-style shift escalation (on_breakdown="escalate"): retry the
# numeric sweep with shift + extra, doubling `extra` from _ESCALATION_START,
# until the factor is clean (zero clamped pivots, all-finite data) or the
# attempt budget runs out.
_ESCALATION_START = 1e-3
_MAX_ESCALATIONS = 16
ON_BREAKDOWN = ("clamp", "raise", "escalate")


def _occupancy_from_rounds(rounds, drop) -> float:
    if drop is not None:
        rounds = [r[~drop[r]] for r in rounds]
        rounds = [r for r in rounds if len(r)]
    live = np.array([len(r) for r in rounds], dtype=np.float64)
    rmax = live.max(initial=1.0)
    return float(np.mean(live / rmax)) if len(live) else 1.0


class SolverPlan:
    """Factor-once / solve-many ICCG plan (see module docstring).

    Build with ``build_plan(a, ...)`` (or the constructor directly).  The
    plan caches the ordering, rounds, IC(0) structure, fused round-major
    tables, packed SpMV operand and jitted PCG; ``solve``/``solve_batched``
    reuse all of it, ``refactor`` renews only the numeric parts.

    ``setup_count`` counts host-side setup passes (initial build and every
    ``refactor``); it must NOT change across ``solve`` calls — asserted by
    tests/test_setup_plan.py.
    """

    def __init__(self, a: sp.spmatrix, method: str = "hbmc",
                 block_size: int = 32, w: int = 8, shift: float = 0.0,
                 spmv_format: str = "ell", dtype=jnp.float64,
                 backend: str = "xla", interpret: bool | None = None,
                 layout: str = "round_major", mesh: Mesh | None = None,
                 mesh_axis: str = "data", lane_multiple: int = 1,
                 spmv_backend: str = "xla", on_breakdown: str = "clamp",
                 validate: str = "off", scheduler: str = "coloring"):
        # deferred: repro.analysis is jax-free but imports nothing from
        # core.plan, so this only guards against future cycles
        from repro.analysis.schedule import VALIDATE_MODES
        if validate not in VALIDATE_MODES:
            raise ValueError(f"unknown validate mode {validate!r}; "
                             f"expected one of {VALIDATE_MODES}")
        if scheduler not in SCHEDULERS:
            raise ValueError(f"unknown scheduler {scheduler!r}; expected "
                             f"one of {SCHEDULERS}")
        # fail fast with the argument's name, before any ordering work:
        # block_size=0 / w=0 used to flow through and corrupt the plan
        block_size = _validate_block_size(block_size, "build_plan")
        w = _validate_w(w, "build_plan")
        if on_breakdown not in ON_BREAKDOWN:
            raise ValueError(f"unknown on_breakdown {on_breakdown!r}; "
                             f"expected one of {ON_BREAKDOWN}")
        if layout not in LAYOUTS:
            raise ValueError(f"unknown layout {layout!r}; expected one of "
                             f"{LAYOUTS}")
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; expected one of "
                             f"{BACKENDS}")
        if spmv_backend not in BACKENDS:
            raise ValueError(f"unknown spmv backend {spmv_backend!r}; "
                             f"expected one of {BACKENDS}")
        if spmv_backend == "pallas" and spmv_format != "sell":
            raise ValueError("spmv_backend='pallas' requires "
                             "spmv_format='sell' (the kernel family is "
                             "SELL-w)")
        if mesh is not None:
            if layout != "round_major":
                raise ValueError("mesh= requires layout='round_major' (the "
                                 "sharded apply is the fused round-major "
                                 "sweep)")
            if backend != "xla":
                raise ValueError("mesh= requires backend='xla' (the Pallas "
                                 "kernel is single-device; shard with the "
                                 "XLA sweep)")
            if mesh_axis not in mesh.axis_names:
                raise ValueError(f"mesh has no axis {mesh_axis!r}; axes are "
                                 f"{mesh.axis_names}")
            # lane axis must shard evenly: fold the axis size into the lane
            # padding (a single-device plan with the same lane_multiple is
            # bitwise identical — the parity oracle of the tests)
            lane_multiple = int(np.lcm(lane_multiple,
                                       mesh.shape[mesh_axis]))
        self.method = method
        self.scheduler = scheduler
        self.block_size = block_size
        self.w = w
        self.shift = shift
        self.on_breakdown = on_breakdown
        self.validate = validate
        # factor-health record, refreshed by every _factor pass
        self.effective_shift = shift
        self.clamped_pivots = 0
        self.shift_schedule: list[tuple[float, int]] = []
        self.spmv_format = spmv_format
        self.spmv_backend = spmv_backend
        self.dtype = dtype
        self.backend = backend
        self.interpret = interpret
        self.layout = layout
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self.lane_multiple = max(int(lane_multiple), 1)
        self._np_dtype = np.dtype(jnp.dtype(dtype))
        self._pcg_cache: dict[tuple, Any] = {}
        self.setup_count = 0
        self.refactor_count = 0
        # bumped only while a PCG signature is being (re)traced
        self._trace_count = 0

        a = sp.csr_matrix(a)
        a.sort_indices()
        # original pattern kept for the refactor structure check
        self._a_indptr = a.indptr.copy()
        self._a_indices = a.indices.copy()

        t0 = time.perf_counter()
        self._sysd = _order_system(a, None, method, block_size, w,
                                   scheduler=scheduler)
        t1 = time.perf_counter()
        self._structure = ic0_structure(self._sysd.a_bar,
                                        self._sysd.fwd_rounds)
        l_bar = self._factor(self._sysd.a_bar)
        t2 = time.perf_counter()
        self._build_operators(l_bar)
        if validate != "off":
            # static race proof BEFORE the plan is handed out: "cheap" is
            # the O(nnz) round-monotonicity scan, "full" additionally
            # proves the materialized trisolve tables and the IC(0) step
            # schedule (raises ScheduleError with the offending witness)
            from repro.analysis.schedule import assert_plan_valid
            assert_plan_valid(self, validate,
                              context=f"build_plan(method={method!r})")
        t3 = time.perf_counter()
        self.timings = SetupBreakdown(ordering=t1 - t0, factor=t2 - t1,
                                      pack=t3 - t2, total=t3 - t0,
                                      **(self._sysd.ordering_stages or {}))
        self.setup_count += 1
        self.lane_occupancy = _occupancy_from_rounds(self._sysd.fwd_rounds,
                                                     self._sysd.drop)

    # -- derived properties -------------------------------------------------

    @property
    def n(self) -> int:
        return self._sysd.n

    @property
    def n_padded(self) -> int:
        return self._sysd.n_padded

    @property
    def n_colors(self) -> int:
        return self._sysd.n_colors

    @property
    def n_rounds(self) -> int:
        return self._precond.n_rounds

    # -- setup internals ----------------------------------------------------

    @property
    def _operands_as_args(self) -> bool:
        """Whether the jitted PCG takes factor/SpMV operands as (pytree)
        ARGUMENTS — then a ``refactor`` swaps device arrays of identical
        shape without any retrace.  True for every path except
        layout="index" + backend="pallas" (whose kernel preconditioner is
        not a pytree; its jit closes over the operands and is rebuilt on
        refactor)."""
        return self.layout == "round_major" or self.backend == "xla"

    def _build_operators(self, l_bar) -> None:
        """Pack the factor + SpMV operand and move them to device.

        Under a mesh, the fused tables' lane axis and the SpMV operand's
        row/slice axis are placed SHARDED (``NamedSharding``); a
        ``refactor`` re-runs this with identical shapes and shardings, so
        the jitted PCG (whose operands are traced arguments) never
        retraces.
        """
        self._precond, self._rm = _build_preconditioner(
            l_bar, self._sysd, self.dtype, self.backend, self.interpret,
            self.layout, self.lane_multiple)
        a_op = (sell.permute_round_major(self._sysd.a_bar, self._rm)
                if self._rm is not None else self._sysd.a_bar)
        self._spmv_vals, self._spmv_cols, self._spmv_n = _pack_spmv(
            a_op, self.spmv_format, self.w, self.dtype)
        if self.mesh is not None:
            mesh, ax = self.mesh, self.mesh_axis
            self._precond = DistributedRoundMajorPreconditioner(
                tables=shard_fused_tables(self._precond.tables, mesh, ax),
                mesh=mesh, axis=ax)
            n_dev = mesh.shape[ax]
            if self.spmv_format == "sell":
                # pad the slice axis so it shards evenly (padded slices are
                # all-zero: they contribute rows beyond n, cut by the [:n])
                pad = (-self._spmv_vals.shape[0]) % n_dev
                if pad:
                    widths = ((0, pad),) + ((0, 0),) * 2
                    self._spmv_vals = jnp.pad(self._spmv_vals, widths)
                    self._spmv_cols = jnp.pad(self._spmv_cols, widths)
                sh = NamedSharding(mesh, P(ax, None, None))
            else:
                sh = NamedSharding(mesh, P(ax, None))
            self._spmv_vals = jax.device_put(self._spmv_vals, sh)
            self._spmv_cols = jax.device_put(self._spmv_cols, sh)
        if not self._operands_as_args:
            self._pcg_cache.clear()   # closed-over operands -> retrace

    def _factor(self, a_bar: sp.csr_matrix) -> sp.csr_matrix:
        """Numeric IC(0) sweep under the plan's ``on_breakdown`` policy.

        A factor is *clean* when no diagonal pivot hit the breakdown guard
        and every entry is finite.  Policies on a dirty factor:

          * ``"clamp"`` (default) — keep the eps-clamped factor, exactly
            the pre-policy behavior (bitwise; the paper's semi-definite
            experiments rely on it), but record ``clamped_pivots``.
          * ``"raise"`` — raise :class:`FactorBreakdownError` immediately.
          * ``"escalate"`` — retry with ``shift + extra`` for doubling
            ``extra`` (Manteuffel-style diagonal shifting) until clean;
            raise FactorBreakdownError if the attempt budget runs out or
            the matrix itself is non-finite (no shift repairs NaN data).

        Every attempt is appended to ``self.shift_schedule`` as
        ``(shift, clamped_pivots)``; ``self.effective_shift`` is the shift
        of the factor actually in use and ``self.clamped_pivots`` its
        clamp count.
        """
        if not np.isfinite(a_bar.data).all():
            raise FactorBreakdownError(
                "matrix values are not finite; no diagonal shift can "
                "repair a NaN/Inf operand", shift_schedule=[])
        l_bar = ic0_refactor(self._structure, a_bar, shift=self.shift)
        clamped = int(getattr(l_bar, "clamped_pivots", 0))
        schedule = [(float(self.shift), clamped)]
        self.shift_schedule = schedule
        if clamped == 0 or self.on_breakdown == "clamp":
            self.effective_shift = self.shift
            self.clamped_pivots = clamped
            return l_bar
        if self.on_breakdown == "raise":
            raise FactorBreakdownError(
                f"IC(0) breakdown: {clamped} pivot(s) clamped at shift="
                f"{self.shift} (on_breakdown='raise'); retry with a larger "
                f"shift or on_breakdown='escalate'",
                clamped_pivots=clamped, shift_schedule=schedule)
        extra = _ESCALATION_START
        for _ in range(_MAX_ESCALATIONS):
            trial = float(self.shift) + extra
            l_bar = ic0_refactor(self._structure, a_bar, shift=trial)
            clamped = int(getattr(l_bar, "clamped_pivots", 0))
            schedule.append((trial, clamped))
            if clamped == 0:
                self.effective_shift = trial
                self.clamped_pivots = 0
                return l_bar
            extra *= 2.0
        raise FactorBreakdownError(
            f"IC(0) breakdown persists after {_MAX_ESCALATIONS} shift "
            f"escalations (last shift {schedule[-1][0]}, "
            f"{schedule[-1][1]} clamped pivot(s))",
            clamped_pivots=clamped, shift_schedule=schedule)

    def refactor(self, a_new: sp.spmatrix) -> SetupBreakdown:
        """Renew the factorization for a structure-identical matrix.

        Re-runs the value-dependent pipeline — permute values,
        round-parallel IC(0) *numeric* phase over the cached structure, and
        the (vectorized, O(nnz)) repack + device transfer — while ordering,
        rounds, layout and the IC(0) symbolic analysis stay cached and the
        jitted PCG is reused without a retrace (operands are traced
        arguments).  Raises ValueError if ``a_new``'s sparsity pattern
        differs.
        """
        a_new = sp.csr_matrix(a_new)
        a_new.sort_indices()
        if (a_new.shape[0] != self.n
                or not np.array_equal(a_new.indptr, self._a_indptr)
                or not np.array_equal(a_new.indices, self._a_indices)):
            raise ValueError("refactor requires a structure-identical "
                             "matrix (same sparsity pattern); build a new "
                             "plan instead")
        t0 = time.perf_counter()
        a_bar = self._sysd.apply_ordering(a_new)
        # factor BEFORE mutating plan state: a FactorBreakdownError from the
        # on_breakdown policy leaves the old (working) operators in place
        l_bar = self._factor(a_bar)
        self._sysd.a_bar = a_bar
        t1 = time.perf_counter()
        self._build_operators(l_bar)
        t2 = time.perf_counter()
        self.setup_count += 1
        self.refactor_count += 1
        return SetupBreakdown(ordering=0.0, factor=t1 - t0, pack=t2 - t1,
                              total=t2 - t0)

    # -- solving ------------------------------------------------------------

    def _pcg_fn(self, batched: bool, rtol: float, maxiter: int,
                record_history: bool,
                divergence_factor: float | None = DIVERGENCE_FACTOR,
                stagnation_window: int | None = STAGNATION_WINDOW):
        dvf = float("inf") if divergence_factor is None \
            else float(divergence_factor)
        stw = maxiter + 1 if stagnation_window is None \
            else int(stagnation_window)
        key = (batched, float(rtol), int(maxiter), bool(record_history),
               dvf, stw)
        fn = self._pcg_cache.get(key)
        if fn is not None:
            return fn
        # rtol/maxiter/record_history are baked in as Python constants; the
        # jitted wrapper is cached so warm solves never retrace, and (where
        # _operands_as_args) the factor/SpMV operands are traced ARGUMENTS
        # so refactor never retraces either.  self._trace_count increments
        # only while tracing — tests assert refactor stays at one trace.
        core = _pcg_batched_device if batched else _pcg_device
        fmt, n_op = self.spmv_format, self._spmv_n
        backend, interpret = self.backend, self.interpret
        spmv_backend = self.spmv_backend

        if self.mesh is not None:
            mesh, ax = self.mesh, self.mesh_axis

            def run(tables, sv, sc, b):
                self._trace_count += 1
                pre = DistributedRoundMajorPreconditioner(tables=tables,
                                                          mesh=mesh, axis=ax)
                apply_ = pre.apply_batched if batched else pre
                spmv = make_sharded_spmv(fmt, n_op, mesh, ax, sv, sc,
                                         batched, spmv_backend=spmv_backend,
                                         interpret=interpret)
                return core(spmv, apply_, b, rtol=rtol, maxiter=maxiter,
                            record_history=record_history,
                            divergence_factor=dvf, stagnation_window=stw)
            fn = jax.jit(run)
        elif self.layout == "round_major":
            def run(tables, sv, sc, b):
                self._trace_count += 1
                pre = RoundMajorPreconditioner(tables=tables,
                                               backend=backend,
                                               interpret=interpret)
                apply_ = pre.apply_batched if batched else pre
                spmv = _make_spmv(fmt, n_op, sv, sc, batched,
                                  spmv_backend=spmv_backend,
                                  interpret=interpret)
                return core(spmv, apply_, b, rtol=rtol, maxiter=maxiter,
                            record_history=record_history,
                            divergence_factor=dvf, stagnation_window=stw)
            fn = jax.jit(run)
        elif backend == "xla":
            n_final = self.n_padded

            def run(fwd, bwd, sv, sc, b):
                self._trace_count += 1
                pre = HBMCPreconditioner(fwd=fwd, bwd=bwd, n_final=n_final,
                                         backend="xla", kernel=None)
                apply_ = pre.apply_batched if batched else pre
                spmv = _make_spmv(fmt, n_op, sv, sc, batched,
                                  spmv_backend=spmv_backend,
                                  interpret=interpret)
                return core(spmv, apply_, b, rtol=rtol, maxiter=maxiter,
                            record_history=record_history,
                            divergence_factor=dvf, stagnation_window=stw)
            fn = jax.jit(run)
        else:
            # index + pallas: the kernel preconditioner is not a pytree, so
            # the operands are closure constants (cache cleared on refactor)
            pre = self._precond
            apply_ = pre.apply_batched if batched else pre
            spmv = _make_spmv(fmt, n_op, self._spmv_vals, self._spmv_cols,
                              batched, spmv_backend=spmv_backend,
                              interpret=interpret)

            def run(b):
                self._trace_count += 1
                return core(spmv, apply_, b, rtol=rtol, maxiter=maxiter,
                            record_history=record_history,
                            divergence_factor=dvf, stagnation_window=stw)
            fn = jax.jit(run)
        self._pcg_cache[key] = fn
        return fn

    def _run_pcg(self, batched: bool, rtol: float, maxiter: int,
                 record_history: bool, b_dev: jax.Array,
                 divergence_factor: float | None = DIVERGENCE_FACTOR,
                 stagnation_window: int | None = STAGNATION_WINDOW):
        fn = self._pcg_fn(batched, rtol, maxiter, record_history,
                          divergence_factor, stagnation_window)
        if self.layout == "round_major":
            return fn(self._precond.tables, self._spmv_vals,
                      self._spmv_cols, b_dev)
        if self.backend == "xla":
            return fn(self._precond.fwd, self._precond.bwd,
                      self._spmv_vals, self._spmv_cols, b_dev)
        return fn(b_dev)

    def _embed(self, b_bar: np.ndarray) -> jax.Array:
        b_host = self._rm.embed(b_bar) if self._rm is not None else b_bar
        b_dev = jnp.asarray(b_host, dtype=self.dtype)
        if self.mesh is not None:   # state vectors are replicated on the mesh
            b_dev = jax.device_put(b_dev, NamedSharding(self.mesh, P()))
        return b_dev

    def _extract(self, x_dev) -> np.ndarray:
        x_bar = (self._rm.extract(np.asarray(x_dev))
                 if self._rm is not None else np.asarray(x_dev))
        return np.asarray(x_bar[self._sysd.perm])

    def _check_slab(self, b: np.ndarray, who: str) -> np.ndarray:
        """Validate a multi-RHS slab: 2-D (n, B) with the plan's dtype.

        A 1-D b gets its own error (naming the B=1 spelling) and a float
        dtype mismatch is an error rather than a silent cast — the packed
        operands are ``self.dtype``, and quietly up/down-casting b would
        produce a result that matches neither precision's solve.
        """
        b = np.asarray(b)
        if b.ndim == 1:
            raise ValueError(
                f"{who} expects b of shape ({self.n}, B), got a 1-D vector "
                f"of shape {b.shape}; pass a single RHS as the one-column "
                f"slab b[:, None] (B = 1), or use plan.solve")
        if b.ndim != 2 or b.shape[0] != self.n:
            raise ValueError(f"{who} expects b of shape "
                             f"({self.n}, B), got {b.shape}")
        if np.issubdtype(b.dtype, np.floating) and b.dtype != self._np_dtype:
            raise TypeError(
                f"{who}: b has dtype {b.dtype} but the plan's packed "
                f"operands are {self._np_dtype}; cast b explicitly "
                f"(b.astype({self._np_dtype})) to opt in")
        return np.asarray(b, dtype=self._np_dtype)

    # -- slab serving primitives (see repro.serve) --------------------------

    @property
    def slab_m(self) -> int:
        """Length of a device-side state column in the solve layout."""
        return self._rm.m if self._rm is not None else self.n_padded

    def embed_rhs(self, b: np.ndarray) -> jax.Array:
        """Embed one RHS (original ordering, shape (n,)) into a device
        column of the solve layout (shape (slab_m,)) — the host half of
        packing a slab slot."""
        b = np.asarray(b, dtype=self._np_dtype)
        if b.shape != (self.n,):
            raise ValueError(f"plan.embed_rhs expects b of shape "
                             f"({self.n},), got {b.shape}")
        b_bar = np.zeros(self.n_padded, dtype=self._np_dtype)
        b_bar[self._sysd.perm] = b
        return self._embed(b_bar)

    def extract_solution(self, x_col) -> np.ndarray:
        """Undo ``embed_rhs``: device column (slab_m,) -> x in the
        caller's original ordering (n,)."""
        return self._extract(x_col)

    def new_slab_state(self, slab_width: int) -> SlabState:
        """An all-empty resident slab: every slot fresh with a zero RHS
        (zero residual initializes inert — see ``SlabState``)."""
        if slab_width < 1:
            raise ValueError(f"slab_width must be >= 1, got {slab_width}")
        m, dt = self.slab_m, self.dtype
        zeros = jnp.zeros((m, slab_width), dtype=dt)
        state = SlabState(
            x=zeros, r=zeros, p=zeros,
            rz=jnp.zeros((slab_width,), dtype=dt),
            bnorm=jnp.ones((slab_width,), dtype=dt),
            active=jnp.zeros((slab_width,), dtype=bool),
            iters=jnp.zeros((slab_width,), dtype=jnp.int32),
            relres=jnp.zeros((slab_width,), dtype=dt),
            fresh=jnp.ones((slab_width,), dtype=bool),
            status=jnp.zeros((slab_width,), dtype=jnp.int32),
            best=jnp.zeros((slab_width,), dtype=dt),
            since_best=jnp.zeros((slab_width,), dtype=jnp.int32))
        if self.mesh is not None:   # slab state is replicated on the mesh
            sh = NamedSharding(self.mesh, P())
            state = SlabState(*(jax.device_put(v, sh) for v in state))
        return state

    def _slab_fn(self, rtol: float, maxiter: int, quantum: int,
                 divergence_factor: float | None = DIVERGENCE_FACTOR,
                 stagnation_window: int | None = STAGNATION_WINDOW):
        """Jitted quantum-step over a resident slab; cached per signature
        exactly like ``_pcg_fn`` (operands as traced args where possible,
        so ``refactor`` never retraces)."""
        dvf = float("inf") if divergence_factor is None \
            else float(divergence_factor)
        stw = maxiter + 1 if stagnation_window is None \
            else int(stagnation_window)
        key = ("slab", float(rtol), int(maxiter), int(quantum), dvf, stw)
        fn = self._pcg_cache.get(key)
        if fn is not None:
            return fn
        fmt, n_op = self.spmv_format, self._spmv_n
        backend, interpret = self.backend, self.interpret
        spmv_backend = self.spmv_backend

        if self.mesh is not None:
            mesh, ax = self.mesh, self.mesh_axis

            def run(tables, sv, sc, state):
                self._trace_count += 1
                pre = DistributedRoundMajorPreconditioner(tables=tables,
                                                          mesh=mesh, axis=ax)
                spmv = make_sharded_spmv(fmt, n_op, mesh, ax, sv, sc,
                                         True, spmv_backend=spmv_backend,
                                         interpret=interpret)
                return _pcg_slab_device(spmv, pre.apply_batched, state,
                                        rtol=rtol, maxiter=maxiter,
                                        quantum=quantum,
                                        divergence_factor=dvf,
                                        stagnation_window=stw)
            fn = jax.jit(run)
        elif self.layout == "round_major":
            def run(tables, sv, sc, state):
                self._trace_count += 1
                pre = RoundMajorPreconditioner(tables=tables,
                                               backend=backend,
                                               interpret=interpret)
                spmv = _make_spmv(fmt, n_op, sv, sc, True,
                                  spmv_backend=spmv_backend,
                                  interpret=interpret)
                return _pcg_slab_device(spmv, pre.apply_batched, state,
                                        rtol=rtol, maxiter=maxiter,
                                        quantum=quantum,
                                        divergence_factor=dvf,
                                        stagnation_window=stw)
            fn = jax.jit(run)
        elif backend == "xla":
            n_final = self.n_padded

            def run(fwd, bwd, sv, sc, state):
                self._trace_count += 1
                pre = HBMCPreconditioner(fwd=fwd, bwd=bwd, n_final=n_final,
                                         backend="xla", kernel=None)
                spmv = _make_spmv(fmt, n_op, sv, sc, True,
                                  spmv_backend=spmv_backend,
                                  interpret=interpret)
                return _pcg_slab_device(spmv, pre.apply_batched, state,
                                        rtol=rtol, maxiter=maxiter,
                                        quantum=quantum,
                                        divergence_factor=dvf,
                                        stagnation_window=stw)
            fn = jax.jit(run)
        else:
            # index + pallas: operands are closure constants (cache cleared
            # on refactor, same as _pcg_fn)
            pre = self._precond
            spmv = _make_spmv(fmt, n_op, self._spmv_vals, self._spmv_cols,
                              True, spmv_backend=spmv_backend,
                              interpret=interpret)

            def run(state):
                self._trace_count += 1
                return _pcg_slab_device(spmv, pre.apply_batched, state,
                                        rtol=rtol, maxiter=maxiter,
                                        quantum=quantum,
                                        divergence_factor=dvf,
                                        stagnation_window=stw)
            fn = jax.jit(run)
        self._pcg_cache[key] = fn
        return fn

    def run_slab(self, state: SlabState, rtol: float = 1e-7,
                 maxiter: int = 10_000,
                 quantum: int = 16,
                 divergence_factor: float | None = DIVERGENCE_FACTOR,
                 stagnation_window: int | None = STAGNATION_WINDOW
                 ) -> tuple[SlabState, jax.Array]:
        """Advance a resident slab by at most ``quantum`` PCG iterations.

        Columns flagged ``fresh`` are (re)initialized from their ``r``
        at entry; continuing columns resume bitwise where they left off
        (dispatch boundaries do not perturb their float sequences).
        Returns ``(new_state, steps_taken)``; every inactive column of the
        new state has a definite ``status``.
        """
        fn = self._slab_fn(rtol, maxiter, quantum,
                           divergence_factor, stagnation_window)
        if self.layout == "round_major":
            return fn(self._precond.tables, self._spmv_vals,
                      self._spmv_cols, state)
        if self.backend == "xla":
            return fn(self._precond.fwd, self._precond.bwd,
                      self._spmv_vals, self._spmv_cols, state)
        return fn(state)

    def solve_slab(self, b: np.ndarray, slab_width: int = 1,
                   rtol: float = 1e-7, maxiter: int = 10_000,
                   slot: int = 0) -> ICCGReport:
        """Solve one RHS through the slab path at a given resident width.

        Packs ``b`` into ``slot`` of an otherwise-empty
        width-``slab_width`` slab and runs it to convergence in a single
        dispatch.  This is the standalone oracle for serving: a column
        served through ``repro.serve.SolverService`` at slab width B in
        slot s is bitwise equal to
        ``plan.solve_slab(b, slab_width=B, slot=s)`` — slab columns are
        independent of their neighbours' contents and of dispatch
        boundaries, but (width, slot) pin the lowered reduction trees (at
        some widths XLA emits lane-position-dependent reductions; B = 2
        does on CPU).  At ``slab_width=1`` it is bitwise equal to
        ``plan.solve_batched(b[:, None])``.  Iteration counts equal the
        single-RHS ``plan.solve`` counts at EVERY width and slot; iterates
        agree with ``plan.solve`` to reduction-order rounding only (XLA
        lowers the batched ``einsum`` dots differently from ``vdot``).
        """
        t0 = time.perf_counter()
        b = np.asarray(b, dtype=self._np_dtype)
        if b.shape != (self.n,):
            raise ValueError(f"plan.solve_slab expects b of shape "
                             f"({self.n},), got {b.shape}")
        if not 0 <= slot < slab_width:
            raise ValueError(f"slot {slot} out of range for slab_width "
                             f"{slab_width}")
        state = self.new_slab_state(slab_width)
        state = state._replace(
            r=state.r.at[:, slot].set(self.embed_rhs(b)))
        t1 = time.perf_counter()
        state, _ = self.run_slab(state, rtol=rtol, maxiter=maxiter,
                                 quantum=maxiter)
        x = jax.block_until_ready(state.x)
        t2 = time.perf_counter()
        x_out = self.extract_solution(x[:, slot])
        relres = float(state.relres[slot])
        res = PCGResult(x=x_out, iterations=int(state.iters[slot]),
                        relres=relres, converged=relres < rtol,
                        history=np.zeros((0,)),
                        status=status_name(state.status[slot]))
        return ICCGReport(
            method=self.method, result=res, n=self.n,
            n_padded=self.n_padded, n_colors=self.n_colors,
            n_rounds=self.n_rounds, setup_seconds=t1 - t0,
            solve_seconds=t2 - t1, lane_occupancy=self.lane_occupancy,
            x=x_out, backend=self.backend, layout=self.layout,
            spmv_backend=self.spmv_backend, scheduler=self.scheduler)

    def solve(self, b: np.ndarray, rtol: float = 1e-7,
              maxiter: int = 10_000,
              record_history: bool = False) -> ICCGReport:
        """Solve A x = b reusing every cached setup product.

        Per-call host work is exactly: embed ``b`` into the solve layout,
        extract ``x`` back into the caller's ordering.
        """
        t0 = time.perf_counter()
        b = np.asarray(b, dtype=self._np_dtype)
        if b.shape != (self.n,):
            raise ValueError(f"plan.solve expects b of shape ({self.n},), "
                             f"got {b.shape}")
        b_bar = np.zeros(self.n_padded, dtype=self._np_dtype)
        b_bar[self._sysd.perm] = b
        b_dev = self._embed(b_bar)
        t1 = time.perf_counter()
        x, it, relres, status, hist = self._run_pcg(False, rtol, maxiter,
                                                    record_history, b_dev)
        x = jax.block_until_ready(x)
        t2 = time.perf_counter()
        x_out = self._extract(x)
        relres = float(relres)
        res = PCGResult(x=x_out, iterations=int(it), relres=relres,
                        converged=relres < rtol, history=np.asarray(hist),
                        status=status_name(status))
        return ICCGReport(
            method=self.method, result=res, n=self.n,
            n_padded=self.n_padded, n_colors=self.n_colors,
            n_rounds=self.n_rounds, setup_seconds=t1 - t0,
            solve_seconds=t2 - t1, lane_occupancy=self.lane_occupancy,
            x=x_out, backend=self.backend, layout=self.layout,
            spmv_backend=self.spmv_backend, scheduler=self.scheduler)

    def solve_batched(self, b: np.ndarray, rtol: float = 1e-7,
                      maxiter: int = 10_000,
                      record_history: bool = False) -> BatchedICCGReport:
        """Solve A x_j = b_j for all columns of ``b`` ((n, B)) in one PCG
        loop, reusing every cached setup product."""
        t0 = time.perf_counter()
        b = self._check_slab(b, "plan.solve_batched")
        b_bar = np.zeros((self.n_padded, b.shape[1]), dtype=self._np_dtype)
        b_bar[self._sysd.perm] = b
        b_dev = self._embed(b_bar)
        t1 = time.perf_counter()
        x, iters, relres, step, status, hist = self._run_pcg(
            True, rtol, maxiter, record_history, b_dev)
        x = jax.block_until_ready(x)
        t2 = time.perf_counter()
        x_out = self._extract(x)
        relres = np.asarray(relres)
        res = BatchedPCGResult(x=x_out, iterations=np.asarray(iters),
                               relres=relres, converged=relres < rtol,
                               n_steps=int(step), history=np.asarray(hist),
                               status=np.asarray(status))
        return BatchedICCGReport(
            method=self.method, result=res, n=self.n,
            n_padded=self.n_padded, n_colors=self.n_colors,
            n_rounds=self.n_rounds, setup_seconds=t1 - t0,
            solve_seconds=t2 - t1, lane_occupancy=self.lane_occupancy,
            x=x_out, backend=self.backend, layout=self.layout,
            spmv_backend=self.spmv_backend, scheduler=self.scheduler)


def build_plan(a: sp.spmatrix, method: str = "hbmc", block_size: int = 32,
               w: int = 8, shift: float = 0.0, spmv_format: str = "ell",
               dtype=jnp.float64, backend: str = "xla",
               interpret: bool | None = None,
               layout: str = "round_major", mesh: Mesh | None = None,
               mesh_axis: str = "data",
               lane_multiple: int = 1,
               spmv_backend: str = "xla",
               on_breakdown: str = "clamp",
               validate: str = "off",
               scheduler: str = "coloring") -> SolverPlan:
    """One-time setup: ordering -> round-parallel IC(0) -> packed operators.

    Returns a ``SolverPlan`` whose ``solve`` / ``solve_batched`` /
    ``refactor`` amortize this cost over arbitrarily many solves.

    With ``mesh=`` (a ``jax.sharding.Mesh``) the plan is distributed: the
    fused round-major tables' lane axis and the ELL/SELL SpMV operand are
    sharded over ``mesh_axis`` and the preconditioner apply runs the fused
    sweep with one collective per round.  ``lane_multiple`` pads the lane
    axis (folded with the mesh axis size automatically); a single-device
    plan built with the same ``lane_multiple`` is the bitwise parity
    oracle for a distributed plan.

    ``backend`` picks the trisolve implementation; ``spmv_backend`` (with
    ``spmv_format="sell"``) independently picks the SpMV one — with both
    set to ``"pallas"`` the entire PCG iteration runs through Pallas
    kernels on one VMEM-resident round-major state.

    ``validate`` runs the static schedule race detector
    (``repro.analysis``) at setup: ``"cheap"`` is an O(nnz)
    round-monotonicity scan of the ordering's rounds, ``"full"``
    additionally proves the materialized trisolve tables and the IC(0)
    step schedule dependency-ordered, and ``"deep"`` adds the static
    kernel checks plus the dtype-flow lint of every lowering path against
    the plan's precision contract (``repro.analysis.dtype_flow``).  A
    violation raises ``repro.analysis.ScheduleError`` carrying the
    offending row pair / edge / round / eqn; ``"off"`` (default) skips
    the proof.

    ``scheduler`` picks how the ordered pattern is cut into parallel
    rounds: ``"coloring"`` (default) uses the method's color rounds,
    ``"levelset"`` re-derives the rounds from the dependency levels of
    the ordered pattern — the minimal-round legal schedule, for
    irregular patterns where coloring degrades to thin rounds.  Both
    feed the identical ``StepTables`` contract, so every backend /
    layout / mesh combination composes with either scheduler.
    """
    return SolverPlan(a, method=method, block_size=block_size, w=w,
                      shift=shift, spmv_format=spmv_format, dtype=dtype,
                      backend=backend, interpret=interpret, layout=layout,
                      mesh=mesh, mesh_axis=mesh_axis,
                      lane_multiple=lane_multiple,
                      spmv_backend=spmv_backend, on_breakdown=on_breakdown,
                      validate=validate, scheduler=scheduler)


# ---------------------------------------------------------------------------
# Operator-building shim kept for benchmarks (pre-plan API surface).
# ---------------------------------------------------------------------------

def _build_operators(sysd: _System, shift: float, spmv_format: str, w: int,
                     dtype, backend: str, interpret: bool | None,
                     layout: str, batched: bool, spmv_backend: str = "xla"):
    """IC(0) + preconditioner + SpMV in the requested layout.

    Returns ``(precond, spmv_fn, rm_layout)`` exactly as the pre-plan
    solver did; ``benchmarks/bench_trisolve.py`` uses it to time raw
    operator applies.  The factorization runs through the round-parallel
    path (``ic0_rounds`` semantics).
    """
    if layout not in LAYOUTS:
        raise ValueError(f"unknown layout {layout!r}; expected one of "
                         f"{LAYOUTS}")
    st = ic0_structure(sysd.a_bar, sysd.fwd_rounds)
    l_bar = ic0_refactor(st, sysd.a_bar, shift=shift)
    precond, rm = _build_preconditioner(l_bar, sysd, dtype, backend,
                                        interpret, layout)
    a_op = sell.permute_round_major(sysd.a_bar, rm) if rm is not None \
        else sysd.a_bar
    single, batched_fn = _build_spmv_ops(a_op, spmv_format, w, dtype,
                                         spmv_backend=spmv_backend,
                                         interpret=interpret)
    return precond, (batched_fn if batched else single), rm
