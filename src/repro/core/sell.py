"""SELL-w packing of the HBMC-ordered triangular factors (paper §4.4.2).

The paper stores L/U in sliced-ELL with slice size = w so each vectorized
round loads w contiguous rows.  On TPU we take the same idea one step
further: all rows belonging to one *global round* (color c, round l) are
mutually independent, so we pack them into one dense padded tile

    rows : (R,)      final row indices of the round     (pad -> n_slots-1)
    cols : (R, K)    column indices of off-diag entries (pad -> n_slots-1)
    vals : (R, K)    matching values                    (pad -> 0.0)
    dinv : (R,)      1 / diagonal                       (pad -> 0.0)

and stack the rounds:  S = n_c * b_s  sequential steps.  The substitution is
then a fixed-shape ``lax.fori_loop`` over S steps of fully dense gather/fma
work — the TPU analogue of "w-wide SIMD per round, one thread sync per color".

Padding scheme: index ``n_slots-1`` is a scratch slot whose value is always
read as garbage*0.0 (pad vals are zero) and written as 0.0 (pad dinv is
zero), so padded lanes are harmless.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp

from .graph import ragged_arange
from .hbmc import HBMCOrdering


class PackingIndexError(ValueError):
    """A pack input carries an out-of-range index (corrupted CSR indices
    or a round referencing a nonexistent row).  Raised on the host before
    any buffer is written — a bad index that reached a packed table would
    otherwise surface only as a wrong answer or a device-side wrap."""


def _check_csr_indices(a: sp.csr_matrix, n_cols: int, what: str) -> None:
    idx = a.indices
    if idx.size and (int(idx.min()) < 0 or int(idx.max()) >= n_cols):
        bad = idx[(idx < 0) | (idx >= n_cols)][0]
        raise PackingIndexError(
            f"{what}: CSR column index {int(bad)} outside [0, {n_cols}) — "
            f"corrupted indices cannot be packed")


def _check_round_rows(rounds: list[np.ndarray], n: int, what: str) -> None:
    for s, r in enumerate(rounds):
        r = np.asarray(r)
        if r.size and (int(r.min()) < 0 or int(r.max()) >= n):
            bad = r[(r < 0) | (r >= n)][0]
            raise PackingIndexError(
                f"{what}: round {s} references row {int(bad)} outside "
                f"[0, {n})")


@dataclasses.dataclass
class StepTables:
    """Host-side packed tables; converted to jnp on first use."""
    rows: np.ndarray   # (S, R) int32
    cols: np.ndarray   # (S, R, K) int32
    vals: np.ndarray   # (S, R, K) f64
    dinv: np.ndarray   # (S, R) f64
    n_slots: int       # n_final + 1 (scratch slot at the end)
    # per-step live row count (R_s <= R), for occupancy accounting
    live: np.ndarray   # (S,) int32

    @property
    def shape(self):
        return self.rows.shape + (self.cols.shape[-1],)


def rounds_hbmc(ordering: HBMCOrdering, reverse: bool = False
                ) -> list[np.ndarray]:
    """Final row indices of every global round (c, l), in execution order."""
    b_s, w = ordering.block_size, ordering.w
    out = []
    colors = range(ordering.n_colors)
    for c in colors:
        base = int(ordering.color_start[c])
        nlev1 = int(ordering.lev1_per_color[c])
        k = np.arange(nlev1)[:, None]          # level-1 block within color
        j = np.arange(w)[None, :]              # lane
        for l in range(b_s):                   # round inside level-1 block
            rows = (base + k * (b_s * w) + l * w + j).ravel()
            out.append(rows)
    if reverse:
        out = out[::-1]
    return out


def rounds_bmc(bmc, reverse: bool = False) -> list[np.ndarray]:
    """Rounds for plain BMC: round (c, t) = t-th unknown of every block of
    color c.  Mathematically identical iteration to the sequential in-block
    sweep (blocks of one color are independent); this is what makes the BMC
    iteration-count comparison meaningful on the same machinery."""
    b_s = bmc.block_size
    color_start = np.concatenate([[0], np.cumsum(bmc.blocks_per_color * b_s)])
    out = []
    for c in range(bmc.n_colors):
        base = int(color_start[c])
        nb = int(bmc.blocks_per_color[c])
        k = np.arange(nb)
        for t in range(b_s):
            out.append(base + k * b_s + t)
    if reverse:
        out = out[::-1]
    return out


def rounds_mc(mc, reverse: bool = False) -> list[np.ndarray]:
    """Rounds for nodal multi-color ordering: one round per color."""
    start = np.concatenate([[0], np.cumsum(mc.color_counts)])
    out = [np.arange(start[c], start[c + 1]) for c in range(mc.n_colors)]
    if reverse:
        out = out[::-1]
    return out


def rounds_levelset(level: np.ndarray, counts: np.ndarray,
                    reverse: bool = False) -> list[np.ndarray]:
    """Rounds from a level-set schedule (``graph.level_sets``).

    Round ``l`` holds every row of dependency level ``l``, in ascending
    row order (the stable sort keeps the in-round lane order
    deterministic).  This is the minimal-round legal schedule for the
    pattern: row counts per round are whatever the dependency structure
    allows, unlike the fixed-width color rounds.  ``reverse=True``
    reverses the round *order* only (the backward-substitution
    convention shared by every ``rounds_*``).
    """
    order = np.argsort(level, kind="stable")
    out = np.split(order, np.cumsum(counts)[:-1]) if len(counts) else []
    if reverse:
        out = out[::-1]
    return out


def rounds_natural(n: int, reverse: bool = False) -> list[np.ndarray]:
    """Fully sequential rounds (the unordered baseline)."""
    out = [np.array([i]) for i in range(n)]
    if reverse:
        out = out[::-1]
    return out


def _pack_dtype(data: np.ndarray) -> np.dtype:
    """Host pack-buffer dtype: keep floating inputs (f32 stays f32);
    promote anything else (int test matrices) to f64."""
    dt = np.asarray(data).dtype
    return dt if np.issubdtype(dt, np.floating) else np.dtype(np.float64)


def pack_steps(tri: sp.csr_matrix, diag: np.ndarray,
               rounds: list[np.ndarray],
               drop_mask: np.ndarray | None = None,
               lane_multiple: int = 1) -> StepTables:
    """Pack a strictly-triangular matrix + diagonal into per-round tables.

    ``tri`` must be the strictly lower (forward) or strictly upper (backward)
    part in the target order; ``rounds`` the execution-ordered row sets
    (mutually independent within a round).  ``drop_mask`` (bool per row) drops
    rows (e.g. dummy padding) from the rounds.  ``lane_multiple`` rounds the
    lane axis R up to a multiple (pad lanes are the usual inert scratch-slot
    lanes) so the lane axis can be sharded evenly over a device mesh.
    """
    tri = sp.csr_matrix(tri)
    tri.sort_indices()
    n = tri.shape[0]
    _check_csr_indices(tri, n, "pack_steps")
    _check_round_rows(rounds, n, "pack_steps")
    n_slots = n + 1
    if drop_mask is not None:
        rounds = [r[~drop_mask[r]] for r in rounds]
        rounds = [r for r in rounds if len(r)]
    S = len(rounds)
    rlens = np.array([len(r) for r in rounds], dtype=np.int64)
    R = int(rlens.max(initial=0))
    R = -(-R // lane_multiple) * lane_multiple
    row_nnz = np.diff(tri.indptr)
    K = int(row_nnz.max(initial=0))
    K = max(K, 1)
    vdt = _pack_dtype(tri.data)
    # one flat scatter instead of a per-row Python loop: lane (s, t) holds
    # round s's t-th row; its nnz entries land at [(s*R + t)*K, ... + nnz)
    all_rows = np.concatenate(rounds).astype(np.int64)
    s_idx = np.repeat(np.arange(S), rlens)
    t_idx = ragged_arange(rlens)
    rows = np.full((S, R), n_slots - 1, dtype=np.int32)
    dinv = np.zeros((S, R), dtype=vdt)
    rows[s_idx, t_idx] = all_rows
    dinv[s_idx, t_idx] = 1.0 / diag[all_rows]
    counts = row_nnz[all_rows]
    k_off = ragged_arange(counts)
    src = np.repeat(tri.indptr[all_rows], counts) + k_off
    dst = np.repeat((s_idx * R + t_idx) * K, counts) + k_off
    cols = np.full(S * R * K, n_slots - 1, dtype=np.int32)
    vals = np.zeros(S * R * K, dtype=vdt)
    cols[dst] = tri.indices[src]
    vals[dst] = tri.data[src]
    return StepTables(rows=rows, cols=cols.reshape(S, R, K),
                      vals=vals.reshape(S, R, K), dinv=dinv,
                      n_slots=n_slots, live=rlens.astype(np.int32))


def pack_factor(l_final: sp.csr_matrix, fwd_rounds: list[np.ndarray],
                bwd_rounds: list[np.ndarray],
                drop_mask: np.ndarray | None = None,
                lane_multiple: int = 1
                ) -> tuple[StepTables, StepTables]:
    """Pack L (lower, incl. diagonal, target order) into forward and backward
    substitution tables (backward uses L^T, reverse round order)."""
    l_final = sp.csr_matrix(l_final)
    diag = l_final.diagonal()
    strict_lower = sp.tril(l_final, k=-1, format="csr")
    strict_upper = sp.csr_matrix(strict_lower.T)
    fwd = pack_steps(strict_lower, diag, fwd_rounds, drop_mask, lane_multiple)
    bwd = pack_steps(strict_upper, diag, bwd_rounds, drop_mask, lane_multiple)
    return fwd, bwd


def pack_factor_hbmc(l_final: sp.csr_matrix, ordering: HBMCOrdering
                     ) -> tuple[StepTables, StepTables]:
    return pack_factor(l_final,
                       rounds_hbmc(ordering, reverse=False),
                       rounds_hbmc(ordering, reverse=True),
                       drop_mask=ordering.is_dummy)


# ----------------------------------------------------------------------
# Round-major repacking (the Pallas kernel's layout contract).
# ----------------------------------------------------------------------

@dataclasses.dataclass
class RoundMajorLayout:
    """The HBMC-index <-> round-major-position bijection (live lanes only).

    Round-major is the execution-order coordinate system: lane ``t`` of
    forward round ``s`` lives at position ``s * R + t`` of a dense ``(S*R,)``
    vector.  Pad lanes (``rows == n_slots - 1``) are *holes*: they hold exact
    zeros for the whole PCG loop and have no HBMC counterpart.

    This object is the ONLY place permutations live in the round-major-native
    solver path: ``embed`` maps the right-hand side in once per solve,
    ``extract`` maps the solution out once per solve.  Everything in between
    (SpMV, both triangular sweeps, all PCG state) stays in round-major
    coordinates.
    """
    rows: np.ndarray   # (S, R) int32 — HBMC index per position (pad -> n_slots-1)
    pos: np.ndarray    # (n_slots,) int64 — HBMC index -> position (none -> S*R)
    n_slots: int

    @property
    def n_steps(self) -> int:
        return self.rows.shape[0]

    @property
    def lanes(self) -> int:
        return self.rows.shape[1]

    @property
    def m(self) -> int:
        """Padded round-major dimension S*R."""
        return self.rows.size

    def embed(self, v: np.ndarray) -> np.ndarray:
        """HBMC-ordered (n,) or (n, B) -> round-major (m,) / (m, B), holes 0."""
        v = np.asarray(v)
        flat = self.rows.reshape(-1)
        live = flat != self.n_slots - 1
        out = np.zeros((self.m,) + v.shape[1:], dtype=v.dtype)
        out[live] = v[flat[live]]
        return out

    def extract(self, y: np.ndarray) -> np.ndarray:
        """Round-major (m,) or (m, B) -> HBMC-ordered (n,) / (n, B)."""
        y = np.asarray(y)
        flat = self.rows.reshape(-1)
        live = flat != self.n_slots - 1
        out = np.zeros((self.n_slots - 1,) + y.shape[1:], dtype=y.dtype)
        out[flat[live]] = y[live]
        return out


def round_major_layout(t: StepTables) -> RoundMajorLayout:
    """Layout induced by the forward StepTables (execution order)."""
    s_, r_ = t.rows.shape
    pos = np.full(t.n_slots, s_ * r_, dtype=np.int64)
    lane = np.arange(s_ * r_).reshape(s_, r_)
    live = t.rows != (t.n_slots - 1)
    pos[t.rows[live]] = lane[live]
    return RoundMajorLayout(rows=t.rows.astype(np.int32), pos=pos,
                            n_slots=t.n_slots)


@dataclasses.dataclass
class RoundMajorTables:
    """StepTables re-indexed into the dense *round-major* coordinate system.

    The Pallas kernel (kernels/hbmc_trisolve.py) stores the solution vector
    in execution order: lane ``t`` of round ``s`` lives at position
    ``s * R + t``.  That turns the per-round scatter of the XLA path
    (``y.at[rows].set``) into a dense contiguous VMEM store, which is the
    TPU analogue of the paper's Fig. 4.6 contiguous AVX-512 stores.

    ``cols`` here are *round-major positions* (entries of previous rounds),
    produced by composing the StepTables column indices with the
    HBMC-index -> round-major-position permutation.  ``rows`` keeps the
    inverse map (the HBMC index of every lane, pad lanes -> ``n_slots-1``)
    so solutions can be scattered back to HBMC order; it is the permutation
    referred to throughout as "kept so solutions map back".
    """
    cols: np.ndarray   # (S, R, K) int32 — round-major gather positions
    vals: np.ndarray   # (S, R, K) f64
    dinv: np.ndarray   # (S, R) f64
    rows: np.ndarray   # (S, R) int32 — HBMC index per lane (pad -> n_slots-1)
    n_slots: int

    @property
    def shape(self):
        return self.rows.shape + (self.cols.shape[-1],)


def to_round_major(t: StepTables) -> RoundMajorTables:
    """Convert scatter-by-``rows`` StepTables to the dense round-major layout.

    Column indices that point at unknowns never assigned to any lane (only
    the scratch pad slot, whose ``vals`` are zero) are mapped to ``S*R``;
    the kernel reads them via ``jnp.take(..., fill_value=0)`` so the
    out-of-range position contributes ``0 * 0``.
    """
    lay = round_major_layout(t)
    return RoundMajorTables(cols=lay.pos[t.cols].astype(np.int32),
                            vals=t.vals, dinv=t.dinv,
                            rows=lay.rows, n_slots=t.n_slots)


@dataclasses.dataclass
class FusedRoundMajorTables:
    """Forward AND backward sweeps packed for one fused 2S-step solve.

    The backward rounds are exactly the forward rounds reversed (``rounds_*``
    build them that way, lane order included), so in *forward* round-major
    coordinates the backward sweep's round ``s'`` writes the contiguous slice
    ``[(S-1-s')*R, (S-s')*R)`` — a dense store, same as the forward sweep.
    That makes one solution buffer sufficient: the forward half fills it with
    ``y = L^{-1} q`` slice by slice, the backward half overwrites it in place
    with ``z = L^{-T} y`` in reverse slice order.  Every value the backward
    gather touches is either already overwritten (a ``z`` entry from a later
    forward round — exactly its dependencies) or the current slice's ``y``
    read before the store.

    Step ``g`` of the fused schedule uses table row ``g``: rows ``0..S-1``
    are the forward rounds, rows ``S..2S-1`` the backward rounds in backward
    execution order.  ``cols`` of BOTH halves are forward round-major gather
    positions (missing -> ``m``, read via ``fill_value=0`` against zero
    ``vals``).
    """
    cols: np.ndarray   # (2S, R, K) int32 — fwd-round-major gather positions
    vals: np.ndarray   # (2S, R, K) f64
    dinv: np.ndarray   # (2S, R) f64
    layout: RoundMajorLayout

    @property
    def n_steps(self) -> int:
        """Rounds per sweep (the fused grid has 2 * n_steps steps)."""
        return self.layout.n_steps

    @property
    def shape(self):
        return self.cols.shape


def fuse_round_major(fwd: StepTables, bwd: StepTables) -> FusedRoundMajorTables:
    """Pack forward + backward StepTables into the fused round-major form."""
    if fwd.rows.shape != bwd.rows.shape or fwd.n_slots != bwd.n_slots:
        raise ValueError("forward/backward tables disagree on round shape")
    if not np.array_equal(bwd.rows[::-1], fwd.rows):
        raise ValueError("backward rounds must be the reversed forward "
                         "rounds (lane order included)")
    lay = round_major_layout(fwd)
    m = lay.m
    k = max(fwd.cols.shape[-1], bwd.cols.shape[-1])

    def half(t: StepTables) -> tuple[np.ndarray, np.ndarray]:
        s_, r_, kt = t.cols.shape
        cols = np.full((s_, r_, k), m, dtype=np.int32)
        vals = np.zeros((s_, r_, k), dtype=t.vals.dtype)
        cols[:, :, :kt] = lay.pos[t.cols]
        vals[:, :, :kt] = t.vals
        return cols, vals

    fc, fv = half(fwd)
    bc, bv = half(bwd)
    return FusedRoundMajorTables(
        cols=np.concatenate([fc, bc], axis=0),
        vals=np.concatenate([fv, bv], axis=0),
        dinv=np.concatenate([fwd.dinv, bwd.dinv], axis=0),
        layout=lay)


def permute_round_major(a: sp.spmatrix, layout: RoundMajorLayout
                        ) -> sp.csr_matrix:
    """Re-index a matrix from HBMC order into round-major positions (m x m).

    Rows/columns of unknowns without a round-major position (dummy padding,
    dropped from the rounds) are removed: their PCG state is identically
    zero in both layouts, so the Krylov process is unchanged.  Hole
    positions become empty rows, so SpMV writes exact zeros there and the
    round-major state vectors keep their holes at zero.
    """
    coo = sp.coo_matrix(a)
    m = layout.m
    rows = layout.pos[coo.row]
    cols = layout.pos[coo.col]
    live = (rows < m) & (cols < m)
    return sp.coo_matrix((coo.data[live], (rows[live], cols[live])),
                         shape=(m, m)).tocsr()


# ----------------------------------------------------------------------
# SELL-w packing of a full matrix for SpMV (paper's "sell_spmv" variant).
# ----------------------------------------------------------------------

@dataclasses.dataclass
class SellMatrix:
    """SELL-C-sigma with C = w, sigma = 1 (HBMC order is already the sort)."""
    cols: np.ndarray      # (n_slices, max_k, w) int32
    vals: np.ndarray      # (n_slices, max_k, w) f64
    slice_k: np.ndarray   # (n_slices,) live k per slice
    n: int
    w: int
    padded_nnz: int
    nnz: int


def _ell_scatter_indices(indptr: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(row, k) destination of every CSR nonzero, as one flat enumeration."""
    lens = np.diff(indptr)
    rows_of = np.repeat(np.arange(len(lens)), lens)
    return rows_of, ragged_arange(lens)


def pack_sell(a: sp.spmatrix, w: int) -> SellMatrix:
    a = sp.csr_matrix(a)
    a.sort_indices()
    n = a.shape[0]
    _check_csr_indices(a, a.shape[1], "pack_sell")
    n_pad = ((n + w - 1) // w) * w
    nnz_per_row = np.zeros(n_pad, dtype=np.int64)
    nnz_per_row[:n] = np.diff(a.indptr)
    n_slices = n_pad // w
    slice_k = nnz_per_row.reshape(n_slices, w).max(axis=1)
    max_k = int(max(slice_k.max(initial=0), 1))
    cols = np.zeros((n_slices, max_k, w), dtype=np.int32)
    vals = np.zeros((n_slices, max_k, w), dtype=_pack_dtype(a.data))
    rows_of, k_off = _ell_scatter_indices(a.indptr)
    cols[rows_of // w, k_off, rows_of % w] = a.indices
    vals[rows_of // w, k_off, rows_of % w] = a.data
    return SellMatrix(cols=cols, vals=vals,
                      slice_k=slice_k.astype(np.int32), n=n, w=w,
                      padded_nnz=int(np.sum(slice_k) * w), nnz=a.nnz)


def pack_ell(a: sp.spmatrix) -> tuple[np.ndarray, np.ndarray]:
    """Row-major ELL (the CRS-like gather path for SpMV): (cols, vals)."""
    a = sp.csr_matrix(a)
    a.sort_indices()
    n = a.shape[0]
    _check_csr_indices(a, a.shape[1], "pack_ell")
    k = int(np.diff(a.indptr).max(initial=0))
    k = max(k, 1)
    cols = np.zeros((n, k), dtype=np.int32)
    vals = np.zeros((n, k), dtype=_pack_dtype(a.data))
    rows_of, k_off = _ell_scatter_indices(a.indptr)
    cols[rows_of, k_off] = a.indices
    vals[rows_of, k_off] = a.data
    return cols, vals
