"""Distribution of the HBMC ICCG solver over a device mesh.

THIS MODULE IS A THIN COMPATIBILITY SHIM.  The distribution layer proper
lives in the plan stack:

    core/plan.py        ``build_plan(a, ..., mesh=, mesh_axis=)`` — a
                        mesh-aware ``SolverPlan`` (factor once, solve many,
                        refactor without retrace), whose preconditioner
                        apply is the fused round-major sweep with ONE
                        collective per round
    core/trisolve.py    ``DistributedRoundMajorPreconditioner`` /
                        ``_dist_substitute_fused`` — the sharded fused
                        fwd+bwd substitution (``shard_map`` over the lane
                        axis)
    core/iccg.py        ``make_sharded_spmv`` — row/slice-sharded ELL/SELL
                        SpMV with one all-gather per apply

Parallel-ordering semantics map onto the mesh exactly as the paper maps
them onto threads (§4.4.3), one level up:

    color      -> sequential rounds (the fori_loop over fused steps)
    level-1 blocks of a color -> *devices* (the mesh axis): the fused
                  tables' lane axis R is sharded, so each device owns a
                  contiguous batch of level-1 blocks
    w lanes    -> VPU vector lanes within a device

Per round, every device solves its lanes locally (gathering from its
replica of y) and the lane updates are all-gathered — the distributed
analogue of the "one synchronization per color" property.  The state
vectors are replicated; the tables (the heavy data: vals/cols) are fully
sharded.

``distributed_iccg`` / ``lower_solver_step`` below are wrappers kept for
the pre-plan call sites; ``shard_tables`` is the legacy index-layout
sharding util (the seed's two-pass path), superseded by the fused plan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .iccg import pcg_iteration, spmv_ell
from .plan import BatchedICCGReport, ICCGReport, build_plan
from .trisolve import DeviceTables, backward_solve, forward_solve


def distributed_iccg(a: sp.spmatrix, b: np.ndarray, mesh: Mesh, *,
                     axis: str = "data", method: str = "hbmc",
                     block_size: int = 32, w: int = 8, shift: float = 0.0,
                     rtol: float = 1e-7, maxiter: int = 10_000,
                     spmv_format: str = "ell", dtype=jnp.float64,
                     record_history: bool = False) -> ICCGReport:
    """One-shot distributed solve: mesh-aware plan, solve, report.

    Takes the ORIGINAL system (``a``, ``b``) — ordering, padding and the
    round-major embedding happen inside the plan, and ``report.x`` /
    ``report.result.x`` carry the solution in the caller's ordering.  (The
    seed-era version consumed a pre-padded HBMC system and returned the
    internal padded/permuted vector — the padded-state leak fixed
    everywhere else in PR3; regression-tested in tests/test_multidevice.py.)

    Workloads solving against one matrix repeatedly should hold the plan:
    ``build_plan(a, ..., mesh=mesh)`` then ``plan.solve(...)`` /
    ``plan.refactor(...)``.
    """
    plan = build_plan(a, method=method, block_size=block_size, w=w,
                      shift=shift, spmv_format=spmv_format, dtype=dtype,
                      mesh=mesh, mesh_axis=axis)
    rep = plan.solve(np.asarray(b), rtol=rtol, maxiter=maxiter,
                     record_history=record_history)
    rep.setup_seconds += plan.timings.total
    return rep


def distributed_iccg_batched(a: sp.spmatrix, b: np.ndarray, mesh: Mesh, *,
                             axis: str = "data", method: str = "hbmc",
                             block_size: int = 32, w: int = 8,
                             shift: float = 0.0, rtol: float = 1e-7,
                             maxiter: int = 10_000,
                             spmv_format: str = "ell", dtype=jnp.float64,
                             record_history: bool = False
                             ) -> BatchedICCGReport:
    """Multi-RHS variant of ``distributed_iccg`` (``b``: (n, B))."""
    plan = build_plan(a, method=method, block_size=block_size, w=w,
                      shift=shift, spmv_format=spmv_format, dtype=dtype,
                      mesh=mesh, mesh_axis=axis)
    rep = plan.solve_batched(np.asarray(b), rtol=rtol, maxiter=maxiter,
                             record_history=record_history)
    rep.setup_seconds += plan.timings.total
    return rep


# ---------------------------------------------------------------------------
# Legacy index-layout sharding (the seed's two-pass path).  Kept because the
# roofline dry-run lowers against it; the production distributed apply is
# the fused round-major sweep above.
# ---------------------------------------------------------------------------

def shard_tables(tables: DeviceTables, mesh: Mesh, axis: str = "data"
                 ) -> DeviceTables:
    """Shard the lane axis (R) of index-layout step tables over ``axis``.

    R is padded to a multiple of the axis size (padding lanes follow the
    scratch-slot convention and are inert).
    """
    n_dev = mesh.shape[axis]
    s, r = tables.dinv.shape
    rpad = (-r) % n_dev
    if rpad:
        pad2 = lambda a, fill: jnp.pad(a, ((0, 0), (0, rpad)),
                                       constant_values=fill)
        pad3 = lambda a, fill: jnp.pad(a, ((0, 0), (0, rpad), (0, 0)),
                                       constant_values=fill)
        tables = DeviceTables(
            rows=pad2(tables.rows, tables.n_slots - 1),
            cols=pad3(tables.cols, tables.n_slots - 1),
            vals=pad3(tables.vals, 0.0),
            dinv=pad2(tables.dinv, 0.0),
            n_slots=tables.n_slots)
    sh2 = NamedSharding(mesh, P(None, axis))
    sh3 = NamedSharding(mesh, P(None, axis, None))
    return DeviceTables(
        rows=jax.device_put(tables.rows, sh2),
        cols=jax.device_put(tables.cols, sh3),
        vals=jax.device_put(tables.vals, sh3),
        dinv=jax.device_put(tables.dinv, sh2),
        n_slots=tables.n_slots)


def lower_solver_step(fwd: DeviceTables, bwd: DeviceTables,
                      a_ell_cols, a_ell_vals, mesh: Mesh, axis="data"):
    """Lower one PCG iteration on the production mesh (dry-run bonus cell:
    the paper's own kernel under the multi-pod roofline).

    The iteration is ``iccg.pcg_iteration`` — the PRECONDITIONED pairings
    (``alpha = (r,z)/(p,Ap)``, ``beta = (r2,z2)/(r,z)``), carrying ``rz``
    between steps, so the lowered HLO contains BOTH triangular sweeps (the
    seed-era version used ``(r,r)`` pairings, which lowered a plain-CG
    kernel with no trisolve traffic at all — asserted against in
    tests/test_multidevice.py).

    Requires n and R to be multiples of the axis size (arrange via the HBMC
    block/w parameters).
    """
    rep = NamedSharding(mesh, P())
    n = fwd.n_slots - 1
    assert a_ell_cols.shape[0] == n

    def one_iteration(x, r, p, rz, vals, cols, fwd_t, bwd_t):
        spmv = lambda v: spmv_ell(vals, cols, v)
        precond = lambda v: backward_solve(bwd_t, forward_solve(fwd_t, v))
        return pcg_iteration(spmv, precond)(x, r, p, rz)

    sds = lambda a, sh: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=sh)
    row_sh = NamedSharding(mesh, P(axis, None))
    sh2 = NamedSharding(mesh, P(None, axis))
    sh3 = NamedSharding(mesh, P(None, axis, None))
    vec = jax.ShapeDtypeStruct((n,), fwd.vals.dtype, sharding=rep)
    scalar = jax.ShapeDtypeStruct((), fwd.vals.dtype, sharding=rep)

    with mesh:
        jitted = jax.jit(one_iteration)
        lowered = jitted.lower(
            vec, vec, vec, scalar,
            sds(a_ell_vals, row_sh), sds(a_ell_cols, row_sh),
            _abstract_tables(fwd, sh2, sh3),
            _abstract_tables(bwd, sh2, sh3))
    return lowered


def _abstract_tables(t: DeviceTables, sh2, sh3) -> DeviceTables:
    sds = lambda a, sh: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=sh)
    return DeviceTables(rows=sds(t.rows, sh2), cols=sds(t.cols, sh3),
                        vals=sds(t.vals, sh3), dinv=sds(t.dinv, sh2),
                        n_slots=t.n_slots)
