"""Distribution of the HBMC ICCG solver over a device mesh.

Parallel-ordering semantics map onto the mesh exactly as the paper maps them
onto threads (§4.4.3), one level up:

    color      -> sequential rounds (the fori_loop over steps)
    level-1 blocks of a color -> *devices* (the `data` mesh axis): the step
                  tables' lane axis R is sharded, so each device owns a
                  contiguous batch of level-1 blocks
    w lanes    -> VPU vector lanes within a device

Per round, every device solves its lanes locally (gathering from its copy
of y) and the lane updates are all-gathered — the distributed analogue of
the "one synchronization per color" property.  The vector y is replicated;
the tables (the heavy data: vals/cols) are fully sharded.  This is the
general-sparsity fallback; a structured-grid build could replace the
all-gather with neighbor collective_permutes (see DESIGN.md §5).

Everything is expressed with jit + NamedSharding: XLA SPMD inserts the
all-gathers, which the dry-run roofline then accounts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .trisolve import DeviceTables, forward_solve, backward_solve
from .iccg import pcg, spmv_ell


def shard_tables(tables: DeviceTables, mesh: Mesh, axis: str = "data"
                 ) -> DeviceTables:
    """Shard the lane axis (R) of the step tables over ``axis``.

    R is padded to a multiple of the axis size (padding lanes follow the
    scratch-slot convention and are inert).
    """
    n_dev = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    s, r = tables.dinv.shape
    rpad = (-r) % n_dev
    if rpad:
        pad2 = lambda a, fill: jnp.pad(a, ((0, 0), (0, rpad)),
                                       constant_values=fill)
        pad3 = lambda a, fill: jnp.pad(a, ((0, 0), (0, rpad), (0, 0)),
                                       constant_values=fill)
        tables = DeviceTables(
            rows=pad2(tables.rows, tables.n_slots - 1),
            cols=pad3(tables.cols, tables.n_slots - 1),
            vals=pad3(tables.vals, 0.0),
            dinv=pad2(tables.dinv, 0.0),
            n_slots=tables.n_slots)
    sh2 = NamedSharding(mesh, P(None, axis))
    sh3 = NamedSharding(mesh, P(None, axis, None))
    return DeviceTables(
        rows=jax.device_put(tables.rows, sh2),
        cols=jax.device_put(tables.cols, sh3),
        vals=jax.device_put(tables.vals, sh3),
        dinv=jax.device_put(tables.dinv, sh2),
        n_slots=tables.n_slots)


def distributed_iccg(a_ell_cols, a_ell_vals, fwd: DeviceTables,
                     bwd: DeviceTables, b, mesh: Mesh, *, rtol=1e-7,
                     maxiter=10_000, axis: str = "data"):
    """Run PCG with the triangular solves and SpMV sharded over ``axis``."""
    fwd_s = shard_tables(fwd, mesh, axis)
    bwd_s = shard_tables(bwd, mesh, axis)
    rep = NamedSharding(mesh, P())
    row_sh = NamedSharding(mesh, P(axis, None))
    n = b.shape[0]
    n_dev = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    rpad = (-n) % n_dev
    cols_p = jnp.pad(a_ell_cols, ((0, rpad), (0, 0)))
    vals_p = jnp.pad(a_ell_vals, ((0, rpad), (0, 0)))
    cols_d = jax.device_put(cols_p, row_sh)
    vals_d = jax.device_put(vals_p, row_sh)
    b_d = jax.device_put(b, rep)

    def spmv(x):
        y = spmv_ell(vals_d, cols_d, jnp.pad(x, (0, rpad)))
        return jax.lax.with_sharding_constraint(y[:n], rep)

    def precond(r):
        y = forward_solve(fwd_s, r)
        z = backward_solve(bwd_s, y)
        return jax.lax.with_sharding_constraint(z, rep)

    with mesh:
        return pcg(spmv, precond, b_d, rtol=rtol, maxiter=maxiter)


def lower_solver_step(fwd: DeviceTables, bwd: DeviceTables,
                      a_ell_cols, a_ell_vals, mesh: Mesh, axis="data"):
    """Lower one PCG iteration on the production mesh (dry-run bonus cell:
    the paper's own kernel under the multi-pod roofline).

    Requires n and R to be multiples of the axis size (arrange via the HBMC
    block/w parameters).
    """
    rep = NamedSharding(mesh, P())
    n = fwd.n_slots - 1
    assert a_ell_cols.shape[0] == n

    def one_iteration(x, r, p, vals, cols, fwd_t, bwd_t):
        ap = spmv_ell(vals, cols, p)
        alpha = jnp.vdot(r, r) / jnp.vdot(p, ap)
        x = x + alpha * p
        r2 = r - alpha * ap
        y = forward_solve(fwd_t, r2)
        z = backward_solve(bwd_t, y)
        beta = jnp.vdot(r2, z) / jnp.vdot(r, r)
        return x, r2, z + beta * p

    sds = lambda a, sh: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=sh)
    row_sh = NamedSharding(mesh, P(axis, None))
    sh2 = NamedSharding(mesh, P(None, axis))
    sh3 = NamedSharding(mesh, P(None, axis, None))
    vec = jax.ShapeDtypeStruct((n,), fwd.vals.dtype, sharding=rep)

    with mesh:
        jitted = jax.jit(one_iteration)
        lowered = jitted.lower(
            vec, vec, vec,
            sds(a_ell_vals, row_sh), sds(a_ell_cols, row_sh),
            _abstract_tables(fwd, sh2, sh3),
            _abstract_tables(bwd, sh2, sh3))
    return lowered


def _abstract_tables(t: DeviceTables, sh2, sh3) -> DeviceTables:
    sds = lambda a, sh: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=sh)
    return DeviceTables(rows=sds(t.rows, sh2), cols=sds(t.cols, sh3),
                        vals=sds(t.vals, sh3), dinv=sds(t.dinv, sh2),
                        n_slots=t.n_slots)
