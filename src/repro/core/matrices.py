"""Test-problem generators standing in for the paper's five datasets.

SuiteSparse is not available offline; each generator mimics the structure of
the corresponding paper matrix family (documented in DESIGN.md §6):

  Thermal2       -> 2-D 5-point FD Laplacian with smooth coefficient jumps
  Parabolic_fem  -> 2-D 5-point FD of (I - dt * Laplacian)  (implicit step)
  G3_circuit     -> irregular graph Laplacian + diagonal (circuit-like)
  Audikw_1       -> 3-D 27-point "structural" stencil (dense-ish rows)
  Ieej           -> 3-D 7-point edge-element-like curl-curl analogue,
                    semi-definite + shift handled by shifted IC (alpha=0.3)

All matrices are symmetric positive (semi-)definite.
"""
from __future__ import annotations

import numpy as np
import scipy.sparse as sp


def laplace_2d(nx: int, ny: int, coeff: np.ndarray | None = None
               ) -> sp.csr_matrix:
    """5-point FD Laplacian on an nx x ny grid (Dirichlet)."""
    n = nx * ny
    idx = np.arange(n).reshape(ny, nx)
    rows, cols, vals = [], [], []
    c = np.ones((ny, nx)) if coeff is None else coeff

    def add(i, j, v):
        rows.append(i); cols.append(j); vals.append(v)

    for dy, dx in ((0, 1), (1, 0)):
        src = idx[:ny - dy, :nx - dx].ravel()
        dst = idx[dy:, dx:].ravel()
        harm = 2.0 / (1.0 / c[:ny - dy, :nx - dx].ravel()
                      + 1.0 / c[dy:, dx:].ravel())
        rows.extend(src); cols.extend(dst); vals.extend(-harm)
        rows.extend(dst); cols.extend(src); vals.extend(-harm)
    a = sp.coo_matrix((vals, (rows, cols)), shape=(n, n)).tocsr()
    d = -np.asarray(a.sum(axis=1)).ravel() + 1e-8
    a.setdiag(d + 4e-2)  # slight diagonal boost: SPD & Dirichlet-like
    return a.tocsr()


def laplace_3d(nx: int, ny: int, nz: int, stencil: int = 7) -> sp.csr_matrix:
    """7- or 27-point FD Laplacian on an nx x ny x nz grid."""
    n = nx * ny * nz
    idx = np.arange(n).reshape(nz, ny, nx)
    rows, cols = [], []
    if stencil == 7:
        offsets = [(0, 0, 1), (0, 1, 0), (1, 0, 0)]
    else:
        offsets = [(dz, dy, dx)
                   for dz in (0, 1) for dy in (-1, 0, 1) for dx in (-1, 0, 1)
                   if (dz, dy, dx) > (0, 0, 0)]
    for dz, dy, dx in offsets:
        zs = slice(max(0, -dz), nz - max(0, dz))
        ys = slice(max(0, -dy), ny - max(0, dy))
        xs = slice(max(0, -dx), nx - max(0, dx))
        zd = slice(max(0, dz), nz - max(0, -dz))
        yd = slice(max(0, dy), ny - max(0, -dy))
        xd = slice(max(0, dx), nx - max(0, -dx))
        src = idx[zs, ys, xs].ravel()
        dst = idx[zd, yd, xd].ravel()
        rows.extend(src); cols.extend(dst)
        rows.extend(dst); cols.extend(src)
    vals = -np.ones(len(rows))
    a = sp.coo_matrix((vals, (rows, cols)), shape=(n, n)).tocsr()
    d = -np.asarray(a.sum(axis=1)).ravel()
    a.setdiag(d + 1e-2)
    return a.tocsr()


def graph_laplacian(n: int, avg_degree: int = 4, seed: int = 0
                    ) -> sp.csr_matrix:
    """Irregular random-graph Laplacian + small diagonal (circuit-like)."""
    rng = np.random.default_rng(seed)
    m = n * avg_degree // 2
    # mix of short-range and long-range edges (circuit nets)
    i_short = rng.integers(0, n - 1, size=m // 2)
    j_short = np.minimum(i_short + rng.integers(1, 16, size=m // 2), n - 1)
    i_long = rng.integers(0, n, size=m - m // 2)
    j_long = rng.integers(0, n, size=m - m // 2)
    i = np.concatenate([i_short, i_long])
    j = np.concatenate([j_short, j_long])
    mask = i != j
    i, j = i[mask], j[mask]
    w = rng.uniform(0.1, 1.0, size=len(i))
    a = sp.coo_matrix((-w, (i, j)), shape=(n, n))
    a = (a + a.T).tocsr()
    a.sum_duplicates()
    d = -np.asarray(a.sum(axis=1)).ravel()
    a.setdiag(d + 1e-3)
    return a.tocsr()


def curlcurl_like(nx: int, ny: int, nz: int, seed: int = 0) -> sp.csr_matrix:
    """Semi-definite curl-curl analogue: 7-point Laplacian with a rank-
    deficient-ish weighting + random reluctivity jumps (eddy-current-like)."""
    rng = np.random.default_rng(seed)
    a = laplace_3d(nx, ny, nz, stencil=7)
    n = a.shape[0]
    # heterogeneous material coefficient (iron vs air: 3 orders of magnitude)
    mat = np.where(rng.random(n) < 0.2, 1.0, 1e-3)
    dscale = sp.diags(np.sqrt(mat))
    a = (dscale @ a @ dscale).tocsr()
    # make it *semi*-definite-ish: shrink the diagonal boost
    a.setdiag(a.diagonal() - 0.9e-2 * mat)
    return a.tocsr()


def paper_problem(name: str, scale: str = "small") -> tuple[sp.csr_matrix, str]:
    """Return (A, description).  scale in {tiny, small, bench}."""
    dims = {
        "tiny":  dict(g2=24, g3=8,  n=600,    c3=8),
        "small": dict(g2=64, g3=16, n=4000,   c3=12),
        "bench": dict(g2=352, g3=46, n=120_000, c3=40),
    }[scale]
    if name == "thermal2":
        ny = nx = dims["g2"]
        rng = np.random.default_rng(1)
        coeff = np.exp(rng.normal(0, 1, size=(ny, nx)))
        return laplace_2d(nx, ny, coeff), "2-D heterogeneous thermal"
    if name == "parabolic_fem":
        nx = ny = dims["g2"]
        a = laplace_2d(nx, ny)
        n = a.shape[0]
        return (sp.identity(n, format="csr") + 0.25 * a).tocsr(), \
            "implicit parabolic step"
    if name == "g3_circuit":
        return graph_laplacian(dims["n"]), "irregular circuit-like"
    if name == "audikw_1":
        g = dims["g3"]
        return laplace_3d(g, g, g, stencil=27), "3-D 27-point structural"
    if name == "ieej":
        g = dims["c3"]
        return curlcurl_like(g, g, max(2, g // 2)), "eddy-current analogue"
    raise KeyError(name)


PAPER_PROBLEMS = ("thermal2", "parabolic_fem", "g3_circuit", "audikw_1", "ieej")
# paper §5.1: shifted ICCG with alpha = 0.3 for Ieej
PAPER_SHIFTS = {"ieej": 0.3}
