"""Parallel Gauss-Seidel / SOR smoothers on the HBMC round machinery.

The paper's scope (§2) includes the GS smoother and SOR alongside IC(0):
the sweep x_i <- (1-w) x_i + w (b_i - sum_{j != i} a_ij x_j) / a_ii is the
same dependence structure as the forward substitution, so the identical
round tables apply — pack the FULL off-diagonal part of A in the ordering's
rounds and run the in-place substitution.  Equivalence of orderings for GS
(eq. 3.4) then holds by the same ER argument; tested in
tests/test_smoothers.py (BMC sweep == HBMC sweep exactly).

This is the building block HPCG-style multigrid smoothers use (paper §1).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from .sell import pack_steps
from .trisolve import DeviceTables, _substitute


@dataclasses.dataclass(frozen=True)
class GSSmoother:
    fwd: DeviceTables       # full off-diagonal rows, forward round order
    bwd: DeviceTables       # same rows, reverse round order (symmetric GS)
    n: int
    omega: float = 1.0      # SOR relaxation

    def sweep(self, b: jax.Array, x: jax.Array, *, reverse: bool = False
              ) -> jax.Array:
        t = self.bwd if reverse else self.fwd
        x_new = _substitute(t, b, x0=x)
        if self.omega != 1.0:
            x_new = (1 - self.omega) * x + self.omega * x_new
        return x_new

    def symmetric_sweep(self, b: jax.Array, x: jax.Array) -> jax.Array:
        return self.sweep(b, self.sweep(b, x), reverse=True)


def build_gs_smoother(a_bar: sp.spmatrix, fwd_rounds, bwd_rounds,
                      drop_mask=None, omega: float = 1.0,
                      dtype=jnp.float64) -> GSSmoother:
    """a_bar: reordered (padded) matrix; rounds from sell.rounds_*."""
    a_bar = sp.csr_matrix(a_bar)
    n = a_bar.shape[0]
    diag = a_bar.diagonal()
    off = a_bar - sp.diags(diag)
    off = sp.csr_matrix(off)
    off.eliminate_zeros()
    fwd = pack_steps(off, diag, fwd_rounds, drop_mask)
    bwd = pack_steps(off, diag, bwd_rounds, drop_mask)
    return GSSmoother(fwd=DeviceTables.from_host(fwd, dtype=dtype),
                      bwd=DeviceTables.from_host(bwd, dtype=dtype),
                      n=n, omega=omega)


def gs_solve(smoother: GSSmoother, b: np.ndarray, *, sweeps: int = 100,
             rtol: float = 1e-8, a_bar: sp.spmatrix | None = None):
    """Stationary GS/SOR iteration (host loop; returns history)."""
    x = jnp.zeros_like(jnp.asarray(b))
    bd = jnp.asarray(b)
    hist = []
    for _ in range(sweeps):
        x = smoother.sweep(bd, x)
        if a_bar is not None:
            r = np.linalg.norm(b - a_bar @ np.asarray(x)) / np.linalg.norm(b)
            hist.append(r)
            if r < rtol:
                break
    return np.asarray(x), hist
