"""Nodal multi-color (MC) and algebraic block multi-color (BMC) orderings.

MC: greedy coloring of the matrix adjacency graph; unknowns ordered by
(color, original index).

BMC (Iwashita, Nakashima, Takahashi, IPDPS 2012): unknowns are first grouped
into blocks of size ``b_s`` with the *simplest heuristic* from that paper (the
one the HBMC paper says it uses): the unknown with the minimal number among
unassigned ones seeds a new block, and the block is grown greedily across
adjacent unassigned unknowns (minimal index first).  The quotient (block)
graph is then greedy-colored, and unknowns are ordered by
(block color, block id, position inside block).

Block building is the one ordering stage with no closed-form vectorization:
the minimal-index growth rule makes every acceptance depend on the previous
one.  ``build_blocks`` vectorizes it anyway with *batched frontier growth*:
per step it gathers the CSR neighbor slices of the whole sorted candidate
frontier at once and accepts the longest prefix whose acceptance provably
cannot be altered by neighbors the accepted nodes introduce (a prefix-min
argument, see ``build_blocks``).  The original element-at-a-time heap walk
survives as ``_build_blocks_walk`` — the bitwise oracle of the property
tests and of ``benchmarks/bench_setup.py``.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp

from .graph import adjacency_lists, ragged_arange


def _validate_block_size(block_size, who: str) -> int:
    """Entry-point guard: ``block_size`` must be a positive int.

    ``block_size=0`` used to degenerate silently — every block became a
    singleton and the padded system collapsed to ``n_padded = 0``, so the
    caller got an empty permutation and garbage downstream; negative
    values degenerated the same way.
    """
    if isinstance(block_size, bool) or not isinstance(
            block_size, (int, np.integer)):
        raise ValueError(
            f"{who}: block_size must be an int, got "
            f"{type(block_size).__name__} ({block_size!r})")
    if block_size < 1:
        raise ValueError(
            f"{who}: block_size must be >= 1, got {block_size} "
            f"(block_size < 1 silently produced an empty padded system)")
    return int(block_size)


def greedy_color(indptr: np.ndarray, indices: np.ndarray, n: int,
                 order: np.ndarray | None = None) -> np.ndarray:
    """Greedy (first-fit) coloring.  Returns color id per node (0-based)."""
    colors = np.full(n, -1, dtype=np.int64)
    scratch = np.full(n, -1, dtype=np.int64)  # color -> last node that used it
    seq = np.arange(n) if order is None else order
    for v in seq:
        for u in indices[indptr[v]:indptr[v + 1]]:
            cu = colors[u]
            if cu >= 0:
                scratch[cu] = v
        c = 0
        while scratch[c] == v:
            c += 1
        colors[v] = c
    return colors


@dataclasses.dataclass(frozen=True)
class MCOrdering:
    """Nodal multi-color ordering."""
    perm: np.ndarray          # perm[old] = new
    colors: np.ndarray        # color of each *old* unknown
    n_colors: int
    color_counts: np.ndarray  # unknowns per color, in new order


def multicolor_ordering(a: sp.spmatrix) -> MCOrdering:
    n = a.shape[0]
    indptr, indices = adjacency_lists(a)
    colors = greedy_color(indptr, indices, n)
    n_colors = int(colors.max()) + 1
    # stable sort by color keeps original order inside each color
    new_order = np.argsort(colors, kind="stable")   # new -> old
    perm = np.empty(n, dtype=np.int64)
    perm[new_order] = np.arange(n)
    counts = np.bincount(colors, minlength=n_colors)
    return MCOrdering(perm=perm, colors=colors, n_colors=n_colors,
                      color_counts=counts)


@dataclasses.dataclass(frozen=True)
class BMCOrdering:
    """Algebraic block multi-color ordering.

    Unknown layout in the new order: colors ascending; inside a color its
    blocks consecutively (``block_size`` unknowns each, padded with dummy
    unknowns so every block is exactly ``block_size`` long); inside a block
    the original relative order is preserved.

    ``perm`` maps old index -> new index over the *padded* system of size
    ``n_padded = n_blocks_total * block_size``.  Dummy slots are the padded
    tail of each block; ``is_dummy`` marks them in the new order.
    """
    perm: np.ndarray
    n: int
    n_padded: int
    block_size: int
    n_colors: int
    block_color: np.ndarray        # color of each block
    blocks_per_color: np.ndarray   # number of blocks in each color
    block_of_new: np.ndarray       # block id (global, color-major) per new idx
    is_dummy: np.ndarray           # bool per new index


@dataclasses.dataclass(frozen=True)
class BlockPartition:
    """Greedy min-index blocks as flat arrays (the array-program form).

    ``members`` concatenates the blocks in build order, ascending inside
    each block (the legacy walk's post-sort); ``lens`` is the member count
    per block.  ``tolists()`` recovers the legacy list-of-lists shape for
    oracle comparisons.
    """
    members: np.ndarray   # (n,) int64 — node ids, block-major
    lens: np.ndarray      # (n_blocks,) int64

    @property
    def n_blocks(self) -> int:
        return len(self.lens)

    @property
    def starts(self) -> np.ndarray:
        """First flat index of every block (len ``n_blocks``)."""
        return np.concatenate([[0], np.cumsum(self.lens)[:-1]]).astype(
            np.int64)

    def tolists(self) -> list[list[int]]:
        ends = np.cumsum(self.lens)
        starts = ends - self.lens
        return [self.members[s:e].tolist() for s, e in zip(starts, ends)]


def _build_blocks_walk(a: sp.spmatrix, block_size: int) -> list[list[int]]:
    """Min-index-seeded greedy block growing (2012 paper, simplest
    heuristic) — the element-at-a-time heap walk.

    Kept as the bitwise ORACLE for :func:`build_blocks`: the property
    tests prove the batched frontier growth reproduces these blocks
    exactly, and ``bench_setup`` prices the vectorized pipeline against
    this walk.  Plain-Python-int hot loop (adjacency converted to lists
    once, a stamp array instead of a per-block set).
    """
    block_size = _validate_block_size(block_size, "_build_blocks_walk")
    n = a.shape[0]
    indptr_a, indices_a = adjacency_lists(a)
    indptr = indptr_a.tolist()
    indices = indices_a.tolist()
    assigned = bytearray(n)
    in_heap = [0] * n        # stamp = block id + 1 marks "already pushed"
    blocks: list[list[int]] = []
    # frontier-based growth: keep candidate set of neighbors of current block
    import heapq
    heappush, heappop = heapq.heappush, heapq.heappop
    next_seed = 0
    while True:
        while next_seed < n and assigned[next_seed]:
            next_seed += 1
        if next_seed >= n:
            break
        blk = [next_seed]
        assigned[next_seed] = 1
        stamp = len(blocks) + 1
        heap: list[int] = []
        for u in indices[indptr[next_seed]:indptr[next_seed + 1]]:
            if not assigned[u] and in_heap[u] != stamp:
                in_heap[u] = stamp; heappush(heap, u)
        while len(blk) < block_size and heap:
            v = heappop(heap)
            if assigned[v]:
                continue
            blk.append(v)
            assigned[v] = 1
            for u in indices[indptr[v]:indptr[v + 1]]:
                if not assigned[u] and in_heap[u] != stamp:
                    in_heap[u] = stamp; heappush(heap, u)
        blk.sort()  # preserve original relative order inside the block
        blocks.append(blk)
    return blocks


_WINDOW_CHUNKS = 64          # max blocks' worth of frontier per window
_SCAN_CHUNK = 4096           # dead-prefix scan granularity


def _window_edges(window: np.ndarray, indptr: np.ndarray,
                  indices: np.ndarray, alive: np.ndarray
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Induced edges of the window subgraph, as window-position pairs.

    One CSR-sliced gather over all window rows at once; membership of the
    endpoints is a ``searchsorted`` against the (sorted) window because the
    window holds *every* alive node in its index range.
    """
    cnt = indptr[window + 1] - indptr[window]
    cols = indices[np.repeat(indptr[window], cnt) + ragged_arange(cnt)]
    pu = np.repeat(np.arange(window.size), cnt)
    keep = alive[cols]
    cols, pu = cols[keep], pu[keep]
    pv = np.searchsorted(window, cols)
    keep = pv < window.size          # alive but beyond the window's max index
    in_win = keep.copy()
    in_win[keep] = window[pv[keep]] == cols[keep]
    return pu[in_win], pv[in_win]


def _walk_one_block(seed: int, block_size: int, indptr: list,
                    indices: list, dead: set) -> np.ndarray:
    """Scalar greedy growth of a single block — the exact walk semantics,
    used as the fallback when a block interleaves index ranges (so no
    aligned chunk can represent it).  ``indptr``/``indices`` are Python
    lists and ``dead`` is a Python set mirroring the assigned mask: the
    fallback must not touch numpy per edge, or it loses to the legacy
    walk on exactly the structures it exists for."""
    import heapq
    blk = [seed]
    seen = {seed}
    heap: list[int] = []
    for u in indices[indptr[seed]:indptr[seed + 1]]:
        if u not in dead and u not in seen:
            seen.add(u); heapq.heappush(heap, u)
    while len(blk) < block_size and heap:
        v = heapq.heappop(heap)
        blk.append(v)
        for u in indices[indptr[v]:indptr[v + 1]]:
            if u not in dead and u not in seen:
                seen.add(u); heapq.heappush(heap, u)
    blk.sort()
    return np.asarray(blk, dtype=np.int64)


def build_blocks(a: sp.spmatrix, block_size: int,
                 adjacency: tuple[np.ndarray, np.ndarray] | None = None
                 ) -> BlockPartition:
    """Vectorized min-index-seeded greedy block growing.

    Bitwise-identical blocks to :func:`_build_blocks_walk` (proven in
    tests/test_properties.py), via a threshold reformulation of the walk.

    Between "record" pops (pops that raise the running index maximum) the
    walk's accepted set equals ``K(theta)`` — the connected component of
    the seed in the subgraph induced on *unassigned nodes with index <=
    theta* — and every distinct ``K`` value is visited, so a block is
    exactly ``K(theta*)`` for the smallest ``theta*`` whose component
    reaches ``block_size`` (when it reaches it exactly).

    That yields a batched *chunk-run* fast path: take an index-window of
    the next ``~64 * block_size`` unassigned nodes (one CSR-sliced edge
    gather for the whole window) and accept every leading aligned
    ``block_size`` chunk that is internally connected — such a chunk IS
    the next block, because the window holds every unassigned node in its
    index range, so its ``K(theta*)`` can contain nothing else.
    Connectivity is certified by the cheapest sufficient test there is:
    every consecutive window pair inside the chunk being adjacent (one
    vectorized flag pass over the gathered edges).  A chunk that fails
    the test (a mesh block spilling into the next grid row, an irregular
    pattern) is grown exactly by a bounded scalar walk instead, and the
    window size / test cadence adapt so persistently unaligned structure
    degrades to walk speed rather than paying for windows it cannot use.

    ``adjacency`` lets callers that already hold the symmetrized
    ``(indptr, indices)`` pair skip recomputing it.
    """
    block_size = _validate_block_size(block_size, "build_blocks")
    n = a.shape[0]
    indptr, indices = (adjacency_lists(a) if adjacency is None
                       else adjacency)
    indptr = np.asarray(indptr, dtype=np.int64)
    indices = np.asarray(indices, dtype=np.int64)
    alive = np.ones(n, dtype=bool)
    avail = np.arange(n, dtype=np.int64)   # alive superset, index-ordered
    lo = 0                                 # scan pointer into avail
    members: list[np.ndarray] = []
    lens: list[int] = []
    bs = block_size
    win_chunks = 16                        # adaptive window, in blocks
    miss_streak = 0                        # consecutive failed chunk tests
    walked = accepted = 0                  # per-epoch regime counters
    adj_lists: tuple[list, list] | None = None   # lazy, for the fallback
    dead: set = set()                      # scalar mirror of ~alive

    def take_window(want: int) -> np.ndarray:
        """Next ``want`` alive nodes in index order (fewer if exhausted)."""
        nonlocal avail, lo
        parts: list[np.ndarray] = []
        got = 0
        pos = lo
        while got < want and pos < avail.size:
            sl = avail[pos:pos + max(2 * (want - got), _SCAN_CHUNK)]
            sel = sl[alive[sl]]
            parts.append(sel)
            got += sel.size
            pos += sl.size
        if pos - lo > 4 * max(got, _SCAN_CHUNK):   # mostly-dead span: compact
            tail = avail[pos:]
            avail = np.concatenate(parts + [tail[alive[tail]]])
            lo = 0
            return avail[:want]
        w = (parts[0] if len(parts) == 1
             else np.concatenate(parts) if parts
             else np.empty(0, dtype=np.int64))
        return w[:want]

    def walk_one(seed: int) -> np.ndarray:
        nonlocal adj_lists
        if adj_lists is None:
            adj_lists = (indptr.tolist(), indices.tolist())
        blk = _walk_one_block(seed, bs, *adj_lists, dead)
        alive[blk] = False
        dead.update(blk.tolist())
        return blk

    while True:
        # advance the scan pointer to the next unassigned node
        while lo < avail.size and not alive[avail[lo]]:
            chunk = alive[avail[lo:lo + _SCAN_CHUNK]]
            j = int(np.argmax(chunk))
            if chunk[j]:
                lo += j
            else:
                lo += chunk.size
        if lo >= avail.size:
            break
        # regime hysteresis: when the structure has been defeating the
        # chunk test this epoch, walk blocks directly and only re-probe a
        # window every 16th block; counters reset each epoch so a
        # structure that becomes aligned again is re-detected
        if len(lens) % 256 == 0:
            walked = accepted = 0
        if walked > accepted + 8 and (len(lens) & 15):
            blk = walk_one(int(avail[lo]))
            members.append(blk)
            lens.append(blk.size)
            walked += 1
            continue
        window = take_window(win_chunks * bs)
        if window.size == 0:
            break
        n_full = window.size // bs
        k = 0
        if n_full:
            pu, pv = _window_edges(window, indptr, indices, alive)
            # flag[i]: window positions i and i+1 are adjacent
            flags = np.zeros(window.size, dtype=bool)
            flags[pu[pv == pu + 1]] = True
            runs = flags[:n_full * bs].reshape(n_full, bs)
            ok = runs[:, :bs - 1].all(axis=1) if bs > 1 else np.ones(
                n_full, dtype=bool)
            k = n_full if ok.all() else int(np.argmin(ok))
        if k:
            acc = window[:k * bs]
            alive[acc] = False
            dead.update(acc.tolist())
            members.append(acc)
            lens.extend([bs] * k)
            miss_streak = 0
            accepted += k
            if 2 * k >= n_full:
                win_chunks = min(2 * win_chunks, _WINDOW_CHUNKS)
        else:
            blk = walk_one(int(window[0]))
            members.append(blk)
            lens.append(blk.size)
            walked += 1
            miss_streak += 1
            if miss_streak >= 2:
                win_chunks = max(win_chunks // 2, 4)
    return BlockPartition(
        members=(np.concatenate(members) if members
                 else np.empty(0, dtype=np.int64)),
        lens=np.asarray(lens, dtype=np.int64))


def color_blocks(a: sp.spmatrix, partition: BlockPartition,
                 block_size: int,
                 adjacency: tuple[np.ndarray, np.ndarray] | None = None
                 ) -> BMCOrdering:
    """Quotient-graph coloring + permutation assembly over built blocks.

    The second half of :func:`block_multicolor_ordering`, split out so the
    setup pipeline can time (and reuse) the block-building stage
    separately.  All array programs: the block membership map, the edge
    contraction, the color-major block gather and the final scatter are
    single numpy expressions — no per-block Python loops.

    ``adjacency`` lets callers that already hold the symmetrized
    ``(indptr, indices)`` (e.g. from the block-build stage) skip
    recomputing it — on large systems the symmetrization dominates
    this stage.
    """
    block_size = _validate_block_size(block_size, "color_blocks")
    n = a.shape[0]
    nb = partition.n_blocks
    blk_lens_src = partition.lens
    block_of = np.empty(n, dtype=np.int64)
    block_of[partition.members] = np.repeat(np.arange(nb), blk_lens_src)
    indptr, indices = (adjacency_lists(a) if adjacency is None
                       else adjacency)
    # block adjacency via edge contraction
    coo_rows = np.repeat(np.arange(n), np.diff(indptr))
    br, bc = block_of[coo_rows], block_of[indices]
    mask = br != bc
    badj = sp.coo_matrix(
        (np.ones(mask.sum(), dtype=np.int8), (br[mask], bc[mask])),
        shape=(nb, nb)).tocsr()
    badj.sum_duplicates()
    bcolors = greedy_color(badj.indptr, badj.indices, nb)
    n_colors = int(bcolors.max()) + 1

    # order blocks by (color, block id)
    border = np.argsort(bcolors, kind="stable")  # new block pos -> old block id
    blocks_per_color = np.bincount(bcolors, minlength=n_colors)

    n_padded = nb * block_size
    blk_lens = blk_lens_src[border]
    # members of the reordered blocks: one segmented gather out of the
    # flat partition (src block `border[i]` supplies slice i)
    flat = partition.members[
        np.repeat(partition.starts[border], blk_lens) + ragged_arange(blk_lens)]
    within = ragged_arange(blk_lens)
    perm = np.empty(n, dtype=np.int64)
    perm[flat] = np.repeat(np.arange(nb) * block_size, blk_lens) + within
    block_of_new = np.repeat(np.arange(nb), block_size)
    is_dummy = (np.arange(n_padded) % block_size
                ) >= np.repeat(blk_lens, block_size)
    block_color = bcolors[border]
    return BMCOrdering(
        perm=perm, n=n, n_padded=n_padded, block_size=block_size,
        n_colors=n_colors, block_color=block_color,
        blocks_per_color=blocks_per_color, block_of_new=block_of_new,
        is_dummy=is_dummy)


def block_multicolor_ordering(a: sp.spmatrix, block_size: int) -> BMCOrdering:
    """BMC ordering = vectorized block building + quotient coloring.

    ``build_blocks`` / ``color_blocks`` expose the two stages separately
    (the setup pipeline times them as ``block_build_s`` / ``color_s``).
    """
    block_size = _validate_block_size(block_size, "block_multicolor_ordering")
    adjacency = adjacency_lists(a)
    return color_blocks(a, build_blocks(a, block_size, adjacency=adjacency),
                        block_size, adjacency=adjacency)


def pad_system(a: sp.spmatrix, b: np.ndarray | None, ordering: BMCOrdering
               ) -> tuple[sp.csr_matrix, np.ndarray | None]:
    """Apply a BMC ordering, embedding the system into the padded size.

    Dummy unknowns get a 1.0 diagonal and zero RHS; they never couple to real
    unknowns, so the Krylov process on the padded system reproduces the
    original one exactly.
    """
    n, npad = ordering.n, ordering.n_padded
    coo = sp.coo_matrix(a)
    p = ordering.perm
    rows = p[coo.row]
    cols = p[coo.col]
    data = coo.data                # keep the caller's dtype (f32 stays f32)
    if not np.issubdtype(data.dtype, np.floating):
        data = data.astype(np.float64)
    dummy_idx = np.nonzero(ordering.is_dummy)[0]
    rows = np.concatenate([rows, dummy_idx])
    cols = np.concatenate([cols, dummy_idx])
    data = np.concatenate([data, np.ones(len(dummy_idx), dtype=data.dtype)])
    a_bar = sp.coo_matrix((data, (rows, cols)), shape=(npad, npad)).tocsr()
    b_bar = None
    if b is not None:
        b = np.asarray(b)          # keep the caller's dtype (f32 stays f32)
        if not np.issubdtype(b.dtype, np.floating):
            # same promotion rule as the matrix data: an int RHS must not
            # flow into the float solve un-promoted
            b = b.astype(np.float64)
        b_bar = np.zeros(npad, dtype=b.dtype)
        b_bar[p] = b
    return a_bar, b_bar
