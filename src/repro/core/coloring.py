"""Nodal multi-color (MC) and algebraic block multi-color (BMC) orderings.

MC: greedy coloring of the matrix adjacency graph; unknowns ordered by
(color, original index).

BMC (Iwashita, Nakashima, Takahashi, IPDPS 2012): unknowns are first grouped
into blocks of size ``b_s`` with the *simplest heuristic* from that paper (the
one the HBMC paper says it uses): the unknown with the minimal number among
unassigned ones seeds a new block, and the block is grown greedily across
adjacent unassigned unknowns (minimal index first).  The quotient (block)
graph is then greedy-colored, and unknowns are ordered by
(block color, block id, position inside block).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp

from .graph import adjacency_lists, ragged_arange


def greedy_color(indptr: np.ndarray, indices: np.ndarray, n: int,
                 order: np.ndarray | None = None) -> np.ndarray:
    """Greedy (first-fit) coloring.  Returns color id per node (0-based)."""
    colors = np.full(n, -1, dtype=np.int64)
    scratch = np.full(n, -1, dtype=np.int64)  # color -> last node that used it
    seq = np.arange(n) if order is None else order
    for v in seq:
        for u in indices[indptr[v]:indptr[v + 1]]:
            cu = colors[u]
            if cu >= 0:
                scratch[cu] = v
        c = 0
        while scratch[c] == v:
            c += 1
        colors[v] = c
    return colors


@dataclasses.dataclass(frozen=True)
class MCOrdering:
    """Nodal multi-color ordering."""
    perm: np.ndarray          # perm[old] = new
    colors: np.ndarray        # color of each *old* unknown
    n_colors: int
    color_counts: np.ndarray  # unknowns per color, in new order


def multicolor_ordering(a: sp.spmatrix) -> MCOrdering:
    n = a.shape[0]
    indptr, indices = adjacency_lists(a)
    colors = greedy_color(indptr, indices, n)
    n_colors = int(colors.max()) + 1
    # stable sort by color keeps original order inside each color
    new_order = np.argsort(colors, kind="stable")   # new -> old
    perm = np.empty(n, dtype=np.int64)
    perm[new_order] = np.arange(n)
    counts = np.bincount(colors, minlength=n_colors)
    return MCOrdering(perm=perm, colors=colors, n_colors=n_colors,
                      color_counts=counts)


@dataclasses.dataclass(frozen=True)
class BMCOrdering:
    """Algebraic block multi-color ordering.

    Unknown layout in the new order: colors ascending; inside a color its
    blocks consecutively (``block_size`` unknowns each, padded with dummy
    unknowns so every block is exactly ``block_size`` long); inside a block
    the original relative order is preserved.

    ``perm`` maps old index -> new index over the *padded* system of size
    ``n_padded = n_blocks_total * block_size``.  Dummy slots are the padded
    tail of each block; ``is_dummy`` marks them in the new order.
    """
    perm: np.ndarray
    n: int
    n_padded: int
    block_size: int
    n_colors: int
    block_color: np.ndarray        # color of each block
    blocks_per_color: np.ndarray   # number of blocks in each color
    block_of_new: np.ndarray       # block id (global, color-major) per new idx
    is_dummy: np.ndarray           # bool per new index


def _build_blocks(a: sp.spmatrix, block_size: int) -> list[list[int]]:
    """Min-index-seeded greedy block growing (2012 paper, simplest heuristic).

    Plain-Python-int hot loop (adjacency converted to lists once, a stamp
    array instead of a per-block set): same blocks as the original numpy
    walk, a few times faster — block building is the dominant host cost of
    the hbmc setup pipeline once factorization and packing are vectorized.
    """
    n = a.shape[0]
    indptr_a, indices_a = adjacency_lists(a)
    indptr = indptr_a.tolist()
    indices = indices_a.tolist()
    assigned = bytearray(n)
    in_heap = [0] * n        # stamp = block id + 1 marks "already pushed"
    blocks: list[list[int]] = []
    # frontier-based growth: keep candidate set of neighbors of current block
    import heapq
    heappush, heappop = heapq.heappush, heapq.heappop
    next_seed = 0
    while True:
        while next_seed < n and assigned[next_seed]:
            next_seed += 1
        if next_seed >= n:
            break
        blk = [next_seed]
        assigned[next_seed] = 1
        stamp = len(blocks) + 1
        heap: list[int] = []
        for u in indices[indptr[next_seed]:indptr[next_seed + 1]]:
            if not assigned[u] and in_heap[u] != stamp:
                in_heap[u] = stamp; heappush(heap, u)
        while len(blk) < block_size and heap:
            v = heappop(heap)
            if assigned[v]:
                continue
            blk.append(v)
            assigned[v] = 1
            for u in indices[indptr[v]:indptr[v + 1]]:
                if not assigned[u] and in_heap[u] != stamp:
                    in_heap[u] = stamp; heappush(heap, u)
        blk.sort()  # preserve original relative order inside the block
        blocks.append(blk)
    return blocks


def block_multicolor_ordering(a: sp.spmatrix, block_size: int) -> BMCOrdering:
    n = a.shape[0]
    blocks = _build_blocks(a, block_size)
    nb = len(blocks)
    # quotient graph over blocks
    block_of = np.empty(n, dtype=np.int64)
    for bi, blk in enumerate(blocks):
        for v in blk:
            block_of[v] = bi
    indptr, indices = adjacency_lists(a)
    # block adjacency via edge contraction
    coo_rows = np.repeat(np.arange(n), np.diff(indptr))
    br, bc = block_of[coo_rows], block_of[indices]
    mask = br != bc
    badj = sp.coo_matrix(
        (np.ones(mask.sum(), dtype=np.int8), (br[mask], bc[mask])),
        shape=(nb, nb)).tocsr()
    badj.sum_duplicates()
    bcolors = greedy_color(badj.indptr, badj.indices, nb)
    n_colors = int(bcolors.max()) + 1

    # order blocks by (color, block id)
    border = np.argsort(bcolors, kind="stable")  # new block pos -> old block id
    blocks_per_color = np.bincount(bcolors, minlength=n_colors)

    n_padded = nb * block_size
    ordered = [blocks[oldb] for oldb in border]
    blk_lens = np.fromiter((len(b) for b in ordered), dtype=np.int64,
                           count=nb)
    import itertools
    flat = np.fromiter(itertools.chain.from_iterable(ordered),
                       dtype=np.int64, count=n)
    within = ragged_arange(blk_lens)
    perm = np.empty(n, dtype=np.int64)
    perm[flat] = np.repeat(np.arange(nb) * block_size, blk_lens) + within
    block_of_new = np.repeat(np.arange(nb), block_size)
    is_dummy = (np.arange(n_padded) % block_size
                ) >= np.repeat(blk_lens, block_size)
    block_color = bcolors[border]
    return BMCOrdering(
        perm=perm, n=n, n_padded=n_padded, block_size=block_size,
        n_colors=n_colors, block_color=block_color,
        blocks_per_color=blocks_per_color, block_of_new=block_of_new,
        is_dummy=is_dummy)


def pad_system(a: sp.spmatrix, b: np.ndarray | None, ordering: BMCOrdering
               ) -> tuple[sp.csr_matrix, np.ndarray | None]:
    """Apply a BMC ordering, embedding the system into the padded size.

    Dummy unknowns get a 1.0 diagonal and zero RHS; they never couple to real
    unknowns, so the Krylov process on the padded system reproduces the
    original one exactly.
    """
    n, npad = ordering.n, ordering.n_padded
    coo = sp.coo_matrix(a)
    p = ordering.perm
    rows = p[coo.row]
    cols = p[coo.col]
    data = coo.data                # keep the caller's dtype (f32 stays f32)
    if not np.issubdtype(data.dtype, np.floating):
        data = data.astype(np.float64)
    dummy_idx = np.nonzero(ordering.is_dummy)[0]
    rows = np.concatenate([rows, dummy_idx])
    cols = np.concatenate([cols, dummy_idx])
    data = np.concatenate([data, np.ones(len(dummy_idx), dtype=data.dtype)])
    a_bar = sp.coo_matrix((data, (rows, cols)), shape=(npad, npad)).tocsr()
    b_bar = None
    if b is not None:
        b = np.asarray(b)          # keep the caller's dtype (f32 stays f32)
        b_bar = np.zeros(npad, dtype=b.dtype)
        b_bar[p] = b
    return a_bar, b_bar
