"""Static Pallas kernel checks for the hbmc_trisolve / sell_spmv families.

The kernels (``repro.kernels``) assume a handful of static properties of
their packed operands that, when violated, fail only at dispatch time (or
worse, silently on TPU where an out-of-tile index wraps).  These checks
prove them on the host before any ``pallas_call``:

  * **shape/grid consistency** — the fused trisolve grid is ``(2S,)`` with
    per-step BlockSpecs ``(1, R, K)`` over ``(2S, R, K)`` operands, the
    SELL grid ``(ns/t,)`` with slice-tile BlockSpecs; block shapes must
    divide the (padded) operand shapes exactly;
  * **index-map bounds** — every gather index a kernel can read with a
    nonzero value must land inside the VMEM-resident vector (the
    ``fill_value=0`` guard is only correct when paired with zero values);
  * **VMEM footprint** — the per-grid-step working set (blocked operands +
    resident vectors, input/output-aliased buffers counted once) against a
    per-core budget, with the estimate returned so callers can rescale.

Checks return :class:`repro.analysis.schedule.Violation` lists (empty =
clean) so the CLI prints one witness format for schedule and kernel
findings alike.  VMEM size per the Pallas TPU guide: ~16 MiB/core.
"""
from __future__ import annotations

import numpy as np

from .schedule import MAX_VIOLATIONS, ScheduleError, Violation

#: Per-core VMEM budget (bytes).  TPU VMEM is ~16 MiB/core; the default
#: leaves headroom for the compiler's own buffers.
VMEM_BUDGET_BYTES = 14 * 2**20

#: Mirrors kernels.config.DEFAULT_SLICE_TILE without importing jax.
DEFAULT_SLICE_TILE = 256


def trisolve_fused_vmem_bytes(s2: int, r: int, k: int, itemsize: int,
                              batch: int = 1) -> int:
    """Working set of one fused-trisolve grid step, in bytes.

    Blocked per step: cols (1, R, K) int32 + vals (1, R, K) dtype +
    dinv (1, R) dtype.  Resident across steps: q (S, R[, B]) dtype and the
    in/out-aliased y (S*R[, B]) dtype (counted once — aliasing means one
    buffer).
    """
    s = s2 // 2
    per_step = r * k * (4 + itemsize) + r * itemsize
    resident = s * r * batch * itemsize * 2          # q + aliased y
    return per_step + resident


def sell_spmv_vmem_bytes(t: int, k: int, w: int, n_pad: int, itemsize: int,
                         batch: int = 1) -> int:
    """Working set of one SELL SpMV grid step, in bytes: vals + cols tiles
    (t, K, w), the resident x (n_pad[, B]) and the output tile
    (t, w[, B])."""
    tiles = t * k * w * (4 + itemsize)
    resident = n_pad * batch * itemsize
    out_tile = t * w * batch * itemsize
    return tiles + resident + out_tile


def check_trisolve_fused(cols, vals, dinv, batch: int = 1,
                         vmem_budget: int = VMEM_BUDGET_BYTES,
                         where: str = "kernel/hbmc_trisolve_fused"
                         ) -> list[Violation]:
    """Static checks for ``kernels.hbmc_trisolve.hbmc_trisolve_fused``
    (and its batched variant) against packed fused tables."""
    cols = np.asarray(cols)
    vals = np.asarray(vals)
    dinv = np.asarray(dinv)
    out: list[Violation] = []
    if cols.ndim != 3 or cols.shape != vals.shape:
        out.append(Violation(
            kind="shape-mismatch", where=where,
            detail=f"cols {cols.shape} vs vals {vals.shape}; expected "
                   f"matching (2S, R, K)"))
        return out
    s2, r_, k_ = cols.shape
    if dinv.shape != (s2, r_):
        out.append(Violation(
            kind="shape-mismatch", where=where,
            detail=f"dinv {dinv.shape} != {(s2, r_)}"))
        return out
    if s2 % 2:
        # grid (2S,) with the fwd/bwd halves mirrored: odd step counts
        # cannot split into two sweeps
        out.append(Violation(
            kind="grid-divisibility", where=where,
            detail=f"fused step axis {s2} is odd; expected 2*S"))
        return out
    m = (s2 // 2) * r_
    if not np.issubdtype(cols.dtype, np.integer):
        out.append(Violation(
            kind="index-dtype", where=where,
            detail=f"cols dtype {cols.dtype} is not integral"))
        return out
    oob = (cols < 0) | (cols > m)
    if oob.any():
        g, t, k = (int(x) for x in np.argwhere(oob)[0])
        out.append(Violation(
            kind="index-bounds", where=where, round=g,
            detail=f"cols[{g},{t},{k}] = {int(cols[g, t, k])} outside the "
                   f"kernel's gather domain [0, {m}] (fill_value pad is "
                   f"exactly {m})"))
    live_oob = (cols == m) & (vals != 0)
    if live_oob.any():
        g, t, k = (int(x) for x in np.argwhere(live_oob)[0])
        out.append(Violation(
            kind="index-bounds", where=where, round=g,
            detail=f"vals[{g},{t},{k}] != 0 on the fill_value pad "
                   f"position — the guarded read would drop a real "
                   f"contribution"))
    need = trisolve_fused_vmem_bytes(s2, r_, k_, vals.dtype.itemsize,
                                     batch=batch)
    if need > vmem_budget:
        out.append(Violation(
            kind="vmem-budget", where=where,
            detail=f"per-step working set ~{need / 2**20:.1f} MiB exceeds "
                   f"the {vmem_budget / 2**20:.1f} MiB budget (S={s2 // 2}, "
                   f"R={r_}, K={k_}, B={batch}); shard rounds across "
                   f"devices or reduce the lane tile"))
    return out[:MAX_VIOLATIONS]


def check_sell_spmv(vals, cols, n_pad: int, batch: int = 1,
                    slice_tile: int = DEFAULT_SLICE_TILE,
                    vmem_budget: int = VMEM_BUDGET_BYTES,
                    where: str = "kernel/sell_spmv") -> list[Violation]:
    """Static checks for the ``kernels.sell_spmv`` family against a packed
    SELL operand; ``n_pad`` is the length of the VMEM-resident x vector."""
    vals = np.asarray(vals)
    cols = np.asarray(cols)
    out: list[Violation] = []
    if vals.ndim != 3 or cols.shape != vals.shape:
        out.append(Violation(
            kind="shape-mismatch", where=where,
            detail=f"cols {cols.shape} vs vals {vals.shape}; expected "
                   f"matching (n_slices, K, w)"))
        return out
    n_slices, k_, w_ = vals.shape
    if slice_tile < 1:
        out.append(Violation(
            kind="grid-divisibility", where=where,
            detail=f"slice_tile {slice_tile} < 1"))
        return out
    if not np.issubdtype(cols.dtype, np.integer):
        out.append(Violation(
            kind="index-dtype", where=where,
            detail=f"cols dtype {cols.dtype} is not integral"))
        return out
    # the kernel pads the slice axis to a multiple of t = min(tile, ns),
    # so the grid always divides; what CAN go wrong is a live gather index
    # outside the resident x (fill_value masks it to 0 — a dropped term)
    t = min(slice_tile, n_slices)
    live = vals != 0
    bad = live & ((cols < 0) | (cols >= n_pad))
    if bad.any():
        s, k, w = (int(x) for x in np.argwhere(bad)[0])
        out.append(Violation(
            kind="index-bounds", where=where, round=s // max(t, 1),
            detail=f"cols[{s},{k},{w}] = {int(cols[s, k, w])} with a "
                   f"nonzero value, outside x's domain [0, {n_pad}) — the "
                   f"fill_value guard would silently drop this term"))
    need = sell_spmv_vmem_bytes(t, k_, w_, n_pad, vals.dtype.itemsize,
                                batch=batch)
    if need > vmem_budget:
        out.append(Violation(
            kind="vmem-budget", where=where,
            detail=f"per-step working set ~{need / 2**20:.1f} MiB exceeds "
                   f"the {vmem_budget / 2**20:.1f} MiB budget "
                   f"(tile={t}, K={k_}, w={w_}, n_pad={n_pad}, B={batch}); "
                   f"lower slice_tile or shard the slice axis"))
    return out[:MAX_VIOLATIONS]


def check_plan_kernels(plan, batch: int = 1,
                       vmem_budget: int = VMEM_BUDGET_BYTES
                       ) -> list[Violation]:
    """Run the static kernel checks a plan's backend selection implies.

    ``backend="pallas"`` (round-major) routes the preconditioner through
    ``hbmc_trisolve_fused``; ``spmv_backend="pallas"`` routes the SpMV
    through ``sell_spmv``.  XLA-only plans return ``[]`` — their lowering
    has no static kernel contract to break.
    """
    out: list[Violation] = []
    if plan.backend == "pallas" and plan.layout == "round_major":
        t = plan._precond.tables
        out += check_trisolve_fused(t.cols, t.vals, t.dinv, batch=batch,
                                    vmem_budget=vmem_budget)
    if plan.spmv_backend == "pallas":
        out += check_sell_spmv(plan._spmv_vals, plan._spmv_cols,
                               n_pad=int(plan.slab_m), batch=batch,
                               vmem_budget=vmem_budget)
    return out


def assert_plan_kernels(plan, batch: int = 1,
                        vmem_budget: int = VMEM_BUDGET_BYTES,
                        context: str = "") -> None:
    violations = check_plan_kernels(plan, batch=batch,
                                    vmem_budget=vmem_budget)
    if violations:
        raise ScheduleError(violations, context=context)
