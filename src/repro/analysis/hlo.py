"""Shared optimized-HLO parsing + trip-count-aware cost analysis.

One home for the HLO text machinery that used to be duplicated between
``launch/hlo_analysis.py`` (full cost walker) and ``launch/roofline.py``
(collective-only parser): the dtype/byte tables, the op/computation
regexes, :func:`parse_module`, and the collective bookkeeping.  Both
launch modules are now thin consumers, and the analyzers in this package
(``collectives``, ``traffic``) build their structural proofs on the same
parse.

``compiled.cost_analysis()`` counts a while-loop body ONCE, regardless of
trip count — with scan-over-layers models that under-reports FLOPs/bytes by
~n_layers and silently drops per-layer collectives.  :class:`Analyzer`
walks the HLO computation graph instead:

  * while ops multiply their body/condition cost by ``known_trip_count``
    (XLA annotates scan/fori loops; dynamic whiles fall back to the bound
    constant in the condition, else 1);
  * fusion/call/conditional recurse into called computations (FLOPs), while
    HBM traffic is charged at the fusion boundary (operands + result), the
    same model XLA's own analysis uses;
  * collectives are recorded by kind with the loop multiplier applied, so a
    per-layer all-reduce inside the layer scan is counted n_layers times.

FLOP model: dot = 2 * result_elems * contraction_size; elementwise-ish ops =
1 flop/output element; reduce = input elems.  Conservative and dominated by
dots for every cell we lower.

Everything here is pure-python text processing: no jax import.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[^\s=]+)\s*=\s*"
    r"(?P<type>\([^()]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>[\w\-]+)\((?P<args>.*?)\)(?P<rest>.*)$")

_COMP_RE = re.compile(r"^(ENTRY\s+)?%?(?P<name>[\w\.\-]+)\s*\(.*\)\s*->.*{\s*$")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "tanh", "exponential", "log", "rsqrt", "sqrt", "negate", "abs", "sign",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "logistic",
    "cosine", "sine", "atan2", "select", "compare", "and", "or", "xor",
    "not", "clamp", "convert", "erf", "exponential-minus-one", "log-plus-one",
    "remainder", "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "cbrt", "is-finite", "stochastic-convert",
}

_MEMORY_OPS = {
    "fusion", "dot", "convolution", "copy", "dynamic-slice",
    "dynamic-update-slice", "gather", "scatter", "sort", "transpose",
    "reduce", "broadcast", "concatenate", "pad", "slice", "reverse", "map",
    "reduce-window", "select-and-scatter", "iota", "rng", "cholesky",
    "triangular-solve", "all-reduce", "all-gather", "reduce-scatter",
    "all-to-all", "collective-permute",
}

# TPU-faithful HBM model: ops a TPU backend materializes for free
_ZERO_COST = {"broadcast", "iota", "constant", "reshape", "bitcast",
              "tuple", "get-tuple-element", "parameter", "after-all",
              "partition-id", "replica-id", "optimization-barrier"}
# producers/consumers that TPU fusion merges (intermediate never hits HBM)
_FUSABLE = _ELEMENTWISE | {"fusion", "dot", "convolution", "reduce",
                           "transpose", "map"}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
# bytes-on-the-wire multiplier per unit buffer (ring algorithms): a ring
# all-reduce moves ~2x its buffer, all-gather/reduce-scatter (n-1)/n ~ 1x
COLL_WIRE = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
             "all-to-all": 1.0, "collective-permute": 1.0}

#: slice-family ops whose RESULT (or update operand) keeps its exact shape
#: through fusion — the reliable, physically-meaningful byte measurements
#: in an optimized module (see ``analysis.traffic``)
SLICE_OPS = ("dynamic-slice", "gather", "slice")


def base_kind(kind: str) -> str:
    """Collective/async op base name: ``all-gather-start`` -> ``all-gather``."""
    return kind[:-6] if kind.endswith("-start") else kind


def shape_info(type_str: str) -> tuple[int, int]:
    """(elements, bytes) of a type string (sums tuple components)."""
    elems = byts = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    type_str: str
    args: list
    rest: str
    elems: int
    bytes: int


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    by_name: dict
    use_count: dict = dataclasses.field(default_factory=dict)


def parse_module(text: str) -> dict:
    comps: dict = {}
    cur = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(m.group("name"), [], {})
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        elems, byts = shape_info(m.group("type"))
        args = [a.strip().lstrip("%") for a in
                _split_args(m.group("args"))]
        op = Op(m.group("name"), m.group("op"), m.group("type"), args,
                m.group("rest"), elems, byts)
        cur.ops.append(op)
        cur.by_name[op.name] = op
    for comp in comps.values():
        uc: dict = {}
        consumers: dict = {}
        for op in comp.ops:
            for a in op.args:
                name = a.split()[-1].lstrip("%")
                uc[name] = uc.get(name, 0) + 1
                consumers.setdefault(name, []).append(op.kind)
        comp.use_count = uc
        comp.consumers = consumers          # type: ignore[attr-defined]
    return comps


def entry_name(text: str, comps: dict) -> str:
    """Name of the module's ENTRY computation (last one in the text)."""
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
    return m.group(1) if m else next(iter(comps))


def _split_args(s: str) -> list:
    """Split top-level comma-separated operand names."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return [a for a in (x.strip() for x in out) if a]


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                      r"(\{[^}]*\}|%?[\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def called_comps(rest: str) -> list:
    """Computation names called by an op (fusion/call/while/conditional)."""
    out = []
    for m in _CALL_RE.finditer(rest):
        v = m.group(1)
        if v.startswith("{"):
            out.extend(x.strip().lstrip("%") for x in
                       v.strip("{}").split(",") if x.strip())
        else:
            out.append(v.lstrip("%"))
    return out


def trip_count(op: Op, comps: dict) -> int:
    """Executed iterations of a while op: the ``known_trip_count``
    annotation when XLA proved one, else the largest s32 constant in the
    condition computation (the bound of a counted loop); dynamic whiles
    degrade to 1."""
    m = _TRIP_RE.search(op.rest)
    if m:
        return int(m.group(1))
    best = 1
    for cname in called_comps(op.rest):
        comp = comps.get(cname)
        if comp is None:
            continue
        for o in comp.ops:
            if o.kind == "constant" and o.type_str.startswith("s32") \
                    and o.args and o.args[0].isdigit():
                best = max(best, int(o.args[0]))
    return best


def replica_group_size(op: Op) -> int | None:
    """Participant count of a collective's first replica group, if the op
    carries literal ``replica_groups={{...}}`` (None for the iota-tile
    encodings some versions emit)."""
    m = _GROUPS_RE.search(op.rest)
    if m is None:
        return None
    return len([x for x in m.group(1).split(",") if x])


def operand_bytes(op: Op, comp: Computation) -> int:
    total = 0
    for a in op.args:
        # strip inline type prefix if present ("f32[..] %x") and constants
        name = a.split()[-1].lstrip("%")
        ref = comp.by_name.get(name)
        if ref is not None:
            total += ref.bytes
    return total


def _arg_op(op: Op, comp: Computation, i: int):
    if i >= len(op.args):
        return None
    return comp.by_name.get(op.args[i].split()[-1].lstrip("%"))


def _bf16_rooted(op, comp: Computation, depth: int = 4) -> bool:
    """True if this f32 value is (transitively) produced from bf16 data —
    i.e. it exists in f32 only because XLA:CPU expands bf16 dots to f32.
    Conservative DFS: unresolvable chains (loop carries, parameters) count
    as NOT bf16-rooted."""
    if op is None or depth <= 0:
        return False
    if "bf16[" in op.type_str:
        return True
    if op.kind == "convert" or (op.kind == "fusion"
                                and "convert" in op.name):
        inner = _arg_op(op, comp, 0)
        return inner is not None and "bf16[" in inner.type_str
    if op.kind in ("dot", "add", "multiply", "subtract", "maximum",
                   "minimum", "copy", "transpose", "reshape", "bitcast",
                   "fusion", "divide", "exponential", "tanh", "select"):
        args = [_arg_op(op, comp, i) for i in range(len(op.args))]
        args = [a for a in args if a is not None and a.kind != "constant"
                and not a.type_str.startswith(("s32", "u32", "pred"))]
        if not args:
            return False
        return all(_bf16_rooted(a, comp, depth - 1) for a in args)
    return False


def _hbm_bytes(op: Op, comp: Computation, base: str) -> float:
    """TPU-faithful HBM traffic for one op, with fusion-chain coalescing:
    a single-use intermediate between two fusable ops never hits HBM."""
    if base in _ZERO_COST:
        return 0.0
    if base == "dynamic-slice":
        return 2.0 * op.bytes                      # read slice + write
    if base == "gather":
        return 2.0 * op.bytes                      # random reads ~ result
    if base == "dynamic-update-slice":
        upd = _arg_op(op, comp, 1)
        b = upd.bytes if upd is not None else op.bytes
        return 2.0 * b                             # in-place slice update
    if base == "scatter":
        upd = _arg_op(op, comp, 2)
        b = upd.bytes if upd is not None else op.bytes
        return 3.0 * b                             # read+modify+write
    if base in ("copy", "concatenate", "pad", "slice", "reverse"):
        return 2.0 * op.bytes
    if base == "sort":
        return 2.0 * (op.bytes + operand_bytes(op, comp))

    # fusable family (elementwise / fusion / dot / reduce / transpose):
    # charge operands whose producer is NOT a single-use fusable op, and
    # the result only if some consumer is non-fusable or it is multi-use.
    total = 0.0
    for a in op.args:
        name = a.split()[-1].lstrip("%")
        ref = comp.by_name.get(name)
        if ref is None:
            continue
        ref_base = base_kind(ref.kind)
        if ref_base in _ZERO_COST:
            continue
        if ref_base in _FUSABLE and comp.use_count.get(name, 0) == 1:
            continue                               # fused edge: free
        total += ref.bytes
    cons = getattr(comp, "consumers", {}).get(op.name, [])
    fused_out = (len(cons) == 1 and cons[0] in _FUSABLE
                 and base in _FUSABLE)
    if not fused_out:
        total += op.bytes
    return total


class Analyzer:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self._memo: dict = {}
        self.entry = entry_name(text, self.comps)

    def _trip_count(self, op: Op) -> int:
        return trip_count(op, self.comps)

    def comp_cost(self, name: str) -> dict:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        zero = {"flops": 0.0, "bytes": 0.0,
                "coll_bytes": defaultdict(float),
                "coll_count": defaultdict(float),
                "coll_wire": 0.0}
        if comp is None:
            return zero
        self._memo[name] = zero  # break cycles defensively
        flops = byts = wire = 0.0
        coll_b: defaultdict = defaultdict(float)
        coll_c: defaultdict = defaultdict(float)

        for op in comp.ops:
            kind = op.kind
            base = base_kind(kind)
            if kind.endswith("-done") or kind.endswith("-update-done"):
                continue
            if base == "while":
                trip = self._trip_count(op)
                for cname in called_comps(op.rest):
                    sub = self.comp_cost(cname)
                    flops += trip * sub["flops"]
                    byts += trip * sub["bytes"]
                    wire += trip * sub["coll_wire"]
                    for k, v in sub["coll_bytes"].items():
                        coll_b[k] += trip * v
                    for k, v in sub["coll_count"].items():
                        coll_c[k] += trip * v
                continue
            if base in ("fusion", "call", "conditional", "async-start"):
                for cname in called_comps(op.rest):
                    sub = self.comp_cost(cname)
                    flops += sub["flops"]
                    byts += sub["bytes"]
                    wire += sub["coll_wire"]
                    for k, v in sub["coll_bytes"].items():
                        coll_b[k] += v
                    for k, v in sub["coll_count"].items():
                        coll_c[k] += v
                if base == "fusion":
                    byts += _hbm_bytes(op, comp, base)
                continue
            if base == "dot":
                contract = 1
                m = _CONTRACT_RE.search(op.rest)
                if m and op.args:
                    lhs = comp.by_name.get(op.args[0].split()[-1].lstrip("%"))
                    if lhs is not None:
                        shp = _SHAPE_RE.search(lhs.type_str)
                        if shp:
                            dims = [int(d) for d in shp.group(2).split(",")
                                    if d]
                            for di in (int(x) for x in m.group(1).split(",")
                                       if x):
                                if di < len(dims):
                                    contract *= dims[di]
                flops += 2.0 * op.elems * contract
                byts += _hbm_bytes(op, comp, base)
                continue
            if base in COLLECTIVES:
                buf = max(op.bytes, operand_bytes(op, comp))
                # CPU-backend correction: XLA CPU expands bf16 dots to f32,
                # so the partitioner moves f32 buffers where TPU would move
                # bf16.  A collective whose operands are (chains of)
                # converts from bf16 is charged at bf16 width.
                if "f32[" in op.type_str and op.args and all(
                        _bf16_rooted(_arg_op(op, comp, i_), comp)
                        for i_ in range(len(op.args))):
                    buf *= 0.5
                coll_b[base] += buf
                coll_c[base] += 1
                wire += COLL_WIRE[base] * buf
                byts += op.bytes + operand_bytes(op, comp)
                continue
            if base in _ELEMENTWISE:
                flops += op.elems
            elif base == "reduce":
                flops += operand_bytes(op, comp) // 4 or op.elems
            byts += _hbm_bytes(op, comp, base)

        out = {"flops": flops, "bytes": byts, "coll_bytes": coll_b,
               "coll_count": coll_c, "coll_wire": wire}
        self._memo[name] = out
        return out

    def totals(self) -> dict:
        t = self.comp_cost(self.entry)
        return {
            "flops": t["flops"],
            "bytes": t["bytes"],
            "collective_wire_bytes": t["coll_wire"],
            "collective_bytes_by_kind": dict(t["coll_bytes"]),
            "collective_counts": dict(t["coll_count"]),
        }


def analyze_hlo(text: str) -> dict:
    return Analyzer(text).totals()


@dataclasses.dataclass
class CollectiveStats:
    """Module-wide collective census (counts are STATIC op counts — no loop
    trip multiplication; use :class:`Analyzer` for executed counts)."""
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def weighted_bytes(self) -> float:
        return sum(COLL_WIRE[k] * b for k, b in self.bytes_by_kind.items())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result-buffer sizes of every collective in the module text.
    Async pairs appear as -start/-done; each op is counted once (at
    -start), since the -done line repeats the buffer."""
    bytes_by: dict = {k: 0 for k in COLLECTIVES}
    count_by: dict = {k: 0 for k in COLLECTIVES}
    for comp in parse_module(hlo_text).values():
        for op in comp.ops:
            base = base_kind(op.kind)
            if base not in COLLECTIVES or op.kind.endswith("-done"):
                continue
            bytes_by[base] += op.bytes
            count_by[base] += 1
    return CollectiveStats(bytes_by_kind=bytes_by, count_by_kind=count_by)
