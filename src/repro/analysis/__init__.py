"""Static analysis: schedule race detection + jaxpr/kernel contract linting.

Proves a plan is race-free and contract-conforming *before* it dispatches:

  ``schedule``       dependency-DAG race detector over rounds, packed
                     trisolve tables and the IC(0) step schedule, with
                     machine-readable ``Violation`` witnesses
  ``contracts``      jaxpr linter with per-lowering-path primitive budgets
  ``kernel_checks``  static Pallas kernel checks (grid/BlockSpec
                     divisibility, gather index bounds, VMEM footprint)

``build_plan(a, validate="cheap"|"full")`` runs the detector at setup;
``python -m repro.analysis`` audits matrices/orderings/plans from the CLI.
"""
from .contracts import (DISTRIBUTED_APPLY, FULL_PALLAS_ITERATION,
                        PALLAS_SPMV, PRECONDITIONED_ITERATION,
                        ROUND_MAJOR_APPLY, ContractError, PrimitiveBudget,
                        assert_budget, count_primitive, lint,
                        primitive_counts, primitives, retraces)
from .kernel_checks import (VMEM_BUDGET_BYTES, assert_plan_kernels,
                            check_plan_kernels, check_sell_spmv,
                            check_trisolve_fused, sell_spmv_vmem_bytes,
                            trisolve_fused_vmem_bytes)
from .schedule import (VALIDATE_MODES, ScheduleError, Violation,
                       assert_plan_valid, check_fused_tables,
                       check_ic0_structure, check_reversed_rounds,
                       check_rounds, check_step_tables, validate_plan)

__all__ = [
    "DISTRIBUTED_APPLY", "FULL_PALLAS_ITERATION", "PALLAS_SPMV",
    "PRECONDITIONED_ITERATION", "ROUND_MAJOR_APPLY", "ContractError",
    "PrimitiveBudget", "assert_budget", "count_primitive", "lint",
    "primitive_counts", "primitives", "retraces",
    "VMEM_BUDGET_BYTES", "assert_plan_kernels", "check_plan_kernels",
    "check_sell_spmv", "check_trisolve_fused", "sell_spmv_vmem_bytes",
    "trisolve_fused_vmem_bytes",
    "VALIDATE_MODES", "ScheduleError", "Violation", "assert_plan_valid",
    "check_fused_tables", "check_ic0_structure", "check_reversed_rounds",
    "check_rounds", "check_step_tables", "validate_plan",
]
