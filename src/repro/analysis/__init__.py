"""Static analysis: schedule races, contracts, numerics and data movement.

Proves a plan is race-free and contract-conforming *before* it dispatches:

  ``schedule``       dependency-DAG race detector over rounds, packed
                     trisolve tables and the IC(0) step schedule, with
                     machine-readable ``Violation`` witnesses
  ``contracts``      jaxpr linter with per-lowering-path primitive budgets
  ``kernel_checks``  static Pallas kernel checks (grid/BlockSpec
                     divisibility, gather index bounds, VMEM footprint)
  ``dtype_flow``     jaxpr dtype-propagation linter proving each lowering
                     path's ``PrecisionContract`` (no silent
                     promotion/demotion, pinned accumulation dtypes)
  ``collectives``    optimized-HLO collective-structure proofs (one tiled
                     all-gather per color round on a mesh, nothing else)
  ``traffic``        static bytes-per-iteration model cross-checked
                     against HLO-measured slice bytes, plus the
                     ``bench-gate`` snapshot regression gate
  ``hlo``            the shared optimized-HLO parser + cost walker the
                     above (and ``launch/``) build on

``build_plan(a, validate="cheap"|"full"|"deep")`` runs the detector at
setup; ``python -m repro.analysis`` audits matrices/orderings/plans from
the CLI, and ``python -m repro.analysis bench-gate`` gates fresh bench
runs against the committed ``BENCH_*.json`` snapshots.
"""
from .collectives import (FORBIDDEN_COLLECTIVES, assert_plan_collectives,
                          check_collective_structure,
                          check_plan_collectives, collective_bodies,
                          optimized_hlo)
from .contracts import (DISTRIBUTED_APPLY, FULL_PALLAS_ITERATION,
                        PALLAS_SPMV, PRECONDITIONED_ITERATION,
                        ROUND_MAJOR_APPLY, ContractError, PrimitiveBudget,
                        assert_budget, count_primitive, format_eqn_path,
                        iter_eqns, lint, primitive_counts, primitives,
                        retraces)
from .dtype_flow import (PrecisionContract, assert_plan_dtype_flow,
                         check_plan_dtype_flow, contract_for_plan,
                         lint_dtype_flow)
from .hlo import CollectiveStats, analyze_hlo, parse_collectives
from .kernel_checks import (VMEM_BUDGET_BYTES, assert_plan_kernels,
                            check_plan_kernels, check_sell_spmv,
                            check_trisolve_fused, sell_spmv_vmem_bytes,
                            trisolve_fused_vmem_bytes)
from .schedule import (VALIDATE_MODES, ScheduleError, Violation,
                       assert_plan_valid, check_fused_tables,
                       check_ic0_structure, check_reversed_rounds,
                       check_rounds, check_step_tables, validate_plan)
from .traffic import (TrafficReport, TrafficTerm, assert_plan_traffic,
                      bench_gate, check_plan_traffic, compare_traffic,
                      measured_slice_bytes, traffic_report)

__all__ = [
    "DISTRIBUTED_APPLY", "FULL_PALLAS_ITERATION", "PALLAS_SPMV",
    "PRECONDITIONED_ITERATION", "ROUND_MAJOR_APPLY", "ContractError",
    "PrimitiveBudget", "assert_budget", "count_primitive",
    "format_eqn_path", "iter_eqns", "lint", "primitive_counts",
    "primitives", "retraces",
    "PrecisionContract", "assert_plan_dtype_flow", "check_plan_dtype_flow",
    "contract_for_plan", "lint_dtype_flow",
    "FORBIDDEN_COLLECTIVES", "assert_plan_collectives",
    "check_collective_structure", "check_plan_collectives",
    "collective_bodies", "optimized_hlo",
    "TrafficReport", "TrafficTerm", "assert_plan_traffic", "bench_gate",
    "check_plan_traffic", "compare_traffic", "measured_slice_bytes",
    "traffic_report",
    "CollectiveStats", "analyze_hlo", "parse_collectives",
    "VMEM_BUDGET_BYTES", "assert_plan_kernels", "check_plan_kernels",
    "check_sell_spmv", "check_trisolve_fused", "sell_spmv_vmem_bytes",
    "trisolve_fused_vmem_bytes",
    "VALIDATE_MODES", "ScheduleError", "Violation", "assert_plan_valid",
    "check_fused_tables", "check_ic0_structure", "check_reversed_rounds",
    "check_rounds", "check_step_tables", "validate_plan",
]
