"""Jaxpr contract linter: per-lowering-path primitive budgets.

The repo's lowering paths each carry a structural contract that used to be
asserted by copy-pasted jaxpr-walking helpers in three test files:

  * the round-major apply performs ZERO scatters (its stores are dense
    ``dynamic_update_slice`` — the layout contract of PR 2);
  * a full-Pallas iteration has zero gather/scatter OUTSIDE ``pallas_call``
    kernels (a kernel's internal VMEM gather is the point, not a leak);
  * the distributed fused apply performs exactly ONE ``all_gather`` per
    color round (the loop body traces once, so the jaxpr shows one);
  * the preconditioned PCG iteration contains BOTH substitution sweeps
    (the seed-era plain-CG pairing bug);
  * ``refactor`` swaps operands with ZERO retraces.

This module is that one API.  ``primitives``/``count_primitive`` are the
walkers; :class:`PrimitiveBudget` + :func:`lint` evaluate a declarative
budget against a callable's jaxpr and return human-readable findings
(empty list = conforming); :func:`assert_budget` raises
:class:`ContractError`.  The ``descend_pallas`` flag decides whether
``pallas_call`` kernel bodies count against the budget — the round-major
apply forbids scatter *everywhere* (descend), the full-Pallas iteration
forbids gather only *outside* kernels (don't descend).
"""
from __future__ import annotations

import dataclasses
from collections import Counter

import jax


class ContractError(AssertionError):
    """A jaxpr violated its lowering-path contract.  Carries ``findings``
    (one string per violated budget line)."""

    def __init__(self, findings: list[str], context: str = ""):
        self.findings = list(findings)
        prefix = f"{context}: " if context else ""
        super().__init__(prefix + "; ".join(self.findings))


def iter_eqns(jaxpr, descend_pallas: bool = True, _path: tuple = ()):
    """Yield ``(path, eqn)`` for every equation in ``jaxpr``, nested
    sub-jaxprs (scan/while/cond bodies, pjit calls, pallas kernels)
    included.  ``path`` is a tuple of ``(primitive_name, eqn_index)``
    frames ending at the eqn itself — enough to name an offending eqn
    uniquely in a witness.  ``descend_pallas=False`` stops at
    ``pallas_call`` boundaries so kernel-internal eqns don't surface."""
    for i, eqn in enumerate(jaxpr.eqns):
        here = _path + ((eqn.primitive.name, i),)
        yield here, eqn
        if not descend_pallas and eqn.primitive.name == "pallas_call":
            continue
        for p in eqn.params.values():
            for sub in (p if isinstance(p, (list, tuple)) else [p]):
                if hasattr(sub, "jaxpr") and hasattr(sub.jaxpr, "eqns"):
                    yield from iter_eqns(sub.jaxpr, descend_pallas, here)
                elif hasattr(sub, "eqns"):           # raw Jaxpr
                    yield from iter_eqns(sub, descend_pallas, here)


def format_eqn_path(path: tuple) -> str:
    """Render an eqn path compactly: ``scan#3/convert_element_type#1``."""
    return "/".join(f"{name}#{i}" for name, i in path)


def primitive_counts(fn, *args, descend_pallas: bool = True) -> Counter:
    """Multiset of primitive names in ``fn``'s jaxpr, nested sub-jaxprs
    included.  ``descend_pallas=False`` stops at ``pallas_call`` boundaries
    so kernel-internal primitives don't count."""
    out: Counter = Counter()
    for _, eqn in iter_eqns(jax.make_jaxpr(fn)(*args).jaxpr,
                            descend_pallas=descend_pallas):
        out[eqn.primitive.name] += 1
    return out


def primitives(fn, *args, descend_pallas: bool = True) -> set:
    """Set of primitive names in ``fn``'s jaxpr (see ``primitive_counts``)."""
    return set(primitive_counts(fn, *args, descend_pallas=descend_pallas))


def count_primitive(fn, name: str, *args,
                    descend_pallas: bool = True) -> int:
    """Occurrences of one primitive in ``fn``'s jaxpr."""
    return primitive_counts(fn, *args,
                            descend_pallas=descend_pallas)[name]


@dataclasses.dataclass(frozen=True)
class PrimitiveBudget:
    """Declarative contract for one lowering path.

    ``forbid_substrings``  no primitive name may contain any of these
    ``require``            each of these primitives must appear >= once
    ``exact``              ((name, count), ...): each must appear exactly
                           ``count`` times
    ``min_loops``          if set, ``scan`` + ``while`` occurrences must be
                           >= this (the both-sweeps check)
    ``descend_pallas``     whether kernel bodies count against the budget
    """
    name: str
    forbid_substrings: tuple = ()
    require: tuple = ()
    exact: tuple = ()
    min_loops: int | None = None
    descend_pallas: bool = True


def lint(fn, *args, budget: PrimitiveBudget) -> list[str]:
    """Evaluate ``budget`` against ``fn``'s jaxpr; return findings."""
    counts = primitive_counts(fn, *args,
                              descend_pallas=budget.descend_pallas)
    findings = []
    for sub in budget.forbid_substrings:
        hits = sorted(p for p in counts if sub in p)
        if hits:
            findings.append(f"[{budget.name}] forbidden primitive(s) "
                            f"{hits} (matched {sub!r})")
    for p in budget.require:
        if counts[p] == 0:
            findings.append(f"[{budget.name}] required primitive {p!r} "
                            f"absent")
    for p, want in budget.exact:
        got = counts[p]
        if got != want:
            findings.append(f"[{budget.name}] expected exactly {want} "
                            f"{p!r}, found {got}")
    if budget.min_loops is not None:
        loops = counts["scan"] + counts["while"]
        if loops < budget.min_loops:
            findings.append(f"[{budget.name}] expected >= "
                            f"{budget.min_loops} loop primitives "
                            f"(scan/while), found {loops}")
    return findings


def assert_budget(fn, *args, budget: PrimitiveBudget,
                  context: str = "") -> None:
    findings = lint(fn, *args, budget=budget)
    if findings:
        raise ContractError(findings, context=context)


# ---------------------------------------------------------------------------
# The repo's lowering-path contracts (the one place they are defined).
# ---------------------------------------------------------------------------

#: Round-major apply/SpMV: zero scatter anywhere — stores are dense
#: dynamic_update_slice (kernel bodies included: the Pallas stores are
#: dense contiguous slices too).
ROUND_MAJOR_APPLY = PrimitiveBudget(
    name="round-major-apply", forbid_substrings=("scatter",),
    descend_pallas=True)

#: Full-Pallas iteration: at least one kernel launch, zero gather/scatter
#: OUTSIDE the kernels.
FULL_PALLAS_ITERATION = PrimitiveBudget(
    name="full-pallas-iteration", forbid_substrings=("gather", "scatter"),
    require=("pallas_call",), descend_pallas=False)

#: Pallas SpMV closure: a kernel launch, no gather outside it.
PALLAS_SPMV = PrimitiveBudget(
    name="pallas-spmv", forbid_substrings=("gather",),
    require=("pallas_call",), descend_pallas=False)

#: Distributed fused apply: exactly one all_gather in the jaxpr.  The fused
#: sweep's fori_loop body traces ONCE, so one all_gather equation in the
#: jaxpr IS one collective per executed color round.
DISTRIBUTED_APPLY = PrimitiveBudget(
    name="distributed-apply", exact=(("all_gather", 1),),
    descend_pallas=True)

#: Preconditioned PCG iteration: both substitution sweeps present.
#: Static-trip-count fori_loops trace as `scan`; they lower to HLO whiles.
PRECONDITIONED_ITERATION = PrimitiveBudget(
    name="preconditioned-iteration", min_loops=2, descend_pallas=True)


def retraces(plan, thunk) -> int:
    """Run ``thunk`` and return how many PCG (re)traces it triggered on
    ``plan`` — the zero-retrace refactor contract is
    ``retraces(plan, lambda: plan.refactor(a2)) == 0`` followed by a
    zero-retrace warm solve."""
    before = plan._trace_count
    thunk()
    return plan._trace_count - before
