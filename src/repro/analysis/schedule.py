"""Static schedule race detector: prove a plan race-free before dispatch.

Every parallel claim of the paper reduces to a static property of the
schedule.  The dependency DAG of a triangular factor L has an edge
``j -> i`` for every strictly-lower nonzero ``L[i, j]``: row ``i``'s
substitution reads ``y[j]``, so ``j`` must be *finished* first.  A round
schedule (MC / BMC / HBMC rounds, or any future scheduler backend) is legal
iff every edge crosses strictly forward in round order — which implies both
halves of the paper's claim at once:

  * every round is an **antichain** of the DAG (no intra-round edge:
    rows of one round are mutually independent, eq. 4.1), and
  * every step **reads only earlier-round writes** (the per-round barrier
    is the only synchronization the sweep needs).

The checkers here verify that property at three levels of materialization:

  ``check_rounds``         the ordering's round sets against the CSR
                           pattern (the O(nnz) "cheap" proof)
  ``check_step_tables``    the packed per-round gather tables
                           (``sell.StepTables`` — what the XLA sweep runs)
  ``check_fused_tables``   the fused fwd+bwd round-major tables
                           (``sell.FusedRoundMajorTables`` — what the
                           Pallas kernel and the shard_map sweep run)
  ``check_ic0_structure``  the IC(0) factorization step schedule
                           (``ic0.IC0Structure`` — the setup pipeline)

All checkers return a list of machine-readable :class:`Violation` witnesses
(empty = proven clean) instead of a bare bool, so a failure names the exact
offending row pair / DAG edge / round.  ``validate_plan`` composes them for
a built ``SolverPlan`` (the ``validate=`` knob of ``build_plan``), and
``python -m repro.analysis`` runs them from the command line.

Everything here is host-side numpy on host-side (or host-copied) tables:
no jax import, so ``core.plan`` can defer-import this module without a
cycle.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp

#: Checkers stop collecting after this many witnesses per artifact: the
#: point of a witness is to pinpoint, not to enumerate every consequence of
#: one corrupted round.
MAX_VIOLATIONS = 16


@dataclasses.dataclass(frozen=True)
class Violation:
    """One schedule/contract defect, pinned to its witness.

    ``kind``   what property failed (e.g. ``"intra-round-edge"``)
    ``where``  which artifact it was found in (``"rounds"``,
               ``"step_tables"``, ``"fused_tables"``, ``"ic0_steps"``,
               ``"kernel"``, ...)
    ``round``  the offending round / step / grid index, when applicable
    ``rows``   the offending row pair ``(i, j)`` in the checked ordering
    ``edge``   the offending DAG edge ``(src, dst)`` (src must finish
               before dst may start) or table-position pair
    ``detail`` human-readable one-liner
    """
    kind: str
    where: str
    round: int | None = None
    rows: tuple | None = None
    edge: tuple | None = None
    detail: str = ""

    def __str__(self) -> str:
        bits = [f"{self.where}: {self.kind}"]
        if self.round is not None:
            bits.append(f"round={self.round}")
        if self.rows is not None:
            bits.append(f"rows={tuple(int(x) for x in self.rows)}")
        if self.edge is not None:
            bits.append(f"edge={tuple(int(x) for x in self.edge)}")
        if self.detail:
            bits.append(f"({self.detail})")
        return " ".join(bits)


class ScheduleError(ValueError):
    """A schedule failed static validation.  Carries the machine-readable
    ``violations`` list; the message shows the first few witnesses."""

    def __init__(self, violations: list[Violation], context: str = ""):
        self.violations = list(violations)
        head = "; ".join(str(v) for v in self.violations[:4])
        more = len(self.violations) - 4
        if more > 0:
            head += f"; ... {more} more"
        prefix = f"{context}: " if context else ""
        super().__init__(f"{prefix}schedule validation failed "
                         f"[{len(self.violations)} violation(s)]: {head}")


def _strict_lower_edges(a: sp.spmatrix) -> tuple[np.ndarray, np.ndarray]:
    """Dependency edges (src=j, dst=i) of the forward sweep: one per
    strictly-lower nonzero a[i, j]."""
    low = sp.tril(sp.csr_matrix(a), k=-1, format="coo")
    return low.col.astype(np.int64), low.row.astype(np.int64)


def check_rounds(a_bar: sp.spmatrix, rounds: list[np.ndarray],
                 drop_mask: np.ndarray | None = None,
                 where: str = "rounds") -> list[Violation]:
    """Prove ``rounds`` is a legal forward schedule for ``a_bar``.

    ``rounds`` are execution-ordered row sets of the (already ordered /
    padded) matrix; ``drop_mask`` marks rows excluded from the schedule
    (dummy padding).  O(nnz + n): one pass to build the row -> round map,
    one vectorized scan over the strictly-lower pattern.  This is exactly
    the ``validate="cheap"`` proof — forward-crossing edges imply both the
    antichain property and read-only-earlier-writes.
    """
    n = a_bar.shape[0]
    out: list[Violation] = []
    round_id = np.full(n, -1, dtype=np.int64)
    for s, r in enumerate(rounds):
        r = np.asarray(r)
        if len(r) and (r.min() < 0 or r.max() >= n):
            bad = int(r[(r < 0) | (r >= n)][0])
            out.append(Violation(
                kind="row-out-of-range", where=where, round=s,
                rows=(bad, bad),
                detail=f"round {s} schedules row {bad} outside [0, {n})"))
            if len(out) >= MAX_VIOLATIONS:
                return out
            r = r[(r >= 0) & (r < n)]
        uniq, counts = np.unique(r, return_counts=True)
        dup = np.concatenate([uniq[counts > 1], r[round_id[r] >= 0]])
        if len(dup):
            i = int(dup[0])
            prev = int(round_id[i]) if round_id[i] >= 0 else s
            out.append(Violation(
                kind="duplicate-row", where=where, round=s, rows=(i, i),
                detail=f"row {i} scheduled in rounds {prev} and {s}"))
            if len(out) >= MAX_VIOLATIONS:
                return out
        round_id[r] = s
    unsched = np.flatnonzero(round_id < 0)
    if drop_mask is not None:
        unsched = unsched[~drop_mask[unsched]]
    for i in unsched[:MAX_VIOLATIONS - len(out)]:
        out.append(Violation(
            kind="unscheduled-row", where=where, rows=(int(i), int(i)),
            detail=f"row {int(i)} appears in no round"))
    if len(out) >= MAX_VIOLATIONS:
        return out

    src, dst = _strict_lower_edges(a_bar)
    rs, rd = round_id[src], round_id[dst]
    live = (rs >= 0) & (rd >= 0)   # unscheduled endpoints already reported,
    # unless they were dropped rows — a dropped row carrying a dependency
    # edge is a silent read of a never-computed value:
    if drop_mask is not None:
        dropped_edge = np.flatnonzero(
            (~live) & (drop_mask[src] | drop_mask[dst]))
        for e in dropped_edge[:MAX_VIOLATIONS - len(out)]:
            out.append(Violation(
                kind="unscheduled-dependency", where=where,
                rows=(int(dst[e]), int(src[e])),
                edge=(int(src[e]), int(dst[e])),
                detail="dependency edge touches a row dropped from the "
                       "schedule"))
        if len(out) >= MAX_VIOLATIONS:
            return out
    bad_same = np.flatnonzero(live & (rs == rd))
    for e in bad_same[:MAX_VIOLATIONS - len(out)]:
        out.append(Violation(
            kind="intra-round-edge", where=where, round=int(rs[e]),
            rows=(int(dst[e]), int(src[e])),
            edge=(int(src[e]), int(dst[e])),
            detail=f"rows {int(src[e])} and {int(dst[e])} share round "
                   f"{int(rs[e])} but are connected — not an antichain"))
    if len(out) >= MAX_VIOLATIONS:
        return out
    bad_order = np.flatnonzero(live & (rs > rd))
    for e in bad_order[:MAX_VIOLATIONS - len(out)]:
        out.append(Violation(
            kind="cross-round-order", where=where, round=int(rd[e]),
            rows=(int(dst[e]), int(src[e])),
            edge=(int(src[e]), int(dst[e])),
            detail=f"row {int(dst[e])} (round {int(rd[e])}) reads row "
                   f"{int(src[e])} written later (round {int(rs[e])})"))
    return out


def check_reversed_rounds(fwd_rounds: list[np.ndarray],
                          bwd_rounds: list[np.ndarray],
                          where: str = "rounds") -> list[Violation]:
    """The backward schedule must be the reversed forward schedule (lane
    order included) — the property ``fuse_round_major`` builds on.  A legal
    forward schedule then implies a legal backward one (same DAG, reversed)."""
    if len(fwd_rounds) != len(bwd_rounds):
        return [Violation(
            kind="round-count-mismatch", where=where,
            detail=f"{len(fwd_rounds)} forward vs {len(bwd_rounds)} "
                   f"backward rounds")]
    out = []
    for s, (f, b) in enumerate(zip(fwd_rounds, reversed(bwd_rounds))):
        if not np.array_equal(np.asarray(f), np.asarray(b)):
            out.append(Violation(
                kind="backward-not-reversed", where=where, round=s,
                detail="backward rounds are not the reversed forward "
                       "rounds (lane order included)"))
            if len(out) >= MAX_VIOLATIONS:
                break
    return out


def _table_arrays(t) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """(rows, cols, vals, n_slots) as host numpy from host or device tables."""
    return (np.asarray(t.rows), np.asarray(t.cols), np.asarray(t.vals),
            int(t.n_slots))


def check_step_tables(tables, tri: sp.spmatrix | None = None,
                      where: str = "step_tables") -> list[Violation]:
    """Verify materialized per-round gather tables (``sell.StepTables`` or
    ``trisolve.DeviceTables``) read only earlier-round writes.

    Checks, per step ``s``: every non-pad column index is a row assigned to
    a strictly earlier step (the packed form of the DAG proof), pad columns
    carry zero values, and indices stay in ``[0, n_slots)``.  With ``tri``
    (the strictly-triangular matrix the tables were packed from) it also
    proves **coverage**: every nonzero of ``tri`` whose row is scheduled
    appears in the tables — a silently dropped dependency is as much a race
    as a misordered one.
    """
    rows, cols, vals, n_slots = _table_arrays(tables)
    s_, r_ = rows.shape
    pad = n_slots - 1
    out: list[Violation] = []

    oob = (cols < 0) | (cols >= n_slots)
    if oob.any():
        s, t, k = (int(x) for x in np.argwhere(oob)[0])
        out.append(Violation(
            kind="index-out-of-range", where=where, round=s,
            detail=f"cols[{s},{t},{k}] = {int(cols[s, t, k])} outside "
                   f"[0, {n_slots})"))
    pad_val = (cols == pad) & (vals != 0)
    if pad_val.any():
        s, t, k = (int(x) for x in np.argwhere(pad_val)[0])
        out.append(Violation(
            kind="nonzero-pad-value", where=where, round=s,
            detail=f"vals[{s},{t},{k}] = {vals[s, t, k]!r} on the scratch "
                   f"pad slot"))

    step_of = np.full(n_slots, -1, dtype=np.int64)
    live = rows != pad
    uniq, counts = np.unique(rows[live], return_counts=True)
    for i in uniq[counts > 1][:MAX_VIOLATIONS - len(out)]:
        out.append(Violation(
            kind="duplicate-row", where=where, rows=(int(i), int(i)),
            detail=f"row {int(i)} assigned to multiple lanes"))
    step_idx = np.broadcast_to(np.arange(s_)[:, None], rows.shape)
    step_of[rows[live]] = step_idx[live]

    # every live (vals != 0, non-pad) gather must hit a row written earlier
    gather = (cols != pad) & (vals != 0)
    src_step = np.where(gather, step_of[np.minimum(cols, pad)], -2)
    reader_step = np.broadcast_to(np.arange(s_)[:, None, None], cols.shape)
    never = gather & (src_step == -1)
    late = gather & (src_step >= reader_step)
    for mask, kind, fmt in (
            (never, "unscheduled-dependency",
             "reads row {src} which is never written"),
            (late, "premature-read",
             "reads row {src} (step {ss}) at step {s}")):
        for s, t, k in np.argwhere(mask)[:MAX_VIOLATIONS - len(out)]:
            s, t, k = int(s), int(t), int(k)
            src = int(cols[s, t, k])
            dst = int(rows[s, t])
            out.append(Violation(
                kind=kind, where=where, round=s, rows=(dst, src),
                edge=(src, dst),
                detail=fmt.format(src=src, s=s,
                                  ss=int(step_of[src]))))
        if len(out) >= MAX_VIOLATIONS:
            return out

    if tri is not None:
        tri = sp.csr_matrix(tri)
        tri.sort_indices()
        packed = set(zip(rows[:, :, None].repeat(
            cols.shape[-1], axis=-1)[gather].tolist(),
            cols[gather].tolist()))
        coo = tri.tocoo()
        for i, j, v in zip(coo.row, coo.col, coo.data):
            if v == 0 or step_of[i] < 0:
                continue
            if (int(i), int(j)) not in packed:
                out.append(Violation(
                    kind="dropped-dependency", where=where,
                    rows=(int(i), int(j)), edge=(int(j), int(i)),
                    detail=f"pattern entry ({int(i)}, {int(j)}) missing "
                           f"from the packed tables"))
                if len(out) >= MAX_VIOLATIONS:
                    break
    return out


def check_fused_tables(fused, where: str = "fused_tables"
                       ) -> list[Violation]:
    """Verify fused fwd+bwd round-major tables
    (``sell.FusedRoundMajorTables`` or ``trisolve.DeviceFusedTables`` +
    layout) are triangular in execution order.

    In forward round-major coordinates, step ``g`` of the fused 2S-step
    schedule writes the contiguous destination slice ``d(g)*R`` with
    ``d(g) = g`` (forward half) or ``2S-1-g`` (backward half).  The race
    freedom proof is positional: every live gather of the forward half must
    read strictly BELOW its destination slice (already-written ``y``), every
    live gather of the backward half strictly ABOVE it (already-overwritten
    ``z`` — its dependencies), and pad gathers (``cols == m``) must carry
    zero values so the ``fill_value=0`` read is inert.
    """
    cols = np.asarray(fused.cols)
    vals = np.asarray(fused.vals)
    lay = getattr(fused, "layout", None)
    s2, r_, k_ = cols.shape
    s_ = s2 // 2
    m = s_ * r_
    out: list[Violation] = []
    if s2 != 2 * s_ or (lay is not None and lay.n_steps != s_):
        out.append(Violation(
            kind="shape-mismatch", where=where,
            detail=f"fused tables have {s2} steps, expected 2*S"))
        return out

    oob = (cols < 0) | (cols > m)
    if oob.any():
        g, t, k = (int(x) for x in np.argwhere(oob)[0])
        out.append(Violation(
            kind="index-out-of-range", where=where, round=g,
            detail=f"cols[{g},{t},{k}] = {int(cols[g, t, k])} outside "
                   f"[0, {m}]"))
    pad_val = (cols == m) & (vals != 0)
    if pad_val.any():
        g, t, k = (int(x) for x in np.argwhere(pad_val)[0])
        out.append(Violation(
            kind="nonzero-pad-value", where=where, round=g,
            detail=f"vals[{g},{t},{k}] = {vals[g, t, k]!r} on the "
                   f"out-of-range pad position"))

    pos = np.arange(m).reshape(s_, r_)
    dest = np.concatenate([pos, pos[::-1]])[:, :, None]
    live = (vals != 0) & (cols < m)
    fwd_bad = live[:s_] & (cols[:s_] >= dest[:s_])
    bwd_bad = live[s_:] & (cols[s_:] <= dest[s_:])
    for half, bad, goff, word in (("forward", fwd_bad, 0, "below"),
                                  ("backward", bwd_bad, s_, "above")):
        for g, t, k in np.argwhere(bad)[:MAX_VIOLATIONS - len(out)]:
            g, t, k = int(g), int(t), int(k)
            src = int(cols[goff + g, t, k])
            dst = int(dest[goff + g, t, 0])
            out.append(Violation(
                kind="premature-read", where=where, round=goff + g,
                rows=(dst, src), edge=(src, dst),
                detail=f"{half} half gathers position {src} at step "
                       f"{goff + g}, not strictly {word} its destination "
                       f"{dst}"))
        if len(out) >= MAX_VIOLATIONS:
            return out
    return out


def check_ic0_structure(st, where: str = "ic0_steps") -> list[Violation]:
    """Verify the IC(0) factorization step schedule is dependency-ordered.

    Step ``s`` of ``ic0.IC0Structure`` computes the entry positions
    ``steps[s][0]``; its inner-product operand positions (``pab``) and the
    diagonal of every dividing row (``dep_off``) must all be *computed at a
    strictly earlier step* — otherwise the vectorized batch reads an
    unfactored value.  Also proves every pattern position is computed
    exactly once.
    """
    out: list[Violation] = []
    nnz = int(st.indices.size)
    step_of_pos = np.full(nnz, -1, dtype=np.int64)
    for s, (pos, n_off, dep_off, rows_di, pab, npair, tgt) in \
            enumerate(st.steps):
        pos = np.asarray(pos)
        seen = step_of_pos[pos] >= 0
        for p in pos[seen][:MAX_VIOLATIONS - len(out)]:
            out.append(Violation(
                kind="duplicate-position", where=where, round=s,
                edge=(int(p), int(p)),
                detail=f"entry position {int(p)} computed at steps "
                       f"{int(step_of_pos[p])} and {s}"))
        step_of_pos[pos] = s
    if len(out) >= MAX_VIOLATIONS:
        return out
    missing = np.flatnonzero(step_of_pos < 0)
    for p in missing[:MAX_VIOLATIONS - len(out)]:
        out.append(Violation(
            kind="uncomputed-position", where=where, edge=(int(p), int(p)),
            detail=f"pattern position {int(p)} is never computed"))
    if len(out) >= MAX_VIOLATIONS:
        return out

    diag_pos = st.indptr[1:] - 1    # diagonal entry position of every row
    row_of_pos = np.repeat(np.arange(st.n), np.diff(st.indptr))
    for s, (pos, n_off, dep_off, rows_di, pab, npair, tgt) in \
            enumerate(st.steps):
        pos = np.asarray(pos)
        # off-diagonal entries divide by the diagonal of row dep_off
        if n_off:
            dstep = step_of_pos[diag_pos[np.asarray(dep_off)]]
            bad = np.flatnonzero(dstep >= s)
            for b in bad[:MAX_VIOLATIONS - len(out)]:
                j = int(np.asarray(dep_off)[b])
                i = int(row_of_pos[pos[b]])
                out.append(Violation(
                    kind="premature-read", where=where, round=s,
                    rows=(i, j), edge=(int(diag_pos[j]), int(pos[b])),
                    detail=f"step {s} divides by diag of row {j} computed "
                           f"at step {int(dstep[b])}"))
            if len(out) >= MAX_VIOLATIONS:
                return out
        if npair:
            pab = np.asarray(pab)
            ostep = step_of_pos[pab]
            bad = np.flatnonzero(ostep >= s)
            for b in bad[:MAX_VIOLATIONS - len(out)]:
                op = int(pab[b])
                tpos = int(pos[np.asarray(tgt)[b % npair]])
                out.append(Violation(
                    kind="premature-read", where=where, round=s,
                    rows=(int(row_of_pos[tpos]), int(row_of_pos[op])),
                    edge=(op, tpos),
                    detail=f"step {s} multiplies operand position {op} "
                           f"computed at step {int(ostep[b])}"))
            if len(out) >= MAX_VIOLATIONS:
                return out
    return out


# ---------------------------------------------------------------------------
# Plan-level composition (the validate= knob).
# ---------------------------------------------------------------------------

VALIDATE_MODES = ("off", "cheap", "full", "deep")


def validate_plan(plan, mode: str = "full") -> list[Violation]:
    """Run the race detector against a built ``SolverPlan``.

    ``mode="cheap"`` — the O(nnz) round-monotonicity scan of the ordering's
    rounds against the ordered matrix pattern, plus the
    backward-is-reversed-forward check.  ``mode="full"`` — additionally
    prove the *materialized* schedules: the packed trisolve tables
    (fused round-major or per-sweep index tables, whichever the plan runs)
    and the IC(0) factorization step schedule.  ``mode="deep"`` — on top
    of "full", run the static kernel checks and trace every lowering path
    through the dtype-flow linter (``analysis.dtype_flow``) — the only
    mode that imports jax, so it stays a deferred import and the cheaper
    modes keep working in jax-free contexts.  Returns the violation list
    (empty = proven); raise via :func:`assert_plan_valid`.
    """
    if mode not in VALIDATE_MODES:
        raise ValueError(f"unknown validate mode {mode!r}; expected one of "
                         f"{VALIDATE_MODES}")
    if mode == "off":
        return []
    sysd = plan._sysd
    out = check_rounds(sysd.a_bar, sysd.fwd_rounds, drop_mask=sysd.drop)
    out += check_reversed_rounds(sysd.fwd_rounds, sysd.bwd_rounds)
    if mode == "cheap" or out:
        return out
    if plan.layout == "round_major":
        out += check_fused_tables(plan._precond.tables)
    else:
        out += check_step_tables(plan._precond.fwd, where="step_tables/fwd")
        out += check_step_tables(plan._precond.bwd, where="step_tables/bwd")
    out += check_ic0_structure(plan._structure)
    if mode == "deep" and not out:
        from .dtype_flow import check_plan_dtype_flow
        from .kernel_checks import check_plan_kernels
        out += check_plan_kernels(plan)
        out += check_plan_dtype_flow(plan)
    return out


def assert_plan_valid(plan, mode: str = "full", context: str = "") -> None:
    """``validate_plan`` that raises :class:`ScheduleError` on violations."""
    violations = validate_plan(plan, mode)
    if violations:
        raise ScheduleError(violations, context=context)
