"""Collective-structure proofs over lowered (optimized) HLO.

The paper's distributed claim (§4.4.3) is that one color-round costs one
synchronization — in the shard_map lowering, ONE tiled ``all-gather`` per
fused sweep step, and nothing else.  ``contracts.DISTRIBUTED_APPLY``
proves that at the jaxpr level (one ``all_gather`` eqn in the traced loop
body); this module proves it survives XLA: the *optimized* HLO of a mesh
plan must contain

  * exactly one all-gather inside exactly one while body for the fused
    apply, with the while's ``known_trip_count`` equal to 2S (S = color
    rounds; the fused sweep runs forward + backward halves), and the
    gather tiled (result bytes == participants x operand bytes);
  * exactly one collective (an all-gather) in the sharded SpMV;
  * zero ``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
    ``collective-permute`` anywhere in the whole PCG solve — the state
    vectors are replicated, so the dot-product pairings need no
    collective at all, and any reduction XLA sneaks in is a regression
    witness;
  * zero collectives of any kind for a single-device plan.

Built on the shared HLO parse in ``analysis.hlo``; witnesses reuse
:class:`~repro.analysis.schedule.Violation`.  CI runs this under
``--xla_force_host_platform_device_count=4``.
"""
from __future__ import annotations

import dataclasses

from . import hlo
from .schedule import ScheduleError, Violation

#: collectives the solver's lowering may never emit (the dot products run
#: replicated; resharding mid-solve would be a layout leak)
FORBIDDEN_COLLECTIVES = ("all-reduce", "reduce-scatter", "all-to-all",
                         "collective-permute")


@dataclasses.dataclass(frozen=True)
class CollectiveBody:
    """One while body carrying collectives in an optimized module."""
    comp: str               # computation name
    trip: int               # executed iterations of the enclosing while
    gathers: tuple          # all-gather op names (direct ops of the body)
    others: tuple           # non-all-gather collective op names


def optimized_hlo(fn, *args) -> str:
    """Optimized (post-SPMD) HLO text of ``jit(fn)`` on ``args``."""
    import jax
    return jax.jit(fn).lower(*args).compile().as_text()


def collective_bodies(text: str) -> tuple[list, dict]:
    """(bodies, module_counts): every while body that directly contains a
    collective, plus the module-wide static collective census by kind."""
    comps = hlo.parse_module(text)
    trips: dict = {}
    for comp in comps.values():
        for op in comp.ops:
            if hlo.base_kind(op.kind) == "while":
                t = hlo.trip_count(op, comps)
                for cname in hlo.called_comps(op.rest):
                    trips[cname] = max(trips.get(cname, 0), t)
    bodies = []
    counts: dict = {}
    for comp in comps.values():
        gathers, others = [], []
        for op in comp.ops:
            base = hlo.base_kind(op.kind)
            if base not in hlo.COLLECTIVES or op.kind.endswith("-done"):
                continue
            counts[base] = counts.get(base, 0) + 1
            (gathers if base == "all-gather" else others).append(op.name)
        if (gathers or others) and comp.name in trips:
            bodies.append(CollectiveBody(
                comp=comp.name, trip=trips[comp.name],
                gathers=tuple(gathers), others=tuple(others)))
    return bodies, counts


def _check_tiled(text: str, where: str) -> list[Violation]:
    """Every all-gather must be tiled: result size == participants x
    operand size (an untiled gather would replicate a full-length vector
    per round — the exact failure mode shard_fused_tables exists to
    avoid)."""
    out = []
    for comp in hlo.parse_module(text).values():
        for op in comp.ops:
            if hlo.base_kind(op.kind) != "all-gather" \
                    or op.kind.endswith("-done"):
                continue
            group = hlo.replica_group_size(op)
            ob = hlo.operand_bytes(op, comp)
            if not ob:
                continue            # operand outside the comp: unprovable
            rb = op.bytes if not op.kind.endswith("-start") else op.bytes - ob
            if group is not None and rb != group * ob:
                out.append(Violation(
                    kind="untiled-all-gather", where=where,
                    detail=f"{op.name} in {comp.name}: result {rb} B != "
                           f"{group} participants x operand {ob} B"))
    return out


def check_collective_structure(text: str, *, n_rounds: int | None = None,
                               expect_gathers: int | None = None,
                               where: str = "collectives"
                               ) -> list[Violation]:
    """Structural proof over one optimized module.

    Always enforced: no forbidden collective kinds, at most one all-gather
    per while body, every gather tiled.  ``n_rounds`` additionally pins
    the sweep shape: exactly one collective-bearing while body whose trip
    count is ``2 * n_rounds``.  ``expect_gathers`` pins the module-wide
    static all-gather op count (e.g. 1 for the sharded SpMV).
    """
    bodies, counts = collective_bodies(text)
    out: list[Violation] = []
    for kind in FORBIDDEN_COLLECTIVES:
        if counts.get(kind):
            out.append(Violation(
                kind="forbidden-collective", where=where,
                detail=f"{counts[kind]} {kind} op(s) in the optimized "
                       f"module; only tiled all-gathers are allowed"))
    for b in bodies:
        if b.others:
            out.append(Violation(
                kind="forbidden-collective", where=where,
                detail=f"while body {b.comp} contains "
                       f"{', '.join(b.others)}"))
        if len(b.gathers) > 1:
            out.append(Violation(
                kind="extra-collective", where=where, round=b.trip,
                detail=f"while body {b.comp} runs {len(b.gathers)} "
                       f"all-gathers per step ({', '.join(b.gathers)}); "
                       f"the sweep contract is one"))
    if n_rounds is not None:
        want_trip = 2 * n_rounds
        sweep = [b for b in bodies if b.gathers]
        if not sweep:
            out.append(Violation(
                kind="missing-collective", where=where,
                detail="no while body contains an all-gather — the fused "
                       "sweep lost its per-round tile exchange"))
        elif len(sweep) > 1:
            out.append(Violation(
                kind="extra-collective", where=where,
                detail=f"{len(sweep)} collective-bearing while bodies "
                       f"({', '.join(b.comp for b in sweep)}); the fused "
                       f"apply has exactly one sweep loop"))
        elif sweep[0].trip != want_trip:
            out.append(Violation(
                kind="trip-count-mismatch", where=where,
                round=sweep[0].trip,
                detail=f"sweep body {sweep[0].comp} runs "
                       f"{sweep[0].trip} steps, expected 2S = "
                       f"{want_trip} (S = {n_rounds} rounds)"))
    if expect_gathers is not None:
        got = counts.get("all-gather", 0)
        if got != expect_gathers:
            out.append(Violation(
                kind="extra-collective" if got > expect_gathers
                else "missing-collective", where=where,
                detail=f"{got} all-gather op(s) in the module, expected "
                       f"exactly {expect_gathers}"))
    out += _check_tiled(text, where)
    return out


def _zero_collectives(text: str, where: str) -> list[Violation]:
    stats = hlo.parse_collectives(text)
    if stats.total_count == 0:
        return []
    kinds = {k: c for k, c in stats.count_by_kind.items() if c}
    return [Violation(
        kind="extra-collective", where=where,
        detail=f"single-device lowering emits collectives: {kinds}")]


def check_plan_collectives(plan) -> list[Violation]:
    """Compile the plan's apply, SpMV and full PCG solve and prove their
    collective structure.  Single-device plans must lower collective-free;
    mesh plans must match the one-tiled-all-gather-per-round contract."""
    import jax.numpy as jnp

    from repro.core.iccg import make_sharded_spmv
    from repro.core.plan import _make_spmv

    q = jnp.zeros((plan.slab_m,), dtype=plan.dtype)
    pre = plan._precond
    out: list[Violation] = []

    if plan.mesh is None:
        spmv = _make_spmv(plan.spmv_format, plan._spmv_n, plan._spmv_vals,
                          plan._spmv_cols, False,
                          spmv_backend=plan.spmv_backend,
                          interpret=plan.interpret)
        out += _zero_collectives(optimized_hlo(lambda x: pre(x), q),
                                 "collectives/apply")
        out += _zero_collectives(optimized_hlo(spmv, q),
                                 "collectives/spmv")
        return out

    spmv = make_sharded_spmv(plan.spmv_format, plan._spmv_n, plan.mesh,
                             plan.mesh_axis, plan._spmv_vals,
                             plan._spmv_cols, False,
                             spmv_backend=plan.spmv_backend,
                             interpret=plan.interpret)
    out += check_collective_structure(
        optimized_hlo(lambda x: pre(x), q), n_rounds=plan.n_rounds,
        where="collectives/apply")
    out += check_collective_structure(
        optimized_hlo(spmv, q), expect_gathers=1, where="collectives/spmv")
    # whole solve: the two sweep loops (init + iteration) and the SpMV may
    # each gather; nothing may reduce — replicated state needs no
    # all-reduce for the dot pairings
    fn = plan._pcg_fn(False, 1e-8, 8, False)
    solve_text = fn.lower(plan._precond.tables, plan._spmv_vals,
                          plan._spmv_cols, q).compile().as_text()
    out += check_collective_structure(solve_text, where="collectives/solve")
    return out


def assert_plan_collectives(plan, context: str = "") -> None:
    """``check_plan_collectives`` that raises :class:`ScheduleError`."""
    violations = check_plan_collectives(plan)
    if violations:
        raise ScheduleError(violations, context=context)
