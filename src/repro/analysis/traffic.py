"""Static traffic model + bench regression gate.

Li (arXiv:1710.04985) argues the end-to-end ICCG win is decided by
bytes-per-iteration; this module makes that quantity a *checked* number
instead of a believed one.

**Static model.**  Every byte the hot loop moves is determined by the
plan's packed table shapes: the fused 2S-step sweep streams its per-step
table slices (cols/vals/dinv) plus four R-vectors of state per step, the
SpMV gathers one x value per packed slot, and the PCG vector work streams
a fixed number of m-vectors per iteration.  :func:`traffic_report`
computes those terms, the per-iteration FLOPs, and the resulting
arithmetic intensity.

**Cross-check.**  The slice-family ops of an optimized module
(``dynamic-slice`` / ``gather`` / ``slice`` results,
``dynamic-update-slice`` updates) keep their exact shapes through XLA
fusion, so summing their bytes with while-loop trip multiplication
reproduces a physical table-streaming model exactly — unlike whole-module
heuristics, which are dominated by fusion-boundary modeling choices.
:func:`check_plan_traffic` compiles the apply and SpMV, extracts that
measurement, and fails with a ``Violation`` witness naming the term if
the static model drifts beyond tolerance (default 10%) — e.g. if table
padding silently inflates, or a lowering change starts re-streaming a
table.

**Bench gate.**  :func:`bench_gate` compares two benchmark snapshots
(committed ``benchmarks/BENCH_*.json`` vs a fresh run) metric-by-metric:
time-like metrics may not regress beyond tolerance, throughput-like
metrics may not drop, iteration counts may not grow.  Wired to
``python -m repro.analysis bench-gate`` and the CI analysis job.
"""
from __future__ import annotations

import dataclasses

from . import hlo
from .schedule import ScheduleError, Violation


@dataclasses.dataclass(frozen=True)
class TrafficTerm:
    """One byte stream of the hot loop.  ``measured_bytes`` is filled by
    the HLO slice-extraction cross-check where the lowering exposes it
    (None = static-only term)."""
    name: str
    static_bytes: float
    measured_bytes: float | None = None
    detail: str = ""

    @property
    def relative_error(self) -> float | None:
        if self.measured_bytes is None or self.measured_bytes == 0:
            return None
        return abs(self.static_bytes - self.measured_bytes) \
            / self.measured_bytes


@dataclasses.dataclass(frozen=True)
class TrafficReport:
    """Per-iteration data movement of one plan, term by term."""
    label: str
    terms: tuple
    iteration_bytes: float      # static bytes per PCG iteration
    iteration_flops: float      # static FLOPs per PCG iteration

    @property
    def arithmetic_intensity(self) -> float:
        return self.iteration_flops / self.iteration_bytes \
            if self.iteration_bytes else 0.0


#: m-vector streams per PCG iteration outside apply/SpMV: two dot
#: pairings (4), three axpy-likes (9), one residual norm (1)
VECTOR_STREAMS_PER_ITERATION = 14


def measured_slice_bytes(text: str) -> float:
    """Sum of slice-family result bytes in an optimized module, with
    while-loop trip multiplication — the physically-pinned subset of HBM
    traffic (table slices, gathers, state updates)."""
    comps = hlo.parse_module(text)
    entry = hlo.entry_name(text, comps)
    memo: dict = {}

    def cost(name: str) -> float:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None:
            return 0.0
        memo[name] = 0.0        # break cycles defensively
        total = 0.0
        for op in comp.ops:
            if op.kind.endswith("-done"):
                continue
            base = hlo.base_kind(op.kind)
            if base == "while":
                trip = hlo.trip_count(op, comps)
                total += trip * sum(cost(c)
                                    for c in hlo.called_comps(op.rest))
            elif base in ("fusion", "call", "conditional", "async-start"):
                total += sum(cost(c) for c in hlo.called_comps(op.rest))
            elif base in hlo.SLICE_OPS:
                total += op.bytes
            elif base == "dynamic-update-slice":
                upd = hlo._arg_op(op, comp, 1)
                total += upd.bytes if upd is not None else op.bytes
        memo[name] = total
        return total

    return cost(entry)


def _apply_static_bytes(plan) -> tuple[float, str]:
    """Sliced bytes of one fused-sweep apply, from the table shapes.

    Per fused step the sweep slices: cols (R*K int32) + vals (R*K item) +
    dinv (R item) + the q read, y-destination read, y gather (R*K item)
    and the y update write — exactly the slice-family ops the optimized
    HLO exposes, so static == measured when nothing leaks.
    """
    t = plan._precond.tables
    s2, r, k = t.cols.shape
    item = plan._np_dtype.itemsize
    cidx = t.cols.dtype.itemsize
    per_step = r * k * (cidx + 2 * item) + 4 * r * item
    return float(s2 * per_step), \
        f"2S={s2} steps x (R={r}, K={k}, {item}B items)"


def _spmv_gather_bytes(plan) -> tuple[float, str]:
    """The x[cols] gather of the packed SpMV: one item per packed slot.
    (The vals/cols streams are consumed straight from parameters — no
    slice op — so they are static-only terms.)"""
    import numpy as np
    slots = int(np.prod(plan._spmv_vals.shape))
    item = plan._np_dtype.itemsize
    return float(slots * item), \
        f"{slots} packed slots x {item}B ({plan.spmv_format})"


def traffic_report(plan, measure: bool = True) -> TrafficReport:
    """Static per-iteration traffic of a plan, with the HLO cross-check
    filled in where the lowering exposes it (round-major XLA paths on a
    single device; pallas kernels and mesh lowerings are static-only)."""
    import numpy as np

    if plan.layout != "round_major":
        raise ValueError("traffic model requires layout='round_major' "
                         "(the native PCG layout); index-layout plans "
                         "have no fused-sweep stream to model")
    item = plan._np_dtype.itemsize
    m = plan.slab_m
    t = plan._precond.tables
    s2, r, k = t.cols.shape
    slots = int(np.prod(plan._spmv_vals.shape))

    apply_static, apply_detail = _apply_static_bytes(plan)
    gather_static, gather_detail = _spmv_gather_bytes(plan)
    apply_measured = gather_measured = None
    measurable = (measure and plan.mesh is None
                  and plan.backend == "xla" and plan.spmv_backend == "xla")
    if measurable:
        import jax
        import jax.numpy as jnp

        from repro.core.plan import _make_spmv
        pre = plan._precond
        q = jnp.zeros((m,), dtype=plan.dtype)
        apply_measured = measured_slice_bytes(
            jax.jit(lambda x: pre(x)).lower(q).compile().as_text())
        spmv = _make_spmv(plan.spmv_format, plan._spmv_n, plan._spmv_vals,
                          plan._spmv_cols, False,
                          spmv_backend=plan.spmv_backend,
                          interpret=plan.interpret)
        gather_measured = measured_slice_bytes(
            jax.jit(spmv).lower(q).compile().as_text())

    # x random reads are the gather term; the streamed remainder is the
    # vals/cols parameters and the y result write
    spmv_stream = float(slots * (item + plan._spmv_cols.dtype.itemsize)
                        + m * item)
    vector_stream = float(VECTOR_STREAMS_PER_ITERATION * m * item)
    terms = (
        TrafficTerm("apply", apply_static, apply_measured, apply_detail),
        TrafficTerm("spmv/gather", gather_static, gather_measured,
                    gather_detail),
        TrafficTerm("spmv/stream", spmv_stream, None,
                    "vals + cols parameter streams + y write"),
        TrafficTerm("vector", vector_stream, None,
                    f"{VECTOR_STREAMS_PER_ITERATION} m-vector streams"),
    )
    # FLOPs: 2 MACs per packed slot (SpMV), 2 per table slot + diag scale
    # (sweep), ~10 per row of vector work
    flops = float(2 * slots + 2 * s2 * r * k + s2 * r + 10 * m)
    total = float(sum(x.static_bytes for x in terms))
    return TrafficReport(
        label=f"{plan.layout}/{plan.backend}/{plan.spmv_format}",
        terms=terms, iteration_bytes=total, iteration_flops=flops)


def compare_traffic(terms, tolerance: float = 0.10,
                    where: str = "traffic") -> list[Violation]:
    """Static-vs-measured witnesses for every cross-checked term."""
    out = []
    for term in terms:
        rel = term.relative_error
        if rel is not None and rel > tolerance:
            out.append(Violation(
                kind="traffic-model-mismatch", where=where,
                detail=f"term {term.name}: static "
                       f"{term.static_bytes:.0f} B vs HLO-measured "
                       f"{term.measured_bytes:.0f} B "
                       f"({100 * rel:.1f}% > {100 * tolerance:.0f}% "
                       f"tolerance; {term.detail})"))
    return out


def check_plan_traffic(plan, tolerance: float = 0.10) -> list[Violation]:
    """Compile the plan's apply + SpMV and prove the static traffic model
    matches the HLO-measured slice bytes within ``tolerance``."""
    report = traffic_report(plan, measure=True)
    return compare_traffic(report.terms, tolerance)


def assert_plan_traffic(plan, tolerance: float = 0.10,
                        context: str = "") -> None:
    violations = check_plan_traffic(plan, tolerance)
    if violations:
        raise ScheduleError(violations, context=context)


# ---------------------------------------------------------------------------
# Bench regression gate over committed BENCH_*.json snapshots.
# ---------------------------------------------------------------------------

#: record fields that identify a list entry (used as the metric path
#: segment so records match structurally, not positionally)
_ID_KEYS = ("problem", "layout", "backend", "spmv_backend", "method",
            "scheduler", "stage", "component", "name", "kind", "B",
            "slab_width", "width", "devices", "n")
_LOWER_SUFFIX = ("_us", "_ms", "_s", "_seconds")
_LOWER_SUBSTR = ("latency", "time", "p50", "p90", "p99")
_HIGHER_SUBSTR = ("per_s", "per_sec", "throughput", "speedup", "hit_rate")
#: iteration-count slack: counts are near-deterministic, but smoke-scale
#: reruns may wiggle by an iteration
_ITER_SLACK = 1.05


def _flatten_metrics(node, prefix: str = "", out: dict | None = None
                     ) -> dict:
    if out is None:
        out = {}
    if isinstance(node, dict):
        for k in sorted(node):
            key = f"{prefix}.{k}" if prefix else str(k)
            _flatten_metrics(node[k], key, out)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            seg = f"[{i}]"
            if isinstance(v, dict):
                ids = [f"{k}={v[k]}" for k in _ID_KEYS
                       if isinstance(v.get(k), (str, int, float))]
                if ids:
                    seg = "[" + ",".join(ids) + "]"
            _flatten_metrics(v, prefix + seg, out)
    elif isinstance(node, bool):
        pass
    elif isinstance(node, (int, float)):
        out[prefix] = float(node)
    return out


def _direction(path: str) -> str | None:
    leaf = path.rsplit(".", 1)[-1].rsplit("]", 1)[-1].lstrip(".")
    if leaf in ("iterations", "iters") or leaf.endswith("_iterations"):
        return "iters"
    # higher-is-better first: "rhs_per_s" must not match the _s suffix
    if any(s in leaf for s in _HIGHER_SUBSTR):
        return "higher"
    if leaf in ("us", "s", "ms") \
            or any(leaf.endswith(s) for s in _LOWER_SUFFIX) \
            or any(s in leaf for s in _LOWER_SUBSTR):
        return "lower"
    return None


def bench_gate(baseline: dict, candidate: dict, tolerance: float = 0.5,
               where: str = "bench-gate") -> list[Violation]:
    """Gate ``candidate`` bench results against a ``baseline`` snapshot.

    Every gateable baseline metric must exist in the candidate (schema
    drift is a failure, not a silent skip) and stay within tolerance in
    its metric's good direction: time-like ``<= base * (1 + tol)``,
    throughput-like ``>= base / (1 + tol)``, iteration counts may not
    grow beyond a fixed 5% determinism slack.  Returns witnesses naming
    the exact metric path; empty = gate passed.
    """
    base = _flatten_metrics(baseline)
    cand = _flatten_metrics(candidate)
    out: list[Violation] = []
    gated = 0
    for path, bv in base.items():
        d = _direction(path)
        if d is None:
            continue
        if path not in cand:
            out.append(Violation(
                kind="missing-metric", where=where,
                detail=f"{path}: present in baseline, absent in "
                       f"candidate (schema drift?)"))
            continue
        cv = cand[path]
        gated += 1
        if d == "iters":
            if cv > bv * _ITER_SLACK + 0.5:
                out.append(Violation(
                    kind="iteration-regression", where=where,
                    detail=f"{path}: {cv:g} iterations vs baseline "
                           f"{bv:g} — convergence regressed"))
        elif bv <= 0:
            continue            # zero baselines carry no gateable ratio
        elif d == "lower" and cv > bv * (1.0 + tolerance):
            out.append(Violation(
                kind="perf-regression", where=where,
                detail=f"{path}: {cv:.4g} vs baseline {bv:.4g} "
                       f"(+{100 * (cv / bv - 1):.0f}% > "
                       f"{100 * tolerance:.0f}% tolerance)"))
        elif d == "higher" and cv < bv / (1.0 + tolerance):
            out.append(Violation(
                kind="perf-regression", where=where,
                detail=f"{path}: {cv:.4g} vs baseline {bv:.4g} "
                       f"(-{100 * (1 - cv / bv):.0f}% > "
                       f"{100 * tolerance:.0f}% tolerance)"))
    if gated == 0 and not out:
        out.append(Violation(
            kind="no-metrics", where=where,
            detail="baseline snapshot exposes no gateable metrics — the "
                   "gate would pass vacuously"))
    return out
