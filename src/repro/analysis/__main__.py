"""Audit matrices / orderings / plans from the command line.

    PYTHONPATH=src python -m repro.analysis
        [--problems thermal2,parabolic_fem,...]   (default: all paper five)
        [--methods hbmc,bmc,mc]                   (default: hbmc,bmc,mc)
        [--scale tiny|small|bench]                (default: tiny)
        [--validate cheap|full]                   (default: full)
        [--contracts]        also lint the apply/SpMV jaxprs
        [--backend xla|pallas] [--spmv-backend xla|pallas]

For every (problem, method) pair this builds a plan, runs the schedule
race detector at the requested depth, the static kernel checks the
backend selection implies, and (with ``--contracts``) the jaxpr budget of
the round-major apply.  Prints one line per audit; on failure prints every
witness and exits 1.  ``laplace2d`` / ``laplace3d`` are accepted as extra
problem names alongside the paper generators.
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp

from repro.analysis import (ROUND_MAJOR_APPLY, check_plan_kernels, lint,
                            validate_plan)
from repro.core import build_plan
from repro.core.matrices import (PAPER_PROBLEMS, PAPER_SHIFTS, laplace_2d,
                                 laplace_3d, paper_problem)


def _matrix(name: str, scale: str):
    if name == "laplace2d":
        g = {"tiny": 16, "small": 64, "bench": 352}[scale]
        return laplace_2d(g, g), "2-D 5-point Laplacian"
    if name == "laplace3d":
        g = {"tiny": 8, "small": 16, "bench": 46}[scale]
        return laplace_3d(g, g, g, stencil=27), "3-D 27-point Laplacian"
    return paper_problem(name, scale)


def audit(name: str, method: str, scale: str, validate: str,
          contracts: bool, backend: str, spmv_backend: str) -> list:
    """Build + audit one (problem, method); returns printable findings."""
    a, _ = _matrix(name, scale)
    shift = PAPER_SHIFTS.get(name, 0.0)
    spmv_format = "sell" if spmv_backend == "pallas" else "ell"
    plan = build_plan(a, method=method, shift=shift, backend=backend,
                      spmv_backend=spmv_backend, spmv_format=spmv_format,
                      validate="off")
    findings = [str(v) for v in validate_plan(plan, validate)]
    findings += [str(v) for v in check_plan_kernels(plan)]
    if contracts:
        if plan.layout == "round_major":
            pre = plan._precond
            q = jnp.zeros((plan.slab_m,), dtype=plan.dtype)
            findings += lint(pre, q, budget=ROUND_MAJOR_APPLY)
    return findings


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static schedule race detector + kernel contract audit")
    ap.add_argument("--problems",
                    default=",".join(PAPER_PROBLEMS),
                    help="comma-separated problem names (paper generators, "
                         "laplace2d, laplace3d)")
    ap.add_argument("--methods", default="hbmc,bmc,mc",
                    help="comma-separated orderings (hbmc,bmc,mc,natural)")
    ap.add_argument("--scale", default="tiny",
                    choices=("tiny", "small", "bench"))
    ap.add_argument("--validate", default="full", choices=("cheap", "full"))
    ap.add_argument("--contracts", action="store_true",
                    help="also lint the apply jaxpr primitive budget")
    ap.add_argument("--backend", default="xla", choices=("xla", "pallas"))
    ap.add_argument("--spmv-backend", default="xla",
                    choices=("xla", "pallas"))
    args = ap.parse_args(argv)
    # plans are built in f64 by default; flip the flag before any tracing
    jax.config.update("jax_enable_x64", True)

    problems = [p for p in args.problems.split(",") if p]
    methods = [m for m in args.methods.split(",") if m]
    failures = 0
    for name in problems:
        for method in methods:
            try:
                findings = audit(name, method, args.scale, args.validate,
                                 args.contracts, args.backend,
                                 args.spmv_backend)
            except Exception as e:  # a build failure is an audit failure
                findings = [f"build failed: {type(e).__name__}: {e}"]
            status = "ok" if not findings else "FAIL"
            print(f"{name:16s} {method:8s} {args.validate:5s} {status}")
            for f in findings:
                print(f"    {f}")
            failures += bool(findings)
    if failures:
        print(f"\n{failures} audit(s) failed", file=sys.stderr)
        return 1
    print(f"\nall {len(problems) * len(methods)} audits clean "
          f"(validate={args.validate}, backend={args.backend})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
