"""Audit matrices / orderings / plans from the command line.

    PYTHONPATH=src python -m repro.analysis
        [--problems thermal2,parabolic_fem,...]   (default: all paper five)
        [--methods hbmc,bmc,mc]                   (default: hbmc,bmc,mc)
        [--schedulers coloring,levelset]          (default: coloring)
        [--scale tiny|small|bench]                (default: tiny)
        [--validate cheap|full|deep]              (default: full)
        [--contracts]        also lint the apply/SpMV jaxprs
        [--dtype-flow]       lint dtype propagation on every lowering path
        [--collectives]      prove the collective structure of the plan's
                             optimized HLO (mesh over all devices when >1)
        [--traffic]          cross-check the static traffic model against
                             HLO-measured bytes  [--traffic-tol 0.10]
        [--witness-json PATH]  dump machine-readable witnesses on failure
        [--backend xla|pallas] [--spmv-backend xla|pallas]

    PYTHONPATH=src python -m repro.analysis bench-gate
        [--baseline-dir benchmarks] [--candidate RUN.json ...]
        [--tolerance 0.5] [--smoke] [--witness-json PATH]

For every (problem, method) pair the audit builds a plan, runs the
schedule race detector at the requested depth, the static kernel checks
the backend selection implies, and any of the opt-in linters above.
Prints one line per audit; on failure prints every witness and exits 1.
``laplace2d`` / ``laplace3d`` are accepted as extra problem names
alongside the paper generators.

``bench-gate`` compares fresh bench runs (``--candidate``) against the
committed ``BENCH_*.json`` snapshots, matching files by their ``schema``
field; ``--smoke`` gates every committed snapshot against itself to
prove the gate covers each schema.
"""
from __future__ import annotations

import argparse
import dataclasses
import glob
import json
import os
import sys

from repro.analysis import (ROUND_MAJOR_APPLY, Violation, bench_gate,
                            check_plan_collectives, check_plan_dtype_flow,
                            check_plan_kernels, check_plan_traffic, lint,
                            validate_plan)


def _matrix(name: str, scale: str):
    from repro.core.matrices import laplace_2d, laplace_3d, paper_problem
    if name == "laplace2d":
        g = {"tiny": 16, "small": 64, "bench": 352}[scale]
        return laplace_2d(g, g), "2-D 5-point Laplacian"
    if name == "laplace3d":
        g = {"tiny": 8, "small": 16, "bench": 46}[scale]
        return laplace_3d(g, g, g, stencil=27), "3-D 27-point Laplacian"
    return paper_problem(name, scale)


def audit(name: str, method: str, scale: str, validate: str,
          contracts: bool, backend: str, spmv_backend: str,
          dtype_flow: bool = False, collectives: bool = False,
          traffic: bool = False, traffic_tol: float = 0.10,
          scheduler: str = "coloring") -> list:
    """Build + audit one (problem, method); returns findings.

    Findings are :class:`Violation` instances where a linter produced a
    witness, plain strings otherwise (jaxpr budget lint, build errors).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import build_plan
    from repro.core.matrices import PAPER_SHIFTS

    a, _ = _matrix(name, scale)
    shift = PAPER_SHIFTS.get(name, 0.0)
    spmv_format = "sell" if spmv_backend == "pallas" else "ell"
    plan = build_plan(a, method=method, shift=shift, backend=backend,
                      spmv_backend=spmv_backend, spmv_format=spmv_format,
                      scheduler=scheduler, validate="off")
    findings: list = list(validate_plan(plan, validate))
    findings += check_plan_kernels(plan)
    if contracts:
        if plan.layout == "round_major":
            pre = plan._precond
            q = jnp.zeros((plan.slab_m,), dtype=plan.dtype)
            findings += lint(pre, q, budget=ROUND_MAJOR_APPLY)
    if dtype_flow:
        findings += check_plan_dtype_flow(plan)
    if traffic:
        try:
            findings += check_plan_traffic(plan, tolerance=traffic_tol)
        except ValueError as e:   # non-round_major layouts have no model
            findings.append(f"traffic model unavailable: {e}")
    if collectives:
        devs = jax.devices()
        if len(devs) > 1:
            from jax.sharding import Mesh
            mesh = Mesh(np.array(devs), ("dev",))
            mplan = build_plan(a, method=method, shift=shift,
                               backend="xla", spmv_backend="xla",
                               scheduler=scheduler,
                               mesh=mesh, mesh_axis="dev", validate="off")
            findings += check_plan_collectives(mplan)
        else:
            # single device: still prove the local paths stay collective-free
            findings += check_plan_collectives(plan)
    return findings


def _witness_dicts(findings: list) -> list[dict]:
    return [dataclasses.asdict(f) if isinstance(f, Violation)
            else {"detail": str(f)} for f in findings]


def _write_witnesses(path: str | None, witnesses: list[dict]) -> None:
    if path:
        with open(path, "w") as fh:
            json.dump(witnesses, fh, indent=2)


def audit_main(argv: list[str] | None = None) -> int:
    from repro.core.matrices import PAPER_PROBLEMS
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static schedule race detector + kernel contract audit")
    ap.add_argument("--problems",
                    default=",".join(PAPER_PROBLEMS),
                    help="comma-separated problem names (paper generators, "
                         "laplace2d, laplace3d)")
    ap.add_argument("--methods", default="hbmc,bmc,mc",
                    help="comma-separated orderings (hbmc,bmc,mc,natural)")
    ap.add_argument("--schedulers", default="coloring",
                    help="comma-separated round-schedule backends to audit "
                         "(coloring,levelset)")
    ap.add_argument("--scale", default="tiny",
                    choices=("tiny", "small", "bench"))
    ap.add_argument("--validate", default="full",
                    choices=("cheap", "full", "deep"))
    ap.add_argument("--contracts", action="store_true",
                    help="also lint the apply jaxpr primitive budget")
    ap.add_argument("--dtype-flow", action="store_true",
                    help="lint dtype propagation on every lowering path")
    ap.add_argument("--collectives", action="store_true",
                    help="prove the optimized-HLO collective structure "
                         "(builds a mesh plan over all devices when >1)")
    ap.add_argument("--traffic", action="store_true",
                    help="cross-check the static traffic model against "
                         "HLO-measured bytes")
    ap.add_argument("--traffic-tol", type=float, default=0.10,
                    help="relative tolerance for --traffic (default 0.10)")
    ap.add_argument("--witness-json", default=None, metavar="PATH",
                    help="dump machine-readable witnesses to PATH")
    ap.add_argument("--backend", default="xla", choices=("xla", "pallas"))
    ap.add_argument("--spmv-backend", default="xla",
                    choices=("xla", "pallas"))
    args = ap.parse_args(argv)
    # plans are built in f64 by default; flip the flag before any tracing
    import jax
    jax.config.update("jax_enable_x64", True)

    problems = [p for p in args.problems.split(",") if p]
    methods = [m for m in args.methods.split(",") if m]
    schedulers = [s for s in args.schedulers.split(",") if s]
    failures = 0
    witnesses: list[dict] = []
    for name in problems:
        for method in methods:
            for scheduler in schedulers:
                try:
                    findings = audit(name, method, args.scale,
                                     args.validate,
                                     args.contracts, args.backend,
                                     args.spmv_backend,
                                     dtype_flow=args.dtype_flow,
                                     collectives=args.collectives,
                                     traffic=args.traffic,
                                     traffic_tol=args.traffic_tol,
                                     scheduler=scheduler)
                except Exception as e:  # a build failure is an audit failure
                    findings = [f"build failed: {type(e).__name__}: {e}"]
                status = "ok" if not findings else "FAIL"
                print(f"{name:16s} {method:8s} {scheduler:9s} "
                      f"{args.validate:5s} {status}")
                for f in findings:
                    print(f"    {f}")
                witnesses += _witness_dicts(findings)
                failures += bool(findings)
    if failures:
        _write_witnesses(args.witness_json, witnesses)
        print(f"\n{failures} audit(s) failed", file=sys.stderr)
        return 1
    print(f"\nall {len(problems) * len(methods) * len(schedulers)} audits "
          f"clean (validate={args.validate}, backend={args.backend}, "
          f"schedulers={','.join(schedulers)})")
    return 0


def bench_gate_main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis bench-gate",
        description="gate bench runs against committed BENCH_*.json "
                    "snapshots (matched by their 'schema' field)")
    ap.add_argument("--baseline-dir", default="benchmarks",
                    help="directory holding committed BENCH_*.json")
    ap.add_argument("--candidate", action="append", default=[],
                    metavar="RUN.json",
                    help="fresh bench output to gate (repeatable)")
    ap.add_argument("--tolerance", type=float, default=0.5,
                    help="allowed relative regression (default 0.5 = 50%%, "
                         "wide because CI machines are noisy)")
    ap.add_argument("--smoke", action="store_true",
                    help="gate every committed snapshot against itself")
    ap.add_argument("--witness-json", default=None, metavar="PATH",
                    help="dump machine-readable witnesses to PATH")
    args = ap.parse_args(argv)

    baselines: dict[str, tuple[str, dict]] = {}
    for path in sorted(glob.glob(os.path.join(args.baseline_dir,
                                              "BENCH_*.json"))):
        with open(path) as fh:
            doc = json.load(fh)
        schema = doc.get("schema", os.path.basename(path))
        baselines[schema] = (path, doc)
    if not baselines:
        print(f"no BENCH_*.json under {args.baseline_dir}", file=sys.stderr)
        return 1

    comparisons: list[tuple[str, dict, dict]] = []
    if args.smoke:
        for schema, (path, doc) in baselines.items():
            comparisons.append((f"{schema} (self)", doc, doc))
    for cpath in args.candidate:
        with open(cpath) as fh:
            cand = json.load(fh)
        schema = cand.get("schema")
        if schema not in baselines:
            known = ", ".join(sorted(baselines))
            print(f"{cpath}: no baseline with schema {schema!r} "
                  f"(known: {known})", file=sys.stderr)
            return 1
        bpath, base = baselines[schema]
        comparisons.append((f"{schema} ({cpath} vs {bpath})", base, cand))
    if not comparisons:
        ap.error("nothing to gate: pass --candidate and/or --smoke")

    failures = 0
    witnesses: list[dict] = []
    for label, base, cand in comparisons:
        found = bench_gate(base, cand, tolerance=args.tolerance,
                           where=f"bench-gate:{base.get('schema')}")
        status = "ok" if not found else "FAIL"
        print(f"{label:60s} {status}")
        for v in found:
            print(f"    {v}")
        witnesses += _witness_dicts(found)
        failures += bool(found)
    if failures:
        _write_witnesses(args.witness_json, witnesses)
        print(f"\n{failures} gate(s) failed", file=sys.stderr)
        return 1
    print(f"\nall {len(comparisons)} gate(s) passed "
          f"(tolerance={args.tolerance:g})")
    return 0


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    if argv and argv[0] == "bench-gate":
        return bench_gate_main(argv[1:])
    return audit_main(argv)


if __name__ == "__main__":
    sys.exit(main())
