"""Jaxpr dtype-propagation linter: prove a plan's precision contract.

ROADMAP item 2 (mixed-precision preconditioning: f32/bf16 operands inside
an f64-accumulated PCG) is only safe to attempt if the *current* dtype
flow is provable: every lowering path must move exactly the dtypes the
plan promised, with no silent float<->float promotion or demotion hiding
in a traced literal, and every dot/reduction accumulating in the pinned
accumulation dtype.  This linter walks the jaxpr of each lowering path
(apply / SpMV / full PCG / slab, single and batched, pallas kernel bodies
included) and checks every equation against a :class:`PrecisionContract`:

  * ``convert_element_type`` between two *strong* float dtypes is a
    silent promotion/demotion unless the contract allowlists that pair —
    converts from weak-typed avals (python literals like ``1.0``) are the
    legitimate jax literal-normalization idiom and pass;
  * ``dot_general`` outputs and ``preferred_element_type`` pins, plus
    float reductions, must land in the contract's accumulation dtype;
  * any other strong float aval must be one of the contract's dtypes
    (vector, accumulation, or table) — a stray f32 constant inside an
    f64 plan is a witness, not a warning.

Violations reuse the :class:`~repro.analysis.schedule.Violation` witness
carrier; ``detail`` names the offending eqn by its nested path
(``scan#3/convert_element_type#1``).  ``validate="deep"`` on
``build_plan`` / ``PlanCache`` runs :func:`check_plan_dtype_flow`
automatically; ``python -m repro.analysis --dtype-flow`` runs it from the
CLI.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .contracts import format_eqn_path, iter_eqns
from .schedule import MAX_VIOLATIONS, ScheduleError, Violation

#: reduction primitives whose output must land in the accumulation dtype
REDUCE_PRIMITIVES = ("reduce_sum", "reduce_prod", "cumsum", "cumprod")


@dataclasses.dataclass(frozen=True)
class PrecisionContract:
    """The dtype promise of one plan configuration.

    ``vector``   dtype of the PCG state vectors (x, r, p, z, b)
    ``accum``    dtype every dot/reduction must accumulate in
    ``tables``   dtype of the packed operands (trisolve tables, SELL/ELL
                 values)
    ``allowed_converts``  extra ``(src, dst)`` strong float->float
                 converts the contract permits (a future mixed-precision
                 plan allowlists its table down-cast here, making the
                 linter the gate that work lands behind)
    """
    name: str
    vector: str
    accum: str
    tables: str
    allowed_converts: tuple = ()

    @property
    def float_dtypes(self) -> frozenset:
        return frozenset((self.vector, self.accum, self.tables))


def contract_for_plan(plan) -> PrecisionContract:
    """The contract a plan's knobs promise.  Today every plan is uniform
    (tables and vectors share ``plan.dtype``, accumulation included); a
    mixed-precision plan will derive a split contract here."""
    d = str(np.dtype(jnp.dtype(plan.dtype)))
    return PrecisionContract(name=f"uniform-{d}", vector=d, accum=d,
                             tables=d)


def _is_float(dtype) -> bool:
    return jax.dtypes.issubdtype(dtype, jnp.floating)


def lint_dtype_flow(fn, *args, contract: PrecisionContract,
                    where: str = "dtype_flow",
                    descend_pallas: bool = True) -> list[Violation]:
    """Trace ``fn(*args)`` and check every eqn against ``contract``.
    Returns machine-readable witnesses (empty = proven clean)."""
    closed = jax.make_jaxpr(fn)(*args)
    out: list[Violation] = []
    allowed = contract.float_dtypes

    for path, eqn in iter_eqns(closed.jaxpr, descend_pallas=descend_pallas):
        if len(out) >= MAX_VIOLATIONS:
            break
        prim = eqn.primitive.name
        loc = format_eqn_path(path)

        if prim == "convert_element_type":
            src = eqn.invars[0].aval
            dst = eqn.outvars[0].aval
            if (_is_float(src.dtype) and _is_float(dst.dtype)
                    and src.dtype != dst.dtype
                    and not getattr(src, "weak_type", False)):
                pair = (str(src.dtype), str(dst.dtype))
                if pair not in tuple(map(tuple, contract.allowed_converts)):
                    shrink = (np.dtype(dst.dtype).itemsize
                              < np.dtype(src.dtype).itemsize)
                    kind = "silent-demotion" if shrink \
                        else "silent-promotion"
                    out.append(Violation(
                        kind=kind, where=where,
                        detail=f"eqn {loc}: strong {pair[0]} -> {pair[1]} "
                               f"convert outside contract "
                               f"{contract.name}"))
            continue

        if prim == "dot_general":
            pref = eqn.params.get("preferred_element_type")
            outd = eqn.outvars[0].aval.dtype
            if _is_float(outd) and str(outd) != contract.accum:
                out.append(Violation(
                    kind="accum-dtype", where=where,
                    detail=f"eqn {loc}: dot accumulates in {outd}, "
                           f"contract pins {contract.accum}"))
                continue
            if pref is not None and _is_float(np.dtype(pref)) \
                    and str(np.dtype(pref)) != contract.accum:
                out.append(Violation(
                    kind="accum-dtype", where=where,
                    detail=f"eqn {loc}: preferred_element_type="
                           f"{np.dtype(pref)}, contract pins "
                           f"{contract.accum}"))
                continue
        elif prim in REDUCE_PRIMITIVES:
            outd = eqn.outvars[0].aval.dtype
            if _is_float(outd) and str(outd) != contract.accum:
                out.append(Violation(
                    kind="accum-dtype", where=where,
                    detail=f"eqn {loc}: {prim} accumulates in {outd}, "
                           f"contract pins {contract.accum}"))
                continue

        # stray-dtype: any strong float aval outside the contract's set
        for v in (*eqn.invars, *eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is None or not hasattr(aval, "dtype"):
                continue
            if getattr(aval, "weak_type", False):
                continue
            if _is_float(aval.dtype) and str(aval.dtype) not in allowed:
                out.append(Violation(
                    kind="stray-dtype", where=where,
                    detail=f"eqn {loc}: {prim} touches {aval.dtype}, "
                           f"contract {contract.name} allows only "
                           f"{sorted(allowed)}"))
                break
    return out


# ---------------------------------------------------------------------------
# Plan-level composition: every lowering path the plan can dispatch.
# ---------------------------------------------------------------------------

def _pcg_args(plan, fn_input):
    """Operand plumbing of ``SolverPlan._run_pcg`` / ``run_slab``: which
    positional args the cached jitted fn takes for this plan config."""
    if plan.layout == "round_major":
        return (plan._precond.tables, plan._spmv_vals, plan._spmv_cols,
                fn_input)
    if plan.backend == "xla":
        return (plan._precond.fwd, plan._precond.bwd, plan._spmv_vals,
                plan._spmv_cols, fn_input)
    return (fn_input,)


def _plan_paths(plan) -> dict:
    """name -> (fn, args) for every lowering path this plan dispatches."""
    from repro.core.iccg import make_sharded_spmv
    from repro.core.plan import _make_spmv

    m = plan.slab_m
    q = jnp.zeros((m,), dtype=plan.dtype)
    qb = jnp.zeros((m, 2), dtype=plan.dtype)
    pre = plan._precond
    if plan.mesh is not None:
        def spmv(batched):
            return make_sharded_spmv(
                plan.spmv_format, plan._spmv_n, plan.mesh, plan.mesh_axis,
                plan._spmv_vals, plan._spmv_cols, batched,
                spmv_backend=plan.spmv_backend, interpret=plan.interpret)
    else:
        def spmv(batched):
            return _make_spmv(
                plan.spmv_format, plan._spmv_n, plan._spmv_vals,
                plan._spmv_cols, batched, spmv_backend=plan.spmv_backend,
                interpret=plan.interpret)

    paths = {
        "apply": (lambda x: pre(x), (q,)),
        "apply_batched": (lambda x: pre.apply_batched(x), (qb,)),
        "spmv": (spmv(False), (q,)),
        "spmv_batched": (spmv(True), (qb,)),
        "pcg": (plan._pcg_fn(False, 1e-8, 8, False),
                _pcg_args(plan, q)),
        "pcg_batched": (plan._pcg_fn(True, 1e-8, 8, False),
                        _pcg_args(plan, qb)),
        "slab": (plan._slab_fn(1e-8, 8, 4),
                 _pcg_args(plan, plan.new_slab_state(2))),
    }
    return paths


def check_plan_dtype_flow(plan, contract: PrecisionContract | None = None,
                          paths: tuple | None = None) -> list[Violation]:
    """Lint every lowering path of a built plan against its precision
    contract.  ``paths`` restricts to a subset of path names (default:
    all of apply/spmv/pcg/slab, single and batched)."""
    contract = contract or contract_for_plan(plan)
    out: list[Violation] = []
    for name, (fn, args) in _plan_paths(plan).items():
        if paths is not None and name not in paths:
            continue
        out += lint_dtype_flow(fn, *args, contract=contract,
                               where=f"dtype_flow/{name}")
        if len(out) >= MAX_VIOLATIONS:
            break
    return out


def assert_plan_dtype_flow(plan,
                           contract: PrecisionContract | None = None,
                           context: str = "") -> None:
    """``check_plan_dtype_flow`` that raises :class:`ScheduleError`."""
    violations = check_plan_dtype_flow(plan, contract)
    if violations:
        raise ScheduleError(violations, context=context)
