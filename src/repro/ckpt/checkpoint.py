"""Step-atomic, mesh-agnostic checkpointing (msgpack + zstd).

Fault-tolerance contract:
  * **atomic** — written to ``<dir>/tmp.<step>`` then renamed; a crash
    mid-write never corrupts the latest checkpoint;
  * **self-verifying** — every leaf carries a crc32; load fails loudly on
    bit rot;
  * **mesh-agnostic / elastic** — leaves are saved as full logical arrays
    (gathered host-side), so a checkpoint written on a 256-chip mesh
    restores onto 512 chips (or a different DP/TP split) by just applying
    the new shardings on load — this is the elastic-rescale path;
  * **resumable stream** — the data pipeline is stateless-indexed, so
    persisting ``step`` alone resumes the exact data order.

At real cluster scale leaves would stream per-shard to a parallel
filesystem; the single-file host-gather here keeps the same API surface.
"""
from __future__ import annotations

import os
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:                      # optional: zstd compression (extras = "ckpt")
    import zstandard
except ImportError:       # pragma: no cover - exercised on minimal installs
    zstandard = None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _compress(raw: bytes) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(raw)
    return zlib.compress(raw, level=6)


def _decompress(blob: bytes) -> bytes:
    if blob[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise ImportError(
                "checkpoint was written with zstd; `pip install zstandard` "
                "to read it")
        return zstandard.ZstdDecompressor().decompress(blob)
    return zlib.decompress(blob)


def _flatten(tree) -> tuple[list[tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = jax.tree_util.keystr(path)
        out.append((key, leaf))
    return out, treedef


def save_checkpoint(path: str, tree, step: int) -> str:
    os.makedirs(path, exist_ok=True)
    leaves, _ = _flatten(tree)
    payload = {"step": step, "leaves": {}}
    for key, leaf in leaves:
        arr = np.asarray(leaf)
        buf = arr.tobytes()
        payload["leaves"][key] = {
            "dtype": str(arr.dtype), "shape": list(arr.shape),
            "crc": zlib.crc32(buf), "data": buf,
        }
    raw = msgpack.packb(payload, use_bin_type=True)
    comp = _compress(raw)
    tmp = os.path.join(path, f"tmp.{step}")
    final = os.path.join(path, f"step_{step:08d}.ckpt")
    with open(tmp, "wb") as f:
        f.write(comp)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, final)
    _write_latest(path, final)
    return final


def _write_latest(path: str, final: str):
    tmp = os.path.join(path, "LATEST.tmp")
    with open(tmp, "w") as f:
        f.write(os.path.basename(final))
    os.rename(tmp, os.path.join(path, "LATEST"))


def latest_checkpoint(path: str) -> str | None:
    marker = os.path.join(path, "LATEST")
    if not os.path.exists(marker):
        return None
    with open(marker) as f:
        name = f.read().strip()
    full = os.path.join(path, name)
    return full if os.path.exists(full) else None


def load_checkpoint(file: str, like_tree, shardings=None) -> tuple[Any, int]:
    """Restore into the structure of ``like_tree`` (values ignored).  Pass
    ``shardings`` (same structure) to place leaves onto a (possibly
    different) mesh — the elastic-rescale path."""
    with open(file, "rb") as f:
        raw = _decompress(f.read())
    payload = msgpack.unpackb(raw, raw=False)
    leaves, treedef = _flatten(like_tree)
    shard_leaves = (None if shardings is None
                    else treedef.flatten_up_to(shardings))
    out = []
    for i, (key, like) in enumerate(leaves):
        rec = payload["leaves"].get(key)
        if rec is None:
            raise KeyError(f"checkpoint missing leaf {key}")
        buf = rec["data"]
        if zlib.crc32(buf) != rec["crc"]:
            raise IOError(f"crc mismatch on leaf {key} (corrupt checkpoint)")
        arr = np.frombuffer(buf, dtype=rec["dtype"]).reshape(rec["shape"])
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), payload["step"]
