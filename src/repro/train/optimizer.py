"""AdamW with cosine schedule, global-norm clipping and ZeRO-style sharding.

Optimizer state inherits the parameter sharding (params are already FSDP x
TP sharded over the mesh, so m/v are too — this *is* ZeRO: no device holds
a full optimizer state replica).  States are kept in f32 regardless of the
param dtype; ``mu_dtype=bf16`` is available as the memory-pressure escape
hatch used by the llama3-405b config (recorded in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    mu_dtype: Any = jnp.float32


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) \
        * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> AdamWState:
    zeros = lambda dt: jax.tree.map(
        lambda p: jnp.zeros(p.shape, dt), params)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=zeros(jnp.float32), v=zeros(jnp.float32))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state: AdamWState):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m.astype(cfg.mu_dtype), v.astype(cfg.mu_dtype)

    # flatten to avoid tuple-leaf ambiguity (params contain tuples of blocks)
    p_leaves, treedef = jax.tree_util.tree_flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = treedef.flatten_up_to(state.m)
    v_leaves = treedef.flatten_up_to(state.v)
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(p_leaves, g_leaves, m_leaves, v_leaves):
        np_, nm, nv = upd(p, g, m, v)
        new_p.append(np_); new_m.append(nm); new_v.append(nv)
    unflat = jax.tree_util.tree_unflatten
    return unflat(treedef, new_p), AdamWState(
        step=step, m=unflat(treedef, new_m), v=unflat(treedef, new_v)), \
        {"grad_norm": gnorm, "lr": lr}
