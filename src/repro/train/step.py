"""Training step: loss, grad, AdamW update — pjit-ready.

The step function is pure; distribution comes entirely from in/out shardings
applied at ``jax.jit`` time (see launch/dryrun.py, launch/train.py).  Under
the hybrid FSDP x TP layout, XLA inserts: all-gather of FSDP-sharded weights
(prefetchable, overlapped by the latency-hiding scheduler), TP-local matmuls
with reduce-scatter/all-reduce at block boundaries, and a gradient
reduce-scatter back to the FSDP shards — the standard ZeRO-1 schedule.

Gradient accumulation: ``microbatches > 1`` scans over micro-slices of the
global batch, accumulating f32 grads, which divides peak activation memory
without touching the math (needed for llama3-405b train_4k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import forward
from repro.models.config import ArchConfig
from .optimizer import AdamWConfig, AdamWState, adamw_update

AUX_LOSS_WEIGHT = 0.01


def make_positions(cfg: ArchConfig, batch: int, seq: int):
    if cfg.m_rope:
        return jnp.broadcast_to(jnp.arange(seq)[None, None], (3, batch, seq))
    return jnp.broadcast_to(jnp.arange(seq)[None], (batch, seq))


XENT_CHUNK = 1024


def chunked_xent(x, head, labels, chunk: int = XENT_CHUNK):
    """Cross entropy without materializing the full (B, S, V) f32 logits:
    scan over sequence chunks with a checkpointed body, so the backward
    recomputes each chunk's logits (one matmul) instead of saving them.
    Cuts several GB of live memory on 100k+-vocab archs (EXPERIMENTS §Perf).
    """
    b, s = labels.shape
    if s % chunk:
        chunk = s    # fall back to one chunk for odd sizes
    nc = s // chunk
    xs = jnp.moveaxis(x.reshape(b, nc, chunk, x.shape[-1]), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)

    @jax.checkpoint
    def body(acc, ys):
        xc, lc = ys
        logits = (xc @ head).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, lc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(nll), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls))
    return total / (b * s)


def loss_fn(params, cfg: ArchConfig, inputs, labels, remat: bool = True):
    b, s = labels.shape
    positions = make_positions(cfg, b, s)
    logits, _, aux = forward(params, cfg, inputs, positions, remat=remat,
                             return_hidden=True)
    # forward returned the final hidden states; apply the LM head in
    # sequence chunks fused with the loss
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    loss = chunked_xent(logits, head, labels)
    return loss + AUX_LOSS_WEIGHT * aux, {"loss": loss, "aux": aux}


def train_step(params, opt_state: AdamWState, batch, *, cfg: ArchConfig,
               opt_cfg: AdamWConfig, microbatches: int = 1,
               remat: bool = True):
    """batch: {"inputs": (B,S) int32 or (B,S,d), "labels": (B,S) int32}."""
    inputs, labels = batch["inputs"], batch["labels"]

    if microbatches == 1:
        grads, metrics = jax.grad(
            lambda p: loss_fn(p, cfg, inputs, labels, remat),
            has_aux=True)(params)
    else:
        b = labels.shape[0]
        mb = b // microbatches
        re_in = inputs.reshape(microbatches, mb, *inputs.shape[1:])
        re_lb = labels.reshape(microbatches, mb, *labels.shape[1:])

        def micro(carry, xs):
            g_acc, l_acc = carry
            mi, ml = xs
            g, m = jax.grad(lambda p: loss_fn(p, cfg, mi, ml, remat),
                            has_aux=True)(params)
            g_acc = jax.tree.map(
                lambda a, x: a + x.astype(jnp.float32), g_acc, g)
            return (g_acc, l_acc + m["loss"]), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g_sum, l_sum), _ = jax.lax.scan(micro, (g0, 0.0), (re_in, re_lb))
        grads = jax.tree.map(lambda g: g / microbatches, g_sum)
        metrics = {"loss": l_sum / microbatches,
                   "aux": jnp.zeros((), jnp.float32)}

    params, opt_state, opt_metrics = adamw_update(
        opt_cfg, params, grads, opt_state)
    metrics.update(opt_metrics)
    return params, opt_state, metrics
