"""Sharding-constraint annotations for model code.

``constrain(x, spec0, spec1, ...)`` is ``lax.with_sharding_constraint`` with
three conveniences that let the same model code run unmodified on any mesh:

  * when no mesh is active it is the identity;
  * the ``BATCH`` sentinel expands to whichever batch-like mesh axes
    ("pod", "data") exist, largest combination that divides the dimension;
  * any entry naming an axis that is absent from the mesh, or that does not
    divide the corresponding dimension, is dropped (replaced by ``None``)
    instead of erroring — e.g. the sequence-parallel ``"model"`` entry
    degrades gracefully at decode time when S == 1.
"""
from __future__ import annotations

import math

import jax
from jax.interpreters import pxla
from jax.sharding import NamedSharding, PartitionSpec as P

from .sharding import _axis_sizes, _batch_entry


class _BatchSentinel:
    """Marker for 'the batch axis of the mesh, whatever it is named'."""
    def __repr__(self):
        return "BATCH"


BATCH = _BatchSentinel()


def _current_mesh():
    """The ambient ``with mesh:`` context, or None outside of one."""
    mesh = pxla.thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def _resolve_entry(entry, dim: int, mesh):
    """One PartitionSpec entry -> validated entry (or None if indivisible)."""
    if entry is None:
        return None
    if isinstance(entry, _BatchSentinel):
        return _batch_entry(mesh, dim)
    sizes = _axis_sizes(mesh)
    axes = (entry,) if isinstance(entry, str) else tuple(entry)
    if not all(a in sizes for a in axes):
        return None
    if dim % math.prod(sizes[a] for a in axes) != 0:
        return None
    return entry


def constrain(x: jax.Array, *entries) -> jax.Array:
    if len(entries) != x.ndim:
        raise ValueError(f"constrain: {len(entries)} entries for rank-"
                         f"{x.ndim} array")
    mesh = _current_mesh()
    if mesh is None:
        return x
    spec = P(*[_resolve_entry(e, d, mesh) for e, d in zip(entries, x.shape)])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
