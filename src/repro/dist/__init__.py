"""Mesh-aware sharding rules and sharding-constraint helpers.

Two small modules with no model knowledge:

  * ``constraints`` — ``constrain(x, ...)`` annotations used inside model
    code; no-ops when no mesh is active, and silently drop any axis that
    would not divide evenly (so the same model code runs on 1..N devices).
  * ``sharding``    — the greedy parameter/batch/cache partition rules used
    by the launcher and the dry-run.
"""
from .constraints import BATCH, constrain
from .sharding import (batch_partition_spec, cache_partition_spec,
                       param_partition_spec, params_shardings)
