"""Greedy, divisibility-safe partition rules for params, batches and caches.

The contract (enforced by tests/test_infra.py) is:

  * a spec NEVER names mesh axes whose product does not divide the
    corresponding array dimension — this is what guarantees every
    architecture lowers on every mesh shape;
  * large matrices are both tensor-parallel ("model" axis) and FSDP
    ("data" axis) sharded: "model" goes to the largest divisible dim,
    "data" to the largest remaining divisible dim.

``mesh`` only needs ``.axis_names`` and ``.devices.shape`` (the dry-run
passes a lightweight stand-in, not a real ``jax.sharding.Mesh``).
"""
from __future__ import annotations

import math

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _place(shape, axis_size: int, taken: set[int]) -> int | None:
    """Largest dim (not yet taken) divisible by axis_size; None if none."""
    order = sorted(range(len(shape)), key=lambda d: -shape[d])
    for d in order:
        if d in taken or shape[d] <= 1:
            continue
        if shape[d] % axis_size == 0:
            return d
    return None


def param_partition_spec(path, leaf, mesh) -> P:
    """Greedy TP+FSDP rule for one parameter leaf.

    "model" shards the largest divisible dimension (tensor parallelism),
    "data" the largest remaining divisible dimension (FSDP).  Dims of size
    <= 1 and indivisible dims stay replicated.  ``path`` is accepted for
    rule refinements but the base rule is shape-only.
    """
    shape = leaf.shape
    if len(shape) == 0:
        return P()
    sizes = _axis_sizes(mesh)
    spec: list = [None] * len(shape)
    taken: set[int] = set()
    for axis in ("model", "data"):
        if axis not in sizes:
            continue
        d = _place(shape, sizes[axis], taken)
        if d is not None:
            spec[d] = axis
            taken.add(d)
    return P(*spec)


def params_shardings(params, mesh):
    """Tree of NamedShardings matching ``params`` (specs or real arrays)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_partition_spec(path, leaf, mesh)), params)


def _batch_entry(mesh, batch: int):
    """Batch-dim entry: largest ("pod","data") combination dividing batch."""
    sizes = _axis_sizes(mesh)
    axes = tuple(a for a in ("pod", "data") if a in sizes)
    while axes and batch % math.prod(sizes[a] for a in axes) != 0:
        axes = axes[1:]
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def batch_partition_spec(mesh, batch: int, ndim: int) -> P:
    """Shard the leading (batch) dim over the data-like axes; rest replicated."""
    return P(_batch_entry(mesh, batch), *([None] * (ndim - 1)))


def cache_partition_spec(mesh, leaf, batch: int) -> P:
    """Decode-cache rule: shard the batch dimension (caches are stacked over
    pattern repeats, so batch is the first dim of size ``batch``)."""
    spec: list = [None] * leaf.ndim
    for d, size in enumerate(leaf.shape):
        if size == batch:
            spec[d] = _batch_entry(mesh, batch)
            break
    return P(*spec)
